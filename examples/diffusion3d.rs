//! End-to-end driver (DESIGN.md §3 "end-to-end validation"): the §6.1
//! 3D diffusion solver `v^ℓ = M v^{ℓ−1}` on a ventricle-shell tetrahedral
//! mesh, run for several hundred real time steps with the UPCv3
//! communication strategy, logging the residual curve — and executing the
//! block compute through the **AOT-compiled Pallas kernel via PJRT** when
//! artifacts are present (falling back to the native kernel otherwise).
//!
//! ```bash
//! make artifacts && cargo run --release --example diffusion3d
//! ```

use upcsim::coordinator::{Backend, Problem, RunConfig, Runner};
use upcsim::mesh::TestProblem;
use upcsim::spmv::Variant;
use upcsim::util::fmt;

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::default_for(Problem::Tp(TestProblem::Tp1));
    cfg.scale_div = 64; // ~106k tets: hundreds of steps in seconds
    cfg.nodes = 2;
    cfg.threads_per_node = 16;
    cfg.variant = Variant::V3;
    cfg.iters = 1000; // accounted simulated iterations (paper's workload)
    cfg.exec_steps = 300; // actually executed time steps
    cfg.backend = if upcsim::runtime::find_artifacts_dir().is_some() {
        Backend::Pjrt
    } else {
        eprintln!("(artifacts missing — run `make artifacts`; using native kernel)");
        Backend::Native
    };

    println!(
        "# 3D diffusion, {} steps on TP1/{} ({} backend), UPCv3, 2x16 threads",
        cfg.exec_steps,
        cfg.scale_div,
        match cfg.backend {
            Backend::Pjrt => "PJRT/Pallas artifact",
            Backend::Native => "native",
        }
    );
    let exec_steps = cfg.exec_steps;
    let report = Runner::new(cfg).run()?;

    println!("n = {} rows, BLOCKSIZE = {}", fmt::int(report.n), report.block_size);
    println!(
        "executed {} steps in {} ({:.1} steps/s)",
        exec_steps,
        fmt::secs(report.exec_wall),
        exec_steps as f64 / report.exec_wall
    );
    println!("inter-thread payload per step: {}", fmt::bytes(report.step_bytes as f64));
    println!(
        "simulated cluster time (1000 iters): {}   model: {}   ratio {:.3}",
        fmt::secs(report.sim_total),
        fmt::secs(report.model_total),
        report.sim_total / report.model_total
    );

    // The residual curve: diffusion must decay monotonically (to rounding).
    println!("\nresidual ‖v_l − v_l−1‖∞ (sampled):");
    let k = report.residuals.len();
    for (step, r) in report
        .residuals
        .iter()
        .enumerate()
        .step_by((k / 12).max(1))
    {
        println!("  step {step:>4}: {r:.6e}");
    }
    let first = report.residuals[0];
    let last = *report.residuals.last().unwrap();
    println!("\nresidual decay: {first:.3e} → {last:.3e} ({:.1}x)", first / last);
    assert!(last < first, "diffusion failed to converge");
    assert!(report.final_max.is_finite());
    println!("checksum = {:.9e} (record in EXPERIMENTS.md)", report.checksum);
    Ok(())
}
