//! §8 demo: the 2D heat-equation solver with halo exchange, validated
//! against a sequential stencil, plus the Table-5-style model comparison
//! for the run's geometry.
//!
//! ```bash
//! cargo run --release --example heat2d_demo
//! ```

use upcsim::heat2d::{seq_reference_step, simulate_heat_step, Heat2dSolver};
use upcsim::machine::HwParams;
use upcsim::model::{predict_heat2d, HeatGrid};
use upcsim::pgas::Topology;
use upcsim::sim::SimParams;
use upcsim::util::{fmt, Rng};

fn main() -> anyhow::Result<()> {
    // A 512×512 field over a 4×4 thread grid (one simulated node).
    let (mg, ng) = (512usize, 512usize);
    let grid = HeatGrid::new(mg, ng, 4, 4);
    let topo = Topology::new(1, 16);
    let hw = HwParams::abel();

    // Initial condition: a hot disc in a cold plate.
    let mut rng = Rng::new(2024);
    let mut f0 = vec![0.0f64; mg * ng];
    for i in 0..mg {
        for k in 0..ng {
            let (di, dk) = (i as f64 - 256.0, k as f64 - 256.0);
            f0[i * ng + k] =
                if di * di + dk * dk < 80.0 * 80.0 { 100.0 } else { rng.f64() };
        }
    }

    // Run 50 steps on the per-thread solver and verify against the
    // sequential stencil.
    let mut solver = Heat2dSolver::new(grid, &f0);
    let mut reference = f0.clone();
    let steps = 50;
    for _ in 0..steps {
        solver.step();
        reference = seq_reference_step(mg, ng, &reference);
    }
    let got = solver.to_global();
    let max_err = got
        .iter()
        .zip(&reference)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("{steps} steps on {mg}x{ng}, 4x4 thread grid");
    println!("max |parallel − sequential| = {max_err:.3e}");
    assert!(max_err < 1e-10, "halo exchange broke the stencil");
    println!(
        "halo payload so far: {}",
        fmt::bytes(solver.inter_thread_bytes as f64)
    );

    // Table-5-style analytics for the paper's geometries.
    println!("\nTable-5-style prediction for this setup (per 1000 steps):");
    let params = SimParams::from_hw(&hw);
    let sim = simulate_heat_step(&grid, &topo, &hw, &params);
    let model = predict_heat2d(&grid, &topo, &hw);
    println!(
        "  T_halo: simulated {}  predicted {}",
        fmt::secs(sim.t_halo * 1000.0),
        fmt::secs(model.t_halo * 1000.0)
    );
    println!(
        "  T_comp: simulated {}  predicted {}",
        fmt::secs(sim.t_comp * 1000.0),
        fmt::secs(model.t_comp * 1000.0)
    );
    Ok(())
}
