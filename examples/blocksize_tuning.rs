//! BLOCKSIZE tuning (the paper's §6.4 point: "tuning BLOCKSIZE by the
//! programmer is a viable approach to performance optimization, and the
//! performance models are essential in this context").
//!
//! Sweeps BLOCKSIZE for all three transformed variants on a fixed mesh and
//! topology, reporting both the simulated time and the model prediction —
//! showing that the *model* alone would have picked the same winner.
//!
//! ```bash
//! cargo run --release --example blocksize_tuning
//! ```

use upcsim::comm::Analysis;
use upcsim::machine::HwParams;
use upcsim::matrix::Ellpack;
use upcsim::mesh::{TetGridSpec, TetMesh};
use upcsim::model::{self, SpmvInputs};
use upcsim::pgas::{Layout, Topology};
use upcsim::sim::{ClusterSim, DEFAULT_CACHE_WINDOW};
use upcsim::spmv::Variant;
use upcsim::util::fmt;

fn main() -> anyhow::Result<()> {
    let mesh = TetMesh::generate(&TetGridSpec::ventricle(200_000, 11));
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let topo = Topology::new(2, 16);
    let hw = HwParams::abel();
    let sim = ClusterSim::new(hw);
    println!("n = {}, 32 threads over 2 nodes, 1000 iterations\n", fmt::int(m.n));
    println!(
        "{:>9}  {:>22}  {:>22}  {:>22}",
        "BLOCKSIZE", "UPCv1 sim/model", "UPCv2 sim/model", "UPCv3 sim/model"
    );

    let mut best: Option<(usize, f64)> = None;
    let mut best_by_model: Option<(usize, f64)> = None;
    for bs in [256usize, 512, 1024, 2048, 4096, 8192, 16384] {
        if m.n / bs < 32 {
            // Fewer blocks than threads would idle threads entirely — not a
            // configuration the paper's schedule ever uses.
            continue;
        }
        let layout = Layout::new(m.n, bs, 32);
        let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, DEFAULT_CACHE_WINDOW);
        let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };
        let mut cells = Vec::new();
        for v in Variant::TRANSFORMED {
            let s = sim.spmv_iteration(v, &inp).total * 1000.0;
            let p = match v {
                Variant::V1 => model::predict_v1(&inp).total,
                Variant::V2 => model::predict_v2(&inp).total,
                Variant::V3 => model::predict_v3(&inp).total,
                Variant::Naive => unreachable!(),
            } * 1000.0;
            if v == Variant::V3 {
                if best.is_none_or(|(_, t)| s < t) {
                    best = Some((bs, s));
                }
                if best_by_model.is_none_or(|(_, t)| p < t) {
                    best_by_model = Some((bs, p));
                }
            }
            cells.push(format!("{:>9.2}/{:<9.2}", s, p));
        }
        println!("{bs:>9}  {}  {}  {}", cells[0], cells[1], cells[2]);
    }

    let (bs_sim, t_sim) = best.unwrap();
    let (bs_model, _) = best_by_model.unwrap();
    println!("\nbest UPCv3 BLOCKSIZE by simulation: {bs_sim} ({t_sim:.2} s / 1000 iters)");
    println!("best UPCv3 BLOCKSIZE by model:      {bs_model}");
    if bs_sim == bs_model {
        println!("→ the closed-form model alone picks the same configuration.");
    } else {
        println!("→ model and simulation disagree here; see EXPERIMENTS.md discussion.");
    }
    Ok(())
}
