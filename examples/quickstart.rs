//! Quickstart: build a small unstructured-mesh SpMV problem, run all four
//! UPC variants, and compare simulated-cluster times against the paper's
//! performance models.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use upcsim::comm::Analysis;
use upcsim::machine::HwParams;
use upcsim::matrix::Ellpack;
use upcsim::mesh::{TetGridSpec, TetMesh};
use upcsim::model::{self, SpmvInputs};
use upcsim::pgas::{Layout, Topology};
use upcsim::sim::{ClusterSim, DEFAULT_CACHE_WINDOW};
use upcsim::spmv::{run_variant, SpmvState, Variant};
use upcsim::util::fmt;

fn main() -> anyhow::Result<()> {
    // 1. A ventricle-shell tetrahedral mesh (~50k tets) and its diffusion
    //    operator in modified EllPack form (paper §3.1).
    let mesh = TetMesh::generate(&TetGridSpec::ventricle(50_000, 42));
    let m = Ellpack::diffusion_from_mesh(&mesh);
    println!("mesh: {} tetrahedra, r_nz = {}", fmt::int(m.n), m.r_nz);

    // 2. Distribute over 32 UPC threads on 2 simulated Abel nodes.
    let layout = Layout::new(m.n, 2048, 32);
    let topo = Topology::new(2, 16);
    let hw = HwParams::abel();
    let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, DEFAULT_CACHE_WINDOW);

    // 3. Numerics: all four variants must agree bitwise with Listing 1.
    let x0 = m.initial_vector(7);
    let mut oracle = vec![0.0; m.n];
    m.spmv_seq(&x0, &mut oracle);
    println!("\n{:<10} {:>14} {:>12} {:>12} {:>10}", "variant", "inter-thread", "simulated", "predicted", "vs oracle");
    let sim = ClusterSim::new(hw);
    let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };
    for variant in Variant::ALL {
        let mut state = SpmvState::new(&m, 2048, 32, &x0);
        let out = run_variant(variant, &mut state, Some(&analysis));
        let bitwise = out.y == oracle;
        let simulated = sim.spmv_iteration(variant, &inp).total;
        let predicted = match variant {
            Variant::Naive => model::predict_naive(&inp, &sim.naive).total,
            Variant::V1 => model::predict_v1(&inp).total,
            Variant::V2 => model::predict_v2(&inp).total,
            Variant::V3 => model::predict_v3(&inp).total,
        };
        println!(
            "{:<10} {:>14} {:>12} {:>12} {:>10}",
            variant.name(),
            fmt::bytes(out.inter_thread_bytes as f64),
            fmt::secs(simulated),
            fmt::secs(predicted),
            if bitwise { "bitwise ==" } else { "MISMATCH!" },
        );
        assert!(bitwise, "{} diverged from the sequential oracle", variant.name());
    }

    println!("\nNote the paper's headline: v3 moves the least data and is fastest");
    println!("across nodes; v1 is competitive only inside one node (Table 3).");
    Ok(())
}
