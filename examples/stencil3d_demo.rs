//! The third workload on the unified runtime: 3D 7-point-stencil diffusion
//! with compiled face exchange, validated against a sequential stencil and
//! run on both engines.
//!
//! ```bash
//! cargo run --release --example stencil3d_demo
//! ```

use upcsim::engine::Engine;
use upcsim::machine::HwParams;
use upcsim::model::predict_stencil3d;
use upcsim::pgas::Topology;
use upcsim::stencil3d::{seq_reference_step3d, Stencil3dGrid, Stencil3dSolver};
use upcsim::util::{fmt, Rng};

fn main() -> anyhow::Result<()> {
    // A 48³ box over a 1×2×2 thread grid.
    let (pg, mg, ng) = (48usize, 48usize, 48usize);
    let grid = Stencil3dGrid::new(pg, mg, ng, 1, 2, 2);

    // Initial condition: a hot ball in a cold box.
    let mut rng = Rng::new(2026);
    let mut f0 = vec![0.0f64; pg * mg * ng];
    for x in 0..pg {
        for y in 0..mg {
            for z in 0..ng {
                let (dx, dy, dz) = (x as f64 - 24.0, y as f64 - 24.0, z as f64 - 24.0);
                f0[(x * mg + y) * ng + z] =
                    if dx * dx + dy * dy + dz * dz < 10.0 * 10.0 { 100.0 } else { rng.f64() };
            }
        }
    }

    // Run on the persistent-pool engine, verify against the sequential
    // stencil.
    let mut solver = Stencil3dSolver::new(grid, &f0);
    let mut reference = f0.clone();
    let steps = 30;
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        solver.step_with(Engine::Parallel);
        reference = seq_reference_step3d(pg, mg, ng, &reference);
    }
    let wall = t0.elapsed().as_secs_f64();
    let max_err = solver
        .to_global()
        .iter()
        .zip(&reference)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("{steps} steps on {pg}x{mg}x{ng}, 1x2x2 thread grid, in {}", fmt::secs(wall));
    println!("max |parallel − sequential| = {max_err:.3e}");
    assert!(max_err < 1e-10, "face exchange broke the stencil");
    println!(
        "compiled plan: {} messages, {} doubles/step; halo payload so far: {}",
        solver.runtime().plan().num_messages(),
        solver.runtime().plan().total_values(),
        fmt::bytes(solver.inter_thread_bytes as f64)
    );

    // Model prediction for the run's geometry.
    let model = predict_stencil3d(&grid, &Topology::new(1, 4), &HwParams::abel());
    println!(
        "predicted per 1000 steps: T_halo {}  T_comp {}",
        fmt::secs(model.t_halo * 1000.0),
        fmt::secs(model.t_comp * 1000.0)
    );
    Ok(())
}
