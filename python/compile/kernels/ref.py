"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately written as the most literal transcription of the
paper's formulas; the Pallas kernels must match them to float tolerance
(pytest + hypothesis sweeps in ``python/tests/test_kernels.py``).
"""

import jax.numpy as jnp


def ellpack_spmv_ref(d, xd, a, xg):
    """``y[k] = d[k]·xd[k] + Σ_j a[k,j]·xg[k,j]`` (paper eq. (3) row form)."""
    return d * xd + jnp.sum(a * xg, axis=1)


def ellpack_spmv_full_ref(d, a, j, x):
    """Whole-matrix oracle including the gather (paper Listing 1):
    ``y[i] = D[i]·x[i] + Σ_j A[i,j]·x[J[i,j]]``.

    Used to check that gather-at-the-coordinator + dense kernel equals the
    original irregular kernel.
    """
    return d * x + jnp.sum(a * x[j], axis=1)


def heat_stencil_ref(phi):
    """Interior 5-point Jacobi update (paper Listing 8)."""
    return 0.25 * (
        phi[:-2, 1:-1] + phi[2:, 1:-1] + phi[1:-1, :-2] + phi[1:-1, 2:]
    )


def block_sum_sq_ref(x):
    return jnp.sum(x * x)[None]
