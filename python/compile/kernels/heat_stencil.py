"""5-point Jacobi stencil kernel for the §8 heat-equation solver.

The coordinator hands the kernel a halo-included ``(m, n)`` tile; the kernel
produces the updated ``(m-2, n-2)`` interior:

    out[i, k] = 0.25 * (phi[i-1,k] + phi[i+1,k] + phi[i,k-1] + phi[i,k+1])

For the TPU mapping the whole tile sits in VMEM (the AOT tile is
258×258 f32 ≈ 266 KiB) and the four shifted reads become cheap in-register
rolls; HBM↔VMEM movement happens once per tile, which is exactly the
paper's 3·(m−2)·(n−2)·sizeof traffic model (eq. (22)).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Interior tile edge the AOT artifact is compiled for.
DEFAULT_TILE = 256


def _stencil_kernel(phi_ref, out_ref):
    phi = phi_ref[...]
    out_ref[...] = 0.25 * (
        phi[:-2, 1:-1] + phi[2:, 1:-1] + phi[1:-1, :-2] + phi[1:-1, 2:]
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def heat_stencil(phi, interpret=True):
    """One Jacobi update of the interior of a halo-included tile.

    Args:
      phi: ``(m, n)`` tile including the one-cell halo ring.

    Returns:
      ``(m-2, n-2)`` updated interior.
    """
    m, n = phi.shape
    assert m > 2 and n > 2
    return pl.pallas_call(
        _stencil_kernel,
        out_shape=jax.ShapeDtypeStruct((m - 2, n - 2), phi.dtype),
        interpret=interpret,
    )(phi)
