"""The EllPack SpMV block kernel (paper Listing 1's inner loops, L1 hot-spot).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's kernel is
a CPU loop with an irregular gather ``x[J[k·r+j]]``. On a TPU-shaped target
the irregular gather belongs to the *coordinator* (it IS the paper's
communication), so the kernel receives a dense, pre-gathered ``(B, r_nz)``
tile ``xg`` and performs the regular part:

    y[k] = d[k] * xd[k] + sum_j a[k, j] * xg[k, j]

Tiling: rows ride the sublane dimension in ``row_tile`` chunks; the 16-wide
``r_nz`` axis rides the lane dimension and is reduced in-register. VMEM per
grid step = ``row_tile * (2*r_nz + 2) * 4`` bytes ≈ 69 KiB for
``row_tile=512, r_nz=16`` — far below the ~16 MiB VMEM budget, leaving room
for double buffering (see DESIGN.md §7 for the roofline estimate).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Row-tile size the AOT artifact is compiled for (manifest `meta.block`).
DEFAULT_BLOCK = 4096
#: Rows per Pallas grid step.
ROW_TILE = 512


def _spmv_kernel(d_ref, xd_ref, a_ref, xg_ref, y_ref):
    """One row tile: dense FMA + lane-axis reduction."""
    d = d_ref[...]
    xd = xd_ref[...]
    a = a_ref[...]
    xg = xg_ref[...]
    y_ref[...] = d * xd + jnp.sum(a * xg, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ellpack_spmv(d, xd, a, xg, interpret=True):
    """Block SpMV: ``y = d * xd + rowsum(a * xg)``.

    Args:
      d:  ``(B,)`` diagonal values of the block's rows.
      xd: ``(B,)`` ``x`` values at the block's own rows.
      a:  ``(B, r_nz)`` off-diagonal values.
      xg: ``(B, r_nz)`` pre-gathered ``x`` values at the column indices.

    Returns:
      ``(B,)`` result rows.
    """
    b, r_nz = a.shape
    assert d.shape == (b,) and xd.shape == (b,) and xg.shape == (b, r_nz)
    row_tile = min(ROW_TILE, b)
    assert b % row_tile == 0, f"block {b} must be a multiple of {row_tile}"
    grid = (b // row_tile,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile,), lambda i: (i,)),
            pl.BlockSpec((row_tile,), lambda i: (i,)),
            pl.BlockSpec((row_tile, r_nz), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, r_nz), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), d.dtype),
        interpret=interpret,
    )(d, xd, a, xg)
