"""Layer-1 Pallas kernels (build-time only; never imported at runtime).

Every kernel here runs with ``interpret=True``: the image's PJRT plugin is
CPU-only and real-TPU Pallas lowering emits Mosaic custom-calls the CPU
client cannot execute. Correctness is asserted against the pure-jnp oracle
in :mod:`compile.kernels.ref` by ``python/tests``.
"""

from .ellpack_spmv import ellpack_spmv, DEFAULT_BLOCK
from .heat_stencil import heat_stencil, DEFAULT_TILE
from .reduce import block_sum_sq

__all__ = [
    "ellpack_spmv",
    "heat_stencil",
    "block_sum_sq",
    "DEFAULT_BLOCK",
    "DEFAULT_TILE",
]
