"""Block sum-of-squares reduction (the driver's residual norm)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sum_sq_kernel(x_ref, out_ref):
    x = x_ref[...]
    out_ref[0] = jnp.sum(x * x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_sum_sq(x, interpret=True):
    """``sum(x**2)`` over one block, returned as a length-1 vector."""
    (b,) = x.shape
    return pl.pallas_call(
        _sum_sq_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=interpret,
    )(x)
