"""AOT lowering: JAX → HLO **text** → `artifacts/` + manifest.json.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import DEFAULT_BLOCK, DEFAULT_TILE

R_NZ = 16  # the paper's fixed off-diagonal count (§6.1)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def spec(shape):
    return {"shape": list(shape), "dtype": "f32"}


def artifact_defs():
    """Every artifact: (name, jitted fn, example args, input/output specs,
    meta)."""
    b, r, t = DEFAULT_BLOCK, R_NZ, DEFAULT_TILE
    return [
        dict(
            name="spmv_block",
            fn=model.spmv_block_step,
            args=(f32(b), f32(b), f32(b, r), f32(b, r)),
            inputs=[spec((b,)), spec((b,)), spec((b, r)), spec((b, r))],
            outputs=[spec((b,))],
            meta={"block": b, "r_nz": r},
        ),
        dict(
            name="spmv_block_norm",
            fn=model.spmv_block_step_with_norm,
            args=(f32(b), f32(b), f32(b, r), f32(b, r)),
            inputs=[spec((b,)), spec((b,)), spec((b, r)), spec((b, r))],
            outputs=[spec((b,)), spec((1,))],
            meta={"block": b, "r_nz": r},
        ),
        dict(
            name="heat2d_step",
            fn=model.heat2d_step,
            args=(f32(t + 2, t + 2),),
            inputs=[spec((t + 2, t + 2))],
            outputs=[spec((t, t))],
            meta={"tile": t},
        ),
        dict(
            name="diffusion_residual",
            fn=model.diffusion_residual,
            args=(f32(b), f32(b)),
            inputs=[spec((b,)), spec((b,))],
            outputs=[spec((1,))],
            meta={"block": b},
        ),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "artifacts": []}
    for d in artifact_defs():
        lowered = jax.jit(d["fn"]).lower(*d["args"])
        text = to_hlo_text(lowered)
        fname = f"{d['name']}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": d["name"],
                "file": fname,
                "inputs": d["inputs"],
                "outputs": d["outputs"],
                "meta": d["meta"],
            }
        )
        print(f"lowered {d['name']:24s} -> {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
