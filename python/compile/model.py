"""Layer-2 JAX model: the compute graphs that get AOT-lowered to HLO text.

Each function composes the L1 Pallas kernels into the block-level step the
Rust coordinator executes. Python never runs at serving time — these exist
only to be lowered by :mod:`compile.aot`.
"""

import jax
import jax.numpy as jnp

from .kernels import block_sum_sq, ellpack_spmv, heat_stencil


def spmv_block_step(d, xd, a, xg):
    """The per-block SpMV the coordinator calls on its hot path.

    Inputs are the pre-gathered tiles (see ``kernels/ellpack_spmv.py`` for
    why the gather lives in the coordinator). Returns a 1-tuple so the AOT
    output is uniform (``return_tuple=True`` lowering).
    """
    return (ellpack_spmv(d, xd, a, xg),)


def spmv_block_step_with_norm(d, xd, a, xg):
    """Block SpMV fused with the residual contribution ``Σ (y − xd)²`` —
    the driver variant that logs convergence without a second pass."""
    y = ellpack_spmv(d, xd, a, xg)
    r = y - xd
    return (y, block_sum_sq(r))


def heat2d_step(phi):
    """One Jacobi step on a halo-included tile (§8, Listing 8)."""
    return (heat_stencil(phi),)


def diffusion_residual(y, x):
    """Standalone residual: ``Σ (y − x)²`` over a block."""
    return (block_sum_sq(y - x),)
