"""L2 shape checks and AOT manifest consistency."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import DEFAULT_BLOCK, DEFAULT_TILE
from compile.kernels.ref import ellpack_spmv_ref


R = aot.R_NZ


def _rand_block(seed=0):
    rng = np.random.default_rng(seed)
    b = DEFAULT_BLOCK
    return (
        rng.standard_normal(b).astype(np.float32),
        rng.standard_normal(b).astype(np.float32),
        rng.standard_normal((b, R)).astype(np.float32),
        rng.standard_normal((b, R)).astype(np.float32),
    )


def test_spmv_block_step_shape_and_value():
    d, xd, a, xg = _rand_block(1)
    (y,) = model.spmv_block_step(d, xd, a, xg)
    assert y.shape == (DEFAULT_BLOCK,)
    np.testing.assert_allclose(y, ellpack_spmv_ref(d, xd, a, xg), rtol=1e-5, atol=1e-5)


def test_spmv_block_step_with_norm():
    d, xd, a, xg = _rand_block(2)
    y, nrm = model.spmv_block_step_with_norm(d, xd, a, xg)
    want = np.sum((np.asarray(y) - xd) ** 2)
    np.testing.assert_allclose(float(nrm[0]), want, rtol=1e-3)


def test_heat2d_step_shape():
    phi = np.random.default_rng(3).standard_normal(
        (DEFAULT_TILE + 2, DEFAULT_TILE + 2)
    ).astype(np.float32)
    (out,) = model.heat2d_step(phi)
    assert out.shape == (DEFAULT_TILE, DEFAULT_TILE)


def test_artifact_defs_are_consistent():
    """Each def's declared specs match its example args and actual outputs."""
    for d in aot.artifact_defs():
        assert len(d["args"]) == len(d["inputs"]), d["name"]
        for arg, spec in zip(d["args"], d["inputs"]):
            assert list(arg.shape) == spec["shape"], d["name"]
        outs = jax.eval_shape(d["fn"], *d["args"])
        assert len(outs) == len(d["outputs"]), d["name"]
        for out, spec in zip(outs, d["outputs"]):
            assert list(out.shape) == spec["shape"], d["name"]


def test_lowering_produces_hlo_text():
    """Every artifact lowers to parseable HLO text (ENTRY + tuple root)."""
    for d in aot.artifact_defs():
        lowered = jax.jit(d["fn"]).lower(*d["args"])
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, d["name"]
        assert "tuple" in text or "ROOT" in text, d["name"]


def test_aot_writes_manifest(tmp_path):
    """End-to-end aot.py run into a temp dir, then validate the manifest."""
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    names = {a["name"] for a in manifest["artifacts"]}
    assert {"spmv_block", "spmv_block_norm", "heat2d_step", "diffusion_residual"} <= names
    for a in manifest["artifacts"]:
        assert (out / a["file"]).exists(), a["name"]
        assert (out / a["file"]).read_text().lstrip().startswith("HloModule")
