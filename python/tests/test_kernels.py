"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; numpy reference data is deterministic
per example. This is the CORE correctness signal for the compile path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

jax.config.update("jax_enable_x64", True)  # the f64 sweep needs real f64

from compile.kernels import ellpack_spmv, heat_stencil, block_sum_sq
from compile.kernels.ref import (
    block_sum_sq_ref,
    ellpack_spmv_full_ref,
    ellpack_spmv_ref,
    heat_stencil_ref,
)

TOL = dict(rtol=1e-5, atol=1e-5)


def rand(rng, *shape, dtype=np.float32):
    return rng.uniform(-1.0, 1.0, size=shape).astype(dtype)


# ---------------------------------------------------------------- ellpack --


@settings(max_examples=30, deadline=None)
@given(
    b_tiles=st.integers(1, 4),
    r_nz=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ellpack_spmv_matches_ref(b_tiles, r_nz, seed):
    rng = np.random.default_rng(seed)
    b = 512 * b_tiles
    d, xd = rand(rng, b), rand(rng, b)
    a, xg = rand(rng, b, r_nz), rand(rng, b, r_nz)
    got = ellpack_spmv(d, xd, a, xg)
    want = ellpack_spmv_ref(d, xd, a, xg)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_small_block_single_tile(seed):
    # Blocks smaller than ROW_TILE take the row_tile=b path.
    rng = np.random.default_rng(seed)
    b, r = 128, 16
    d, xd, a, xg = rand(rng, b), rand(rng, b), rand(rng, b, r), rand(rng, b, r)
    np.testing.assert_allclose(
        ellpack_spmv(d, xd, a, xg), ellpack_spmv_ref(d, xd, a, xg), **TOL
    )


def test_gather_plus_kernel_equals_irregular_oracle():
    """Coordinator-side gather + dense kernel == the paper's Listing 1."""
    rng = np.random.default_rng(7)
    n, r = 2048, 16
    d = rand(rng, n)
    a = rand(rng, n, r)
    j = rng.integers(0, n, size=(n, r)).astype(np.int32)
    x = rand(rng, n)
    want = ellpack_spmv_full_ref(d, a, j, x)
    xg = x[j]  # what the Rust coordinator does before calling the kernel
    got = ellpack_spmv(d, x, a, xg)
    np.testing.assert_allclose(got, want, **TOL)


def test_ellpack_f64():
    rng = np.random.default_rng(3)
    b, r = 512, 16
    d = rand(rng, b, dtype=np.float64)
    xd = rand(rng, b, dtype=np.float64)
    a = rand(rng, b, r, dtype=np.float64)
    xg = rand(rng, b, r, dtype=np.float64)
    got = ellpack_spmv(d, xd, a, xg)
    want = ellpack_spmv_ref(d, xd, a, xg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_padded_rows_produce_zero():
    b, r = 512, 16
    d = np.zeros(b, np.float32)
    xd = np.ones(b, np.float32)
    a = np.zeros((b, r), np.float32)
    xg = np.ones((b, r), np.float32)
    np.testing.assert_array_equal(np.asarray(ellpack_spmv(d, xd, a, xg)), 0.0)


# ----------------------------------------------------------------- stencil --


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(3, 70),
    n=st.integers(3, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_heat_stencil_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    phi = rand(rng, m, n)
    got = heat_stencil(phi)
    want = heat_stencil_ref(phi)
    assert got.shape == (m - 2, n - 2)
    np.testing.assert_allclose(got, want, **TOL)


def test_heat_stencil_constant_field_fixed_point():
    phi = np.full((34, 34), 7.5, np.float32)
    out = np.asarray(heat_stencil(phi))
    np.testing.assert_allclose(out, 7.5, rtol=1e-6)


# ------------------------------------------------------------------ reduce --


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 4096), seed=st.integers(0, 2**31 - 1))
def test_block_sum_sq(b, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b)
    got = block_sum_sq(x)
    want = block_sum_sq_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_sum_sq_zero():
    assert float(block_sum_sq(np.zeros(16, np.float32))[0]) == 0.0
