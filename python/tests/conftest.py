"""Make `compile.*` importable whether pytest runs from repo root or
`python/` (the Makefile uses the latter, the CI one-liner the former)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
