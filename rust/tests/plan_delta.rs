//! Property suite for the versioned plan lifecycle:
//! `apply_delta(plan, diff(plan, plan'))` must be fingerprint-identical to
//! compiling `plan'` from scratch, across random gather and strided
//! mutations (content rerolls, pair removals, pair additions), and a
//! JSON-shipped delta sequence must keep two replicas on the same
//! fingerprint chain.

use upcsim::comm::{
    chain_fingerprint, CommPlan, ExchangePlan, PlanDelta, StridedBlock, StridedPlan,
};
use upcsim::pgas::Layout;
use upcsim::util::Rng;

const THREADS: usize = 6;
const BS: usize = 8;

/// Compile a condensed gather plan from a pair-mask matrix: bit `b` of
/// `mask[r][s]` means receiver `r` needs global index `s·BS + b` from `s`.
fn gather_from(mask: &[Vec<u16>]) -> ExchangePlan {
    let layout = Layout::new(THREADS * BS, BS, THREADS);
    let mut recv: Vec<Vec<(u32, u32)>> = Vec::with_capacity(THREADS);
    for r in 0..THREADS {
        let mut list = Vec::new();
        for s in 0..THREADS {
            if s == r {
                continue;
            }
            for b in 0..BS {
                if mask[r][s] >> b & 1 == 1 {
                    list.push((s as u32, (s * BS + b) as u32));
                }
            }
        }
        recv.push(list);
    }
    CommPlan::from_recv_needs(&layout, &recv).into()
}

/// Compile a canonical-order strided plan from a column-count matrix:
/// `cols[r][s] > 0` means one `cols`-wide row copy from `s` to `r`.
fn strided_from(cols: &[Vec<usize>]) -> ExchangePlan {
    let mut copies: Vec<(usize, usize, StridedBlock, StridedBlock)> = Vec::new();
    for r in 0..THREADS {
        for s in 0..THREADS {
            if s == r || cols[r][s] == 0 {
                continue;
            }
            let c = cols[r][s];
            copies.push((s, r, StridedBlock::row(s * BS, c), StridedBlock::row(64 + r * BS, c)));
        }
    }
    ExchangePlan::Strided(StridedPlan::from_msgs(THREADS, &copies))
}

/// Mutate `k` random off-diagonal pairs of a decision matrix. `reroll`
/// draws the new cell value; forcing one mutation to zero and one to a
/// fresh nonzero value exercises removals and additions every trial.
fn mutate(m: &mut [Vec<usize>], rng: &mut Rng, k: usize, hi: usize) {
    for i in 0..k {
        let r = rng.usize_in(0, THREADS);
        let mut s = rng.usize_in(0, THREADS);
        if s == r {
            s = (s + 1) % THREADS;
        }
        m[r][s] = match i {
            0 => 0,                   // pair removal
            1 => rng.usize_in(1, hi), // pair addition / content change
            _ => rng.usize_in(0, hi), // anything
        };
    }
}

fn random_matrix(rng: &mut Rng, hi: usize) -> Vec<Vec<usize>> {
    (0..THREADS).map(|_| (0..THREADS).map(|_| rng.usize_in(0, hi)).collect()).collect()
}

fn to_mask(m: &[Vec<usize>]) -> Vec<Vec<u16>> {
    m.iter().map(|row| row.iter().map(|&v| v as u16).collect()).collect()
}

#[test]
fn random_gather_mutations_patch_to_the_scratch_fingerprint() {
    let mut rng = Rng::new(0x5eed_0001);
    for trial in 0..40 {
        let mut m = random_matrix(&mut rng, 1 << BS);
        let old = gather_from(&to_mask(&m));
        mutate(&mut m, &mut rng, rng.usize_in(2, 7), 1 << BS);
        let new = gather_from(&to_mask(&m));
        let delta = PlanDelta::diff(&old, &new).unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        assert_eq!(delta.base_fingerprint(), old.fingerprint(), "trial {trial}");
        let patched = old.apply_delta(&delta).unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        assert_eq!(patched.fingerprint(), new.fingerprint(), "trial {trial}: patched != scratch");
        patched.validate(&|_| usize::MAX).unwrap_or_else(|e| panic!("trial {trial}: {e}"));
    }
}

#[test]
fn random_strided_mutations_patch_to_the_scratch_fingerprint() {
    let mut rng = Rng::new(0x5eed_0002);
    for trial in 0..40 {
        let mut m = random_matrix(&mut rng, 4);
        let old = strided_from(&m);
        mutate(&mut m, &mut rng, rng.usize_in(2, 7), 4);
        let new = strided_from(&m);
        let delta = PlanDelta::diff(&old, &new).unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        assert_eq!(delta.form_name(), "strided", "trial {trial}");
        let patched = old.apply_delta(&delta).unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        assert_eq!(patched.fingerprint(), new.fingerprint(), "trial {trial}: patched != scratch");
    }
}

/// Two replicas advance through the same random generation history — one
/// patching plans it diffed locally, one applying the JSON wire form of
/// each delta — and must agree on every plan fingerprint and on the
/// generation chain value at every step.
#[test]
fn shipped_delta_sequence_keeps_replicas_on_one_chain() {
    let mut rng = Rng::new(0x5eed_0003);
    let mut m = random_matrix(&mut rng, 1 << BS);
    let mut local = gather_from(&to_mask(&m));
    let mut remote = local.clone();
    let mut chain_local = local.fingerprint();
    let mut chain_remote = chain_local;
    for gen in 1..=12 {
        mutate(&mut m, &mut rng, rng.usize_in(1, 5), 1 << BS);
        let next = gather_from(&to_mask(&m));
        let delta = PlanDelta::diff(&local, &next).unwrap();
        let wire = delta.to_json().compact();
        let shipped = PlanDelta::from_json(&upcsim::util::json::parse(&wire).unwrap()).unwrap();
        remote = remote.apply_delta(&shipped).unwrap_or_else(|e| panic!("gen {gen}: {e}"));
        chain_local = chain_fingerprint(chain_local, &delta);
        chain_remote = chain_fingerprint(chain_remote, &shipped);
        local = next;
        assert_eq!(remote.fingerprint(), local.fingerprint(), "gen {gen}: replicas diverged");
        assert_eq!(chain_local, chain_remote, "gen {gen}: chains diverged");
    }
}
