//! Edge-layout equivalence for the grid workloads on the unified exchange
//! runtime: the sequential oracle and the persistent-pool parallel engine
//! must agree **bitwise** — fields *and* `inter_thread_bytes` — on
//! non-square grids, degenerate 1×N / N×1 thread layouts, and
//! minimum-size subdomains, over many steps.

use upcsim::engine::Engine;
use upcsim::heat2d::{seq_reference_step, Heat2dSolver};
use upcsim::model::HeatGrid;
use upcsim::stencil3d::{seq_reference_step3d, Stencil3dGrid, Stencil3dSolver};
use upcsim::util::Rng;

fn random_field(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.f64_in(0.0, 100.0)).collect()
}

/// Run both engines side by side for `steps` steps, asserting bitwise
/// equality of the gathered fields and the traffic counters every step.
fn check_heat2d(mg: usize, ng: usize, mp: usize, np: usize, steps: usize, seed: u64) {
    let grid = HeatGrid::new(mg, ng, mp, np);
    let f0 = random_field(mg * ng, seed);
    let mut seq = Heat2dSolver::new(grid, &f0);
    let mut par = Heat2dSolver::new(grid, &f0);
    for step in 0..steps {
        seq.step_with(Engine::Sequential);
        par.step_with(Engine::Parallel);
        let (gs, gp) = (seq.to_global(), par.to_global());
        assert!(
            gs.iter().zip(&gp).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{mg}x{ng}/{mp}x{np}: fields diverge at step {step}"
        );
        assert_eq!(
            seq.inter_thread_bytes, par.inter_thread_bytes,
            "{mg}x{ng}/{mp}x{np}: byte counters diverge at step {step}"
        );
    }
}

#[test]
fn heat2d_non_square_grids() {
    check_heat2d(24, 60, 3, 4, 30, 1);
    check_heat2d(60, 24, 4, 3, 30, 2);
    check_heat2d(18, 80, 2, 8, 20, 3);
}

#[test]
fn heat2d_degenerate_thread_layouts() {
    // 1×N: only horizontal (strided-column) halos.
    check_heat2d(16, 60, 1, 6, 25, 4);
    // N×1: only vertical (contiguous-row) halos.
    check_heat2d(60, 16, 6, 1, 25, 5);
    // Single thread: no halos at all.
    check_heat2d(16, 16, 1, 1, 10, 6);
}

#[test]
fn heat2d_minimum_subdomains() {
    // 1-cell interiors: every interior cell is adjacent to every halo.
    check_heat2d(4, 4, 4, 4, 20, 7);
    check_heat2d(1, 8, 1, 8, 20, 8);
    check_heat2d(3, 6, 3, 2, 20, 9);
}

#[test]
fn heat2d_long_run_stays_on_reference() {
    // 50 steps against the global-field reference (tolerance), while both
    // engines stay bitwise-equal (exact).
    let (mg, ng) = (30, 42);
    let grid = HeatGrid::new(mg, ng, 3, 2);
    let f0 = random_field(mg * ng, 10);
    let mut par = Heat2dSolver::new(grid, &f0);
    let mut reference = f0;
    for step in 0..50 {
        par.step_with(Engine::Parallel);
        reference = seq_reference_step(mg, ng, &reference);
        let got = par.to_global();
        for (idx, (a, b)) in got.iter().zip(&reference).enumerate() {
            assert!((a - b).abs() < 1e-11, "step {step} idx {idx}: {a} vs {b}");
        }
    }
}

fn check_stencil3d(
    dims: (usize, usize, usize),
    procs: (usize, usize, usize),
    steps: usize,
    seed: u64,
) {
    let grid = Stencil3dGrid::new(dims.0, dims.1, dims.2, procs.0, procs.1, procs.2);
    let f0 = random_field(dims.0 * dims.1 * dims.2, seed);
    let mut seq = Stencil3dSolver::new(grid, &f0);
    let mut par = Stencil3dSolver::new(grid, &f0);
    for step in 0..steps {
        seq.step_with(Engine::Sequential);
        par.step_with(Engine::Parallel);
        let (gs, gp) = (seq.to_global(), par.to_global());
        assert!(
            gs.iter().zip(&gp).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{dims:?}/{procs:?}: fields diverge at step {step}"
        );
        assert_eq!(
            seq.inter_thread_bytes, par.inter_thread_bytes,
            "{dims:?}/{procs:?}: byte counters diverge at step {step}"
        );
    }
}

#[test]
fn stencil3d_engine_equivalence_layouts() {
    check_stencil3d((8, 12, 16), (2, 3, 4), 10, 11);
    // Degenerate splits along a single axis.
    check_stencil3d((4, 4, 16), (1, 1, 8), 12, 12);
    check_stencil3d((16, 4, 4), (8, 1, 1), 12, 13);
    // Minimum 1-cell interiors.
    check_stencil3d((3, 3, 3), (3, 3, 3), 10, 14);
}

#[test]
fn stencil3d_tracks_reference() {
    let (pg, mg, ng) = (10, 8, 12);
    let grid = Stencil3dGrid::new(pg, mg, ng, 2, 2, 3);
    let f0 = random_field(pg * mg * ng, 15);
    let mut par = Stencil3dSolver::new(grid, &f0);
    let mut reference = f0;
    for step in 0..25 {
        par.step_with(Engine::Parallel);
        reference = seq_reference_step3d(pg, mg, ng, &reference);
        let got = par.to_global();
        for (idx, (a, b)) in got.iter().zip(&reference).enumerate() {
            assert!((a - b).abs() < 1e-11, "step {step} idx {idx}: {a} vs {b}");
        }
    }
}

#[test]
fn traffic_counters_match_geometry() {
    // heat2d: one message per directed neighbour pair, sized by the shared
    // edge; stencil3d: sized by the shared face. Counters are linear in the
    // step count.
    let grid = HeatGrid::new(24, 60, 3, 4);
    let f0 = random_field(24 * 60, 16);
    let mut solver = Heat2dSolver::new(grid, &f0);
    let per_step: u64 = (0..grid.threads())
        .flat_map(|t| grid.neighbours(t))
        .map(|(_, len, _)| (len * 8) as u64)
        .sum();
    for k in 1..=4u64 {
        solver.step_with(Engine::Parallel);
        assert_eq!(solver.inter_thread_bytes, k * per_step);
    }

    let grid3 = Stencil3dGrid::new(8, 12, 16, 2, 3, 4);
    let f0 = random_field(8 * 12 * 16, 17);
    let mut solver3 = Stencil3dSolver::new(grid3, &f0);
    let per_step3: u64 = (0..grid3.threads())
        .flat_map(|t| grid3.neighbours(t))
        .map(|(_, len, _)| (len * 8) as u64)
        .sum();
    for k in 1..=4u64 {
        solver3.step_with(Engine::Parallel);
        assert_eq!(solver3.inter_thread_bytes, k * per_step3);
    }
}
