//! mdlite acceptance matrix: the incremental plan lifecycle must be
//! bitwise identical to the full-recompile oracle on both engines and on
//! the loopback socket world, for rebuild periods K ∈ {1, 16, 64}.
//! Steps > 64 so even the K = 64 column recompiles beyond generation 0.

use std::time::Duration;
use upcsim::engine::Engine;
use upcsim::mdlite::{run, run_socket, Lifecycle, MdConfig};

fn config(rebuild_every: usize) -> MdConfig {
    MdConfig {
        cells_x: 24,
        cells_y: 24,
        threads: 4,
        particles: 96,
        steps: 80,
        rebuild_every,
        seed: 0x4d44,
    }
}

#[test]
fn incremental_matches_oracle_on_every_arm_and_period() {
    for k in [1usize, 16, 64] {
        let cfg = config(k);
        let oracle = run(&cfg, Engine::Sequential, Lifecycle::FullRecompile).unwrap();
        assert!(oracle.generations >= 2, "K = {k}: oracle never rebuilt");
        for engine in [Engine::Sequential, Engine::Parallel] {
            let incr = run(&cfg, engine, Lifecycle::Incremental).unwrap();
            assert_eq!(
                incr.checksum(),
                oracle.checksum(),
                "K = {k}, {} engine: incremental diverged from the oracle",
                engine.name()
            );
            assert_eq!(incr.generations, oracle.generations, "K = {k}: generation count");
            assert_eq!(incr.plan_fp, oracle.plan_fp, "K = {k}: final plan fingerprint");
        }
        let sock = run_socket(&cfg, Lifecycle::Incremental, Some(Duration::from_secs(60))).unwrap();
        assert_eq!(
            sock.checksum(),
            oracle.checksum(),
            "K = {k}, socket world: incremental diverged from the oracle"
        );
        assert_eq!(sock.generations, oracle.generations, "K = {k}: socket generation count");
        assert_eq!(sock.plan_fp, oracle.plan_fp, "K = {k}: socket final plan fingerprint");
    }
}

#[test]
fn socket_full_recompile_also_matches() {
    // The socket world's full-recompile arm pins the delta shipping as an
    // optimization, not a semantic change: both lifecycles land on the
    // same field.
    let cfg = config(16);
    let inproc = run(&cfg, Engine::Sequential, Lifecycle::FullRecompile).unwrap();
    let sock = run_socket(&cfg, Lifecycle::FullRecompile, Some(Duration::from_secs(60))).unwrap();
    assert_eq!(sock.checksum(), inproc.checksum());
    assert_eq!(sock.plan_fp, inproc.plan_fp);
}

#[test]
fn shorter_rebuild_period_never_lowers_generation_count() {
    let gens: Vec<u64> = [1usize, 16, 64]
        .iter()
        .map(|&k| run(&config(k), Engine::Sequential, Lifecycle::Incremental).unwrap().generations)
        .collect();
    assert!(gens[0] >= gens[1] && gens[1] >= gens[2], "generations not monotone: {gens:?}");
    assert!(gens[2] >= 2, "K = 64 must rebuild at least once beyond generation 0");
}
