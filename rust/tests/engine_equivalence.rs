//! Integration: the parallel execution engine is bitwise equivalent to the
//! sequential oracle — `y`, inter-thread byte counts, and transfer counts —
//! for every variant across a grid of (n, r_nz, threads, blocksize)
//! shapes, on single time steps and through multi-step time loops.

use upcsim::comm::Analysis;
use upcsim::engine::{Engine, SpmvEngine};
use upcsim::matrix::Ellpack;
use upcsim::pgas::{Layout, Topology};
use upcsim::spmv::{run_variant, SpmvState, Variant};

fn check_combo(m: &Ellpack, bs: usize, nodes: usize, tpn: usize, pool: &mut SpmvEngine, seed: u64) {
    let threads = nodes * tpn;
    let layout = Layout::new(m.n, bs, threads);
    let topo = Topology::new(nodes, tpn);
    let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, usize::MAX);
    analysis.validate().unwrap();
    let x0 = m.initial_vector(seed);
    for v in Variant::ALL {
        let mut seq = SpmvState::new(m, bs, threads, &x0);
        let want = run_variant(v, &mut seq, Some(&analysis));
        let mut par = SpmvState::new(m, bs, threads, &x0);
        let got = pool.run(v, &mut par, Some(&analysis));
        let shape = format!("{} n={} bs={bs} threads={threads}", v.name(), m.n);
        assert_eq!(got.y, want.y, "{shape}: y diverges");
        assert_eq!(
            got.inter_thread_bytes, want.inter_thread_bytes,
            "{shape}: byte counts diverge"
        );
        assert_eq!(got.transfers, want.transfers, "{shape}: transfer counts diverge");
        assert_eq!(par.y_global(), seq.y_global(), "{shape}: shared y diverges");
    }
}

#[test]
fn engines_agree_across_shapes() {
    // One pool reused throughout: its persistent workspaces must survive
    // shape changes between calls.
    let mut pool = SpmvEngine::new(Engine::Parallel);
    for &(n, rnz, bs, nodes, tpn, seed) in &[
        (64usize, 2usize, 4usize, 2usize, 2usize, 1u64),
        (301, 5, 16, 1, 8, 2),
        (1000, 4, 64, 2, 4, 3),
        (50, 1, 1, 3, 1, 4),
        // r_nz = 16 exercises the unrolled kernel specialization.
        (513, 16, 32, 1, 4, 5),
        // More threads than blocks for some threads (idle workers).
        (97, 3, 8, 1, 12, 6),
    ] {
        let m = Ellpack::random(n, rnz, seed);
        check_combo(&m, bs, nodes, tpn, &mut pool, seed);
    }
}

#[test]
fn engines_agree_on_mesh_problem() {
    let mesh = upcsim::mesh::tiny_mesh();
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let mut pool = SpmvEngine::new(Engine::Parallel);
    for &(bs, nodes, tpn) in &[(128usize, 2usize, 4usize), (64, 1, 16), (256, 4, 2)] {
        check_combo(&m, bs, nodes, tpn, &mut pool, 7);
    }
}

#[test]
fn time_loop_agrees_bitwise() {
    let mesh = upcsim::mesh::tiny_mesh();
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let layout = Layout::new(m.n, 128, 8);
    let analysis = Analysis::build(&m.j, m.r_nz, layout, Topology::new(2, 4), usize::MAX);
    let x0 = m.initial_vector(42);
    for v in Variant::ALL {
        let mut seq_state = SpmvState::new(&m, 128, 8, &x0);
        let mut par_state = SpmvState::new(&m, 128, 8, &x0);
        let mut pool = SpmvEngine::new(Engine::Parallel);
        for step in 0..5 {
            run_variant(v, &mut seq_state, Some(&analysis));
            seq_state.swap_xy();
            pool.run(v, &mut par_state, Some(&analysis));
            par_state.swap_xy();
            assert_eq!(
                seq_state.x_global(),
                par_state.x_global(),
                "{} diverges at step {step}",
                v.name()
            );
        }
    }
}

#[test]
fn prop_engines_agree_on_random_problems() {
    let mut pool = SpmvEngine::new(Engine::Parallel);
    upcsim::testing::check_prop(
        "engine-equivalence",
        12,
        |r| {
            let n = r.usize_in(10, 400);
            let rnz = r.usize_in(1, 6);
            let bs = r.usize_in(1, 60);
            let tpn = r.usize_in(1, 4);
            let nodes = r.usize_in(1, 3);
            (Ellpack::random(n, rnz, r.next_u64()), bs, nodes, tpn)
        },
        |(m, bs, nodes, tpn)| {
            let threads = nodes * tpn;
            let layout = Layout::new(m.n, *bs, threads);
            let analysis =
                Analysis::build(&m.j, m.r_nz, layout, Topology::new(*nodes, *tpn), usize::MAX);
            let x0 = m.initial_vector(1);
            for v in Variant::ALL {
                let mut seq = SpmvState::new(m, *bs, threads, &x0);
                let want = run_variant(v, &mut seq, Some(&analysis));
                let mut par = SpmvState::new(m, *bs, threads, &x0);
                let got = pool.run(v, &mut par, Some(&analysis));
                if got.y != want.y {
                    return Err(format!("{}: y diverges", v.name()));
                }
                if got.inter_thread_bytes != want.inter_thread_bytes
                    || got.transfers != want.transfers
                {
                    return Err(format!("{}: counters diverge", v.name()));
                }
            }
            Ok(())
        },
    );
}
