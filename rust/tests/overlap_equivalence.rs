//! Split-phase overlap equivalence: the overlapped step protocol
//! (`begin_exchange` → interior compute → `finish_exchange` → boundary
//! compute) must be **bitwise identical** — fields/vectors *and* traffic
//! counters — to the synchronous protocol and the sequential oracle, on all
//! three workloads (heat-2D, 3D stencil, SpMV V3), across edge layouts.
//! Plus the decomposition property: interior ∪ boundary covers every owned
//! cell exactly once for arbitrary subdomain shapes.

use upcsim::comm::{Analysis, ComputeSplit};
use upcsim::engine::{Engine, SpmvEngine};
use upcsim::heat2d::Heat2dSolver;
use upcsim::matrix::Ellpack;
use upcsim::model::HeatGrid;
use upcsim::pgas::{Layout, Topology};
use upcsim::spmv::{run_variant, SpmvState, Variant};
use upcsim::stencil3d::{Stencil3dGrid, Stencil3dSolver};
use upcsim::testing::check_prop;
use upcsim::util::Rng;

fn random_field(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.f64_in(0.0, 100.0)).collect()
}

/// Property: for arbitrary 2D subdomain shapes (including 1-cell-thick and
/// single-cell owned regions), the split covers the owned region exactly
/// once.
#[test]
fn prop_split2d_covers_owned_exactly_once() {
    check_prop(
        "compute-split-2d",
        96,
        |r| (r.usize_in(3, 40), r.usize_in(3, 40)),
        |&(m, n)| {
            let split = ComputeSplit::grid2d(m, n);
            split.validate(&ComputeSplit::owned2d(m, n), m * n)?;
            let covered = split.interior_cells() + split.boundary_cells();
            if covered != (m - 2) * (n - 2) {
                return Err(format!("covered {covered} of {} cells", (m - 2) * (n - 2)));
            }
            Ok(())
        },
    );
}

/// Property: same for arbitrary 3D box shapes.
#[test]
fn prop_split3d_covers_owned_exactly_once() {
    check_prop(
        "compute-split-3d",
        64,
        |r| (r.usize_in(3, 14), r.usize_in(3, 14), r.usize_in(3, 14)),
        |&(p, m, n)| {
            let split = ComputeSplit::grid3d(p, m, n);
            split.validate(&ComputeSplit::owned3d(p, m, n), p * m * n)?;
            let covered = split.interior_cells() + split.boundary_cells();
            if covered != (p - 2) * (m - 2) * (n - 2) {
                return Err(format!("covered {covered} cells"));
            }
            Ok(())
        },
    );
}

/// Run three heat-2D solvers in lockstep — synchronous sequential oracle,
/// overlapped sequential, overlapped parallel — asserting bitwise equality
/// every step.
fn check_heat2d(mg: usize, ng: usize, mp: usize, np: usize, steps: usize, seed: u64) {
    let grid = HeatGrid::new(mg, ng, mp, np);
    let f0 = random_field(mg * ng, seed);
    let mut sync = Heat2dSolver::new(grid, &f0);
    let mut ovl_seq = Heat2dSolver::new(grid, &f0);
    let mut ovl_par = Heat2dSolver::new(grid, &f0);
    for step in 0..steps {
        sync.step_with(Engine::Sequential);
        ovl_seq.step_overlapped_with(Engine::Sequential);
        ovl_par.step_overlapped_with(Engine::Parallel);
        let want = sync.to_global();
        for (label, got) in [("seq", ovl_seq.to_global()), ("par", ovl_par.to_global())] {
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{mg}x{ng}/{mp}x{np}: overlapped {label} diverges at step {step}"
            );
        }
        assert_eq!(sync.inter_thread_bytes, ovl_seq.inter_thread_bytes);
        assert_eq!(sync.inter_thread_bytes, ovl_par.inter_thread_bytes);
    }
}

#[test]
fn heat2d_overlap_bitwise_across_layouts() {
    check_heat2d(24, 60, 3, 4, 20, 1); // non-square
    check_heat2d(16, 60, 1, 6, 15, 2); // 1×N: column halos only
    check_heat2d(60, 16, 6, 1, 15, 3); // N×1: row halos only
    check_heat2d(16, 16, 1, 1, 10, 4); // single thread, no halos
    check_heat2d(4, 4, 4, 4, 15, 5); // 1-cell interiors (all boundary)
    check_heat2d(3, 6, 3, 2, 15, 6);
}

fn check_stencil3d(
    dims: (usize, usize, usize),
    procs: (usize, usize, usize),
    steps: usize,
    seed: u64,
) {
    let grid = Stencil3dGrid::new(dims.0, dims.1, dims.2, procs.0, procs.1, procs.2);
    let f0 = random_field(dims.0 * dims.1 * dims.2, seed);
    let mut sync = Stencil3dSolver::new(grid, &f0);
    let mut ovl_seq = Stencil3dSolver::new(grid, &f0);
    let mut ovl_par = Stencil3dSolver::new(grid, &f0);
    for step in 0..steps {
        sync.step_with(Engine::Sequential);
        ovl_seq.step_overlapped_with(Engine::Sequential);
        ovl_par.step_overlapped_with(Engine::Parallel);
        let want = sync.to_global();
        for (label, got) in [("seq", ovl_seq.to_global()), ("par", ovl_par.to_global())] {
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{dims:?}/{procs:?}: overlapped {label} diverges at step {step}"
            );
        }
        assert_eq!(sync.inter_thread_bytes, ovl_par.inter_thread_bytes);
    }
}

#[test]
fn stencil3d_overlap_bitwise_across_layouts() {
    check_stencil3d((8, 12, 16), (2, 3, 4), 8, 11);
    check_stencil3d((4, 4, 16), (1, 1, 8), 10, 12); // single-axis split
    check_stencil3d((16, 4, 4), (8, 1, 1), 10, 13);
    check_stencil3d((3, 3, 3), (3, 3, 3), 8, 14); // 1-cell interiors
    check_stencil3d((6, 6, 6), (1, 1, 1), 6, 15); // single thread
}

/// SpMV V3: the overlapped executor must reproduce the sequential oracle's
/// `y`, byte and transfer counts bitwise, on both engines, across layouts
/// and over multi-step runs.
#[test]
fn spmv_v3_overlap_bitwise() {
    let mesh = upcsim::mesh::tiny_mesh();
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let x0 = m.initial_vector(23);
    for (bs, nodes, tpn) in [(128usize, 2usize, 4usize), (64, 1, 4), (256, 1, 2)] {
        let threads = nodes * tpn;
        let layout = Layout::new(m.n, bs, threads);
        let analysis =
            Analysis::build(&m.j, m.r_nz, layout, Topology::new(nodes, tpn), usize::MAX);
        analysis.validate().unwrap();
        let mut seq_state = SpmvState::new(&m, bs, threads, &x0);
        let want = run_variant(Variant::V3, &mut seq_state, Some(&analysis));
        for engine in Engine::ALL {
            let mut eng = SpmvEngine::new(engine);
            let mut state = SpmvState::new(&m, bs, threads, &x0);
            let got = eng.run_overlapped(&mut state, &analysis);
            assert_eq!(got.y, want.y, "{} bs={bs}: y diverges", engine.name());
            assert_eq!(got.inter_thread_bytes, want.inter_thread_bytes, "{}", engine.name());
            assert_eq!(got.transfers, want.transfers, "{}", engine.name());
        }
    }
}

/// Time-stepped SpMV: overlapped and synchronous V3 stay bitwise locked
/// over many iterations (double-buffered arena halves alternate).
#[test]
fn spmv_v3_overlap_time_loop() {
    let m = Ellpack::random(600, 5, 77);
    let x0 = m.initial_vector(5);
    let (bs, threads) = (32usize, 6usize);
    let layout = Layout::new(m.n, bs, threads);
    let analysis =
        Analysis::build(&m.j, m.r_nz, layout, Topology::single_node(threads), usize::MAX);
    let mut sync_eng = SpmvEngine::new(Engine::Parallel);
    let mut sync_state = SpmvState::new(&m, bs, threads, &x0);
    let mut ovl_eng = SpmvEngine::new(Engine::Parallel);
    let mut ovl_state = SpmvState::new(&m, bs, threads, &x0);
    for step in 0..9 {
        sync_eng.run(Variant::V3, &mut sync_state, Some(&analysis));
        sync_state.swap_xy();
        ovl_eng.run_overlapped(&mut ovl_state, &analysis);
        ovl_state.swap_xy();
        assert_eq!(
            sync_state.x_global(),
            ovl_state.x_global(),
            "overlapped V3 diverges at step {step}"
        );
    }
}
