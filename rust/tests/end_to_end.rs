//! Integration: the coordinator's end-to-end pipeline and the harness table
//! generators on test-scale problems.

use upcsim::coordinator::{Backend, Problem, RunConfig, Runner};
use upcsim::harness::{self, HarnessConfig, Workspace};
use upcsim::mesh::TestProblem;
use upcsim::spmv::Variant;

fn quick() -> RunConfig {
    let mut cfg = RunConfig::default_for(Problem::Custom(5_000));
    cfg.block_size = Some(128);
    cfg.nodes = 2;
    cfg.threads_per_node = 8;
    cfg.iters = 1000;
    cfg.exec_steps = 10;
    cfg.backend = Backend::Native;
    cfg
}

#[test]
fn runner_all_variants_stable_and_ordered() {
    let mesh = Runner::new(quick()).build_mesh();
    let mut totals = Vec::new();
    for v in Variant::ALL {
        let mut cfg = quick();
        cfg.variant = v;
        let r = Runner::new(cfg).run_on(&mesh).unwrap();
        // Diffusion decays.
        assert!(
            r.residuals.last().unwrap() <= &r.residuals[0],
            "{}: residual grew",
            v.name()
        );
        totals.push((v, r.sim_total, r.checksum));
    }
    // All variants produce the identical numeric state.
    for w in totals.windows(2) {
        assert_eq!(w[0].2.to_bits(), w[1].2.to_bits());
    }
    // Multi-node: naive slowest, v3 fastest.
    let t = |v: Variant| totals.iter().find(|(x, _, _)| *x == v).unwrap().1;
    assert!(t(Variant::Naive) > t(Variant::V1));
    assert!(t(Variant::V1) > t(Variant::V3));
}

#[test]
fn table3_shape_holds_at_test_scale() {
    // The headline qualitative claims of Table 3, checked end-to-end from
    // mesh generation through the simulator:
    //  (a) multi-node v1 ≫ v3; (b) v3 scales (2 nodes < 1 node);
    //  (c) single-node v1 beats v2.
    let cfg = HarnessConfig::test_sized();
    let mut ws = Workspace::new();
    let t = harness::table3(&cfg, &mut ws);
    let row = |name: &str| -> Vec<f64> {
        t.rows
            .iter()
            .find(|r| r[0].trim() == name)
            .unwrap()
            .iter()
            .skip(1)
            .map(|c| c.parse().unwrap())
            .collect()
    };
    // First problem block only (rows repeat per problem).
    let v1 = row("UPCv1");
    let v2 = row("UPCv2");
    let v3 = row("UPCv3");
    // (a) multi-node fine-grained collapse: v1 ≫ v3 at 2 and 4 nodes.
    assert!(v1[1] > 2.0 * v3[1], "2 nodes: v1 {} vs v3 {}", v1[1], v3[1]);
    assert!(v1[2] > 2.0 * v3[2], "4 nodes: v1 {} vs v3 {}", v1[2], v3[2]);
    // (b) condensing beats whole blocks where remote traffic matters
    //     (2–16 nodes; at the extremes the two converge at test scale).
    for c in 1..5 {
        assert!(v3[c] <= v2[c] * 1.05, "col {c}: v3 {} vs v2 {}", v3[c], v2[c]);
    }
    // (c) the single-node v1 < v2 exception needs the paper's
    //     BLOCKSIZE ≫ stencil-span regime, which a 1/256-scale problem with
    //     the scaled BLOCKSIZE schedule cannot reach; it is asserted at the
    //     proper regime by model::spmv::tests::single_node_v1_beats_v2 and
    //     sim::cluster::tests::single_node_v1_beats_v2_like_table3.
    // (d) v1's 1 → 2 node cliff (the paper's 28.8 s → 522 s).
    assert!(v1[1] > 5.0 * v1[0], "v1 cliff missing: {:?}", v1);
}

#[test]
fn table4_model_tracks_sim_at_small_thread_counts() {
    let cfg = HarnessConfig::test_sized();
    let mut ws = Workspace::new();
    let t = harness::table4(&cfg, &mut ws);
    // Row 0 = 16 threads. Columns: THREADS BS v1a v1p v2a v2p v3a v3p.
    let r0: Vec<f64> = t.rows[0].iter().map(|c| c.parse().unwrap_or(f64::NAN)).collect();
    for (a, p, name) in [(r0[2], r0[3], "v1"), (r0[4], r0[5], "v2"), (r0[6], r0[7], "v3")] {
        let ratio = a / p;
        assert!(
            (0.4..2.5).contains(&ratio),
            "{name}: actual {a} predicted {p} ratio {ratio}"
        );
    }
}

#[test]
fn reports_are_persisted() {
    let dir = std::env::temp_dir().join(format!("upcsim-reports-{}", std::process::id()));
    let mut cfg = HarnessConfig::test_sized();
    cfg.out_dir = Some(dir.clone());
    let mut ws = Workspace::new();
    let t = harness::table1(&cfg, &mut ws);
    harness::emit(&cfg, "table1", &t);
    assert!(dir.join("table1.txt").exists());
    assert!(dir.join("table1.csv").exists());
    let csv = std::fs::read_to_string(dir.join("table1.csv")).unwrap();
    assert!(csv.contains("Test problem 1"));
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn full_tp_pipeline_smoke() {
    // TP1 at 1/512 scale through the whole Runner.
    let mut cfg = RunConfig::default_for(Problem::Tp(TestProblem::Tp1));
    cfg.scale_div = 512;
    cfg.exec_steps = 3;
    cfg.iters = 1000;
    let r = Runner::new(cfg).run().unwrap();
    assert!(r.n > 5_000);
    assert!(r.sim_total > 0.0 && r.model_total > 0.0);
    let ratio = r.sim_total / r.model_total;
    assert!((0.3..4.0).contains(&ratio), "sim/model ratio {ratio}");
}
