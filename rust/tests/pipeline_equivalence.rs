//! Multi-step pipeline equivalence: a `run_pipelined` batch of S steps must
//! be **bitwise identical** — fields/vectors *and* traffic counters — to S
//! synchronous steps, on all three workloads (heat-2D, 3D stencil, SpMV
//! V3), on both engines, across edge layouts. Plus the protocol
//! properties: one pool dispatch per batch, the consumed-epoch ack bound
//! (no sender ever observed more than D epochs ahead of a receiver that
//! just consumed — for every configured depth D, not just the default 2),
//! depth sweeps D ∈ {1..4} in-process and across the socket world, fused
//! boundary-compute equivalence, and mixed-protocol equivalence when
//! synchronous, overlapped and pipelined steps interleave on one runtime.

use std::time::Duration;
use upcsim::comm::{Analysis, StridedBlock, StridedPlan};
use upcsim::engine::{Engine, ExchangeRuntime, FaultKind, FaultPlan, SpmvEngine};
use upcsim::heat2d::Heat2dSolver;
use upcsim::matrix::Ellpack;
use upcsim::model::HeatGrid;
use upcsim::pgas::{Layout, Topology};
use upcsim::spmv::{run_variant, SpmvState, Variant};
use upcsim::stencil3d::{Stencil3dGrid, Stencil3dSolver};
use upcsim::testing::check_prop;
use upcsim::transport::{
    run_reference, run_socket_world_depth, ChaosAction, PlanMode, Proto, WorkloadSpec, WORKLOADS,
};
use upcsim::util::Rng;

/// The buffer depths every sweep below covers.
const DEPTHS: [usize; 4] = [1, 2, 3, 4];

fn random_field(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.f64_in(0.0, 100.0)).collect()
}

/// Drive a heat-2D solver `steps` steps with a synchronous oracle, a
/// sequential pipelined batch, and a parallel pipelined batch; assert
/// bitwise equality of fields and byte counters.
fn check_heat2d(mg: usize, ng: usize, mp: usize, np: usize, steps: usize, seed: u64) {
    let grid = HeatGrid::new(mg, ng, mp, np);
    let f0 = random_field(mg * ng, seed);
    let mut sync = Heat2dSolver::new(grid, &f0);
    for _ in 0..steps {
        sync.step_with(Engine::Sequential);
    }
    let want = sync.to_global();
    for engine in Engine::ALL {
        let mut pipe = Heat2dSolver::new(grid, &f0);
        pipe.run_pipelined_with(engine, steps);
        let got = pipe.to_global();
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{mg}x{ng}/{mp}x{np} S={steps}: pipelined {} diverges",
            engine.name()
        );
        assert_eq!(sync.inter_thread_bytes, pipe.inter_thread_bytes, "{}", engine.name());
        assert!(pipe.runtime().max_sender_lead() <= 2);
    }
}

#[test]
fn heat2d_pipeline_bitwise_across_layouts() {
    check_heat2d(24, 60, 3, 4, 9, 1); // non-square, mixed halos
    check_heat2d(16, 60, 1, 6, 8, 2); // 1×N: column halos only
    check_heat2d(60, 16, 6, 1, 8, 3); // N×1: row halos only
    check_heat2d(24, 24, 2, 2, 7, 4); // 2×2
    check_heat2d(16, 16, 1, 1, 5, 5); // single thread, no halos
    check_heat2d(4, 4, 4, 4, 6, 6); // 1-cell interiors (all boundary)
}

/// Property: random small layouts and batch sizes stay bitwise locked on
/// the parallel engine.
#[test]
fn prop_heat2d_pipeline_equivalence() {
    check_prop(
        "heat2d-pipeline",
        24,
        |r| {
            let mp = r.usize_in(1, 3);
            let np = r.usize_in(1, 3);
            let mg = mp * r.usize_in(3, 9);
            let ng = np * r.usize_in(3, 9);
            let steps = r.usize_in(1, 6);
            (mg, ng, mp, np, steps, r.usize_in(0, 1_000_000) as u64)
        },
        |&(mg, ng, mp, np, steps, seed)| {
            let grid = HeatGrid::new(mg, ng, mp, np);
            let f0 = random_field(mg * ng, seed);
            let mut sync = Heat2dSolver::new(grid, &f0);
            for _ in 0..steps {
                sync.step_with(Engine::Sequential);
            }
            let mut pipe = Heat2dSolver::new(grid, &f0);
            pipe.run_pipelined_with(Engine::Parallel, steps);
            let want = sync.to_global();
            let got = pipe.to_global();
            if !want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()) {
                return Err(format!("{mg}x{ng}/{mp}x{np} S={steps} diverged"));
            }
            if sync.inter_thread_bytes != pipe.inter_thread_bytes {
                return Err("byte counters diverged".into());
            }
            Ok(())
        },
    );
}

fn check_stencil3d(
    dims: (usize, usize, usize),
    procs: (usize, usize, usize),
    steps: usize,
    seed: u64,
) {
    let grid = Stencil3dGrid::new(dims.0, dims.1, dims.2, procs.0, procs.1, procs.2);
    let f0 = random_field(dims.0 * dims.1 * dims.2, seed);
    let mut sync = Stencil3dSolver::new(grid, &f0);
    for _ in 0..steps {
        sync.step_with(Engine::Sequential);
    }
    let want = sync.to_global();
    for engine in Engine::ALL {
        let mut pipe = Stencil3dSolver::new(grid, &f0);
        pipe.run_pipelined_with(engine, steps);
        let got = pipe.to_global();
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{dims:?}/{procs:?} S={steps}: pipelined {} diverges",
            engine.name()
        );
        assert_eq!(sync.inter_thread_bytes, pipe.inter_thread_bytes, "{}", engine.name());
        assert!(pipe.runtime().max_sender_lead() <= 2);
    }
}

#[test]
fn stencil3d_pipeline_bitwise_across_layouts() {
    check_stencil3d((8, 12, 16), (2, 3, 4), 6, 11);
    check_stencil3d((4, 4, 16), (1, 1, 8), 7, 12); // single-axis split
    check_stencil3d((16, 4, 4), (8, 1, 1), 7, 13);
    check_stencil3d((3, 3, 3), (3, 3, 3), 6, 14); // 1-cell interiors
    check_stencil3d((6, 6, 6), (1, 1, 1), 4, 15); // single thread
}

/// SpMV V3: a pipelined batch must reproduce S oracle iterations (each
/// followed by the x/y swap) bitwise — final vector, byte and transfer
/// counts — on both engines, across layouts.
#[test]
fn spmv_v3_pipeline_bitwise() {
    let mesh = upcsim::mesh::tiny_mesh();
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let x0 = m.initial_vector(23);
    for (bs, nodes, tpn, steps) in
        [(128usize, 2usize, 4usize, 5usize), (64, 1, 4, 4), (256, 1, 2, 3), (128, 1, 8, 1)]
    {
        let threads = nodes * tpn;
        let layout = Layout::new(m.n, bs, threads);
        let analysis =
            Analysis::build(&m.j, m.r_nz, layout, Topology::new(nodes, tpn), usize::MAX);

        // Oracle: S sequential V3 iterations with the §6.1 swap.
        let mut oracle_state = SpmvState::new(&m, bs, threads, &x0);
        let mut oracle_bytes = 0u64;
        let mut oracle_transfers = 0u64;
        for _ in 0..steps {
            let out = run_variant(Variant::V3, &mut oracle_state, Some(&analysis));
            oracle_bytes += out.inter_thread_bytes;
            oracle_transfers += out.transfers;
            oracle_state.swap_xy();
        }

        for engine in Engine::ALL {
            let mut eng = SpmvEngine::new(engine);
            let mut state = SpmvState::new(&m, bs, threads, &x0);
            let got = eng.run_pipelined(steps, &mut state, &analysis);
            state.swap_xy(); // complete the last pointer swap, like the oracle
            assert_eq!(
                state.x_global(),
                oracle_state.x_global(),
                "{} bs={bs} S={steps}: final vector diverges",
                engine.name()
            );
            assert_eq!(got.inter_thread_bytes, oracle_bytes, "{}", engine.name());
            assert_eq!(got.transfers, oracle_transfers, "{}", engine.name());
            // The V3 ack gate held the depth-2 bound too.
            assert!(eng.max_sender_lead() <= 2, "lead {}", eng.max_sender_lead());
        }
    }
}

/// Chained pipelined batches interleaved with single-step protocols stay
/// locked to the oracle over a long run (arena parity, flags and acks stay
/// coherent across protocol switches).
#[test]
fn spmv_v3_pipeline_time_loop_mixed() {
    let m = Ellpack::random(600, 5, 77);
    let x0 = m.initial_vector(5);
    let (bs, threads) = (32usize, 6usize);
    let layout = Layout::new(m.n, bs, threads);
    let analysis =
        Analysis::build(&m.j, m.r_nz, layout, Topology::single_node(threads), usize::MAX);
    let mut sync_eng = SpmvEngine::new(Engine::Parallel);
    let mut sync_state = SpmvState::new(&m, bs, threads, &x0);
    let mut mix_eng = SpmvEngine::new(Engine::Parallel);
    let mut mix_state = SpmvState::new(&m, bs, threads, &x0);
    // (protocol, steps): sync and overlapped are single steps.
    let schedule: &[(&str, usize)] =
        &[("pipe", 3), ("sync", 1), ("pipe", 2), ("ovl", 1), ("pipe", 4), ("sync", 1)];
    for &(proto, steps) in schedule {
        match proto {
            "sync" => {
                mix_eng.run(Variant::V3, &mut mix_state, Some(&analysis));
            }
            "ovl" => {
                mix_eng.run_overlapped(&mut mix_state, &analysis);
            }
            _ => {
                mix_eng.run_pipelined(steps, &mut mix_state, &analysis);
            }
        }
        mix_state.swap_xy();
        for _ in 0..steps {
            sync_eng.run(Variant::V3, &mut sync_state, Some(&analysis));
            sync_state.swap_xy();
        }
        assert_eq!(
            sync_state.x_global(),
            mix_state.x_global(),
            "mixed run diverges after {proto} x{steps}"
        );
    }
    assert!(mix_eng.max_sender_lead() <= 2, "lead {}", mix_eng.max_sender_lead());
}

/// Depth-bound under an artificially slow receiver: thread 0's boundary
/// kernel sleeps every epoch, so the other threads race ahead — the ack
/// protocol must cap the observed sender lead at 2 epochs, and the batch
/// must still be bitwise correct.
#[test]
fn pipeline_depth_bounded_with_slow_receiver() {
    // A 4-thread ring: t sends its last owned cell right, first owned cell
    // left — every thread has two senders and two receivers.
    let threads = 4usize;
    let n = 6usize; // 4 owned cells + 2 ghosts per thread
    let mut copies = Vec::new();
    for t in 0..threads {
        let right = (t + 1) % threads;
        let left = (t + threads - 1) % threads;
        copies.push((t, right, StridedBlock::row(4, 1), StridedBlock::row(0, 1)));
        copies.push((t, left, StridedBlock::row(1, 1), StridedBlock::row(5, 1)));
    }
    let plan = StridedPlan::from_msgs(threads, &copies);
    let steps = 12usize;

    let run = |slow: bool| -> (Vec<Vec<f64>>, u64) {
        let mut rt = ExchangeRuntime::new(plan.clone());
        let mut fields: Vec<Vec<f64>> = (0..threads)
            .map(|t| (0..n).map(|i| (t * 10 + i) as f64).collect())
            .collect();
        let mut out = fields.clone();
        rt.run_pipelined(
            Engine::Parallel,
            steps,
            &mut fields,
            &mut out,
            |_t, field, out| {
                for i in 2..4 {
                    out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                }
            },
            move |t, field, out| {
                if slow && t == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                for i in [1usize, 4] {
                    out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                }
            },
        );
        let owned = fields.iter().map(|f| f[1..5].to_vec()).collect();
        (owned, rt.max_sender_lead())
    };

    let (fast_fields, fast_lead) = run(false);
    let (slow_fields, slow_lead) = run(true);
    assert_eq!(fast_fields, slow_fields, "a slow receiver must not change results");
    assert!(fast_lead <= 2, "lead {fast_lead} > 2");
    assert!(slow_lead <= 2, "lead {slow_lead} > 2 with a slow receiver");
}

/// The pipelined parallel batch costs exactly one pool dispatch, and the
/// sequential oracle costs none.
#[test]
fn pipeline_batch_dispatch_accounting() {
    let grid = HeatGrid::new(24, 24, 2, 2);
    let f0 = random_field(24 * 24, 8);
    let mut solver = Heat2dSolver::new(grid, &f0);
    assert_eq!(solver.runtime().dispatches(), 0);
    solver.run_pipelined_with(Engine::Sequential, 5);
    assert_eq!(solver.runtime().dispatches(), 0, "the oracle never dispatches");
    solver.run_pipelined_with(Engine::Parallel, 7);
    assert_eq!(solver.runtime().dispatches(), 1, "one dispatch per batch");
    solver.run_pipelined_with(Engine::Parallel, 3);
    assert_eq!(solver.runtime().dispatches(), 2);
    // Single-step protocols cost one dispatch per step, for contrast.
    solver.step_with(Engine::Parallel);
    solver.step_overlapped_with(Engine::Parallel);
    assert_eq!(solver.runtime().dispatches(), 4);
}

/// Mixed protocols on the grid solvers: interleave synchronous, overlapped
/// and pipelined steps (both engines) against a pure-synchronous oracle.
#[test]
fn heat2d_mixed_protocols_bitwise() {
    let grid = HeatGrid::new(24, 36, 2, 3);
    let f0 = random_field(24 * 36, 21);
    let mut oracle = Heat2dSolver::new(grid, &f0);
    let mut mixed = Heat2dSolver::new(grid, &f0);
    let schedule: &[(&str, Engine, usize)] = &[
        ("sync", Engine::Parallel, 1),
        ("pipe", Engine::Parallel, 3),
        ("ovl", Engine::Sequential, 1),
        ("pipe", Engine::Sequential, 2),
        ("ovl", Engine::Parallel, 1),
        ("pipe", Engine::Parallel, 4),
        ("sync", Engine::Sequential, 1),
    ];
    for &(proto, engine, steps) in schedule {
        match proto {
            "sync" => mixed.step_with(engine),
            "ovl" => mixed.step_overlapped_with(engine),
            _ => mixed.run_pipelined_with(engine, steps),
        }
        for _ in 0..steps {
            oracle.step_with(Engine::Sequential);
        }
        let want = oracle.to_global();
        let got = mixed.to_global();
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "mixed heat2d diverges after {proto} x{steps}"
        );
        assert_eq!(oracle.inter_thread_bytes, mixed.inter_thread_bytes);
    }
}

/// Depth sweep on the grid solvers: D ∈ {1..4} must be bitwise identical
/// to the synchronous oracle on both engines, and the observed sender lead
/// must respect the configured bound (not the historical 2).
#[test]
fn heat2d_depth_sweep_bitwise_and_lead_bounded() {
    let grid = HeatGrid::new(24, 36, 2, 3);
    let f0 = random_field(24 * 36, 31);
    let mut sync = Heat2dSolver::new(grid, &f0);
    let steps = 6usize;
    for _ in 0..steps {
        sync.step_with(Engine::Sequential);
    }
    let want = sync.to_global();
    for depth in DEPTHS {
        for engine in Engine::ALL {
            let mut pipe = Heat2dSolver::new(grid, &f0);
            pipe.set_depth(depth);
            pipe.run_pipelined_with(engine, steps);
            let got = pipe.to_global();
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "heat2d D={depth} {}: pipelined diverges",
                engine.name()
            );
            assert_eq!(sync.inter_thread_bytes, pipe.inter_thread_bytes, "D={depth}");
            let lead = pipe.runtime().max_sender_lead();
            assert!(lead <= depth as u64, "heat2d D={depth}: lead {lead}");
        }
    }
}

#[test]
fn stencil3d_depth_sweep_bitwise_and_lead_bounded() {
    let grid = Stencil3dGrid::new(8, 12, 8, 2, 3, 2);
    let f0 = random_field(8 * 12 * 8, 33);
    let mut sync = Stencil3dSolver::new(grid, &f0);
    let steps = 5usize;
    for _ in 0..steps {
        sync.step_with(Engine::Sequential);
    }
    let want = sync.to_global();
    for depth in DEPTHS {
        for engine in Engine::ALL {
            let mut pipe = Stencil3dSolver::new(grid, &f0);
            pipe.set_depth(depth);
            pipe.run_pipelined_with(engine, steps);
            let got = pipe.to_global();
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "stencil3d D={depth} {}: pipelined diverges",
                engine.name()
            );
            assert_eq!(sync.inter_thread_bytes, pipe.inter_thread_bytes, "D={depth}");
            let lead = pipe.runtime().max_sender_lead();
            assert!(lead <= depth as u64, "stencil3d D={depth}: lead {lead}");
        }
    }
}

/// Depth sweep on the SpMV V3 pipeline: the engine's configured depth must
/// not change the iterates, bytes or transfers, and the ack gate must hold
/// the configured bound.
#[test]
fn spmv_depth_sweep_bitwise_and_lead_bounded() {
    let mesh = upcsim::mesh::tiny_mesh();
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let x0 = m.initial_vector(41);
    let (bs, nodes, tpn, steps) = (128usize, 2usize, 4usize, 5usize);
    let threads = nodes * tpn;
    let layout = Layout::new(m.n, bs, threads);
    let analysis = Analysis::build(&m.j, m.r_nz, layout, Topology::new(nodes, tpn), usize::MAX);

    let mut oracle_state = SpmvState::new(&m, bs, threads, &x0);
    let mut oracle_bytes = 0u64;
    for _ in 0..steps {
        let out = run_variant(Variant::V3, &mut oracle_state, Some(&analysis));
        oracle_bytes += out.inter_thread_bytes;
        oracle_state.swap_xy();
    }

    for depth in DEPTHS {
        for engine in Engine::ALL {
            let mut eng = SpmvEngine::new(engine);
            eng.set_depth(depth);
            let mut state = SpmvState::new(&m, bs, threads, &x0);
            let got = eng.run_pipelined(steps, &mut state, &analysis);
            state.swap_xy();
            assert_eq!(
                state.x_global(),
                oracle_state.x_global(),
                "spmv D={depth} {}: final vector diverges",
                engine.name()
            );
            assert_eq!(got.inter_thread_bytes, oracle_bytes, "D={depth}");
            let lead = eng.max_sender_lead();
            assert!(lead <= depth as u64, "spmv D={depth}: lead {lead}");
        }
    }
}

/// The socket world at every buffer depth must reproduce the in-process
/// reference bitwise — fields, payload bytes, transfer counts — on all
/// three workloads: depth changes scheduling slack only, never data.
#[test]
fn socket_world_depth_sweep_matches_reference() {
    for name in WORKLOADS {
        let spec = WorkloadSpec::for_name(name, 2).unwrap();
        let reference = run_reference(&spec, Proto::Pipeline, 4);
        for depth in DEPTHS {
            let world = run_socket_world_depth(
                &spec,
                Proto::Pipeline,
                4,
                Some(Duration::from_secs(30)),
                ChaosAction::None,
                PlanMode::Compiled,
                depth,
            )
            .unwrap_or_else(|e| panic!("{name} D={depth}: socket world failed: {e}"));
            assert!(
                world.stalls.is_empty() && world.killed.is_empty(),
                "{name} D={depth}: stalls {:?} / deaths {:?}",
                world.stalls,
                world.killed
            );
            assert_eq!(world.fields.len(), reference.fields.len());
            for (rank, (got, want)) in world.fields.iter().zip(&reference.fields).enumerate() {
                assert!(
                    got.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{name} D={depth}: rank {rank} field diverges"
                );
            }
            assert_eq!(world.bytes, reference.bytes, "{name} D={depth}: payload bytes");
            assert_eq!(world.transfers, reference.transfers, "{name} D={depth}: transfers");
        }
    }
}

/// Fault-injected slow receiver at configurable depth: with thread 0
/// sleeping before every unpack from epoch 2 on, the other senders race
/// ahead — the consumed-epoch ack gate must cap the lead at the
/// *configured* D (1 and 3, not just the historical 2), and the iterates
/// must stay bitwise identical to a clean run.
#[test]
fn sender_lead_stays_bounded_under_a_slow_receiver() {
    let m = Ellpack::random(600, 5, 91);
    let x0 = m.initial_vector(9);
    let (bs, threads, steps) = (32usize, 6usize, 6usize);
    let layout = Layout::new(m.n, bs, threads);
    let analysis =
        Analysis::build(&m.j, m.r_nz, layout, Topology::single_node(threads), usize::MAX);
    for depth in [1usize, 3] {
        let run = |faults: Option<FaultPlan>| -> (Vec<f64>, u64) {
            let mut eng = SpmvEngine::new(Engine::Parallel);
            eng.set_depth(depth);
            if let Some(f) = faults {
                eng.set_fault_plan(f);
            }
            let mut state = SpmvState::new(&m, bs, threads, &x0);
            eng.run_pipelined(steps, &mut state, &analysis);
            state.swap_xy();
            (state.x_global(), eng.max_sender_lead())
        };
        let (clean, clean_lead) = run(None);
        let slow_plan = FaultPlan::none().with(0, 2, FaultKind::SlowReceiver(Duration::from_millis(15)));
        let (slow, slow_lead) = run(Some(slow_plan));
        assert!(
            clean.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()),
            "D={depth}: a slow receiver must not change results"
        );
        assert!(clean_lead <= depth as u64, "D={depth}: clean lead {clean_lead}");
        assert!(slow_lead <= depth as u64, "D={depth}: slow lead {slow_lead}");
    }
}

/// The fused split-phase step must stay bitwise locked to the plain
/// synchronous step over a multi-step run — both on a layout where every
/// interior rank fuses its up/down ghost rows, and on short subdomains
/// (m < 4) where `step_fused` falls back to plain unpacking.
#[test]
fn fused_heat2d_steps_match_plain_steps_bitwise() {
    for (mg, ng, mp, np, seed) in [(32usize, 32usize, 2usize, 2usize, 51u64), (8, 24, 4, 1, 52)] {
        let grid = HeatGrid::new(mg, ng, mp, np);
        let f0 = random_field(mg * ng, seed);
        let mut plain = Heat2dSolver::new(grid, &f0);
        let mut fused = Heat2dSolver::new(grid, &f0);
        for step in 0..6 {
            plain.step_with(Engine::Sequential);
            fused.step_fused();
            let want = plain.to_global();
            let got = fused.to_global();
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{mg}x{ng}/{mp}x{np}: fused diverges at step {step}"
            );
            assert_eq!(plain.inter_thread_bytes, fused.inter_thread_bytes, "step {step}");
        }
    }
}
