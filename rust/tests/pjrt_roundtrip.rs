//! Integration: the full AOT bridge — python-lowered HLO-text artifacts
//! loaded and executed through PJRT, numerically cross-checked against the
//! native Rust kernel and the paper's Listing-1 oracle.
//!
//! Skipped (with a notice) when `make artifacts` has not been run.

use upcsim::coordinator::PjrtCompute;
use upcsim::matrix::Ellpack;
use upcsim::runtime::{find_artifacts_dir, Engine};
use upcsim::spmv::{spmv_block_gathered, BlockCompute};
use upcsim::util::Rng;

fn artifacts_available() -> bool {
    if !Engine::available() {
        eprintln!("SKIP: built without the `pjrt` feature — rebuild with --features pjrt");
        return false;
    }
    if find_artifacts_dir().is_none() {
        eprintln!("SKIP: no artifacts/manifest.json — run `make artifacts`");
        return false;
    }
    true
}

#[test]
fn spmv_artifact_matches_native_kernel() {
    if !artifacts_available() {
        return;
    }
    let mut pjrt = PjrtCompute::discover().expect("engine");
    let b = pjrt.tile_rows();
    let r = 16;
    let mut rng = Rng::new(99);
    // Random block data, including an n > b x_copy with out-of-block
    // column references.
    let n = 3 * b + 777; // force tile padding in the last chunk
    let x_copy: Vec<f64> = (0..n).map(|_| rng.f64_in(-1.0, 1.0)).collect();
    let d: Vec<f64> = (0..n).map(|_| rng.f64_in(0.5, 2.0)).collect();
    let a: Vec<f64> = (0..n * r).map(|_| rng.f64_in(-0.1, 0.1)).collect();
    let j: Vec<u32> = (0..n * r).map(|_| rng.usize_in(0, n) as u32).collect();

    let mut y_native = vec![0.0f64; n];
    spmv_block_gathered(0, &d, &a, &j, r, &x_copy, &mut y_native);
    let mut y_pjrt = vec![0.0f64; n];
    pjrt.block(0, &d, &a, &j, r, &x_copy, &mut y_pjrt);

    let mut max_rel = 0.0f64;
    for i in 0..n {
        let rel = (y_native[i] - y_pjrt[i]).abs() / (1.0 + y_native[i].abs());
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 1e-5, "PJRT vs native max rel err {max_rel}");
    assert!(pjrt.calls >= 4, "expected ≥4 tile executions, got {}", pjrt.calls);
}

#[test]
fn heat_artifact_matches_reference() {
    if !artifacts_available() {
        return;
    }
    let mut engine = Engine::discover().expect("engine");
    let spec = engine.spec("heat2d_step").expect("spec").clone();
    let tile = spec.meta["tile"];
    let m = tile + 2;
    let mut rng = Rng::new(5);
    let phi: Vec<f32> = (0..m * m).map(|_| rng.f64_in(0.0, 1.0) as f32).collect();
    let outs = engine.run_f32("heat2d_step", &[&phi]).expect("run");
    let out = &outs[0];
    assert_eq!(out.len(), tile * tile);
    // Reference 5-point update.
    for i in 1..m - 1 {
        for k in 1..m - 1 {
            let want = 0.25
                * (phi[(i - 1) * m + k]
                    + phi[(i + 1) * m + k]
                    + phi[i * m + k - 1]
                    + phi[i * m + k + 1]);
            let got = out[(i - 1) * tile + (k - 1)];
            assert!(
                (want - got).abs() < 1e-5,
                "tile ({i},{k}): {got} vs {want}"
            );
        }
    }
}

#[test]
fn residual_artifact_sums_squares() {
    if !artifacts_available() {
        return;
    }
    let mut engine = Engine::discover().expect("engine");
    let spec = engine.spec("diffusion_residual").expect("spec").clone();
    let b = spec.meta["block"];
    let y: Vec<f32> = (0..b).map(|i| (i % 7) as f32 * 0.25).collect();
    let x: Vec<f32> = (0..b).map(|i| (i % 5) as f32 * 0.5).collect();
    let outs = engine.run_f32("diffusion_residual", &[&y, &x]).expect("run");
    let want: f32 = y.iter().zip(&x).map(|(a, b)| (a - b) * (a - b)).sum();
    let got = outs[0][0];
    assert!(
        (got - want).abs() / want.max(1.0) < 1e-4,
        "{got} vs {want}"
    );
}

#[test]
fn full_variant_run_through_pjrt_matches_oracle() {
    if !artifacts_available() {
        return;
    }
    use upcsim::comm::Analysis;
    use upcsim::pgas::{Layout, Topology};
    use upcsim::spmv::{run_variant_with, SpmvState, Variant};

    let mesh = upcsim::mesh::tiny_mesh();
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let x0 = m.initial_vector(17);
    let mut oracle = vec![0.0; m.n];
    m.spmv_seq(&x0, &mut oracle);

    let layout = Layout::new(m.n, 256, 8);
    let topo = Topology::new(2, 4);
    let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, usize::MAX);
    let mut state = SpmvState::new(&m, 256, 8, &x0);
    let mut pjrt = PjrtCompute::discover().expect("engine");
    let out = run_variant_with(Variant::V3, &mut state, Some(&analysis), &mut pjrt);

    // f32 artifact → tolerance, not bitwise.
    let mut max_rel = 0.0f64;
    for i in 0..m.n {
        let rel = (out.y[i] - oracle[i]).abs() / (1.0 + oracle[i].abs());
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 1e-4, "UPCv3+PJRT vs oracle max rel err {max_rel}");
}
