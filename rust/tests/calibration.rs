//! Integration tests for the host calibration + model-validation pipeline
//! (`repro calibrate` / `repro validate`).

use upcsim::harness::{self, HarnessConfig, Workspace};
use upcsim::machine::{Calibration, HwParams, HwSource};
use upcsim::spmv::Variant;

/// A deterministic host-like parameter set, so the validation test does not
/// depend on actually measuring the (possibly noisy, debug-built) test host.
fn synthetic_host_hw() -> HwParams {
    HwParams {
        w_thread_private: 4.0e9,
        w_node_remote: 8.0e9,
        tau: 1.0e-7,
        cache_line: 64,
        threads_per_node: 8,
        w_node_single: 6.0e9,
        w_pack: 4.0e9,
    }
}

#[test]
fn calibration_measures_finite_positive_values() {
    // Quick profile: must stay cheap enough for debug-build test runs.
    let cal = Calibration::measure(true);
    for (name, v) in [
        ("w_thread_private", cal.hw.w_thread_private),
        ("w_node_remote", cal.hw.w_node_remote),
        ("tau", cal.hw.tau),
        ("w_node_single", cal.hw.w_node_single),
        ("w_pack", cal.hw.w_pack),
        ("stream_node", cal.stream_node),
        ("stream_single", cal.stream_single),
        ("memcpy_cross", cal.memcpy_cross),
    ] {
        assert!(v.is_finite() && v > 0.0, "{name} = {v}");
    }
    assert!(cal.hw.cache_line.is_power_of_two(), "{}", cal.hw.cache_line);
    assert!((8..=1024).contains(&cal.hw.cache_line), "{}", cal.hw.cache_line);
    assert!(cal.hw.threads_per_node >= 1);
    // The single-thread point never exceeds the aggregate (clamped).
    assert!(cal.hw.w_node_single <= cal.stream_node * (1.0 + 1e-12));
    assert!(cal.quick);
}

#[test]
fn calibration_json_roundtrip_through_file() {
    let cal = Calibration::measure(true);
    let path = std::env::temp_dir().join(format!("upcsim_cal_{}.json", std::process::id()));
    cal.save(&path).expect("save calibration");
    let loaded = Calibration::load(&path).expect("load calibration");
    // The JSON emitter prints floats with Rust's shortest-roundtrip
    // formatting, so the reloaded HwParams must be *identical*.
    assert_eq!(cal.hw, loaded.hw);
    assert_eq!(cal, loaded);
    // And the file is what `--hw file:<path>` consumes.
    let via_source = HwSource::File(path.clone()).resolve(true).expect("resolve file source");
    assert_eq!(via_source, cal.hw);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn model_validation_tiny_mesh_covers_all_variants() {
    let mut cfg = HarnessConfig::test_sized();
    cfg.scale_div = 2048; // a few thousand rows: fast even in debug builds
    cfg.hw = synthetic_host_hw();
    cfg.hw_label = "synthetic".to_string();
    let mut ws = Workspace::new();
    let report = harness::model_validation(&cfg, &mut ws, 3, 2, 2);
    assert!(!report.points.is_empty());
    for variant in Variant::ALL {
        let points: Vec<_> = report.points.iter().filter(|p| p.variant == variant).collect();
        assert!(!points.is_empty(), "{} missing from the sweep", variant.name());
        for p in &points {
            assert!(p.measured.is_finite() && p.measured > 0.0, "{}", variant.name());
            assert!(p.predicted.is_finite() && p.predicted > 0.0, "{}", variant.name());
            assert!(p.ratio().is_finite() && p.ratio() > 0.0, "{}", variant.name());
        }
        let g = report.geomean_ratio(variant);
        assert!(g.is_finite() && g > 0.0, "{}: geomean {g}", variant.name());
    }
    // The BENCH_model.json document carries one entry per point plus the
    // per-variant accuracy block.
    let json = &report.json;
    assert_eq!(json.get("bench").unwrap().as_str().unwrap(), "validate/model");
    assert_eq!(json.get("hw_source").unwrap().as_str().unwrap(), "synthetic");
    let results = json.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), report.points.len());
    let acc = json.get("accuracy_geomean").unwrap();
    for variant in Variant::ALL {
        let g = acc.get(variant.name()).and_then(|v| v.as_f64()).unwrap();
        assert!(g.is_finite() && g > 0.0, "{}: {g}", variant.name());
    }
    // The table mirrors the SpMV points and workload rows, plus the 4
    // per-variant and per-workload-label accuracy summary rows.
    assert_eq!(
        report.table.rows.len(),
        report.points.len() + report.workloads.len() + 4 + harness::WORKLOAD_LABELS.len()
    );
    // Every workload label (sync, overlapped, pipelined) is represented.
    for w in harness::WORKLOAD_LABELS {
        assert!(
            report.workloads.iter().any(|p| p.workload == w),
            "missing workload rows for {w}"
        );
    }
}
