//! Plan-optimizer equivalence (the condensing/consolidation acceptance
//! gate).
//!
//! One workload, three plan variants — raw per-element
//! ([`PlanMode::Raw`]), compiled ([`PlanMode::Compiled`]), and optimizer
//! output ([`PlanMode::Optimized`]) — must produce bitwise-identical fields
//! under every protocol in both the in-process reference and the loopback
//! socket world. The optimizer may only change message granularity,
//! duplication, and arena order: [`PlanStats`] must strictly improve on the
//! irregular SpMV gather, and a checkpoint taken under one plan variant
//! must be rejected when restored under another (the fingerprint is part of
//! the snapshot contract).

use std::time::Duration;
use upcsim::comm::{PlanOptimizer, PlanStats};
use upcsim::engine::Engine;
use upcsim::heat2d::Heat2dSolver;
use upcsim::transport::{
    run_reference_mode, run_socket_world_mode, ChaosAction, PlanMode, Proto, WorkloadSpec,
    WORKLOADS,
};

fn field_bits(fields: &[Vec<f64>]) -> Vec<Vec<u64>> {
    fields.iter().map(|f| f.iter().map(|v| v.to_bits()).collect()).collect()
}

/// Every plan variant, in both memory worlds, against the compiled
/// in-process reference: fields bitwise, wire counters consistent between
/// the worlds running the *same* variant.
fn assert_mode_worlds_match(name: &str, procs: usize, proto: Proto, steps: u64) {
    let spec = WorkloadSpec::for_name(name, procs).unwrap();
    let deadline = Some(Duration::from_secs(30));
    let reference = run_reference_mode(&spec, proto, steps, PlanMode::Compiled);
    let mut bytes_by_mode = Vec::new();
    for mode in [PlanMode::Raw, PlanMode::Optimized] {
        let inproc = run_reference_mode(&spec, proto, steps, mode);
        assert_eq!(
            field_bits(&inproc.fields),
            field_bits(&reference.fields),
            "{name}/{}/{}: in-process fields diverged from the compiled plan",
            proto.name(),
            mode.name()
        );
        let socket = run_socket_world_mode(&spec, proto, steps, deadline, ChaosAction::None, mode)
            .unwrap_or_else(|e| panic!("{name}/{}/{}: socket world: {e}", proto.name(), mode.name()));
        assert!(
            socket.stalls.is_empty() && socket.killed.is_empty(),
            "{name}/{}/{}: unexpected stalls {:?}",
            proto.name(),
            mode.name(),
            socket.stalls
        );
        assert_eq!(
            field_bits(&socket.fields),
            field_bits(&reference.fields),
            "{name}/{}/{}: socket fields diverged from the compiled plan",
            proto.name(),
            mode.name()
        );
        // The wire counters are a property of the plan variant, not of the
        // memory world carrying it.
        assert_eq!(socket.bytes, inproc.bytes, "{name}/{}/{}", proto.name(), mode.name());
        assert_eq!(socket.transfers, inproc.transfers, "{name}/{}/{}", proto.name(), mode.name());
        bytes_by_mode.push(inproc.bytes);
    }
    assert!(
        bytes_by_mode[1] <= bytes_by_mode[0],
        "{name}/{}: the optimized plan moved more bytes ({}) than the raw one ({})",
        proto.name(),
        bytes_by_mode[1],
        bytes_by_mode[0]
    );
}

/// All workloads x all protocols x {raw, optimized} x {inproc, socket}.
#[test]
fn optimized_and_raw_worlds_match_reference_bitwise() {
    for name in WORKLOADS {
        for proto in Proto::ALL {
            assert_mode_worlds_match(name, 2, proto, 3);
        }
    }
}

/// A wider mesh routes consolidated messages through different stream
/// pairs; the pipelined protocol adds the depth-2 ack window on top.
#[test]
fn three_rank_pipelined_optimized_worlds_match() {
    for name in WORKLOADS {
        assert_mode_worlds_match(name, 3, Proto::Pipeline, 4);
    }
}

/// On the irregular SpMV gather the optimizer must strictly improve every
/// [`PlanStats`] axis that condensing targets, and its output must be the
/// very plan the inspector's analysis compiles (fingerprint-equal).
#[test]
fn planstats_strictly_improve_on_spmv() {
    let spec = WorkloadSpec::for_name("spmv", 3).unwrap();
    let raw = spec.plan_with(PlanMode::Raw);
    let compiled = spec.plan_with(PlanMode::Compiled);
    let optimized = spec.plan_with(PlanMode::Optimized);
    let before = PlanStats::of(&raw);
    let after = PlanStats::of(&optimized);
    assert!(after.improves_on(&before), "{before:?} -> {after:?}");
    assert!(after.messages < before.messages, "{before:?} -> {after:?}");
    assert!(after.values < before.values, "duplicates must be condensed away");
    assert!(after.payload_bytes < before.payload_bytes);
    assert!(after.index_arena_bytes < before.index_arena_bytes);
    assert_eq!(
        optimized.fingerprint(),
        compiled.fingerprint(),
        "optimizing the raw gather must land on the analysis-compiled plan"
    );
    // Optimizing an already-condensed plan changes nothing (idempotence).
    let twice = PlanOptimizer::default().optimize(&optimized);
    assert_eq!(twice.fingerprint(), optimized.fingerprint());
}

/// The grid workloads carry no duplicates, so the optimizer's win is pure
/// consolidation: same payload, no more messages than the hand-written
/// plan, and never worse statistics than the raw per-element form.
#[test]
fn grid_consolidation_preserves_payload_and_reduces_messages() {
    for name in ["heat", "stencil"] {
        let spec = WorkloadSpec::for_name(name, 3).unwrap();
        let raw = PlanStats::of(&spec.plan_with(PlanMode::Raw));
        let compiled = spec.plan_with(PlanMode::Compiled);
        let optimized = spec.plan_with(PlanMode::Optimized);
        let after = PlanStats::of(&optimized);
        assert!(after.improves_on(&raw), "{name}: {raw:?} -> {after:?}");
        assert_eq!(after.payload_bytes, raw.payload_bytes, "{name}: consolidation moves no data");
        assert!(after.messages < raw.messages, "{name}");
        assert!(
            optimized.num_messages() <= compiled.num_messages(),
            "{name}: optimizer may not fragment the hand-written plan"
        );
    }
}

/// A checkpoint snapshots the plan fingerprint; restoring it into a solver
/// running a *different* plan variant must fail loudly, and restoring into
/// the same variant must round-trip.
#[test]
fn checkpoint_from_raw_plan_is_rejected_by_optimized_solver() {
    let spec = WorkloadSpec::for_name("heat", 2).unwrap();
    let WorkloadSpec::Heat { grid, .. } = spec else {
        panic!("heat spec")
    };
    let global: Vec<f64> = (0..grid.m_glob * grid.n_glob).map(|i| i as f64).collect();
    let raw = spec.plan_with(PlanMode::Raw).as_strided().unwrap().clone();
    let optimized = spec.plan_with(PlanMode::Optimized).as_strided().unwrap().clone();
    assert_ne!(raw.fingerprint(), optimized.fingerprint());

    let mut raw_solver = Heat2dSolver::with_plan(grid, &global, raw);
    raw_solver.step_with(Engine::Sequential);
    let ck = raw_solver.checkpoint(1);

    let mut opt_solver = Heat2dSolver::with_plan(grid, &global, optimized);
    let err = opt_solver.restore(&ck).expect_err("cross-plan restore must be rejected");
    assert!(err.contains("plan"), "error should name the plan mismatch: {err}");

    // Same-variant restore still round-trips.
    let mut raw_solver2 = Heat2dSolver::with_plan(
        grid,
        &global,
        spec.plan_with(PlanMode::Raw).as_strided().unwrap().clone(),
    );
    assert_eq!(raw_solver2.restore(&ck), Ok(1));
}
