//! Fault-injection (chaos) suite for the fault-tolerant exchange runtime.
//!
//! Every injected fault — delayed publish, dropped publish, phase-targeted
//! panic, slow receiver — must deterministically convert into a structured
//! [`StallError`] or a poisoned dispatch within the configured wait
//! deadline on all three pipelined workloads (heat-2D, 3D stencil, SpMV
//! V3); fault/protocol pairs that are benign by design must complete
//! cleanly and bitwise-correctly. On top of that: poison-at-every-phase
//! drills (the pool must survive and stay reusable), checkpoint/restart
//! round-trips that are bitwise identical to uninterrupted runs, and the
//! mixed-protocol epoch-hygiene regression.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use upcsim::comm::Analysis;
use upcsim::engine::{Engine, FaultKind, FaultPlan, Phase, SpmvCheckpoint, SpmvEngine, StallError};
use upcsim::heat2d::Heat2dSolver;
use upcsim::matrix::Ellpack;
use upcsim::model::HeatGrid;
use upcsim::pgas::{Layout, Topology};
use upcsim::spmv::{SpmvState, Variant};
use upcsim::stencil3d::{Stencil3dGrid, Stencil3dSolver};
use upcsim::util::Rng;

/// Short enough to keep the suite fast, long enough to be unambiguous
/// against scheduler noise.
const DEADLINE: Duration = Duration::from_millis(60);
/// Injected sleep: must exceed [`DEADLINE`] so delay faults stall.
const DELAY: Duration = Duration::from_millis(200);
const STEPS: usize = 6;

fn random_field(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.f64_in(0.0, 100.0)).collect()
}

/// The four acceptance fault families, all injected into thread 0 at
/// exchange epoch 2 (each workload below runs on a fresh runtime, so the
/// first batch spans epochs `1..=STEPS`).
fn scenarios() -> Vec<(&'static str, FaultPlan)> {
    vec![
        ("delayed publish", FaultPlan::none().with(0, 2, FaultKind::DelayPublish(DELAY))),
        ("dropped publish", FaultPlan::none().with(0, 2, FaultKind::DropPublish)),
        ("panic at pack", FaultPlan::none().with(0, 2, FaultKind::PanicAt(Phase::Pack))),
        ("slow receiver", FaultPlan::none().with(0, 2, FaultKind::SlowReceiver(DELAY))),
    ]
}

/// Assert that a faulted batch failed, and failed the *right* way: a
/// structured stall for timing faults, an "injected fault" poison for
/// panic faults.
fn assert_converted(name: &str, workload: &str, result: std::thread::Result<()>) {
    let payload = match result {
        Ok(()) => panic!("{workload}/{name}: fault went unnoticed (batch completed)"),
        Err(p) => p,
    };
    if name.contains("panic") {
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&'static str>().map(|s| (*s).to_string()))
            .unwrap_or_default();
        assert!(msg.contains("injected fault"), "{workload}/{name}: poison message {msg:?}");
    } else {
        let stall = StallError::from_panic(payload.as_ref())
            .unwrap_or_else(|| panic!("{workload}/{name}: expected a StallError payload"));
        assert!(stall.waited >= DEADLINE, "{workload}/{name}: waited {:?}", stall.waited);
        assert!(
            matches!(stall.phase, Phase::Transfer | Phase::AckGate | Phase::Barrier),
            "{workload}/{name}: stalled in unexpected phase {}",
            stall.phase
        );
    }
}

#[test]
fn pipelined_faults_convert_on_heat2d() {
    let grid = HeatGrid::new(16, 16, 2, 2);
    let f0 = random_field(16 * 16, 1);
    for (name, plan) in scenarios() {
        let mut solver = Heat2dSolver::new(grid, &f0);
        solver.runtime_mut().set_wait_deadline(Some(DEADLINE));
        solver.runtime_mut().set_fault_plan(plan);
        let res = catch_unwind(AssertUnwindSafe(|| {
            solver.run_pipelined_with(Engine::Parallel, STEPS);
        }));
        assert_converted(name, "heat2d", res);
        // The pool survives the poison: health is readable and idle.
        let health = solver.runtime().health();
        assert_eq!(health.workers.len(), grid.threads());
        assert!(!health.in_flight);
    }
}

#[test]
fn pipelined_faults_convert_on_stencil3d() {
    let grid = Stencil3dGrid::new(8, 8, 8, 1, 2, 2);
    let f0 = random_field(8 * 8 * 8, 2);
    for (name, plan) in scenarios() {
        let mut solver = Stencil3dSolver::new(grid, &f0);
        solver.runtime_mut().set_wait_deadline(Some(DEADLINE));
        solver.runtime_mut().set_fault_plan(plan);
        let res = catch_unwind(AssertUnwindSafe(|| {
            solver.run_pipelined_with(Engine::Parallel, STEPS);
        }));
        assert_converted(name, "stencil3d", res);
    }
}

fn spmv_fixture() -> (Ellpack, usize, usize, Analysis, Vec<f64>) {
    let m = Ellpack::random(600, 6, 5);
    let threads = 4;
    let bs = m.n.div_ceil(threads * 4);
    let layout = Layout::new(m.n, bs, threads);
    let analysis =
        Analysis::build(&m.j, m.r_nz, layout, Topology::single_node(threads), usize::MAX);
    let x0 = m.initial_vector(9);
    (m, bs, threads, analysis, x0)
}

#[test]
fn pipelined_faults_convert_on_spmv() {
    let (m, bs, threads, analysis, x0) = spmv_fixture();
    for (name, plan) in scenarios() {
        let mut engine = SpmvEngine::new(Engine::Parallel);
        engine.set_wait_deadline(Some(DEADLINE));
        engine.set_fault_plan(plan);
        let mut state = SpmvState::new(&m, bs, threads, &x0);
        let res = catch_unwind(AssertUnwindSafe(|| {
            engine.run_pipelined(STEPS, &mut state, &analysis);
        }));
        assert_converted(name, "spmv-v3", res);
    }
}

/// Dropped publishes/acks are pure bookkeeping under the synchronous
/// barrier protocol: the batch must complete cleanly *and* bitwise match
/// the fault-free run.
#[test]
fn sync_protocol_ignores_dropped_flags() {
    let grid = HeatGrid::new(16, 16, 2, 2);
    let f0 = random_field(16 * 16, 3);
    let mut clean = Heat2dSolver::new(grid, &f0);
    for _ in 0..4 {
        clean.step_with(Engine::Parallel);
    }
    let want = clean.to_global();
    for kind in [FaultKind::DropPublish, FaultKind::DropAck] {
        let mut faulted = Heat2dSolver::new(grid, &f0);
        faulted.runtime_mut().set_wait_deadline(Some(DEADLINE));
        faulted.runtime_mut().set_fault_plan(FaultPlan::none().with(0, 1, kind));
        for _ in 0..4 {
            faulted.step_with(Engine::Parallel);
        }
        let got = faulted.to_global();
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "sync batch diverged under a benign {kind:?}"
        );
    }
}

/// A dropped ack is benign on the depth-1 overlapped protocol (no ack gate
/// ever fires), while a dropped publish stalls it.
#[test]
fn overlapped_protocol_fault_matrix() {
    let grid = HeatGrid::new(16, 16, 2, 2);
    let f0 = random_field(16 * 16, 4);

    let mut benign = Heat2dSolver::new(grid, &f0);
    benign.runtime_mut().set_wait_deadline(Some(DEADLINE));
    benign.runtime_mut().set_fault_plan(FaultPlan::none().with(0, 1, FaultKind::DropAck));
    for _ in 0..3 {
        benign.step_overlapped_with(Engine::Parallel);
    }

    let mut stalled = Heat2dSolver::new(grid, &f0);
    stalled.runtime_mut().set_wait_deadline(Some(DEADLINE));
    stalled.runtime_mut().set_fault_plan(FaultPlan::none().with(0, 1, FaultKind::DropPublish));
    let res = catch_unwind(AssertUnwindSafe(|| {
        stalled.step_overlapped_with(Engine::Parallel);
    }));
    let payload = res.expect_err("a dropped publish must stall the overlapped step");
    let stall = StallError::from_panic(payload.as_ref()).expect("structured stall");
    // A neighbour of thread 0 stalls waiting for the dropped flag; a
    // non-neighbour may reach the closing barrier and time out there
    // instead, and either report can win the payload race.
    assert!(matches!(stall.phase, Phase::Transfer | Phase::Barrier));
    if stall.phase == Phase::Transfer {
        assert_eq!(stall.peer, Some(0));
    }
}

/// Poison the pipelined batch at each instrumented phase in turn; the
/// dispatch must fail every time, and the pool must remain usable for a
/// clean, bitwise-correct batch afterwards.
#[test]
fn poison_at_every_phase_leaves_pool_reusable() {
    let grid = HeatGrid::new(16, 16, 2, 2);
    let f0 = random_field(16 * 16, 5);
    let mut oracle = Heat2dSolver::new(grid, &f0);
    oracle.run_pipelined_with(Engine::Sequential, STEPS);
    let want = oracle.to_global();

    for phase in [Phase::Pack, Phase::Transfer, Phase::Boundary] {
        let mut solver = Heat2dSolver::new(grid, &f0);
        solver.runtime_mut().set_wait_deadline(Some(DEADLINE));
        for thread in [0usize, 3] {
            // The epoch counter survives poisoned batches (it is bumped up
            // front), so pin each fault relative to the live counter.
            let fire_at = solver.runtime().epoch() + 2;
            solver
                .runtime_mut()
                .set_fault_plan(FaultPlan::none().with(thread, fire_at, FaultKind::PanicAt(phase)));
            let res = catch_unwind(AssertUnwindSafe(|| {
                solver.run_pipelined_with(Engine::Parallel, STEPS);
            }));
            assert!(res.is_err(), "panic at {phase} on thread {thread} did not poison");
        }
        // Same solver, same pool: clear the faults, reset the fields, and
        // demand a bitwise-correct batch.
        solver.runtime_mut().clear_faults();
        let fresh = Heat2dSolver::new(grid, &f0);
        let ck = fresh.checkpoint(0);
        solver.restore(&ck).expect("same plan, restore must succeed");
        solver.run_pipelined_with(Engine::Parallel, STEPS);
        let got = solver.to_global();
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "pool poisoned at {phase} did not recover to a bitwise-correct batch"
        );
    }
}

/// Checkpoint every C steps, kill the continuation with a sticky dropped
/// publish, restore a fresh solver from the last checkpoint, finish the
/// run — the result must be bitwise identical to an uninterrupted run,
/// byte counters included.
#[test]
fn heat2d_checkpoint_restart_is_bitwise() {
    let grid = HeatGrid::new(16, 16, 2, 2);
    let f0 = random_field(16 * 16, 6);
    let total = 10usize;

    let mut reference = Heat2dSolver::new(grid, &f0);
    reference.run_pipelined_with(Engine::Parallel, total);

    let mut victim = Heat2dSolver::new(grid, &f0);
    victim.runtime_mut().set_wait_deadline(Some(DEADLINE));
    let mut last = None;
    victim.run_pipelined_checkpointed_with(Engine::Parallel, 6, 3, &mut |c| last = Some(c));
    // Kill the continuation mid-batch (sticky drop from epoch 1 suppresses
    // every publish of the next batch).
    victim.runtime_mut().set_fault_plan(FaultPlan::none().with(0, 1, FaultKind::DropPublish));
    let killed = catch_unwind(AssertUnwindSafe(|| {
        victim.run_pipelined_with(Engine::Parallel, total - 6);
    }));
    assert!(killed.is_err(), "kill fault did not fire");

    let ck = last.expect("at least one checkpoint was sunk");
    assert_eq!(ck.step, 6);
    let mut resumed = Heat2dSolver::new(grid, &f0);
    let done = resumed.restore(&ck).unwrap() as usize;
    resumed.run_pipelined_with(Engine::Parallel, total - done);
    assert!(
        reference
            .to_global()
            .iter()
            .zip(resumed.to_global().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "resumed run diverges from the uninterrupted run"
    );
    assert_eq!(resumed.inter_thread_bytes, reference.inter_thread_bytes);
}

#[test]
fn stencil3d_checkpoint_restart_is_bitwise() {
    let grid = Stencil3dGrid::new(8, 8, 8, 1, 2, 2);
    let f0 = random_field(8 * 8 * 8, 7);
    let total = 8usize;

    let mut reference = Stencil3dSolver::new(grid, &f0);
    reference.run_pipelined_with(Engine::Parallel, total);

    let mut victim = Stencil3dSolver::new(grid, &f0);
    let mut last = None;
    victim.run_pipelined_checkpointed_with(Engine::Parallel, 4, 2, &mut |c| last = Some(c));
    let ck = last.expect("checkpoint sunk");
    assert_eq!(ck.step, 4);

    let mut resumed = Stencil3dSolver::new(grid, &f0);
    let done = resumed.restore(&ck).unwrap() as usize;
    resumed.run_pipelined_with(Engine::Parallel, total - done);
    assert!(
        reference
            .to_global()
            .iter()
            .zip(resumed.to_global().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "resumed stencil3d run diverges"
    );
    assert_eq!(resumed.inter_thread_bytes, reference.inter_thread_bytes);
}

#[test]
fn spmv_checkpoint_restart_is_bitwise() {
    let (m, bs, threads, analysis, x0) = spmv_fixture();
    let total = 10usize;

    let mut ref_engine = SpmvEngine::new(Engine::Parallel);
    let mut ref_state = SpmvState::new(&m, bs, threads, &x0);
    ref_engine.run_pipelined(total, &mut ref_state, &analysis);

    let mut victim_engine = SpmvEngine::new(Engine::Parallel);
    victim_engine.set_wait_deadline(Some(DEADLINE));
    let mut victim_state = SpmvState::new(&m, bs, threads, &x0);
    let mut last: Option<SpmvCheckpoint> = None;
    victim_engine.run_pipelined_checkpointed(6, 3, &mut victim_state, &analysis, &mut |c| {
        last = Some(c);
    });
    // Kill the continuation; the checkpoint must still restore cleanly.
    victim_engine.set_fault_plan(FaultPlan::none().with(0, 1, FaultKind::DropPublish));
    let killed = catch_unwind(AssertUnwindSafe(|| {
        victim_state.swap_xy();
        victim_engine.run_pipelined(total - 6, &mut victim_state, &analysis);
    }));
    assert!(killed.is_err(), "kill fault did not fire");

    let ck = last.expect("checkpoint sunk");
    assert_eq!(ck.step, 6);
    let mut resumed_engine = SpmvEngine::new(Engine::Parallel);
    let mut resumed_state = SpmvState::new(&m, bs, threads, &x0);
    let done = resumed_engine.restore(&ck, &mut resumed_state, &analysis).unwrap() as usize;
    resumed_engine.run_pipelined(total - done, &mut resumed_state, &analysis);

    let want = ref_state.y_global();
    let got = resumed_state.y_global();
    assert!(
        want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
        "resumed SpMV run diverges from the uninterrupted run"
    );
}

/// Checkpointed batching itself (no kill) must equal one big batch.
#[test]
fn checkpointed_driver_matches_single_batch() {
    let (m, bs, threads, analysis, x0) = spmv_fixture();
    let mut a_engine = SpmvEngine::new(Engine::Parallel);
    let mut a_state = SpmvState::new(&m, bs, threads, &x0);
    let one = a_engine.run_pipelined(9, &mut a_state, &analysis);

    let mut b_engine = SpmvEngine::new(Engine::Parallel);
    let mut b_state = SpmvState::new(&m, bs, threads, &x0);
    let mut count = 0usize;
    let batched =
        b_engine.run_pipelined_checkpointed(9, 4, &mut b_state, &analysis, &mut |_| count += 1);
    assert_eq!(count, 3, "9 steps in batches of 4 sink 3 checkpoints");
    assert_eq!(one.inter_thread_bytes, batched.inter_thread_bytes);
    assert_eq!(one.transfers, batched.transfers);
    let (want, got) = (a_state.y_global(), b_state.y_global());
    assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
}

/// A checkpoint must refuse to restore onto a different decomposition.
#[test]
fn restore_rejects_foreign_plan() {
    let f0 = random_field(16 * 16, 8);
    let solver = Heat2dSolver::new(HeatGrid::new(16, 16, 2, 2), &f0);
    let ck = solver.checkpoint(3);
    let mut other = Heat2dSolver::new(HeatGrid::new(16, 16, 1, 4), &f0);
    let err = other.restore(&ck).unwrap_err();
    assert!(err.contains("does not match"), "unexpected error: {err}");

    let (m, bs, threads, analysis, x0) = spmv_fixture();
    let mut engine = SpmvEngine::new(Engine::Parallel);
    let state = SpmvState::new(&m, bs, threads, &x0);
    let ck = engine.checkpoint(1, &state, &analysis);
    let other_layout = Layout::new(m.n, bs * 2, threads);
    let other_analysis =
        Analysis::build(&m.j, m.r_nz, other_layout, Topology::single_node(threads), usize::MAX);
    let mut other_state = SpmvState::new(&m, bs * 2, threads, &x0);
    let err = engine.restore(&ck, &mut other_state, &other_analysis).unwrap_err();
    assert!(err.contains("does not match"), "unexpected error: {err}");
}

/// A checkpoint records the pipeline depth D it was taken under; restoring
/// into a runtime configured at a different depth must be rejected (the
/// schedules are bitwise-equal, but the run's recorded stall envelope
/// would lie), while restoring at the matching depth succeeds.
#[test]
fn restore_rejects_depth_mismatch() {
    let grid = HeatGrid::new(16, 16, 2, 2);
    let f0 = random_field(16 * 16, 9);
    let mut deep = Heat2dSolver::new(grid, &f0);
    deep.set_depth(3);
    let ck = deep.checkpoint(4);
    assert_eq!(ck.depth, 3, "checkpoint must record the live pipeline depth");

    let mut shallow = Heat2dSolver::new(grid, &f0);
    assert_eq!(shallow.depth(), 2, "default depth changed; update this test");
    let err = shallow.restore(&ck).unwrap_err();
    assert!(err.contains("depth 3"), "unexpected error: {err}");
    assert!(err.contains("does not match"), "unexpected error: {err}");
    shallow.set_depth(3);
    let step = shallow.restore(&ck).expect("matching depth must restore");
    assert_eq!(step, 4);

    let (m, bs, threads, analysis, x0) = spmv_fixture();
    let mut engine = SpmvEngine::new(Engine::Parallel);
    engine.set_depth(4);
    let state = SpmvState::new(&m, bs, threads, &x0);
    let ck = engine.checkpoint(2, &state, &analysis);
    assert_eq!(ck.depth, 4);
    let mut resumed_engine = SpmvEngine::new(Engine::Parallel);
    let mut resumed_state = SpmvState::new(&m, bs, threads, &x0);
    let err = resumed_engine.restore(&ck, &mut resumed_state, &analysis).unwrap_err();
    assert!(err.contains("depth 4"), "unexpected error: {err}");
    resumed_engine.set_depth(4);
    resumed_engine
        .restore(&ck, &mut resumed_state, &analysis)
        .expect("matching depth must restore");
}

/// Epoch hygiene: mixing the synchronous, overlapped and pipelined
/// protocols on one engine keeps every flag publish monotone (the
/// publish-backwards assertion must not fire) and stays bitwise locked to
/// the sequential oracle.
#[test]
fn mixed_protocols_keep_epochs_monotone() {
    let (m, bs, threads, analysis, x0) = spmv_fixture();
    let mut finals: Vec<Vec<f64>> = Vec::new();
    for mode in Engine::ALL {
        let mut engine = SpmvEngine::new(mode);
        let mut state = SpmvState::new(&m, bs, threads, &x0);
        engine.run(Variant::V3, &mut state, Some(&analysis));
        state.swap_xy();
        engine.run_overlapped(&mut state, &analysis);
        state.swap_xy();
        engine.run_pipelined(3, &mut state, &analysis);
        state.swap_xy();
        engine.run(Variant::V3, &mut state, Some(&analysis));
        state.swap_xy();
        engine.run_pipelined(2, &mut state, &analysis);
        finals.push(state.y_global());
    }
    assert!(
        finals[0].iter().zip(&finals[1]).all(|(a, b)| a.to_bits() == b.to_bits()),
        "mixed-protocol schedule diverges between engines"
    );
}
