//! Cross-world transport equivalence (the tentpole acceptance gate).
//!
//! One compiled [`ExchangePlan`](upcsim::comm::ExchangePlan), three memory
//! worlds: the in-process sequential reference, an in-process loopback
//! socket world (one thread per rank), and the multi-process `repro launch`
//! orchestrator. All three workloads must produce bitwise-identical fields
//! and identical wire counters under every protocol, and a slow or killed
//! peer must surface as a structured stall within the deadline — never a
//! hang.

use std::time::{Duration, Instant};
use upcsim::transport::{
    run_reference, run_socket_world, ChaosAction, Proto, WorkloadSpec, WORKLOADS,
};

fn assert_worlds_match(name: &str, procs: usize, proto: Proto, steps: u64) {
    let spec = WorkloadSpec::for_name(name, procs).unwrap();
    let deadline = Some(Duration::from_secs(30));
    let world = run_socket_world(&spec, proto, steps, deadline, ChaosAction::None)
        .unwrap_or_else(|e| panic!("{name}/{}: socket world failed: {e}", proto.name()));
    assert!(
        world.stalls.is_empty() && world.killed.is_empty(),
        "{name}/{}: unexpected stalls {:?} / deaths {:?}",
        proto.name(),
        world.stalls,
        world.killed
    );
    let reference = run_reference(&spec, proto, steps);
    assert_eq!(world.bytes, reference.bytes, "{name}/{}: payload bytes", proto.name());
    assert_eq!(world.transfers, reference.transfers, "{name}/{}: transfers", proto.name());
    assert_eq!(world.fields.len(), reference.fields.len());
    for (r, (got, want)) in world.fields.iter().zip(&reference.fields).enumerate() {
        assert_eq!(got.len(), want.len(), "{name}/{}: rank {r} field length", proto.name());
        for (i, (a, b)) in got.iter().zip(want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{name}/{}: rank {r} field[{i}] = {a} vs reference {b}",
                proto.name()
            );
        }
    }
}

/// All three workloads x all three protocols over a 2-rank loopback socket
/// mesh: bitwise-identical fields and identical byte/transfer counters
/// against the in-process reference.
#[test]
fn socket_world_matches_reference_bitwise() {
    for name in WORKLOADS {
        for proto in Proto::ALL {
            assert_worlds_match(name, 2, proto, 3);
        }
    }
}

/// Wider meshes route every plan edge through a different stream pair; the
/// pipelined protocol additionally exercises the depth-2 ack window.
#[test]
fn three_rank_pipelined_worlds_match() {
    for name in WORKLOADS {
        assert_worlds_match(name, 3, Proto::Pipeline, 4);
    }
}

/// A peer napping past the wait deadline must convert into a structured
/// stall naming the socket transport — and the world must return promptly,
/// not hang for the duration of the nap times the epoch count.
#[test]
fn slow_peer_converts_to_stall_within_deadline() {
    let spec = WorkloadSpec::for_name("heat", 2).unwrap();
    let t0 = Instant::now();
    let world = run_socket_world(
        &spec,
        Proto::Sync,
        4,
        Some(Duration::from_millis(250)),
        ChaosAction::SlowAt(1, Duration::from_millis(2000)),
    )
    .unwrap();
    assert!(!world.stalls.is_empty(), "healthy rank should have stalled: {world:?}");
    let (rank, msg) = &world.stalls[0];
    assert_eq!(*rank, 0, "the healthy rank stalls, the slowed one naps");
    assert!(msg.contains("socket:rank-"), "stall names the peer's transport identity: {msg}");
    assert!(t0.elapsed() < Duration::from_secs(20), "took {:?}", t0.elapsed());
}

/// A rank dying mid-pipeline is reported as killed, and every survivor
/// raises a clean stall (the reader marks the dead stream, waits error out).
#[test]
fn killed_peer_is_reported_not_hung() {
    let spec = WorkloadSpec::for_name("spmv", 2).unwrap();
    let world = run_socket_world(
        &spec,
        Proto::Pipeline,
        5,
        Some(Duration::from_millis(500)),
        ChaosAction::KillAt(2),
    )
    .unwrap();
    assert_eq!(world.killed, vec![1], "the highest rank takes the chaos action");
    assert!(!world.stalls.is_empty(), "the survivor must stall, not finish: {world:?}");
}

// ---------------------------------------------------------------------------
// World 3: the real multi-process orchestrator, driven through the binary.
// ---------------------------------------------------------------------------

fn repro(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .output()
        .expect("spawning the repro binary")
}

fn assert_launch_ok(out: &std::process::Output, needle: &str) {
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "launch failed\nstdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains(needle), "missing '{needle}'\nstdout:\n{stdout}\nstderr:\n{stderr}");
}

/// `repro launch --procs 2`: spawned worker processes receive the
/// serialized plan, run the protocol over real sockets, and the leader
/// verifies fields and counters bitwise against the in-process reference.
#[test]
fn launch_two_procs_verifies_bitwise() {
    for (workload, proto) in [("heat", "sync"), ("stencil", "overlap"), ("spmv", "pipeline")] {
        let out = repro(&[
            "launch", "--procs", "2", "--workload", workload, "--proto", proto, "--steps", "3",
        ]);
        assert_launch_ok(&out, "verified bitwise against the in-process reference");
    }
}

/// A chaos-killed worker exits with the planned code and every surviving
/// process stalls cleanly instead of hanging the launch.
#[test]
fn launch_chaos_kill_is_contained() {
    let out = repro(&[
        "launch", "--procs", "2", "--workload", "heat", "--proto", "pipeline", "--steps", "4",
        "--chaos", "kill@2", "--deadline-ms", "800",
    ]);
    assert_launch_ok(&out, "all survivors stalled cleanly");
}
