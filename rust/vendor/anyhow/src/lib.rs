//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment ships no registry crates, so this path dependency
//! provides exactly the API surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the
//! [`Context`] extension trait. Error values carry a flattened message
//! string (context layers are joined with `": "`, the same rendering
//! `{:#}` produces with the real crate).
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`; that keeps the blanket `From` conversion for `?`
//! coherent.

use std::fmt;

/// A flattened dynamic error: a message, possibly with context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prefix a context layer (used by the [`Context`] trait).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Attach context to an error as it propagates.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macros_and_context_render() {
        fn inner() -> Result<()> {
            bail!("bad value {}", 42);
        }
        let e = inner().context("while parsing").unwrap_err();
        assert_eq!(format!("{e}"), "while parsing: bad value 42");
        assert_eq!(format!("{e:?}"), "while parsing: bad value 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).is_err());
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<i32, std::fmt::Error> = Ok(1);
        let v = ok.with_context(|| -> String { unreachable!("not evaluated on Ok") });
        assert_eq!(v.unwrap(), 1);
    }
}
