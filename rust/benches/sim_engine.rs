//! Criterion-lite bench: the simulator + model evaluation cost per
//! configuration (this is what bounds how fast the harness can sweep).

use upcsim::benchlib::{BenchConfig, Bencher};
use upcsim::comm::Analysis;
use upcsim::machine::HwSource;
use upcsim::matrix::Ellpack;
use upcsim::mesh::{TetGridSpec, TetMesh};
use upcsim::model::{self, SpmvInputs};
use upcsim::pgas::{Layout, Topology};
use upcsim::sim::{ClusterSim, DEFAULT_CACHE_WINDOW};
use upcsim::spmv::Variant;

fn main() {
    let mut b = Bencher::from_args(BenchConfig::default());
    let mesh = TetMesh::generate(&TetGridSpec::ventricle(400_000, 7));
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let layout = Layout::new(m.n, 4096, 64);
    let topo = Topology::new(4, 16);
    let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, DEFAULT_CACHE_WINDOW);
    // UPCSIM_HW=abel|host|file:<path> selects the parameter set (see
    // `repro calibrate`); default is the paper's Abel constants.
    let src = HwSource::from_env().expect("UPCSIM_HW");
    // Rescaled to the simulated 16-threads/node topology (§5.1).
    let hw = src.resolve(true).expect("hw resolution").with_threads_per_node(16);
    println!("hardware parameters: {}\n", src.label());
    let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };
    let sim = ClusterSim::new(hw);

    for v in Variant::ALL {
        b.bench(&format!("sim/iteration/{}", v.name()), || {
            std::hint::black_box(sim.spmv_iteration(v, &inp).total);
        });
    }
    b.bench("model/predict_v1", || {
        std::hint::black_box(model::predict_v1(&inp).total);
    });
    b.bench("model/predict_v2", || {
        std::hint::black_box(model::predict_v2(&inp).total);
    });
    b.bench("model/predict_v3", || {
        std::hint::black_box(model::predict_v3(&inp).total);
    });
    b.finish();
}
