//! Criterion-lite bench: PJRT execution of the AOT Pallas artifact vs the
//! native kernel on identical block workloads. Quantifies the cost of the
//! artifact path (staging + f32 + PJRT dispatch) so EXPERIMENTS.md can state
//! when it pays off. Skipped without artifacts.

use upcsim::benchlib::{BenchConfig, Bencher};
use upcsim::coordinator::PjrtCompute;
use upcsim::spmv::{spmv_block_gathered, BlockCompute};
use upcsim::util::Rng;

fn main() {
    let Ok(mut pjrt) = PjrtCompute::discover() else {
        println!("SKIP: artifacts missing — run `make artifacts` first");
        return;
    };
    let mut b = Bencher::from_args(BenchConfig::default());
    let bsz = pjrt.tile_rows();
    let r = 16;
    let n = 4 * bsz;
    let mut rng = Rng::new(1);
    let x_copy: Vec<f64> = (0..n).map(|_| rng.f64_in(-1.0, 1.0)).collect();
    let d: Vec<f64> = (0..n).map(|_| rng.f64_in(0.5, 2.0)).collect();
    let a: Vec<f64> = (0..n * r).map(|_| rng.f64_in(-0.1, 0.1)).collect();
    let j: Vec<u32> = (0..n * r).map(|_| rng.usize_in(0, n) as u32).collect();
    let mut y = vec![0.0f64; n];

    let rows = n as f64;
    b.bench_items("pjrt/spmv-4-tiles", rows, || {
        pjrt.block(0, &d, &a, &j, r, &x_copy, &mut y);
        std::hint::black_box(&y);
    });
    b.bench_items("native/spmv-same-work", rows, || {
        spmv_block_gathered(0, &d, &a, &j, r, &x_copy, &mut y);
        std::hint::black_box(&y);
    });
    b.finish();
}
