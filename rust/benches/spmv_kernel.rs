//! Criterion-lite bench: the native SpMV hot path (L3's per-block kernel).
//!
//! The §Perf target (EXPERIMENTS.md): sustain ≥ 60 % of the host-STREAM
//! roofline for the eq. (6) traffic formula (216 B/row at r_nz = 16).

use upcsim::benchlib::{BenchConfig, Bencher};
use upcsim::comm::Analysis;
use upcsim::engine::{Engine, SpmvEngine};
use upcsim::matrix::Ellpack;
use upcsim::mesh::{TetGridSpec, TetMesh};
use upcsim::microbench;
use upcsim::pgas::{Layout, Topology};
use upcsim::spmv::{spmv_block_gathered, spmv_parallel, SpmvState, Variant};
use upcsim::util::fmt;
use upcsim::util::json::Value;

fn main() {
    let mut b = Bencher::from_args(BenchConfig::default());

    // Host roofline anchor.
    let stream = microbench::stream_host(1 << 21);
    println!("host STREAM triad: {}\n", fmt::rate(stream.bandwidth()));

    let mesh = TetMesh::generate(&TetGridSpec::ventricle(400_000, 7));
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let x: Vec<f64> = m.initial_vector(3);
    let mut y = vec![0.0f64; m.n];

    // Whole-matrix pass: n rows × 216 B of eq.(6) traffic.
    let bytes = m.n as f64 * m.d_min_comp_bytes();
    b.bench_bytes("spmv/native/full-pass", bytes, || {
        spmv_block_gathered(0, &m.diag, &m.a, &m.j, m.r_nz, &x, &mut y);
        std::hint::black_box(&y);
    });

    // Block-tiled pass (the shape the executors drive): 4096-row blocks.
    let bs = 4096;
    b.bench_bytes("spmv/native/4096-blocks", bytes, || {
        let mut off = 0;
        while off < m.n {
            let len = (m.n - off).min(bs);
            spmv_block_gathered(
                off,
                &m.diag[off..off + len],
                &m.a[off * 16..(off + len) * 16],
                &m.j[off * 16..(off + len) * 16],
                16,
                &x,
                &mut y[off..off + len],
            );
            off += len;
        }
        std::hint::black_box(&y);
    });

    // Host-parallel pass — the like-for-like comparison against the
    // all-core STREAM roofline.
    b.bench_bytes("spmv/native/parallel", bytes, || {
        spmv_parallel(&m.diag, &m.a, &m.j, m.r_nz, &x, &mut y);
        std::hint::black_box(&y);
    });

    // Sequential oracle (Listing 1) for reference.
    b.bench_bytes("spmv/listing1-oracle", bytes, || {
        m.spmv_seq(&x, &mut y);
        std::hint::black_box(&y);
    });

    if let Some(r) = b.results().iter().find(|r| r.name.contains("parallel")) {
        let frac = r.bandwidth().unwrap() / stream.bandwidth();
        println!(
            "\nparallel kernel sustains {:.1}% of host STREAM roofline (target ≥ 60%)",
            frac * 100.0
        );
    }

    // --- Engine comparison: sequential oracle vs the worker pool ---------
    //
    // Full UPC-variant execution (transport + compute) at 8 logical
    // threads, both engines, all four variants. Medians land in
    // BENCH_engine.json at the repo root so the perf trajectory is
    // machine-readable.
    let threads = 8;
    let bs = 4096;
    let layout = Layout::new(m.n, bs, threads);
    let topo = Topology::new(2, 4);
    let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, usize::MAX);
    let x0 = m.initial_vector(5);
    let mut entries: Vec<(Engine, Variant, f64)> = Vec::new();
    for engine in Engine::ALL {
        let mut eng = SpmvEngine::new(engine);
        for v in Variant::ALL {
            let mut state = SpmvState::new(&m, bs, threads, &x0);
            let name = format!("engine/{}/{}", engine.name(), v.name());
            if let Some(r) = b.bench(&name, || {
                let out = eng.run(v, &mut state, Some(&analysis));
                std::hint::black_box(&out);
            }) {
                entries.push((engine, v, r.time.p50));
            }
        }
    }

    let median_of = |e: Engine, v: Variant| {
        entries
            .iter()
            .find(|&&(xe, xv, _)| xe == e && xv == v)
            .map(|&(_, _, p50)| p50)
    };
    let mut root = Value::obj();
    root.set("bench", Value::Str("spmv_kernel/engine".to_string()));
    // Stamp the host roofline so BENCH_engine.json is comparable across
    // machines (same anchor `repro calibrate` measures as W_node).
    root.set("host_stream_bps", Value::Num(stream.bandwidth()));
    root.set("n", Value::Num(m.n as f64));
    root.set("r_nz", Value::Num(m.r_nz as f64));
    root.set("threads", Value::Num(threads as f64));
    root.set("block_size", Value::Num(bs as f64));
    let mut results = Vec::new();
    for (engine, variant, p50) in &entries {
        let mut o = Value::obj();
        o.set("engine", Value::Str(engine.name().to_string()));
        o.set("variant", Value::Str(variant.name().to_string()));
        o.set("median_ns_per_iter", Value::Num((p50 * 1e9).round()));
        results.push(o);
    }
    root.set("results", Value::Arr(results));
    for v in Variant::ALL {
        if let (Some(s), Some(p)) = (median_of(Engine::Sequential, v), median_of(Engine::Parallel, v))
        {
            root.set(
                &format!("speedup_{}", v.name().replace(' ', "_")),
                Value::Num(s / p),
            );
            println!("{}: parallel speedup over sequential = {:.2}x", v.name(), s / p);
        }
    }
    if !entries.is_empty() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json");
        match std::fs::write(path, root.pretty()) {
            Ok(()) => println!("[engine medians saved to {path}]"),
            Err(e) => eprintln!("warning: cannot write {path}: {e}"),
        }
    }

    b.finish();
}
