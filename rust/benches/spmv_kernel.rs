//! Criterion-lite bench: the native SpMV hot path (L3's per-block kernel).
//!
//! The §Perf target (EXPERIMENTS.md): sustain ≥ 60 % of the host-STREAM
//! roofline for the eq. (6) traffic formula (216 B/row at r_nz = 16).

use upcsim::benchlib::{BenchConfig, Bencher};
use upcsim::matrix::Ellpack;
use upcsim::mesh::{TetGridSpec, TetMesh};
use upcsim::microbench;
use upcsim::spmv::{spmv_block_gathered, spmv_parallel};
use upcsim::util::fmt;

fn main() {
    let mut b = Bencher::from_args(BenchConfig::default());

    // Host roofline anchor.
    let stream = microbench::stream_host(1 << 21);
    println!("host STREAM triad: {}\n", fmt::rate(stream.bandwidth()));

    let mesh = TetMesh::generate(&TetGridSpec::ventricle(400_000, 7));
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let x: Vec<f64> = m.initial_vector(3);
    let mut y = vec![0.0f64; m.n];

    // Whole-matrix pass: n rows × 216 B of eq.(6) traffic.
    let bytes = m.n as f64 * m.d_min_comp_bytes();
    b.bench_bytes("spmv/native/full-pass", bytes, || {
        spmv_block_gathered(0, &m.diag, &m.a, &m.j, m.r_nz, &x, &mut y);
        std::hint::black_box(&y);
    });

    // Block-tiled pass (the shape the executors drive): 4096-row blocks.
    let bs = 4096;
    b.bench_bytes("spmv/native/4096-blocks", bytes, || {
        let mut off = 0;
        while off < m.n {
            let len = (m.n - off).min(bs);
            spmv_block_gathered(
                off,
                &m.diag[off..off + len],
                &m.a[off * 16..(off + len) * 16],
                &m.j[off * 16..(off + len) * 16],
                16,
                &x,
                &mut y[off..off + len],
            );
            off += len;
        }
        std::hint::black_box(&y);
    });

    // Host-parallel pass — the like-for-like comparison against the
    // all-core STREAM roofline.
    b.bench_bytes("spmv/native/parallel", bytes, || {
        spmv_parallel(&m.diag, &m.a, &m.j, m.r_nz, &x, &mut y);
        std::hint::black_box(&y);
    });

    // Sequential oracle (Listing 1) for reference.
    b.bench_bytes("spmv/listing1-oracle", bytes, || {
        m.spmv_seq(&x, &mut y);
        std::hint::black_box(&y);
    });

    if let Some(r) = b.results().iter().find(|r| r.name.contains("parallel")) {
        let frac = r.bandwidth().unwrap() / stream.bandwidth();
        println!(
            "\nparallel kernel sustains {:.1}% of host STREAM roofline (target ≥ 60%)",
            frac * 100.0
        );
    }
    b.finish();
}
