//! Criterion-lite bench: per-step halo-exchange cost of the grid workloads
//! on the unified exchange runtime, plus the spawn-per-step → persistent
//! pool comparison and the synchronous → split-phase-overlap comparison.
//!
//! Emits `BENCH_halo.json` at the repo root:
//!
//! * per-step medians for heat-2D and the 3D stencil on both engines;
//! * a legacy heat-2D step (the seed implementation: per-step `Vec` strip
//!   allocations + one `std::thread::scope` spawn per step) vs the
//!   pool-based solver — `speedup_pool_vs_spawn` is the headline number;
//! * the raw dispatch microbenchmark: `thread::scope` spawn/join of N no-op
//!   workers vs one no-op pool dispatch at the same width.
//!
//! And `BENCH_overlap.json`:
//!
//! * sync vs split-phase-overlapped per-step medians for heat-2D (several
//!   thread layouts), the 3D stencil, and SpMV V3 on the parallel engine,
//!   with per-layout `speedup` ratios and the best ratio as the headline.
//!
//! And `BENCH_pipeline.json`:
//!
//! * sync vs overlapped vs multi-step-pipelined per-step medians on the
//!   same workloads/layouts — the pipelined value amortizes one 8-step
//!   batch dispatch (the consumed-epoch ack protocol) over its steps, with
//!   per-layout speedups vs both single-step protocols.
//!
//! And `BENCH_chaos.json`:
//!
//! * the cost of the deadline-aware wait ladder: heat-2D pipelined per-step
//!   median with the default wait deadline armed vs deadlines disabled
//!   (infinite waits, the pre-fault-tolerance behaviour), with the
//!   `overhead_pct` headline against a 3% budget.

use upcsim::benchlib::{BenchConfig, Bencher};
use upcsim::comm::Analysis;
use upcsim::engine::{Engine, SpmvEngine, WorkerPool};
use upcsim::heat2d::Heat2dSolver;
use upcsim::matrix::Ellpack;
use upcsim::model::HeatGrid;
use upcsim::pgas::{Layout, Topology};
use upcsim::spmv::{SpmvState, Variant};
use upcsim::stencil3d::{Stencil3dGrid, Stencil3dSolver};
use upcsim::util::json::Value;
use upcsim::util::Rng;

/// The seed implementation of the parallel heat-2D step: stage every
/// boundary strip into freshly allocated `Vec`s, then spawn one scoped OS
/// thread per grid thread — per step. Kept here as the bench baseline the
/// persistent runtime is measured against.
struct LegacySpawnHeat2d {
    grid: HeatGrid,
    phi: Vec<Vec<f64>>,
    phin: Vec<Vec<f64>>,
}

impl LegacySpawnHeat2d {
    fn new(grid: HeatGrid, global: &[f64]) -> LegacySpawnHeat2d {
        let (m, n) = grid.subdomain();
        let mut phi = Vec::with_capacity(grid.threads());
        for t in 0..grid.threads() {
            let (ip, kp) = grid.coords(t);
            let (row0, col0) = (ip * (m - 2), kp * (n - 2));
            let mut field = vec![0.0f64; m * n];
            for i in 0..m {
                for k in 0..n {
                    let gi = row0 as isize + i as isize - 1;
                    let gk = col0 as isize + k as isize - 1;
                    if gi >= 0
                        && (gi as usize) < grid.m_glob
                        && gk >= 0
                        && (gk as usize) < grid.n_glob
                    {
                        field[i * n + k] = global[gi as usize * grid.n_glob + gk as usize];
                    }
                }
            }
            phi.push(field);
        }
        let phin = phi.clone();
        LegacySpawnHeat2d { grid, phi, phin }
    }

    fn step(&mut self) {
        let grid = self.grid;
        let (m, n) = grid.subdomain();
        struct Strips {
            col_first: Vec<f64>,
            col_last: Vec<f64>,
            row_first: Vec<f64>,
            row_last: Vec<f64>,
        }
        let strips: Vec<Strips> = (0..grid.threads())
            .map(|t| {
                let phi = &self.phi[t];
                Strips {
                    col_first: (1..m - 1).map(|i| phi[i * n + 1]).collect(),
                    col_last: (1..m - 1).map(|i| phi[i * n + n - 2]).collect(),
                    row_first: phi[n + 1..n + n - 1].to_vec(),
                    row_last: phi[(m - 2) * n + 1..(m - 2) * n + n - 1].to_vec(),
                }
            })
            .collect();
        let strips = &strips;
        std::thread::scope(|s| {
            for (t, (phi, phin)) in
                self.phi.iter_mut().zip(self.phin.iter_mut()).enumerate()
            {
                s.spawn(move || {
                    let (ip, kp) = grid.coords(t);
                    if kp > 0 {
                        let src = &strips[grid.rank(ip, kp - 1)].col_last;
                        for (i, v) in src.iter().enumerate() {
                            phi[(i + 1) * n] = *v;
                        }
                    }
                    if kp < grid.nprocs - 1 {
                        let src = &strips[grid.rank(ip, kp + 1)].col_first;
                        for (i, v) in src.iter().enumerate() {
                            phi[(i + 1) * n + n - 1] = *v;
                        }
                    }
                    if ip > 0 {
                        let src = &strips[grid.rank(ip - 1, kp)].row_last;
                        phi[1..n - 1].copy_from_slice(src);
                    }
                    if ip < grid.mprocs - 1 {
                        let src = &strips[grid.rank(ip + 1, kp)].row_first;
                        phi[(m - 1) * n + 1..(m - 1) * n + n - 1].copy_from_slice(src);
                    }
                    // The 5-point Jacobi update + fixed-boundary copy-through.
                    for i in 1..m - 1 {
                        for k in 1..n - 1 {
                            phin[i * n + k] = 0.25
                                * (phi[(i - 1) * n + k]
                                    + phi[(i + 1) * n + k]
                                    + phi[i * n + k - 1]
                                    + phi[i * n + k + 1]);
                        }
                    }
                    if ip == 0 {
                        for k in 0..n {
                            phin[n + k] = phi[n + k];
                        }
                    }
                    if ip == grid.mprocs - 1 {
                        for k in 0..n {
                            phin[(m - 2) * n + k] = phi[(m - 2) * n + k];
                        }
                    }
                    if kp == 0 {
                        for i in 0..m {
                            phin[i * n + 1] = phi[i * n + 1];
                        }
                    }
                    if kp == grid.nprocs - 1 {
                        for i in 0..m {
                            phin[i * n + n - 2] = phi[i * n + n - 2];
                        }
                    }
                });
            }
        });
        std::mem::swap(&mut self.phi, &mut self.phin);
    }
}

fn main() {
    let mut b = Bencher::from_args(BenchConfig::default());
    let mut entries: Vec<(String, f64)> = Vec::new();
    let record = |entries: &mut Vec<(String, f64)>, name: &str, p50: Option<f64>| {
        if let Some(p50) = p50 {
            entries.push((name.to_string(), p50));
        }
    };

    // --- heat-2D: per-step medians on both engines + the legacy baseline --
    let (mg, ng, mp, np) = (384usize, 384usize, 2usize, 2usize);
    let grid = HeatGrid::new(mg, ng, mp, np);
    let mut rng = Rng::new(42);
    let f0: Vec<f64> = (0..mg * ng).map(|_| rng.f64_in(0.0, 100.0)).collect();

    for engine in Engine::ALL {
        let mut solver = Heat2dSolver::new(grid, &f0);
        solver.step_with(engine); // warmup: compiles nothing, spawns the pool
        let name = format!("heat2d/{}/{}x{}", engine.name(), mg, ng);
        let r = b.bench(&name, || {
            solver.step_with(engine);
            std::hint::black_box(&solver.inter_thread_bytes);
        });
        record(&mut entries, &name, r.map(|r| r.time.p50));
    }
    {
        let mut legacy = LegacySpawnHeat2d::new(grid, &f0);
        legacy.step();
        let name = format!("heat2d/spawn-per-step/{mg}x{ng}");
        let r = b.bench(&name, || {
            legacy.step();
            std::hint::black_box(&legacy.phi);
        });
        record(&mut entries, &name, r.map(|r| r.time.p50));
        // Sanity: the legacy baseline and the runtime solver agree bitwise.
        let mut a = LegacySpawnHeat2d::new(grid, &f0);
        let mut c = Heat2dSolver::new(grid, &f0);
        for _ in 0..3 {
            a.step();
            c.step_with(Engine::Parallel);
        }
        let ga = {
            let (m, n) = grid.subdomain();
            let mut out = vec![0.0f64; mg * ng];
            for t in 0..grid.threads() {
                let (ip, kp) = grid.coords(t);
                let (row0, col0) = (ip * (m - 2), kp * (n - 2));
                for i in 1..m - 1 {
                    for k in 1..n - 1 {
                        out[(row0 + i - 1) * ng + (col0 + k - 1)] = a.phi[t][i * n + k];
                    }
                }
            }
            out
        };
        let gc = c.to_global();
        assert!(
            ga.iter().zip(&gc).all(|(x, y)| x.to_bits() == y.to_bits()),
            "legacy and runtime heat2d solvers diverged"
        );
    }

    // --- 3D stencil: per-step medians on both engines ---------------------
    let (pg3, mg3, ng3) = (48usize, 48usize, 48usize);
    let grid3 = Stencil3dGrid::new(pg3, mg3, ng3, 1, 2, 2);
    let f03: Vec<f64> = (0..pg3 * mg3 * ng3).map(|_| rng.f64_in(0.0, 100.0)).collect();
    for engine in Engine::ALL {
        let mut solver = Stencil3dSolver::new(grid3, &f03);
        solver.step_with(engine);
        let name = format!("stencil3d/{}/{}^3", engine.name(), pg3);
        let r = b.bench(&name, || {
            solver.step_with(engine);
            std::hint::black_box(&solver.inter_thread_bytes);
        });
        record(&mut entries, &name, r.map(|r| r.time.p50));
    }

    // --- split-phase overlap + multi-step pipeline vs sync ----------------
    // One (sync, overlap, pipeline) median triple per workload/layout;
    // layouts exercise row-only, column-only and mixed halo shapes. The
    // pipelined column times one PIPE-step batch (a single pool dispatch)
    // and reports it per step.
    const PIPE: usize = 8;
    let mut overlap_pairs: Vec<(String, f64, f64)> = Vec::new();
    let mut pipeline_rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for &(mp, np) in &[(2usize, 2usize), (1, 4), (4, 1)] {
        let grid = HeatGrid::new(mg, ng, mp, np);
        let mut sync = Heat2dSolver::new(grid, &f0);
        sync.step_with(Engine::Parallel);
        let sync_name = format!("heat2d/sync/{mp}x{np}");
        let rs = b
            .bench(&sync_name, || {
                sync.step_with(Engine::Parallel);
                std::hint::black_box(&sync.inter_thread_bytes);
            })
            .map(|r| r.time.p50);
        let mut ovl = Heat2dSolver::new(grid, &f0);
        ovl.step_overlapped_with(Engine::Parallel);
        let ovl_name = format!("heat2d/overlap/{mp}x{np}");
        let ro = b
            .bench(&ovl_name, || {
                ovl.step_overlapped_with(Engine::Parallel);
                std::hint::black_box(&ovl.inter_thread_bytes);
            })
            .map(|r| r.time.p50);
        let mut pipe = Heat2dSolver::new(grid, &f0);
        pipe.run_pipelined_with(Engine::Parallel, PIPE);
        let pipe_name = format!("heat2d/pipeline/{mp}x{np}");
        let rp = b
            .bench(&pipe_name, || {
                pipe.run_pipelined_with(Engine::Parallel, PIPE);
                std::hint::black_box(&pipe.inter_thread_bytes);
            })
            .map(|r| r.time.p50 / PIPE as f64);
        if let (Some(rs), Some(ro)) = (rs, ro) {
            overlap_pairs.push((format!("heat2d/{mp}x{np}"), rs, ro));
            if let Some(rp) = rp {
                pipeline_rows.push((format!("heat2d/{mp}x{np}"), rs, ro, rp));
            }
        }
    }
    {
        let mut sync = Stencil3dSolver::new(grid3, &f03);
        sync.step_with(Engine::Parallel);
        let rs = b
            .bench("stencil3d/sync/1x2x2", || {
                sync.step_with(Engine::Parallel);
                std::hint::black_box(&sync.inter_thread_bytes);
            })
            .map(|r| r.time.p50);
        let mut ovl = Stencil3dSolver::new(grid3, &f03);
        ovl.step_overlapped_with(Engine::Parallel);
        let ro = b
            .bench("stencil3d/overlap/1x2x2", || {
                ovl.step_overlapped_with(Engine::Parallel);
                std::hint::black_box(&ovl.inter_thread_bytes);
            })
            .map(|r| r.time.p50);
        let mut pipe = Stencil3dSolver::new(grid3, &f03);
        pipe.run_pipelined_with(Engine::Parallel, PIPE);
        let rp = b
            .bench("stencil3d/pipeline/1x2x2", || {
                pipe.run_pipelined_with(Engine::Parallel, PIPE);
                std::hint::black_box(&pipe.inter_thread_bytes);
            })
            .map(|r| r.time.p50 / PIPE as f64);
        if let (Some(rs), Some(ro)) = (rs, ro) {
            overlap_pairs.push(("stencil3d/1x2x2".to_string(), rs, ro));
            if let Some(rp) = rp {
                pipeline_rows.push(("stencil3d/1x2x2".to_string(), rs, ro, rp));
            }
        }
    }
    {
        // SpMV V3: synchronous barrier step vs the split-phase overlapped
        // step vs the pipelined batch on the same compiled plan.
        let threads = 4usize;
        let m = Ellpack::random(20_000, 16, 3);
        let bs = m.n.div_ceil(threads * 4);
        let layout = Layout::new(m.n, bs, threads);
        let analysis =
            Analysis::build(&m.j, m.r_nz, layout, Topology::single_node(threads), usize::MAX);
        let x0 = m.initial_vector(9);
        let mut engine = SpmvEngine::new(Engine::Parallel);
        let mut state = SpmvState::new(&m, bs, threads, &x0);
        engine.run(Variant::V3, &mut state, Some(&analysis));
        state.swap_xy();
        let rs = b
            .bench("spmv-v3/sync/4t", || {
                engine.run(Variant::V3, &mut state, Some(&analysis));
                state.swap_xy();
            })
            .map(|r| r.time.p50);
        let mut engine = SpmvEngine::new(Engine::Parallel);
        let mut state = SpmvState::new(&m, bs, threads, &x0);
        engine.run_overlapped(&mut state, &analysis);
        state.swap_xy();
        let ro = b
            .bench("spmv-v3/overlap/4t", || {
                engine.run_overlapped(&mut state, &analysis);
                state.swap_xy();
            })
            .map(|r| r.time.p50);
        let mut engine = SpmvEngine::new(Engine::Parallel);
        let mut state = SpmvState::new(&m, bs, threads, &x0);
        engine.run_pipelined(PIPE, &mut state, &analysis);
        state.swap_xy();
        let rp = b
            .bench("spmv-v3/pipeline/4t", || {
                engine.run_pipelined(PIPE, &mut state, &analysis);
                state.swap_xy();
            })
            .map(|r| r.time.p50 / PIPE as f64);
        if let (Some(rs), Some(ro)) = (rs, ro) {
            overlap_pairs.push(("spmv-v3/4t".to_string(), rs, ro));
            if let Some(rp) = rp {
                pipeline_rows.push(("spmv-v3/4t".to_string(), rs, ro, rp));
            }
        }
    }

    // --- dispatch overhead: thread::scope spawn vs pool wakeup ------------
    let workers = grid.threads();
    {
        let name = format!("dispatch/scope-spawn/{workers}");
        let r = b.bench(&name, || {
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| std::hint::black_box(0u64));
                }
            });
        });
        record(&mut entries, &name, r.map(|r| r.time.p50));
        let mut pool = WorkerPool::new();
        pool.run(workers, &|_| {});
        let name = format!("dispatch/pool/{workers}");
        let r = b.bench(&name, || {
            pool.run(workers, &|ctx| {
                std::hint::black_box(ctx.id);
            });
        });
        record(&mut entries, &name, r.map(|r| r.time.p50));
    }

    // --- BENCH_halo.json --------------------------------------------------
    let median_of = |needle: &str| {
        entries.iter().find(|(n, _)| n.starts_with(needle)).map(|&(_, p50)| p50)
    };
    let mut root = Value::obj();
    root.set("bench", Value::Str("halo_exchange".to_string()));
    root.set("heat2d_grid", Value::Str(format!("{mg}x{ng} over {mp}x{np}")));
    root.set("stencil3d_grid", Value::Str(format!("{pg3}x{mg3}x{ng3} over 1x2x2")));
    let mut results = Vec::new();
    for (name, p50) in &entries {
        let mut o = Value::obj();
        o.set("name", Value::Str(name.clone()));
        o.set("median_ns_per_step", Value::Num((p50 * 1e9).round()));
        results.push(o);
    }
    root.set("results", Value::Arr(results));
    if let (Some(spawn), Some(pool)) =
        (median_of("heat2d/spawn-per-step"), median_of("heat2d/parallel"))
    {
        root.set("speedup_pool_vs_spawn", Value::Num(spawn / pool));
        println!("\nheat2d: persistent pool vs spawn-per-step = {:.2}x", spawn / pool);
    }
    if let (Some(spawn), Some(pool)) =
        (median_of("dispatch/scope-spawn"), median_of("dispatch/pool"))
    {
        root.set("speedup_dispatch", Value::Num(spawn / pool));
        println!("dispatch: pool wakeup vs scope spawn = {:.2}x", spawn / pool);
    }
    if !entries.is_empty() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_halo.json");
        upcsim::benchlib::save_bench_json(path, "halo medians", &root);
    }

    // --- BENCH_overlap.json -----------------------------------------------
    if !overlap_pairs.is_empty() {
        let mut root = Value::obj();
        root.set("bench", Value::Str("halo_exchange/overlap".to_string()));
        root.set("engine", Value::Str("parallel".to_string()));
        let mut results = Vec::new();
        let mut best = f64::NEG_INFINITY;
        let mut best_name = String::new();
        println!();
        for (name, sync, ovl) in &overlap_pairs {
            let speedup = sync / ovl;
            let mut o = Value::obj();
            o.set("workload", Value::Str(name.clone()));
            o.set("sync_median_ns_per_step", Value::Num((sync * 1e9).round()));
            o.set("overlap_median_ns_per_step", Value::Num((ovl * 1e9).round()));
            o.set("speedup_overlap_vs_sync", Value::Num(speedup));
            results.push(o);
            println!("{name}: overlapped vs sync = {speedup:.2}x");
            if speedup > best {
                best = speedup;
                best_name = name.clone();
            }
        }
        root.set("results", Value::Arr(results));
        root.set("best_speedup", Value::Num(best));
        root.set("best_workload", Value::Str(best_name));
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_overlap.json");
        upcsim::benchlib::save_bench_json(path, "overlap medians", &root);
    }

    // --- BENCH_pipeline.json ----------------------------------------------
    // Sync vs overlapped vs pipelined per-step medians; the pipelined value
    // amortizes one PIPE-step dispatch over its steps.
    if !pipeline_rows.is_empty() {
        let mut root = Value::obj();
        root.set("bench", Value::Str("halo_exchange/pipeline".to_string()));
        root.set("engine", Value::Str("parallel".to_string()));
        root.set("pipeline_steps", Value::Num(PIPE as f64));
        let mut results = Vec::new();
        let mut best = f64::NEG_INFINITY;
        let mut best_name = String::new();
        println!();
        for (name, sync, ovl, pipe) in &pipeline_rows {
            let vs_sync = sync / pipe;
            let vs_ovl = ovl / pipe;
            let mut o = Value::obj();
            o.set("workload", Value::Str(name.clone()));
            o.set("sync_median_ns_per_step", Value::Num((sync * 1e9).round()));
            o.set("overlap_median_ns_per_step", Value::Num((ovl * 1e9).round()));
            o.set("pipeline_median_ns_per_step", Value::Num((pipe * 1e9).round()));
            o.set("speedup_pipeline_vs_sync", Value::Num(vs_sync));
            o.set("speedup_pipeline_vs_overlap", Value::Num(vs_ovl));
            results.push(o);
            println!(
                "{name}: pipelined vs sync = {vs_sync:.2}x, vs overlapped = {vs_ovl:.2}x"
            );
            if vs_ovl > best {
                best = vs_ovl;
                best_name = name.clone();
            }
        }
        root.set("results", Value::Arr(results));
        root.set("best_speedup_vs_overlap", Value::Num(best));
        root.set("best_workload", Value::Str(best_name));
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_pipeline.json");
        upcsim::benchlib::save_bench_json(path, "pipeline medians", &root);
    }
    // --- BENCH_chaos.json -------------------------------------------------
    // What the deadline-aware wait ladder costs on the fault-free fast
    // path: the same pipelined heat-2D batch with the default deadline
    // armed vs deadlines disabled (infinite waits). Budget: <= 3%.
    {
        let mut armed = Heat2dSolver::new(grid, &f0);
        armed.run_pipelined_with(Engine::Parallel, PIPE);
        let ra = b
            .bench("heat2d/pipeline-deadline/2x2", || {
                armed.run_pipelined_with(Engine::Parallel, PIPE);
                std::hint::black_box(&armed.inter_thread_bytes);
            })
            .map(|r| r.time.p50 / PIPE as f64);
        let mut bare = Heat2dSolver::new(grid, &f0);
        bare.runtime_mut().set_wait_deadline(None);
        bare.run_pipelined_with(Engine::Parallel, PIPE);
        let rb = b
            .bench("heat2d/pipeline-no-deadline/2x2", || {
                bare.run_pipelined_with(Engine::Parallel, PIPE);
                std::hint::black_box(&bare.inter_thread_bytes);
            })
            .map(|r| r.time.p50 / PIPE as f64);
        if let (Some(with_deadline), Some(without)) = (ra, rb) {
            let overhead_pct = (with_deadline / without - 1.0) * 100.0;
            let mut root = Value::obj();
            root.set("bench", Value::Str("halo_exchange/chaos".to_string()));
            root.set("workload", Value::Str(format!("heat2d/pipeline/{mg}x{ng} over 2x2")));
            root.set("pipeline_steps", Value::Num(PIPE as f64));
            root.set(
                "deadline_median_ns_per_step",
                Value::Num((with_deadline * 1e9).round()),
            );
            root.set("no_deadline_median_ns_per_step", Value::Num((without * 1e9).round()));
            root.set("overhead_pct", Value::Num(overhead_pct));
            root.set("overhead_budget_pct", Value::Num(3.0));
            println!("\nheat2d: deadline-aware waits overhead = {overhead_pct:.2}%");
            let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_chaos.json");
            upcsim::benchlib::save_bench_json(path, "chaos overhead", &root);
        }
    }
    b.finish();
}
