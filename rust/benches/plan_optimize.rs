//! Criterion-lite bench: the plan-optimizer compile pass (condensing a raw
//! gather, consolidating a raw strided plan) and the per-step win its
//! output buys on the executed SpMV V3 data path. §Perf target: optimizing
//! stays a one-time preparation cost — orders of magnitude under the step
//! time it saves.

use upcsim::benchlib::{BenchConfig, Bencher};
use upcsim::comm::{Analysis, CommPlan, ExchangePlan, PlanDelta, PlanOptimizer, PlanStats};
use upcsim::engine::{Engine, SpmvEngine};
use upcsim::matrix::Ellpack;
use upcsim::pgas::{Layout, Topology};
use upcsim::spmv::{SpmvState, Variant};
use upcsim::transport::{PlanMode, WorkloadSpec};

/// Dense synthetic gather needs: every thread pulls `vals_per_pair` values
/// from every other thread (`threads·(threads−1)` pairs), with `salt`
/// perturbing the index choice so two calls can differ in selected pairs.
fn dense_needs(threads: usize, bs: usize, vals_per_pair: usize, salt: &[usize]) -> ExchangePlan {
    let mut recv: Vec<Vec<(u32, u32)>> = Vec::with_capacity(threads);
    for t in 0..threads {
        let mut list = Vec::new();
        for s in 0..threads {
            if s == t {
                continue;
            }
            let pair = t * threads + s;
            let shift = if salt.contains(&pair) { 1 } else { 0 };
            for k in 0..vals_per_pair {
                list.push((s as u32, (s * bs + 2 * k + shift) as u32));
            }
        }
        list.sort_unstable();
        recv.push(list);
    }
    let layout = Layout::new(threads * bs, bs, threads);
    CommPlan::from_recv_needs(&layout, &recv).into()
}

/// Median seconds over `iters` timed calls.
fn median_secs(iters: usize, mut f: impl FnMut()) -> f64 {
    let mut t = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        t.push(t0.elapsed().as_secs_f64());
    }
    t.sort_by(f64::total_cmp);
    t[t.len() / 2]
}

fn main() {
    let mut b = Bencher::from_args(BenchConfig::heavy());
    let procs = 8;
    let spec = WorkloadSpec::for_name("spmv", procs).unwrap();
    let WorkloadSpec::Spmv(p) = spec else {
        unreachable!()
    };
    let raw_gather = spec.plan_with(PlanMode::Raw);
    let stencil_spec = WorkloadSpec::for_name("stencil", procs).unwrap();
    let raw_strided = stencil_spec.plan_with(PlanMode::Raw);
    let opt = PlanOptimizer::default();

    let before = PlanStats::of(&raw_gather);
    let after = PlanStats::of(&opt.optimize(&raw_gather));
    println!(
        "spmv raw -> optimized: {} -> {} msgs, {} -> {} values, {} -> {} arena bytes",
        before.messages,
        after.messages,
        before.values,
        after.values,
        before.index_arena_bytes,
        after.index_arena_bytes
    );

    // The compile pass itself, throughput in plan values processed.
    b.bench_items("optimize/spmv-raw-gather", before.values as f64, || {
        let plan = opt.optimize(&raw_gather);
        std::hint::black_box(&plan);
    });
    b.bench_items(
        "optimize/stencil-raw-strided",
        PlanStats::of(&raw_strided).values as f64,
        || {
            let plan = opt.optimize(&raw_strided);
            std::hint::black_box(&plan);
        },
    );

    // The executed V3 step under each plan variant — the consumer of the
    // pass above, where condensing turns into wall-clock.
    let nnz = (p.n * p.r_nz) as f64;
    for mode in [PlanMode::Raw, PlanMode::Optimized] {
        let m = Ellpack::random(p.n, p.r_nz, p.mat_seed);
        let x0 = m.initial_vector(p.x_seed);
        let mut state = SpmvState::new(&m, p.block, p.procs, &x0);
        let mut analysis = Analysis::build(
            &m.j,
            m.r_nz,
            state.layout,
            Topology::single_node(p.procs),
            usize::MAX,
        );
        analysis.plan = spec
            .plan_with(mode)
            .as_gather()
            .expect("spmv runs a gather plan")
            .clone();
        let mut engine = SpmvEngine::new(Engine::Sequential);
        b.bench_items(&format!("spmv-step/{}", mode.name()), nnz, || {
            let out = engine.run(Variant::V3, &mut state, Some(&analysis));
            std::hint::black_box(&out);
            state.swap_xy();
        });
    }

    // Incremental recompilation: patching ~1% of the (receiver, sender)
    // pairs of a dense 32-thread gather plan must stay well under a full
    // compile — the premise of the versioned plan lifecycle. §Perf target:
    // apply_delta on a 1% patch < 10% of the from-scratch compile.
    let threads = 32;
    let (bs, vals) = (64, 16);
    let old_plan = dense_needs(threads, bs, vals, &[]);
    let total_pairs = threads * (threads - 1);
    let salt: Vec<usize> =
        (0..total_pairs / 100).map(|i| (i * 37 + 1) % (threads * threads)).collect();
    let new_plan = dense_needs(threads, bs, vals, &salt);
    let delta = PlanDelta::diff(&old_plan, &new_plan).expect("diffable generations");
    println!(
        "delta: {} dirty of {} pairs ({:.1}%), {} patch values",
        delta.dirty_pairs(),
        total_pairs,
        100.0 * delta.dirty_pairs() as f64 / total_pairs as f64,
        delta.patch_values(),
    );
    assert!(
        old_plan.apply_delta(&delta).expect("applies").fingerprint() == new_plan.fingerprint(),
        "patched plan must be fingerprint-identical to the from-scratch compile"
    );
    b.bench_items("plan-lifecycle/full-compile", total_pairs as f64, || {
        let plan = dense_needs(threads, bs, vals, &salt);
        std::hint::black_box(&plan);
    });
    b.bench_items("plan-lifecycle/apply-delta-1pct", delta.dirty_pairs() as f64, || {
        let plan = old_plan.apply_delta(&delta).expect("applies");
        std::hint::black_box(&plan);
    });
    let t_full = median_secs(40, || {
        std::hint::black_box(&dense_needs(threads, bs, vals, &salt));
    });
    let t_patch = median_secs(40, || {
        std::hint::black_box(&old_plan.apply_delta(&delta).expect("applies"));
    });
    println!(
        "1% patch: {:.3e} s vs full compile {:.3e} s ({:.1}% of full)",
        t_patch,
        t_full,
        100.0 * t_patch / t_full
    );
    assert!(
        t_patch < 0.1 * t_full,
        "apply_delta on a 1% patch took {t_patch:.3e} s, >= 10% of the {t_full:.3e} s full compile"
    );

    b.finish();
}
