//! Criterion-lite bench: the plan-optimizer compile pass (condensing a raw
//! gather, consolidating a raw strided plan) and the per-step win its
//! output buys on the executed SpMV V3 data path. §Perf target: optimizing
//! stays a one-time preparation cost — orders of magnitude under the step
//! time it saves.

use upcsim::benchlib::{BenchConfig, Bencher};
use upcsim::comm::{Analysis, PlanOptimizer, PlanStats};
use upcsim::engine::{Engine, SpmvEngine};
use upcsim::matrix::Ellpack;
use upcsim::pgas::Topology;
use upcsim::spmv::{SpmvState, Variant};
use upcsim::transport::{PlanMode, WorkloadSpec};

fn main() {
    let mut b = Bencher::from_args(BenchConfig::heavy());
    let procs = 8;
    let spec = WorkloadSpec::for_name("spmv", procs).unwrap();
    let WorkloadSpec::Spmv(p) = spec else {
        unreachable!()
    };
    let raw_gather = spec.plan_with(PlanMode::Raw);
    let stencil_spec = WorkloadSpec::for_name("stencil", procs).unwrap();
    let raw_strided = stencil_spec.plan_with(PlanMode::Raw);
    let opt = PlanOptimizer::default();

    let before = PlanStats::of(&raw_gather);
    let after = PlanStats::of(&opt.optimize(&raw_gather));
    println!(
        "spmv raw -> optimized: {} -> {} msgs, {} -> {} values, {} -> {} arena bytes",
        before.messages,
        after.messages,
        before.values,
        after.values,
        before.index_arena_bytes,
        after.index_arena_bytes
    );

    // The compile pass itself, throughput in plan values processed.
    b.bench_items("optimize/spmv-raw-gather", before.values as f64, || {
        let plan = opt.optimize(&raw_gather);
        std::hint::black_box(&plan);
    });
    b.bench_items(
        "optimize/stencil-raw-strided",
        PlanStats::of(&raw_strided).values as f64,
        || {
            let plan = opt.optimize(&raw_strided);
            std::hint::black_box(&plan);
        },
    );

    // The executed V3 step under each plan variant — the consumer of the
    // pass above, where condensing turns into wall-clock.
    let nnz = (p.n * p.r_nz) as f64;
    for mode in [PlanMode::Raw, PlanMode::Optimized] {
        let m = Ellpack::random(p.n, p.r_nz, p.mat_seed);
        let x0 = m.initial_vector(p.x_seed);
        let mut state = SpmvState::new(&m, p.block, p.procs, &x0);
        let mut analysis = Analysis::build(
            &m.j,
            m.r_nz,
            state.layout,
            Topology::single_node(p.procs),
            usize::MAX,
        );
        analysis.plan = spec
            .plan_with(mode)
            .as_gather()
            .expect("spmv runs a gather plan")
            .clone();
        let mut engine = SpmvEngine::new(Engine::Sequential);
        b.bench_items(&format!("spmv-step/{}", mode.name()), nnz, || {
            let out = engine.run(Variant::V3, &mut state, Some(&analysis));
            std::hint::black_box(&out);
            state.swap_xy();
        });
    }
    b.finish();
}
