//! Criterion-lite bench: the communication-traffic analyzer (the paper's
//! "one-time preparation step"). §Perf target: > 100 M nnz/s.

use upcsim::benchlib::{BenchConfig, Bencher};
use upcsim::comm::Analysis;
use upcsim::engine::{Engine, SpmvEngine};
use upcsim::matrix::Ellpack;
use upcsim::mesh::{TetGridSpec, TetMesh};
use upcsim::pgas::{Layout, Topology};
use upcsim::sim::DEFAULT_CACHE_WINDOW;
use upcsim::spmv::{SpmvState, Variant};

fn main() {
    let mut b = Bencher::from_args(BenchConfig::heavy());
    let mesh = TetMesh::generate(&TetGridSpec::ventricle(400_000, 7));
    let m = Ellpack::diffusion_from_mesh(&mesh);
    let nnz = (m.n * m.r_nz) as f64;

    for &(nodes, tpn, bs) in &[(1usize, 16usize, 4096usize), (4, 16, 4096), (64, 16, 416)] {
        let layout = Layout::new(m.n, bs, nodes * tpn);
        let topo = Topology::new(nodes, tpn);
        b.bench_items(
            &format!("analysis/{}x{}threads/bs{}", nodes, tpn, bs),
            nnz,
            || {
                let a = Analysis::build(&m.j, m.r_nz, layout, topo, DEFAULT_CACHE_WINDOW);
                std::hint::black_box(&a);
            },
        );
    }

    // The executed V3 data path (pack → put → barrier → unpack + compute)
    // on both engines — the consumer of the compiled plan built above.
    let layout = Layout::new(m.n, 4096, 16);
    let topo = Topology::new(1, 16);
    let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, DEFAULT_CACHE_WINDOW);
    let x0 = m.initial_vector(9);
    for engine in Engine::ALL {
        let mut eng = SpmvEngine::new(engine);
        let mut state = SpmvState::new(&m, 4096, 16, &x0);
        b.bench_items(&format!("exec-v3/{}", engine.name()), nnz, || {
            let out = eng.run(Variant::V3, &mut state, Some(&analysis));
            std::hint::black_box(&out);
        });
    }
    b.finish();
}
