//! Criterion-lite bench: the kernel tier vs its scalar references, fused
//! boundary compute vs the separate unpack + boundary sweeps, and the
//! depth-D pipeline sweep.
//!
//! Emits `BENCH_simd.json` at the repo root:
//!
//! * indexed gather (pack), indexed scatter (unpack) and contiguous block
//!   copy medians, tuned kernel vs the scalar element loop the runtimes
//!   used before the kernel tier — `speedup_pack` / `speedup_unpack` are
//!   the headline numbers the CI gate checks against `speedup_target`;
//! * a fused heat-2D step ([`Heat2dSolver::step_fused`]) vs the plain
//!   split-phase step on the sequential engine;
//! * heat-2D pipelined per-step medians at buffer depth D ∈ {1..4}
//!   (parallel engine, one 8-step batch per sample).
//!
//! The index list mirrors the `repro calibrate` pack probe: shuffled
//! within 64-element windows, monotone across windows — irregular like a
//! compiled halo plan, not a pure stream. Build with `--features simd` to
//! widen the kernels' unroll from 4 to 8 lanes; the JSON records which
//! shape ran.

use upcsim::benchlib::{BenchConfig, Bencher};
use upcsim::engine::{kernels, Engine};
use upcsim::heat2d::Heat2dSolver;
use upcsim::model::HeatGrid;
use upcsim::util::json::Value;
use upcsim::util::Rng;

/// Window-shuffled monotone index list, same shape as
/// `microbench::pack_bandwidth_host`.
fn plan_indices(elems: usize, seed: u64) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..elems as u32).collect();
    let mut rng = Rng::new(seed);
    for window in idx.chunks_mut(64) {
        for i in (1..window.len()).rev() {
            let j = rng.usize_in(0, i);
            window.swap(i, j);
        }
    }
    idx
}

fn main() {
    let mut b = Bencher::from_args(BenchConfig::default());
    let mut entries: Vec<(String, f64)> = Vec::new();
    let record = |entries: &mut Vec<(String, f64)>, name: &str, p50: Option<f64>| {
        if let Some(p50) = p50 {
            entries.push((name.to_string(), p50));
        }
    };

    // --- gather / scatter / block copy: kernel vs scalar ------------------
    let elems = 1usize << 20;
    let idx = plan_indices(elems, 0x9AC4_BA4D);
    let src: Vec<f64> = (0..elems).map(|i| i as f64).collect();
    let mut dst = vec![0.0f64; elems];
    // One load + one store of 8 B per element, per pass.
    let pass_bytes = (elems * 16) as f64;

    // Sanity first: the tuned loops are bitwise-identical to the scalar
    // references on this very operand set.
    {
        let mut a = vec![0.0f64; elems];
        let mut c = vec![0.0f64; elems];
        kernels::pack_gather(&src, &idx, &mut a);
        kernels::pack_gather_scalar(&src, &idx, &mut c);
        assert!(a.iter().zip(&c).all(|(x, y)| x.to_bits() == y.to_bits()), "gather diverged");
        let mut a2 = vec![0.0f64; elems];
        let mut c2 = vec![0.0f64; elems];
        kernels::scatter_indexed(&mut a2, &idx, &a);
        kernels::scatter_indexed_scalar(&mut c2, &idx, &c);
        assert!(a2.iter().zip(&c2).all(|(x, y)| x.to_bits() == y.to_bits()), "scatter diverged");
    }

    for (name, scalar) in [("pack-gather/kernel", false), ("pack-gather/scalar", true)] {
        let r = b.bench_bytes(name, pass_bytes, || {
            if scalar {
                kernels::pack_gather_scalar(&src, &idx, &mut dst);
            } else {
                kernels::pack_gather(&src, &idx, &mut dst);
            }
            std::hint::black_box(&dst[elems - 1]);
        });
        record(&mut entries, name, r.map(|r| r.time.p50));
    }
    for (name, scalar) in [("unpack-scatter/kernel", false), ("unpack-scatter/scalar", true)] {
        let r = b.bench_bytes(name, pass_bytes, || {
            if scalar {
                kernels::scatter_indexed_scalar(&mut dst, &idx, &src);
            } else {
                kernels::scatter_indexed(&mut dst, &idx, &src);
            }
            std::hint::black_box(&dst[elems - 1]);
        });
        record(&mut entries, name, r.map(|r| r.time.p50));
    }
    for (name, scalar) in [("block-copy/kernel", false), ("block-copy/scalar", true)] {
        let r = b.bench_bytes(name, pass_bytes, || {
            if scalar {
                kernels::copy_block_scalar(&src, &mut dst);
            } else {
                kernels::copy_block(&src, &mut dst);
            }
            std::hint::black_box(&dst[elems - 1]);
        });
        record(&mut entries, name, r.map(|r| r.time.p50));
    }

    // --- fused boundary compute vs plain split-phase ----------------------
    let (mg, ng, mp, np) = (384usize, 384usize, 2usize, 2usize);
    let grid = HeatGrid::new(mg, ng, mp, np);
    let mut rng = Rng::new(42);
    let f0: Vec<f64> = (0..mg * ng).map(|_| rng.f64_in(0.0, 100.0)).collect();
    {
        let mut plain = Heat2dSolver::new(grid, &f0);
        plain.step_with(Engine::Sequential);
        let name = format!("heat2d/plain-seq/{mg}x{ng}");
        let r = b.bench(&name, || {
            plain.step_with(Engine::Sequential);
            std::hint::black_box(&plain.inter_thread_bytes);
        });
        record(&mut entries, &name, r.map(|r| r.time.p50));
        let mut fused = Heat2dSolver::new(grid, &f0);
        fused.step_fused();
        let name = format!("heat2d/fused-seq/{mg}x{ng}");
        let r = b.bench(&name, || {
            fused.step_fused();
            std::hint::black_box(&fused.inter_thread_bytes);
        });
        record(&mut entries, &name, r.map(|r| r.time.p50));
    }

    // --- pipelined per-step medians across buffer depths ------------------
    const PIPE: usize = 8;
    let mut depth_rows: Vec<(usize, f64)> = Vec::new();
    for depth in [1usize, 2, 3, 4] {
        let mut solver = Heat2dSolver::new(grid, &f0);
        solver.set_depth(depth);
        solver.run_pipelined_with(Engine::Parallel, PIPE);
        let name = format!("heat2d/pipeline-d{depth}/{mg}x{ng}");
        let r = b
            .bench(&name, || {
                solver.run_pipelined_with(Engine::Parallel, PIPE);
                std::hint::black_box(&solver.inter_thread_bytes);
            })
            .map(|r| r.time.p50 / PIPE as f64);
        record(&mut entries, &name, r);
        if let Some(p50) = r {
            depth_rows.push((depth, p50));
        }
    }

    // --- BENCH_simd.json --------------------------------------------------
    let median_of = |needle: &str| {
        entries.iter().find(|(n, _)| n.starts_with(needle)).map(|&(_, p50)| p50)
    };
    let mut root = Value::obj();
    root.set("bench", Value::Str("pack_kernels".to_string()));
    root.set("elems", Value::Num(elems as f64));
    root.set("lanes", Value::Num(kernels::LANES as f64));
    root.set("simd_feature", Value::Bool(cfg!(feature = "simd")));
    root.set("speedup_target", Value::Num(1.2));
    let mut results = Vec::new();
    for (name, p50) in &entries {
        let mut o = Value::obj();
        o.set("name", Value::Str(name.clone()));
        o.set("median_ns_per_iter", Value::Num((p50 * 1e9).round()));
        results.push(o);
    }
    root.set("results", Value::Arr(results));
    println!();
    for (key, kernel, scalar) in [
        ("speedup_pack", "pack-gather/kernel", "pack-gather/scalar"),
        ("speedup_unpack", "unpack-scatter/kernel", "unpack-scatter/scalar"),
        ("speedup_copy", "block-copy/kernel", "block-copy/scalar"),
    ] {
        if let (Some(k), Some(s)) = (median_of(kernel), median_of(scalar)) {
            root.set(key, Value::Num(s / k));
            println!("{key}: kernel vs scalar = {:.2}x", s / k);
        }
    }
    if let (Some(plain), Some(fused)) =
        (median_of("heat2d/plain-seq"), median_of("heat2d/fused-seq"))
    {
        root.set("speedup_fused", Value::Num(plain / fused));
        println!("speedup_fused: fused vs plain split-phase = {:.2}x", plain / fused);
    }
    if !depth_rows.is_empty() {
        let mut arr = Vec::new();
        let (mut best_d, mut best_t) = (0usize, f64::INFINITY);
        for &(depth, p50) in &depth_rows {
            let mut o = Value::obj();
            o.set("depth", Value::Num(depth as f64));
            o.set("median_ns_per_step", Value::Num((p50 * 1e9).round()));
            arr.push(o);
            if p50 < best_t {
                best_t = p50;
                best_d = depth;
            }
        }
        root.set("depth_sweep", Value::Arr(arr));
        root.set("best_depth", Value::Num(best_d as f64));
        println!("best pipeline depth on this host: D = {best_d}");
    }
    if !entries.is_empty() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_simd.json");
        upcsim::benchlib::save_bench_json(path, "pack kernel medians", &root);
    }
    b.finish();
}
