//! Criterion-lite bench: end-to-end regeneration time of every paper table
//! and figure (at 1/64 scale so `cargo bench` stays snappy; the CLI runs the
//! canonical 1/16 scale).

use upcsim::benchlib::{BenchConfig, Bencher};
use upcsim::harness::{self, HarnessConfig, Workspace};

fn main() {
    let mut b = Bencher::from_args(BenchConfig::heavy());
    let mut cfg = HarnessConfig::default();
    cfg.scale_div = 64;
    cfg.out_dir = None;
    // UPCSIM_HW=abel|host|file:<path> regenerates every table on a different
    // hardware parameter set (see `repro calibrate`).
    let src = upcsim::machine::HwSource::from_env().expect("UPCSIM_HW");
    cfg.hw = src.resolve(true).expect("hw resolution");
    cfg.hw_label = src.label();
    // Pre-warm the workspace so mesh generation cost is reported separately.
    let mut ws = Workspace::new();
    b.bench("tables/mesh-generation(all 3, 1/64)", || {
        let mut fresh = Workspace::new();
        for tp in upcsim::mesh::TestProblem::ALL {
            std::hint::black_box(fresh.mesh(tp, cfg.scale_div, upcsim::mesh::Ordering::Natural).n);
        }
    });
    for tp in upcsim::mesh::TestProblem::ALL {
        ws.mesh(tp, cfg.scale_div, upcsim::mesh::Ordering::Natural);
    }
    b.bench("tables/table2", || {
        std::hint::black_box(harness::table2(&cfg, &mut ws));
    });
    b.bench("tables/table3", || {
        std::hint::black_box(harness::table3(&cfg, &mut ws));
    });
    b.bench("tables/table4", || {
        std::hint::black_box(harness::table4(&cfg, &mut ws));
    });
    b.bench("tables/table5", || {
        std::hint::black_box(harness::table5(&cfg));
    });
    b.bench("tables/figure1", || {
        std::hint::black_box(harness::figure1(&cfg, &mut ws));
    });
    b.bench("tables/figure2", || {
        std::hint::black_box(harness::figure2_volumes(&cfg, &mut ws));
        std::hint::black_box(harness::figure2_blocksize(&cfg, &mut ws));
    });
    b.bench("tables/ablations", || {
        std::hint::black_box(harness::ablation_blocksize(&cfg, &mut ws));
        std::hint::black_box(harness::ablation_threads_per_node(&cfg, &mut ws));
    });
    b.finish();
}
