//! PJRT runtime bridge — loads the AOT-compiled HLO-text artifacts produced
//! by `python/compile/aot.py` and executes them from the Rust request path.
//!
//! Interchange is HLO **text** (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits protos with 64-bit instruction ids that the image's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! `/opt/xla-example/README.md`). Python runs only at build time
//! (`make artifacts`); this module is all that touches the artifacts at
//! runtime.

mod engine;
mod manifest;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

/// Default artifacts directory, relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory by walking up from CWD (works from repo
/// root, examples, and test binaries).
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(env) = std::env::var("UPCSIM_ARTIFACTS") {
        let p = std::path::PathBuf::from(env);
        return p.join("manifest.json").exists().then_some(p);
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let candidate = dir.join(ARTIFACTS_DIR);
        if candidate.join("manifest.json").exists() {
            return Some(candidate);
        }
        if !dir.pop() {
            return None;
        }
    }
}
