//! The PJRT execution engine: compile-once, execute-many.
//!
//! The real implementation binds to the `xla` crate, which only exists in
//! the full image's toolchain. It is gated behind the `pjrt` cargo feature;
//! the default build compiles an API-identical stub that still loads and
//! validates manifests but reports execution as unavailable, so every
//! caller (coordinator, CLI `--backend pjrt`, integration tests) degrades
//! with a clear error instead of failing to link.

use super::manifest::{ArtifactSpec, Manifest};
use anyhow::{anyhow, Result};
use std::path::Path;

#[cfg(feature = "pjrt")]
pub use real::Engine;
#[cfg(not(feature = "pjrt"))]
pub use stub::Engine;

#[cfg(feature = "pjrt")]
mod real {
    use super::*;
    use std::collections::HashMap;

    /// Wraps a PJRT CPU client plus a cache of compiled executables, one per
    /// artifact. Compilation happens on first use; the hot path is
    /// [`Engine::run_f32`].
    pub struct Engine {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Engine {
        /// Whether this build can execute artifacts (true: `pjrt` feature on).
        pub fn available() -> bool {
            true
        }

        /// Create an engine over an artifacts directory (must contain
        /// `manifest.json`).
        pub fn new(dir: &Path) -> Result<Engine> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Engine { client, manifest, cache: HashMap::new() })
        }

        /// Create an engine by discovering the artifacts directory.
        pub fn discover() -> Result<Engine> {
            let dir = super::super::find_artifacts_dir()
                .ok_or_else(|| anyhow!("no artifacts/manifest.json found — run `make artifacts`"))?;
            Engine::new(&dir)
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
            self.manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
        }

        /// Ensure `name` is compiled and cached.
        pub fn load(&mut self, name: &str) -> Result<()> {
            if self.cache.contains_key(name) {
                return Ok(());
            }
            let spec = self.spec(name)?.clone();
            let path = spec
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-UTF8 artifact path"))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
            Ok(())
        }

        /// Execute artifact `name` with f32 inputs; returns the flattened f32
        /// outputs. Inputs are validated against the manifest's shapes.
        ///
        /// AOT functions are lowered with `return_tuple=True`, so the raw
        /// output is a 1-tuple (or n-tuple) that we unpack.
        pub fn run_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            self.load(name)?;
            let spec = self.spec(name)?.clone();
            if inputs.len() != spec.inputs.len() {
                return Err(anyhow!(
                    "artifact {name}: {} inputs given, {} expected",
                    inputs.len(),
                    spec.inputs.len()
                ));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (k, (data, tspec)) in inputs.iter().zip(&spec.inputs).enumerate() {
                if data.len() != tspec.elements() {
                    return Err(anyhow!(
                        "artifact {name} input {k}: {} elements given, {} expected",
                        data.len(),
                        tspec.elements()
                    ));
                }
                let dims: Vec<i64> = tspec.shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape input {k}: {e:?}"))?;
                literals.push(lit);
            }
            let exe = self.cache.get(name).expect("loaded above");
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
            let mut lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
            // Unpack the tuple of outputs.
            let parts = lit
                .decompose_tuple()
                .map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
            if parts.len() != spec.outputs.len() {
                return Err(anyhow!(
                    "artifact {name}: {} outputs, {} expected",
                    parts.len(),
                    spec.outputs.len()
                ));
            }
            let mut out = Vec::with_capacity(parts.len());
            for (k, part) in parts.iter().enumerate() {
                let v = part
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("reading output {k} of {name}: {e:?}"))?;
                out.push(v);
            }
            Ok(out)
        }

        /// PJRT platform string (for logs).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }

    impl std::fmt::Debug for Engine {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Engine")
                .field("artifacts", &self.manifest.artifacts.len())
                .field("cached", &self.cache.len())
                .finish()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::*;

    /// Featureless stand-in: manifest handling works, execution errors out.
    pub struct Engine {
        manifest: Manifest,
    }

    impl Engine {
        /// Whether this build can execute artifacts (false: stub build).
        pub fn available() -> bool {
            false
        }

        pub fn new(dir: &Path) -> Result<Engine> {
            let manifest = Manifest::load(dir)?;
            Ok(Engine { manifest })
        }

        pub fn discover() -> Result<Engine> {
            let dir = super::super::find_artifacts_dir()
                .ok_or_else(|| anyhow!("no artifacts/manifest.json found — run `make artifacts`"))?;
            Engine::new(&dir)
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
            self.manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
        }

        pub fn load(&mut self, name: &str) -> Result<()> {
            self.spec(name)?;
            Err(anyhow!(
                "cannot compile '{name}': built without the `pjrt` feature. \
                 Enabling it needs the full image's `xla` bindings: add \
                 `xla = {{ path = \"...\" }}` to rust/Cargo.toml [dependencies], \
                 then `cargo build --features pjrt`"
            ))
        }

        pub fn run_f32(&mut self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            self.load(name)?;
            unreachable!("load always errors in the stub build")
        }

        pub fn platform(&self) -> String {
            "unavailable (pjrt feature disabled)".to_string()
        }
    }

    impl std::fmt::Debug for Engine {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Engine")
                .field("artifacts", &self.manifest.artifacts.len())
                .field("pjrt", &"disabled")
                .finish()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Engine construction fails cleanly without artifacts.
    #[test]
    fn missing_dir_errors() {
        assert!(Engine::new(Path::new("/nonexistent-artifacts")).is_err());
    }

    // Execution against real artifacts is covered by the integration test
    // `rust/tests/pjrt_roundtrip.rs`, which is skipped when `make artifacts`
    // has not run (or when the `pjrt` feature is off).
    #[test]
    fn discover_is_optional() {
        // Must not panic either way.
        let _ = Engine::discover();
    }
}
