//! The artifact manifest written by `python/compile/aot.py`.
//!
//! ```json
//! {
//!   "version": 1,
//!   "artifacts": [
//!     {"name": "spmv_block", "file": "spmv_block.hlo.txt",
//!      "block": 4096, "r_nz": 16,
//!      "inputs":  [{"shape": [4096], "dtype": "f32"}, ...],
//!      "outputs": [{"shape": [4096], "dtype": "f32"}]}
//!   ]
//! }
//! ```

use crate::util::json::{self, Value};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<TensorSpec> {
        let shape = v
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("f32")
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form integer metadata (e.g. `block`, `r_nz`, `tile_m`).
    pub meta: std::collections::BTreeMap<String, usize>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = json::parse(text).context("manifest.json is not valid JSON")?;
        let arts = root
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = dir.join(
                a.get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow!("artifact {name} missing file"))?,
            );
            let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("artifact {name} missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let mut meta = std::collections::BTreeMap::new();
            if let Some(Value::Obj(map)) = a.get("meta") {
                for (k, v) in map {
                    if let Some(x) = v.as_usize() {
                        meta.insert(k.clone(), x);
                    }
                }
            }
            let inputs = tensors("inputs")?;
            let outputs = tensors("outputs")?;
            artifacts.push(ArtifactSpec { name, file, inputs, outputs, meta });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "spmv_block", "file": "spmv_block.hlo.txt",
         "meta": {"block": 4096, "r_nz": 16},
         "inputs": [{"shape": [4096], "dtype": "f32"},
                    {"shape": [4096], "dtype": "f32"},
                    {"shape": [4096, 16], "dtype": "f32"},
                    {"shape": [4096, 16], "dtype": "f32"}],
         "outputs": [{"shape": [4096], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("spmv_block").unwrap();
        assert_eq!(a.meta["block"], 4096);
        assert_eq!(a.inputs[2].shape, vec![4096, 16]);
        assert_eq!(a.inputs[2].elements(), 65536);
        assert_eq!(a.file, Path::new("/tmp/arts/spmv_block.hlo.txt"));
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "not json").is_err());
        assert!(Manifest::parse(
            Path::new("/tmp"),
            r#"{"artifacts": [{"name": "x"}]}"#
        )
        .is_err());
    }
}
