//! Criterion-lite: a minimal benchmarking harness.
//!
//! The offline build ships no `criterion`, so `cargo bench` runs
//! `harness = false` binaries (`rust/benches/*.rs`) built on this module.
//! Each benchmark does timed warmup followed by batched measurement until a
//! wall-clock budget or iteration cap is reached, and reports mean/σ/min/p50.

use crate::util::fmt;
use crate::util::json::Value;
use crate::util::Stats;
use std::time::{Duration, Instant};

/// Write a `BENCH_*.json` report document: pretty-printed, best-effort.
/// Every bench and validation artifact goes through here so the emission
/// protocol (pretty JSON, one `[<label> saved to <path>]` confirmation
/// line, a warning instead of a panic on an unwritable checkout) cannot
/// drift between emitters. Object documents are stamped with a
/// [`provenance`] block (git SHA, hardware-source label, UTC timestamp)
/// unless the emitter already set one, so any two artifacts can be
/// compared knowing what code and machine produced them.
pub fn save_bench_json(path: &str, label: &str, root: &Value) {
    let mut doc = root.clone();
    if matches!(doc, Value::Obj(_)) && doc.get("provenance").is_none() {
        doc.set("provenance", provenance());
    }
    match std::fs::write(path, doc.pretty()) {
        Ok(()) => println!("[{label} saved to {path}]"),
        Err(e) => eprintln!("warning: cannot write {path}: {e}"),
    }
}

/// The provenance block stamped into every artifact: the checkout's git
/// SHA (`null` outside a git checkout or without a `git` binary), the
/// hardware-source label the run was parameterized with (`UPCSIM_HW`,
/// same grammar as `--hw`), the build target, and a UTC wall-clock
/// timestamp. All best-effort — a missing tool degrades a field, never
/// the artifact.
pub fn provenance() -> Value {
    let mut o = Value::obj();
    o.set(
        "git_sha",
        match git_head_sha() {
            Some(sha) => Value::Str(sha),
            None => Value::Null,
        },
    );
    let hw = crate::machine::HwSource::from_env()
        .map(|s| s.label())
        .unwrap_or_else(|_| "unknown".to_string());
    o.set("hw", Value::Str(hw));
    o.set(
        "target",
        Value::Str(format!("{}-{}", std::env::consts::ARCH, std::env::consts::OS)),
    );
    o.set("timestamp_utc", Value::Str(utc_now_iso8601()));
    o
}

fn git_head_sha() -> Option<String> {
    let out = std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let sha = String::from_utf8(out.stdout).ok()?;
    let sha = sha.trim();
    (!sha.is_empty()).then(|| sha.to_string())
}

/// `YYYY-MM-DDTHH:MM:SSZ` from the system clock, without a date crate.
fn utc_now_iso8601() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (y, m, d) = civil_from_days((secs / 86_400) as i64);
    let tod = secs % 86_400;
    format!(
        "{y:04}-{m:02}-{d:02}T{:02}:{:02}:{:02}Z",
        tod / 3600,
        (tod % 3600) / 60,
        tod % 60
    )
}

/// Days-since-epoch → proleptic Gregorian civil date (Howard Hinnant's
/// `civil_from_days` algorithm).
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    (yoe + era * 400 + i64::from(m <= 2), m, d)
}

/// Configuration for a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup budget.
    pub warmup: Duration,
    /// Measurement budget.
    pub measure: Duration,
    /// Minimum number of measured samples.
    pub min_samples: usize,
    /// Maximum number of measured samples.
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_samples: 10,
            max_samples: 200,
        }
    }
}

impl BenchConfig {
    /// A faster profile for heavyweight end-to-end benchmarks.
    pub fn heavy() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(100),
            measure: Duration::from_secs(3),
            min_samples: 3,
            max_samples: 20,
        }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration time statistics, in seconds.
    pub time: Stats,
    /// Optional throughput denominator: items processed per iteration.
    pub items_per_iter: Option<f64>,
    /// Optional bytes moved per iteration (for bandwidth reporting).
    pub bytes_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|it| it / self.time.mean)
    }

    pub fn bandwidth(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b / self.time.mean)
    }

    pub fn render(&self) -> String {
        let mut line = format!(
            "{:<44} {:>12} ± {:>10}  (min {:>10}, n={})",
            self.name,
            fmt::secs(self.time.mean),
            fmt::secs(self.time.std),
            fmt::secs(self.time.min),
            self.time.n,
        );
        if let Some(tp) = self.throughput() {
            line.push_str(&format!("  {:>10.2} Melem/s", tp / 1e6));
        }
        if let Some(bw) = self.bandwidth() {
            line.push_str(&format!("  {:>12}", fmt::rate(bw)));
        }
        line
    }
}

/// A collection of benchmarks sharing one configuration; prints results as
/// they complete and a summary at the end.
pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
    filter: Option<String>,
}

impl Bencher {
    /// Create a bencher; honours a substring filter passed as argv[1]
    /// (mirroring `cargo bench -- <filter>`).
    pub fn from_args(config: BenchConfig) -> Bencher {
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with("--"));
        Bencher { config, results: Vec::new(), filter }
    }

    pub fn new(config: BenchConfig) -> Bencher {
        Bencher { config, results: Vec::new(), filter: None }
    }

    fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Run one benchmark. `f` is called once per iteration; use
    /// `std::hint::black_box` inside to defeat the optimizer.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Option<&BenchResult> {
        self.bench_with(name, None, None, &mut f)
    }

    /// Run one benchmark with a throughput denominator (`items` processed per
    /// iteration).
    pub fn bench_items<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) -> Option<&BenchResult> {
        self.bench_with(name, Some(items), None, &mut f)
    }

    /// Run one benchmark with a bandwidth denominator (`bytes` moved per
    /// iteration).
    pub fn bench_bytes<F: FnMut()>(&mut self, name: &str, bytes: f64, mut f: F) -> Option<&BenchResult> {
        self.bench_with(name, None, Some(bytes), &mut f)
    }

    fn bench_with(
        &mut self,
        name: &str,
        items: Option<f64>,
        bytes: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> Option<&BenchResult> {
        if !self.enabled(name) {
            return None;
        }
        // Warmup, also estimating the per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.config.warmup || warm_iters == 0 {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let est = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

        // Choose a batch size so one sample takes ≥ ~1ms (amortizes timer cost).
        let batch = ((1e-3 / est.max(1e-12)).ceil() as u64).clamp(1, 1_000_000);
        let mut samples = Vec::new();
        let measure_start = Instant::now();
        while (measure_start.elapsed() < self.config.measure
            || samples.len() < self.config.min_samples)
            && samples.len() < self.config.max_samples
        {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
        }

        let result = BenchResult {
            name: name.to_string(),
            time: Stats::from(&samples),
            items_per_iter: items,
            bytes_per_iter: bytes,
        };
        println!("{}", result.render());
        self.results.push(result);
        self.results.last()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a final summary block.
    pub fn finish(&self) {
        println!("\n=== {} benchmarks complete ===", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_samples: 3,
            max_samples: 10,
        }
    }

    #[test]
    fn measures_something() {
        let mut b = Bencher::new(quick());
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        let r = &b.results()[0];
        assert!(r.time.mean > 0.0);
        assert!(r.time.n >= 3);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bencher::new(quick());
        b.bench_items("items", 100.0, || {
            std::hint::black_box(0u64);
        });
        assert!(b.results()[0].throughput().unwrap() > 0.0);
    }

    #[test]
    fn civil_date_known_values() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(11_016), (2000, 2, 29)); // leap day
        assert_eq!(civil_from_days(11_017), (2000, 3, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1));
    }

    #[test]
    fn provenance_block_is_complete() {
        let p = provenance();
        // git_sha is best-effort (Null outside a checkout), the rest is
        // always present.
        assert!(p.get("git_sha").is_some());
        let ts = p.get("timestamp_utc").unwrap().as_str().unwrap();
        assert_eq!(ts.len(), 20, "{ts}");
        assert!(ts.ends_with('Z') && ts.contains('T'), "{ts}");
        assert!(ts.starts_with("20"), "{ts}"); // this decade, give or take
        assert!(!p.get("hw").unwrap().as_str().unwrap().is_empty());
        assert!(!p.get("target").unwrap().as_str().unwrap().is_empty());
    }

    #[test]
    fn save_stamps_provenance_once() {
        let path = std::env::temp_dir().join(format!("upcsim_prov_{}.json", std::process::id()));
        let mut root = Value::obj();
        root.set("bench", Value::Str("unit".into()));
        save_bench_json(path.to_str().unwrap(), "unit", &root);
        let doc = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(doc.get("provenance").is_some(), "artifact not stamped");
        assert!(doc.get("provenance").unwrap().get("timestamp_utc").is_some());
        // An emitter-provided block wins over the automatic stamp.
        let mut custom = Value::obj();
        custom.set("provenance", Value::Str("mine".into()));
        save_bench_json(path.to_str().unwrap(), "unit", &custom);
        let doc = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("provenance").unwrap().as_str().unwrap(), "mine");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bencher::new(quick());
        b.filter = Some("nomatch".to_string());
        assert!(b.bench("skipped", || {}).is_none());
        assert!(b.results().is_empty());
    }
}
