//! # upcsim
//!
//! A reproduction of *"Performance optimization and modeling of fine-grained
//! irregular communication in UPC"* (Lagravière et al., 2019) as a
//! Rust + JAX + Pallas three-layer system.
//!
//! The paper studies four implementations of sparse matrix-vector
//! multiplication (SpMV) in the UPC PGAS language — a naive version and three
//! increasingly aggressive transformations (thread privatization, block-wise
//! bulk transfer, message condensing + consolidation) — and derives
//! closed-form performance models for each from exact communication-traffic
//! counts plus four hardware characteristic parameters.
//!
//! This crate provides:
//!
//! * [`pgas`] — block-cyclic shared-array layout math (UPC eq. (1) semantics).
//! * [`machine`] — the hardware characteristic parameters and cost primitives
//!   of the paper's §5.2.2, with the Abel-cluster defaults from §6.2.
//! * [`mesh`] — synthetic unstructured tetrahedral meshes (substituting the
//!   paper's heart-ventricle TetGen meshes) and a 2D uniform mesh.
//! * [`matrix`] — the modified EllPack (D + A split) sparse format of §3.1.
//! * [`comm`] — the communication-traffic analyzer producing every count the
//!   §5 models need, and the condensed/consolidated communication plan.
//! * [`spmv`] — executable implementations of the paper's Listings 1–5.
//! * [`engine`] — execution-engine selection: the sequential oracle vs the
//!   persistent parallel worker pool (one long-lived OS thread per UPC
//!   thread over the compiled communication plan), plus the
//!   workload-agnostic exchange runtime all grid workloads share.
//! * [`model`] — the performance-model engine (eqs. (5)–(18), (19)–(22)).
//! * [`sim`] — the simulated cluster with per-thread clocks and per-node NIC
//!   serialization that produces "measured" times.
//! * [`heat2d`] — the §8 2D heat-equation solver and its model.
//! * [`mdlite`] — a dynamic-pattern particle/field workload whose gather
//!   plan is rebuilt every K steps, driving the versioned plan lifecycle
//!   (incremental [`PlanDelta`](comm::PlanDelta) recompilation validated
//!   bitwise against a full-recompile oracle).
//! * [`stencil3d`] — a 3D 7-point-stencil diffusion workload compiled onto
//!   the same exchange runtime (the "not limited to UPC" demonstration).
//! * [`transport`] — the pluggable transport layer: the five-operation
//!   [`Transport`](transport::Transport) trait behind every exchange
//!   protocol, its in-process and TCP-socket backends, and the
//!   `repro launch` multi-process orchestrator.
//! * [`microbench`] — STREAM / ping-pong / τ microbenchmarks (§6.2).
//! * [`runtime`] — PJRT bridge loading AOT-compiled HLO-text artifacts
//!   produced by the Python compile path (`python/compile/`).
//! * [`coordinator`] — run configuration + the end-to-end runner.
//! * [`harness`] — regeneration of every table and figure in the paper.
//! * [`util`], [`benchlib`], [`testing`], [`cli`] — self-contained
//!   infrastructure (JSON, PRNG, stats, bench + property-test drivers).

pub mod benchlib;
pub mod cli;
pub mod comm;
pub mod coordinator;
pub mod engine;
pub mod harness;
pub mod heat2d;
pub mod machine;
pub mod matrix;
pub mod mdlite;
pub mod mesh;
pub mod microbench;
pub mod model;
pub mod pgas;
pub mod runtime;
pub mod sim;
pub mod spmv;
pub mod stencil3d;
pub mod testing;
pub mod transport;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
