//! The executable 3D 7-point-stencil solver: per-thread halo-extended
//! boxes, a compiled face-exchange plan, and Jacobi diffusion steps on the
//! shared [`ExchangeRuntime`].

use super::Stencil3dGrid;
use crate::comm::{ComputeSplit, StridedBlock, StridedPlan};
use crate::engine::{
    check_depth, check_generation, check_plan_hash, Checkpoint, Engine, ExchangeRuntime,
};

/// Compile the six face exchanges into a strided block-copy plan.
///
/// Local layout is x-major: `idx = x·m·n + y·n + z` with halo-extended dims
/// `(p, m, n)`. Faces carry the *interior* of the boundary plane only (the
/// 7-point stencil needs no edges or corners):
///
/// * x-faces — rows over y (`row_stride = n`), contiguous in z;
/// * y-faces — rows over x (`row_stride = m·n`), contiguous in z;
/// * z-faces — rows over x (`row_stride = m·n`), strided in y
///   (`col_stride = n`): the doubly-strided shape that pays pack time.
pub(crate) fn face_plan(grid: &Stencil3dGrid) -> StridedPlan {
    let (p, m, n) = grid.subdomain();
    let mn = m * n;
    let (pi, mi, ni) = (p - 2, m - 2, n - 2);
    // The interior of plane x = X / y = Y / z = Z, as a StridedBlock.
    let x_face = |x: usize| StridedBlock::plane(x * mn + n + 1, mi, n, ni, 1);
    let y_face = |y: usize| StridedBlock::plane(mn + y * n + 1, pi, mn, ni, 1);
    let z_face = |z: usize| StridedBlock::plane(mn + n + z, pi, mn, mi, n);
    let mut copies = Vec::new();
    for t in 0..grid.threads() {
        let (ip, jp, kp) = grid.coords(t);
        // x− neighbour's last interior plane → my x = 0 plane, and so on.
        if ip > 0 {
            copies.push((grid.rank(ip - 1, jp, kp), t, x_face(p - 2), x_face(0)));
        }
        if ip < grid.pprocs - 1 {
            copies.push((grid.rank(ip + 1, jp, kp), t, x_face(1), x_face(p - 1)));
        }
        if jp > 0 {
            copies.push((grid.rank(ip, jp - 1, kp), t, y_face(m - 2), y_face(0)));
        }
        if jp < grid.mprocs - 1 {
            copies.push((grid.rank(ip, jp + 1, kp), t, y_face(1), y_face(m - 1)));
        }
        if kp > 0 {
            copies.push((grid.rank(ip, jp, kp - 1), t, z_face(n - 2), z_face(0)));
        }
        if kp < grid.nprocs - 1 {
            copies.push((grid.rank(ip, jp, kp + 1), t, z_face(1), z_face(n - 1)));
        }
    }
    let plan = StridedPlan::from_msgs(grid.threads(), &copies);
    debug_assert!(plan.validate(&|_| p * mn).is_ok());
    plan
}

/// Compile the interior/boundary decomposition for the overlapped step and
/// validate it (debug builds) against the canonical owned region.
pub(crate) fn compute_split(grid: &Stencil3dGrid) -> ComputeSplit {
    let (p, m, n) = grid.subdomain();
    let split = ComputeSplit::grid3d(p, m, n);
    debug_assert!(
        split.validate(&ComputeSplit::owned3d(p, m, n), p * m * n).is_ok(),
        "stencil3d split invalid: {:?}",
        split.validate(&ComputeSplit::owned3d(p, m, n), p * m * n)
    );
    split
}

/// Per-thread subdomain state plus the compiled exchange runtime.
#[derive(Debug)]
pub struct Stencil3dSolver {
    pub grid: Stencil3dGrid,
    /// `phi[t]` — thread t's p×m×n (halo-included) box, x-major.
    phi: Vec<Vec<f64>>,
    phin: Vec<Vec<f64>>,
    runtime: ExchangeRuntime,
    /// Interior/boundary decomposition for the split-phase overlapped step.
    split: ComputeSplit,
    /// Halo-exchange byte counter (payload crossing thread boundaries).
    pub inter_thread_bytes: u64,
}

impl Stencil3dSolver {
    /// Initialize from a global field of `p_glob × m_glob × n_glob` values.
    /// Boundary values of the global domain are treated as fixed (Dirichlet).
    pub fn new(grid: Stencil3dGrid, global: &[f64]) -> Stencil3dSolver {
        let plan = face_plan(&grid);
        Stencil3dSolver::with_plan(grid, global, plan)
    }

    /// Initialize with a caller-supplied face plan — a raw
    /// ([`refine_strided`](crate::comm::refine_strided)) or optimized
    /// ([`PlanOptimizer`](crate::comm::PlanOptimizer)) variant of
    /// `face_plan`. The plan must carry the same cell assignments; only
    /// message granularity and arena order may differ.
    pub fn with_plan(grid: Stencil3dGrid, global: &[f64], plan: StridedPlan) -> Stencil3dSolver {
        assert_eq!(global.len(), grid.p_glob * grid.m_glob * grid.n_glob);
        let phi: Vec<Vec<f64>> =
            (0..grid.threads()).map(|t| initial_field(grid, global, t)).collect();
        let phin = phi.clone();
        let runtime = ExchangeRuntime::new(plan);
        let split = compute_split(&grid);
        Stencil3dSolver { grid, phi, phin, runtime, split, inter_thread_bytes: 0 }
    }

    /// The compiled exchange runtime (plan + arena + pool).
    pub fn runtime(&self) -> &ExchangeRuntime {
        &self.runtime
    }

    /// Mutable runtime access — for configuring wait deadlines and fault
    /// plans on the underlying pool.
    pub fn runtime_mut(&mut self) -> &mut ExchangeRuntime {
        &mut self.runtime
    }

    /// Structural fingerprint of the compiled face plan (stamped into
    /// checkpoints).
    pub fn plan_fingerprint(&self) -> u64 {
        self.runtime.plan_fingerprint()
    }

    /// Snapshot the solver between batches: both field buffers, the byte
    /// counter, and the plan fingerprint. `step` is caller-stamped.
    pub fn checkpoint(&self, step: u64) -> Checkpoint {
        Checkpoint {
            step,
            plan_hash: self.plan_fingerprint(),
            depth: self.runtime.depth(),
            generation: self.runtime.generation(),
            fields: self.phi.clone(),
            scratch: self.phin.clone(),
            inter_thread_bytes: self.inter_thread_bytes,
        }
    }

    /// Restore a snapshot taken by [`checkpoint`](Self::checkpoint), after
    /// verifying the plan fingerprint and field shapes; returns the
    /// checkpoint's step stamp. The runtime's monotone exchange epochs are
    /// *not* reset — resuming is safe at any epoch.
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<u64, String> {
        check_plan_hash("stencil3d", self.plan_fingerprint(), ck.plan_hash)?;
        check_depth("stencil3d", self.runtime.depth(), ck.depth)?;
        check_generation("stencil3d", self.runtime.generation(), ck.generation)?;
        let (p, m, n) = self.grid.subdomain();
        if ck.fields.len() != self.grid.threads() || ck.scratch.len() != self.grid.threads() {
            return Err("stencil3d checkpoint thread count mismatch".into());
        }
        if ck.fields.iter().chain(&ck.scratch).any(|f| f.len() != p * m * n) {
            return Err("stencil3d checkpoint field shape mismatch".into());
        }
        self.phi.clone_from(&ck.fields);
        self.phin.clone_from(&ck.scratch);
        self.inter_thread_bytes = ck.inter_thread_bytes;
        Ok(ck.step)
    }

    /// Run `steps` pipelined time steps in batches of `every`, handing a
    /// checkpoint to `sink` after each batch — bitwise identical to one
    /// [`run_pipelined_with`](Self::run_pipelined_with) call over `steps`.
    /// Checkpoints are stamped with steps completed within this call.
    pub fn run_pipelined_checkpointed_with(
        &mut self,
        engine: Engine,
        steps: usize,
        every: usize,
        sink: &mut dyn FnMut(Checkpoint),
    ) {
        let every = every.max(1);
        let mut done = 0usize;
        while done < steps {
            let batch = (steps - done).min(every);
            self.run_pipelined_with(engine, batch);
            done += batch;
            sink(self.checkpoint(done as u64));
        }
    }

    /// The compiled interior/boundary decomposition.
    pub fn split(&self) -> &ComputeSplit {
        &self.split
    }

    /// Per-thread halo-extended fields (`phi`), e.g. for comparing a
    /// distributed run's rank-local results against this reference.
    pub fn local_fields(&self) -> &[Vec<f64>] {
        &self.phi
    }

    /// One time step on the sequential oracle engine.
    pub fn step(&mut self) {
        self.step_with(Engine::Sequential);
    }

    /// One time step on the chosen engine: face exchange through the
    /// compiled plan, then the 7-point Jacobi update. Both engines are
    /// bitwise identical in fields and byte counts.
    pub fn step_with(&mut self, engine: Engine) {
        let grid = self.grid;
        self.runtime.step_strided(engine, &mut self.phi, &mut self.phin, |t, phi, phin| {
            Self::jacobi_update(grid, t, phi, phin);
        });
        self.inter_thread_bytes += self.runtime.payload_bytes();
        std::mem::swap(&mut self.phi, &mut self.phin);
    }

    /// One split-phase overlapped time step: pack + publish, interior
    /// 7-point Jacobi (overlapping the face exchange), per-peer waits +
    /// unpack, boundary-shell Jacobi + the fixed-boundary copy-through.
    /// Bitwise identical to [`Self::step_with`] — see
    /// [`crate::engine::ExchangeRuntime::step_overlapped`].
    pub fn step_overlapped_with(&mut self, engine: Engine) {
        let grid = self.grid;
        let (_, m, n) = grid.subdomain();
        let mn = m * n;
        let split = &self.split;
        self.runtime.step_overlapped(
            engine,
            &mut self.phi,
            &mut self.phin,
            |_t, phi, phin| {
                jacobi_blocks3d(mn, n, &split.interior, phi, phin);
            },
            |t, phi, phin| {
                jacobi_blocks3d(mn, n, &split.boundary, phi, phin);
                Self::fixed_boundary_copy(grid, t, phi, phin);
            },
        );
        self.inter_thread_bytes += self.runtime.payload_bytes();
        std::mem::swap(&mut self.phi, &mut self.phin);
    }

    /// The runtime's pipeline depth D (buffered staging slots).
    pub fn depth(&self) -> usize {
        self.runtime.depth()
    }

    /// Reconfigure the pipeline depth between steps or batches
    /// ([`ExchangeRuntime::set_depth`]). Depth changes never alter results
    /// — only how much sender/receiver jitter the pipeline absorbs.
    pub fn set_depth(&mut self, depth: usize) {
        self.runtime.set_depth(depth);
    }

    /// Run `steps` split-phase time steps in **one** pool dispatch — the
    /// multi-step pipelined protocol, with the same interior/boundary
    /// kernels as [`Self::step_overlapped_with`] per epoch and the
    /// consumed-epoch ack protocol bounding fast threads to D epochs ahead
    /// (the runtime's pipeline depth, 2 by default). Bitwise identical to
    /// `steps` sequential steps; the driver leaves the final field under
    /// `phi`.
    pub fn run_pipelined_with(&mut self, engine: Engine, steps: usize) {
        let grid = self.grid;
        let (_, m, n) = grid.subdomain();
        let mn = m * n;
        let split = &self.split;
        self.runtime.run_pipelined(
            engine,
            steps,
            &mut self.phi,
            &mut self.phin,
            |_t, phi, phin| {
                jacobi_blocks3d(mn, n, &split.interior, phi, phin);
            },
            |t, phi, phin| {
                jacobi_blocks3d(mn, n, &split.boundary, phi, phin);
                Self::fixed_boundary_copy(grid, t, phi, phin);
            },
        );
        self.inter_thread_bytes += steps as u64 * self.runtime.payload_bytes();
    }

    /// 7-point Jacobi for one thread: average of the six face neighbours on
    /// the interior, plus the fixed global-boundary copy-through.
    pub(crate) fn jacobi_update(grid: Stencil3dGrid, t: usize, phi: &[f64], phin: &mut [f64]) {
        let (p, m, n) = grid.subdomain();
        let mn = m * n;
        for x in 1..p - 1 {
            for y in 1..m - 1 {
                let base = x * mn + y * n;
                for z in 1..n - 1 {
                    let c = base + z;
                    phin[c] = (phi[c - mn]
                        + phi[c + mn]
                        + phi[c - n]
                        + phi[c + n]
                        + phi[c - 1]
                        + phi[c + 1])
                        / 6.0;
                }
            }
        }
        Self::fixed_boundary_copy(grid, t, phi, phin);
    }

    /// Global-boundary planes stay fixed (Dirichlet): copy them through.
    /// Runs after every cell update on both step protocols.
    pub(crate) fn fixed_boundary_copy(
        grid: Stencil3dGrid,
        t: usize,
        phi: &[f64],
        phin: &mut [f64],
    ) {
        let (p, m, n) = grid.subdomain();
        let mn = m * n;
        let (ip, jp, kp) = grid.coords(t);
        if ip == 0 {
            phin[mn..2 * mn].copy_from_slice(&phi[mn..2 * mn]);
        }
        if ip == grid.pprocs - 1 {
            phin[(p - 2) * mn..(p - 1) * mn].copy_from_slice(&phi[(p - 2) * mn..(p - 1) * mn]);
        }
        if jp == 0 {
            for x in 0..p {
                let base = x * mn + n;
                phin[base..base + n].copy_from_slice(&phi[base..base + n]);
            }
        }
        if jp == grid.mprocs - 1 {
            for x in 0..p {
                let base = x * mn + (m - 2) * n;
                phin[base..base + n].copy_from_slice(&phi[base..base + n]);
            }
        }
        if kp == 0 {
            for x in 0..p {
                for y in 0..m {
                    phin[x * mn + y * n + 1] = phi[x * mn + y * n + 1];
                }
            }
        }
        if kp == grid.nprocs - 1 {
            for x in 0..p {
                for y in 0..m {
                    phin[x * mn + y * n + n - 2] = phi[x * mn + y * n + n - 2];
                }
            }
        }
    }

    /// Gather the global interior field (for comparison with the reference).
    pub fn to_global(&self) -> Vec<f64> {
        let grid = self.grid;
        let (p, m, n) = grid.subdomain();
        let mut out = vec![0.0f64; grid.p_glob * grid.m_glob * grid.n_glob];
        for t in 0..grid.threads() {
            let (ip, jp, kp) = grid.coords(t);
            let (x0, y0, z0) = (ip * (p - 2), jp * (m - 2), kp * (n - 2));
            for x in 1..p - 1 {
                for y in 1..m - 1 {
                    for z in 1..n - 1 {
                        out[((x0 + x - 1) * grid.m_glob + (y0 + y - 1)) * grid.n_glob
                            + (z0 + z - 1)] = self.phi[t][(x * m + y) * n + z];
                    }
                }
            }
        }
        out
    }
}

/// The 7-point Jacobi expression over a list of [`StridedBlock`] cell sets
/// (x stride `mn`, y stride `n`). Per-cell expression and operand order are
/// identical to [`Stencil3dSolver::jacobi_update`]'s nested loops, so any
/// partition of the owned region evaluates bitwise identically.
pub(crate) fn jacobi_blocks3d(
    mn: usize,
    n: usize,
    blocks: &[StridedBlock],
    phi: &[f64],
    phin: &mut [f64],
) {
    for b in blocks {
        for r in 0..b.rows {
            let base = b.offset + r * b.row_stride;
            for cc in 0..b.cols {
                let c = base + cc * b.col_stride;
                phin[c] = (phi[c - mn]
                    + phi[c + mn]
                    + phi[c - n]
                    + phi[c + n]
                    + phi[c - 1]
                    + phi[c + 1])
                    / 6.0;
            }
        }
    }
}

/// Thread `t`'s halo-extended `p × m × n` box cut from the global field:
/// interior cells plus whatever halo overlaps the global domain
/// (out-of-range halo stays 0). Shared by the in-process solver and the
/// per-rank distributed drivers so every backend starts bitwise identical.
pub(crate) fn initial_field(grid: Stencil3dGrid, global: &[f64], t: usize) -> Vec<f64> {
    let (p, m, n) = grid.subdomain();
    let (ip, jp, kp) = grid.coords(t);
    let (x0, y0, z0) = (ip * (p - 2), jp * (m - 2), kp * (n - 2));
    let mut field = vec![0.0f64; p * m * n];
    for x in 0..p {
        for y in 0..m {
            for z in 0..n {
                let gx = x0 as isize + x as isize - 1;
                let gy = y0 as isize + y as isize - 1;
                let gz = z0 as isize + z as isize - 1;
                if gx >= 0
                    && (gx as usize) < grid.p_glob
                    && gy >= 0
                    && (gy as usize) < grid.m_glob
                    && gz >= 0
                    && (gz as usize) < grid.n_glob
                {
                    field[(x * m + y) * n + z] = global
                        [(gx as usize * grid.m_glob + gy as usize) * grid.n_glob + gz as usize];
                }
            }
        }
    }
    field
}

/// Sequential reference: one 7-point Jacobi step on the global field (fixed
/// global boundary). Uses the same expression order as the solver.
pub fn seq_reference_step3d(p: usize, m: usize, n: usize, phi: &[f64]) -> Vec<f64> {
    let mut out = phi.to_vec();
    let mn = m * n;
    for x in 1..p - 1 {
        for y in 1..m - 1 {
            let base = x * mn + y * n;
            for z in 1..n - 1 {
                let c = base + z;
                out[c] = (phi[c - mn]
                    + phi[c + mn]
                    + phi[c - n]
                    + phi[c + n]
                    + phi[c - 1]
                    + phi[c + 1])
                    / 6.0;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_field(len: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.f64_in(0.0, 100.0)).collect()
    }

    #[test]
    fn matches_reference_over_steps() {
        let (pg, mg, ng) = (8, 12, 16);
        let grid = Stencil3dGrid::new(pg, mg, ng, 2, 3, 4);
        let f0 = random_field(pg * mg * ng, 5);
        let mut solver = Stencil3dSolver::new(grid, &f0);
        let mut reference = f0.clone();
        for step in 0..8 {
            solver.step();
            reference = seq_reference_step3d(pg, mg, ng, &reference);
            let got = solver.to_global();
            for (idx, (a, b)) in got.iter().zip(&reference).enumerate() {
                assert!((a - b).abs() < 1e-12, "step {step} idx {idx}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn engines_bitwise_identical() {
        let grid = Stencil3dGrid::new(8, 8, 8, 2, 2, 2);
        let f0 = random_field(512, 9);
        let mut seq = Stencil3dSolver::new(grid, &f0);
        let mut par = Stencil3dSolver::new(grid, &f0);
        for step in 0..6 {
            seq.step_with(Engine::Sequential);
            par.step_with(Engine::Parallel);
            assert_eq!(seq.to_global(), par.to_global(), "step {step}");
            assert_eq!(seq.inter_thread_bytes, par.inter_thread_bytes, "step {step}");
        }
    }

    #[test]
    fn face_traffic_counted() {
        // 2×2×2 split of an 8³ box: every thread has 3 neighbours with 4×4
        // faces → 24 messages of 16 doubles.
        let grid = Stencil3dGrid::new(8, 8, 8, 2, 2, 2);
        let f0 = random_field(512, 1);
        let mut solver = Stencil3dSolver::new(grid, &f0);
        assert_eq!(solver.runtime().plan().num_messages(), 24);
        assert_eq!(solver.runtime().plan().total_values(), 24 * 16);
        solver.step();
        assert_eq!(solver.inter_thread_bytes, 24 * 16 * 8);
    }

    #[test]
    fn single_thread_box_works() {
        let grid = Stencil3dGrid::new(6, 6, 6, 1, 1, 1);
        let f0 = random_field(216, 3);
        let mut solver = Stencil3dSolver::new(grid, &f0);
        solver.step();
        let want = seq_reference_step3d(6, 6, 6, &f0);
        let got = solver.to_global();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
        assert_eq!(solver.inter_thread_bytes, 0);
    }

    #[test]
    fn overlapped_step_bitwise_identical() {
        let grid = Stencil3dGrid::new(8, 12, 16, 2, 3, 4);
        let f0 = random_field(8 * 12 * 16, 19);
        let mut sync = Stencil3dSolver::new(grid, &f0);
        let mut ovl_seq = Stencil3dSolver::new(grid, &f0);
        let mut ovl_par = Stencil3dSolver::new(grid, &f0);
        for step in 0..5 {
            sync.step_with(Engine::Sequential);
            ovl_seq.step_overlapped_with(Engine::Sequential);
            ovl_par.step_overlapped_with(Engine::Parallel);
            let want = sync.to_global();
            assert!(
                want.iter().zip(&ovl_seq.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "seq overlap diverges at step {step}"
            );
            assert!(
                want.iter().zip(&ovl_par.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "par overlap diverges at step {step}"
            );
            assert_eq!(sync.inter_thread_bytes, ovl_par.inter_thread_bytes, "step {step}");
        }
    }

    #[test]
    fn pipelined_batch_bitwise_identical() {
        let grid = Stencil3dGrid::new(8, 12, 16, 2, 3, 4);
        let f0 = random_field(8 * 12 * 16, 29);
        let mut sync = Stencil3dSolver::new(grid, &f0);
        let mut pipe_seq = Stencil3dSolver::new(grid, &f0);
        let mut pipe_par = Stencil3dSolver::new(grid, &f0);
        for (round, steps) in [(0usize, 2usize), (1, 1), (2, 3)] {
            for _ in 0..steps {
                sync.step_with(Engine::Sequential);
            }
            pipe_seq.run_pipelined_with(Engine::Sequential, steps);
            pipe_par.run_pipelined_with(Engine::Parallel, steps);
            let want = sync.to_global();
            assert!(
                want.iter().zip(&pipe_seq.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "seq pipeline diverges in round {round}"
            );
            assert!(
                want.iter().zip(&pipe_par.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "par pipeline diverges in round {round}"
            );
            assert_eq!(sync.inter_thread_bytes, pipe_par.inter_thread_bytes, "round {round}");
        }
        assert!(pipe_par.runtime().max_sender_lead() <= pipe_par.depth() as u64);
    }

    #[test]
    fn pipelined_depth_sweep_bitwise_identical() {
        // Depth-D pipelines through the 3D solver API: every D matches the
        // synchronous oracle and respects its own lead bound.
        let grid = Stencil3dGrid::new(8, 12, 16, 2, 3, 4);
        let f0 = random_field(8 * 12 * 16, 31);
        let mut sync = Stencil3dSolver::new(grid, &f0);
        for _ in 0..4 {
            sync.step_with(Engine::Sequential);
        }
        let want = sync.to_global();
        for depth in [1usize, 3, 4] {
            let mut pipe = Stencil3dSolver::new(grid, &f0);
            pipe.set_depth(depth);
            assert_eq!(pipe.depth(), depth);
            pipe.run_pipelined_with(Engine::Parallel, 4);
            assert!(
                want.iter().zip(&pipe.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "depth {depth} diverges"
            );
            assert!(
                pipe.runtime().max_sender_lead() <= depth as u64,
                "depth {depth} lead {}",
                pipe.runtime().max_sender_lead()
            );
        }
    }

    #[test]
    fn compiled_plan_matches_geometry() {
        for (dims, procs) in [
            ((8usize, 12usize, 16usize), (2usize, 3usize, 4usize)),
            ((4, 4, 12), (1, 1, 6)),
            ((12, 4, 4), (6, 1, 1)),
            ((3, 3, 3), (3, 3, 3)), // minimum 1-cell interiors
        ] {
            let grid = Stencil3dGrid::new(dims.0, dims.1, dims.2, procs.0, procs.1, procs.2);
            let plan = super::face_plan(&grid);
            let (p, m, n) = grid.subdomain();
            plan.validate(&|_| p * m * n).unwrap();
            crate::comm::ExchangePlan::from(plan.clone()).validate(&|_| p * m * n).unwrap();
            // The interior/boundary split covers the owned region exactly.
            let split = super::compute_split(&grid);
            split.validate(&ComputeSplit::owned3d(p, m, n), p * m * n).unwrap();
            let expected_msgs: usize =
                (0..grid.threads()).map(|t| grid.neighbours(t).len()).sum();
            let expected_values: usize = (0..grid.threads())
                .flat_map(|t| grid.neighbours(t))
                .map(|(_, len, _)| len)
                .sum();
            assert_eq!(plan.num_messages(), expected_msgs);
            assert_eq!(plan.total_values(), expected_values);
        }
    }
}
