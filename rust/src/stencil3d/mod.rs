//! A 3D 7-point-stencil diffusion workload on the unified exchange runtime.
//!
//! This is the "not limited to UPC" — and not limited to 2D — demonstration:
//! a third workload compiled onto the *same* machinery as SpMV and heat-2D.
//! The global `P × M × N` box is partitioned over a
//! `pprocs × mprocs × nprocs` thread grid; each thread owns a
//! `(p−2) × (m−2) × (n−2)` interior plus a one-cell halo. The six face
//! exchanges compile to [`StridedBlock`](crate::comm::StridedBlock) plane
//! descriptors (z-faces doubly strided, x/y-faces row-chunked) in a
//! [`StridedPlan`](crate::comm::StridedPlan); time stepping is one
//! [`ExchangeRuntime::step_strided`](crate::engine::ExchangeRuntime) call —
//! zero per-step allocations, zero per-step thread spawns, on either engine.
//!
//! * [`Stencil3dGrid`] — the geometry (dims, coords, faces).
//! * [`Stencil3dSolver`] — per-thread storage + the compiled runtime,
//!   validated against [`seq_reference_step3d`].
//! * [`crate::model::predict_stencil3d`] — the eqs. (19)–(22) analogue.

mod solver;

pub use solver::{seq_reference_step3d, Stencil3dSolver};
pub(crate) use solver::{compute_split, face_plan, initial_field, jacobi_blocks3d};

/// Geometry of a 3D stencil run: global box and thread-grid partitioning.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stencil3dGrid {
    /// Global box dimensions (x-major: index = x·M·N + y·N + z).
    pub p_glob: usize,
    pub m_glob: usize,
    pub n_glob: usize,
    /// Thread-grid partitioning along x, y, z.
    pub pprocs: usize,
    pub mprocs: usize,
    pub nprocs: usize,
}

impl Stencil3dGrid {
    pub fn new(
        p_glob: usize,
        m_glob: usize,
        n_glob: usize,
        pprocs: usize,
        mprocs: usize,
        nprocs: usize,
    ) -> Stencil3dGrid {
        assert!(
            p_glob % pprocs == 0 && m_glob % mprocs == 0 && n_glob % nprocs == 0,
            "uneven partitioning"
        );
        Stencil3dGrid { p_glob, m_glob, n_glob, pprocs, mprocs, nprocs }
    }

    pub fn threads(&self) -> usize {
        self.pprocs * self.mprocs * self.nprocs
    }

    /// Per-thread subdomain dims including the halo layer.
    pub fn subdomain(&self) -> (usize, usize, usize) {
        (
            self.p_glob / self.pprocs + 2,
            self.m_glob / self.mprocs + 2,
            self.n_glob / self.nprocs + 2,
        )
    }

    /// Grid coordinates of a thread (x-major rank order).
    pub fn coords(&self, t: usize) -> (usize, usize, usize) {
        let per_plane = self.mprocs * self.nprocs;
        (t / per_plane, (t / self.nprocs) % self.mprocs, t % self.nprocs)
    }

    pub fn rank(&self, ip: usize, jp: usize, kp: usize) -> usize {
        (ip * self.mprocs + jp) * self.nprocs + kp
    }

    /// The ≤ 6 face neighbours of thread `t`:
    /// `(neighbour id, face size in doubles, doubly-strided?)`. Only the
    /// z-faces (`kp ± 1`) are doubly strided — their fastest axis jumps by
    /// `n` — so only they pay the eq. (19) pack penalty in the model.
    pub fn neighbours(&self, t: usize) -> Vec<(usize, usize, bool)> {
        let (ip, jp, kp) = self.coords(t);
        let (p, m, n) = self.subdomain();
        let (pi, mi, ni) = (p - 2, m - 2, n - 2);
        let mut out = Vec::with_capacity(6);
        if ip > 0 {
            out.push((self.rank(ip - 1, jp, kp), mi * ni, false));
        }
        if ip < self.pprocs - 1 {
            out.push((self.rank(ip + 1, jp, kp), mi * ni, false));
        }
        if jp > 0 {
            out.push((self.rank(ip, jp - 1, kp), pi * ni, false));
        }
        if jp < self.mprocs - 1 {
            out.push((self.rank(ip, jp + 1, kp), pi * ni, false));
        }
        if kp > 0 {
            out.push((self.rank(ip, jp, kp - 1), pi * mi, true));
        }
        if kp < self.nprocs - 1 {
            out.push((self.rank(ip, jp, kp + 1), pi * mi, true));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_rank_roundtrip() {
        let g = Stencil3dGrid::new(8, 12, 16, 2, 3, 4);
        assert_eq!(g.threads(), 24);
        for t in 0..g.threads() {
            let (ip, jp, kp) = g.coords(t);
            assert_eq!(g.rank(ip, jp, kp), t);
            assert!(ip < 2 && jp < 3 && kp < 4);
        }
        assert_eq!(g.subdomain(), (6, 6, 6));
    }

    #[test]
    fn neighbour_counts_and_sizes() {
        let g = Stencil3dGrid::new(12, 12, 12, 3, 3, 3);
        // Corner thread: 3 neighbours; center thread: 6.
        assert_eq!(g.neighbours(0).len(), 3);
        let center = g.rank(1, 1, 1);
        let nb = g.neighbours(center);
        assert_eq!(nb.len(), 6);
        // All faces are 4×4 = 16 doubles on this cubic split.
        assert!(nb.iter().all(|&(_, len, _)| len == 16));
        // Exactly the two z-faces are doubly strided.
        assert_eq!(nb.iter().filter(|&&(_, _, s)| s).count(), 2);
    }
}
