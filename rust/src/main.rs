//! `repro` — the CLI entry point (leader process).
//!
//! Subcommands:
//!
//! * `repro mesh [--scale N]` — generate the Table 1 meshes, print stats.
//! * `repro bench <table1|table2|table3|table4|table5|figure1|figure2|
//!   ablation-blocksize|ablation-ordering|ablation-tpn|baseline-mpi|all>
//!   [--scale N]
//!   [--iters K]` — regenerate paper tables/figures into `reports/`.
//! * `repro microbench` — §6.2 hardware-constant recovery.
//! * `repro calibrate [--quick] [--save PATH]` — measure this host's four
//!   hardware characteristic parameters, save them as JSON.
//! * `repro run [--variant v3] [--nodes N] [--tpn T] [--steps S]
//!   [--backend native|pjrt] [--problem tp1|tp2|tp3] [--scale N]` —
//!   end-to-end diffusion driver.
//! * `repro heat` / `repro stencil` — the grid workloads (§8 2D heat, 3D
//!   7-point stencil) on the unified exchange runtime.
//! * `repro validate [model]` — measured (parallel engine wall-clock) vs
//!   predicted (calibrated models) for all four variants plus the grid
//!   workloads.
//! * `repro validate pjrt` — numeric equivalence native ↔ PJRT artifacts.
//! * `repro chaos` — fault-injection drill: verifies injected protocol
//!   faults convert to structured stalls/poisons within the wait deadline,
//!   then a checkpoint/restart round-trip.
//! * `repro launch --procs P` — multi-process orchestrator: spawns `P`
//!   worker processes, ships each the serialized exchange plan over a
//!   loopback socket mesh, runs the chosen workload/protocol across process
//!   boundaries and verifies fields and byte counters bitwise against the
//!   in-process reference (`repro _worker` is the private spawned-rank
//!   entry).
//! * `repro validate --transport socket` — measured-vs-predicted for the
//!   loopback socket world, with the model's τ/bandwidth taken from a
//!   socket ping-pong probe.
//! * `repro mdlite` — dynamic-pattern mini-MD workload: incremental plan
//!   recompilation (a `PlanDelta` every K steps) checked bitwise against a
//!   full-recompile oracle on both engines and the socket world.
//! * `repro validate --dynamic` — measured-vs-predicted rebuild
//!   amortization for mdlite across rebuild periods.
//!
//! Every model/simulator consumer takes `--hw abel|host|file:<path>` to
//! select the hardware parameter set (paper constants, a fresh host
//! calibration, or a saved calibration file).

use anyhow::{anyhow, bail, Result};
use upcsim::cli::Args;
use upcsim::coordinator::{Backend, Problem, RunConfig, Runner};
use upcsim::engine::Engine;
use upcsim::harness::{self, HarnessConfig, Workspace};
use upcsim::machine::{Calibration, HwParams, HwSource};
use upcsim::mesh::{Ordering, TestProblem};
use upcsim::spmv::Variant;
use upcsim::util::fmt;

fn main() {
    // `repro _worker ...` is the spawned rank process of `repro launch`;
    // its argv is a private protocol (parsed by `worker_main`), not the
    // public flag grammar.
    let raw: Vec<String> = std::env::args().collect();
    if raw.get(1).map(String::as_str) == Some("_worker") {
        if let Err(e) = upcsim::transport::worker_main(&raw[2..]) {
            eprintln!("worker error: {e}");
            std::process::exit(1);
        }
        return;
    }
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Resolve `--hw abel|host|file:<path>` (and the `--quick` measurement
/// profile) into concrete parameters plus a provenance label.
fn resolve_hw(args: &Args, default: HwSource) -> Result<(HwParams, String)> {
    let src = match args.str_flag("hw") {
        None => default,
        Some(s) => HwSource::parse(s)?,
    };
    let quick = args.bool_flag("quick");
    if src == HwSource::Host {
        eprintln!(
            "[calibrating host hardware parameters ({} profile)...]",
            if quick { "quick" } else { "full" }
        );
    }
    Ok((src.resolve(quick)?, src.label()))
}

fn harness_config(args: &Args) -> Result<HarnessConfig> {
    harness_config_with_hw(args, HwSource::Abel)
}

fn harness_config_with_hw(args: &Args, default_hw: HwSource) -> Result<HarnessConfig> {
    let mut cfg = HarnessConfig::default();
    cfg.scale_div = if args.bool_flag("full-scale") {
        1
    } else {
        args.usize_flag("scale", 16)?
    };
    cfg.iters = args.usize_flag("iters", 1000)?;
    cfg.engine = parse_engine(args)?;
    let (hw, label) = resolve_hw(args, default_hw)?;
    cfg.hw = hw;
    cfg.hw_label = label;
    if let Some(dir) = args.str_flag("out") {
        cfg.out_dir = Some(dir.into());
    }
    Ok(cfg)
}

fn parse_engine(args: &Args) -> Result<Engine> {
    match args.str_flag("engine") {
        None => Ok(Engine::Sequential),
        Some(e) => Engine::parse(e).ok_or_else(|| anyhow!("unknown engine '{e}' (seq|par)")),
    }
}

/// Parse `--depth D|auto`: `Some(D)` pins the pipeline buffer depth,
/// `None` means the caller resolves it through the depth model
/// ([`choose_depth`](upcsim::model::choose_depth)). Absent flag = `Some(2)`,
/// the historical default.
fn parse_depth_flag(args: &Args) -> Result<Option<usize>> {
    match args.str_flag("depth") {
        None => Ok(Some(2)),
        Some("auto") => Ok(None),
        Some(s) => {
            let d: usize =
                s.parse().map_err(|_| anyhow!("--depth expects an integer or 'auto', got '{s}'"))?;
            Ok(Some(d.max(1)))
        }
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "mesh" => cmd_mesh(args),
        "bench" => cmd_bench(args),
        "microbench" => cmd_microbench(args),
        "calibrate" => cmd_calibrate(args),
        "run" => cmd_run(args),
        "heat" => cmd_heat(args),
        "stencil" => cmd_stencil(args),
        "chaos" => cmd_chaos(args),
        "launch" => cmd_launch(args),
        "plan" => cmd_plan(args),
        "mdlite" => cmd_mdlite(args),
        "validate" => match args.positional.first().map(|s| s.as_str()) {
            None | Some("model") => cmd_validate_model(args),
            Some("pjrt") => cmd_validate_pjrt(args),
            Some(other) => bail!("unknown validate target '{other}' (model | pjrt)"),
        },
        "" | "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `repro help`)"),
    }
}

const HELP: &str = "\
repro — UPC fine-grained irregular communication reproduction (Lagravière et al. 2019)

USAGE: repro <subcommand> [flags]

SUBCOMMANDS
  mesh        generate the Table 1 meshes and print statistics
  bench <id>  regenerate a paper table/figure (table1..table5, figure1,
              figure2, ablation-blocksize, ablation-ordering, ablation-tpn,
              microbench, all)
  microbench  §6.2 hardware-constant recovery on the simulated cluster
  calibrate   measure THIS host's four hardware characteristic parameters
              (--quick for the fast profile; --save PATH, default
              calibration.json)
  run         end-to-end 3D diffusion driver (v^l = M v^{l-1})
  heat        §8 2D heat solver: real numerics + Table-5-style prediction
              (--m 512 --nprocs 4 --mprocs 4 --steps 50; --overlap runs the
              split-phase overlapped step protocol, --fused the overlapped
              step with the unpack fused into the boundary update,
              --pipeline S the multi-step pipelined protocol in S-step
              batches; --depth D sets the pipeline buffer depth, default 2,
              --depth auto takes the depth model's pick for this grid)
  stencil     3D 7-point-stencil diffusion on the same exchange runtime
              (--p 64 --pprocs 1 --mprocs 2 --nprocs 2 --steps 20;
              --overlap / --pipeline S / --depth D|auto as above)
  chaos       fault-injection drill: inject delayed/dropped publishes,
              phase-targeted panics and slow receivers into the pipelined
              protocol on heat2d, stencil3d and SpMV V3, and verify every
              fault converts to a structured stall/poison within the wait
              deadline; then a checkpoint/restart demo (kill mid-run,
              resume, compare bitwise). Flags: --deadline-ms D (150),
              --steps S (6), --seed N (adds a seeded random fault scenario)
  launch      multi-process transport drill: spawn --procs P worker
              processes (default 2), ship each the serialized exchange plan
              over loopback sockets, run --workload heat|stencil|spmv|all
              x --proto sync|overlap|pipeline|all (defaults: all x all,
              --steps 4 each; --depth D buffered slots per rank, default 2,
              --depth auto probes the socket and takes the model's pick
              per workload) across process boundaries, and verify fields
              and byte counters bitwise against the in-process reference
              (--no-verify skips). --chaos kill@EPOCH | slow@EPOCH:MS
              injects a fault into the highest rank; --deadline-ms D
              (10000) bounds every wait; --plan compiled|raw|optimized
              selects the exchange-plan variant every rank runs
  plan        compile each workload's raw, compiled, and optimized exchange
              plans and print the message/byte/block/arena statistics plus
              the raw->optimized deltas (--workload heat|stencil|spmv|all,
              --procs P default 2; JSON to stdout, --json PATH to save)
  mdlite      dynamic-pattern mini-MD workload: particles drift across a
              cell grid and the gather plan is recompiled incrementally (a
              PlanDelta every --rebuild-every K steps, fingerprint-chained
              generations), checked bitwise against a full-recompile oracle
              on both engines and the loopback socket world (--quick small
              config; --cells N --threads T --particles P --steps S
              --seed N; --no-socket skips the socket arm)
  validate [model]  measured-vs-predicted: all four variants plus the
              split-phase overlapped and multi-step pipelined paths (V3,
              heat2d, stencil3d) on the parallel engine, wall-clock vs the
              calibrated eqs. (5)-(18), overlap, and pipeline models
              (--hw host by default; --steps S samples/point; --pipeline P
              batch size, default 8; --depth D buffer depth, default 2, or
              --depth auto for the model's pick — the pick is recorded in
              BENCH_model.json as depth_model_choice either way; also
              reports the pack-kernel bandwidth and a D=1..4 depth sweep
              outside the gate; emits BENCH_model.json, --json PATH to
              move it; --budget R exits nonzero when any geomean leaves
              [1/R, R], 0 = report only)
  validate --transport socket  measured-vs-predicted for the loopback
              socket world: nine (workload x protocol) rows against the
              model with the socket probe's tau/bandwidth substituted
              (--procs P ranks, --steps S, --budget R default 25; emits
              BENCH_transport.json, exits nonzero outside budget)
  validate --optimize  measured-vs-predicted for the plan optimizer: the
              raw-vs-optimized per-step speedup of every workload against
              the model's prediction from the condensed message count and
              volume, after checking all three plan variants produce
              bitwise-identical fields (--procs P, --steps S, --budget R
              default 25; emits BENCH_planopt.json, exits nonzero outside
              budget)
  validate --dynamic  measured-vs-predicted rebuild amortization for the
              mdlite dynamic-pattern workload: per-step cost at the static
              and K in {16, 64} rebuild periods against the rebuild model
              T_total = R*T_recompile + steps*T_step, after a bitwise
              incremental-vs-oracle check (--quick, --budget R default 25;
              emits BENCH_dynamic.json, exits nonzero outside budget)
  validate pjrt     numeric equivalence: native kernel vs PJRT artifacts

COMMON FLAGS
  --scale N         problem scale divisor (default 16; --full-scale for 1)
  --iters K         accounted SpMV iterations (default 1000)
  --out DIR         report output directory (default reports/)
  --engine seq|par  execution engine for real data movement: sequential
                    oracle or one OS thread per UPC thread (default seq)
  --hw SRC          hardware parameters for models/simulator: abel (paper
                    constants, default), host (calibrate now), or
                    file:<path> (a saved `repro calibrate` JSON)
  --quick           use the fast, slightly noisier calibration profile

RUN FLAGS
  --problem tp1|tp2|tp3|custom   workload (default tp1)
  --n N                          custom problem size (with --problem custom)
  --variant naive|v1|v2|v3       implementation (default v3)
  --nodes N --tpn T              topology (default 2 x 16)
  --blocksize B                  override BLOCKSIZE
  --steps S                      executed time steps (default 100)
  --depth D|auto                 exchange pipeline buffer depth (default 2;
                                 auto = the depth model's pick, recorded in
                                 the run report)
  --ordering natural|rcm|morton|random
  --backend native|pjrt          compute backend (default native)
";

fn cmd_mesh(args: &Args) -> Result<()> {
    let cfg = harness_config(args)?;
    args.finish()?;
    let mut ws = Workspace::new();
    let t = harness::table1(&cfg, &mut ws);
    harness::emit(&cfg, "table1", &t);
    for tp in TestProblem::ALL {
        let mesh = ws.mesh(tp, cfg.scale_div, Ordering::Natural);
        let full = mesh.degree.iter().filter(|&&d| d as usize == upcsim::mesh::R_NZ).count();
        println!(
            "{}: n={} mean|i-j|={:.0} full-degree rows={:.1}%",
            tp.name(),
            fmt::int(mesh.n),
            mesh.mean_index_distance(),
            100.0 * full as f64 / mesh.n as f64
        );
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let cfg = harness_config(args)?;
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    args.finish()?;
    let mut ws = Workspace::new();
    let mut run = |id: &str| -> Result<()> {
        let t0 = std::time::Instant::now();
        let table = match id {
            "table1" => harness::table1(&cfg, &mut ws),
            "table2" => harness::table2(&cfg, &mut ws),
            "table3" => harness::table3(&cfg, &mut ws),
            "table4" => harness::table4(&cfg, &mut ws),
            "table5" => harness::table5(&cfg),
            "figure1" => harness::figure1(&cfg, &mut ws),
            "figure2" => {
                let t = harness::figure2_volumes(&cfg, &mut ws);
                harness::emit(&cfg, "figure2_volumes", &t);
                harness::figure2_blocksize(&cfg, &mut ws)
            }
            "ablation-blocksize" => harness::ablation_blocksize(&cfg, &mut ws),
            "ablation-ordering" => harness::ablation_ordering(&cfg, &mut ws),
            "ablation-tpn" => harness::ablation_threads_per_node(&cfg, &mut ws),
            "baseline-mpi" => harness::baseline_mpi(&cfg, &mut ws),
            "microbench" => harness::microbench_table(&cfg),
            other => bail!("unknown bench id '{other}'"),
        };
        let name = if id == "figure2" { "figure2_blocksize" } else { id };
        harness::emit(&cfg, name, &table);
        println!("[{id} took {}]\n", fmt::secs(t0.elapsed().as_secs_f64()));
        Ok(())
    };
    if what == "all" {
        for id in [
            "table1", "table2", "table3", "table4", "table5", "figure1", "figure2",
            "ablation-blocksize", "ablation-ordering", "ablation-tpn", "baseline-mpi",
            "microbench",
        ] {
            run(id)?;
        }
        Ok(())
    } else {
        run(what)
    }
}

fn cmd_microbench(args: &Args) -> Result<()> {
    let cfg = harness_config(args)?;
    args.finish()?;
    let t = harness::microbench_table(&cfg);
    harness::emit(&cfg, "microbench", &t);
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let quick = args.bool_flag("quick");
    let save: std::path::PathBuf = args.str_flag("save").unwrap_or("calibration.json").into();
    args.finish()?;
    println!(
        "# measuring host hardware characteristic parameters ({} profile)",
        if quick { "quick" } else { "full" }
    );
    let t0 = std::time::Instant::now();
    let cal = Calibration::measure(quick);
    let threads = cal.hw.threads_per_node;
    let mut t = fmt::Table::new(
        format!("host calibration — {threads} hardware threads"),
        &["Parameter", "Value", "Microbenchmark"],
    );
    t.row(vec![
        "W_thread_private".into(),
        fmt::rate(cal.hw.w_thread_private),
        format!("STREAM triad x{threads} (aggregate {})", fmt::rate(cal.stream_node)),
    ]);
    t.row(vec![
        "W_node(1)".into(),
        fmt::rate(cal.hw.w_node_single),
        "STREAM triad, 1 thread (saturation-curve anchor)".into(),
    ]);
    t.row(vec![
        "W_node_remote".into(),
        fmt::rate(cal.hw.w_node_remote),
        "cross-thread contiguous memcpy (ping-pong analog)".into(),
    ]);
    t.row(vec![
        "W_pack".into(),
        fmt::rate(cal.hw.w_pack),
        "indexed gather+scatter round trip (halo pack/unpack analog)".into(),
    ]);
    t.row(vec![
        "tau".into(),
        fmt::secs(cal.hw.tau),
        "random individual cross-thread access (Listing-6 analog)".into(),
    ]);
    t.row(vec![
        "cache line".into(),
        format!("{} B", cal.hw.cache_line),
        "strided-access knee".into(),
    ]);
    if cal.socket_model().is_some() {
        t.row(vec![
            "socket latency".into(),
            fmt::secs(cal.socket_latency),
            "loopback TCP ping-pong (socket transport tau)".into(),
        ]);
        t.row(vec![
            "socket bandwidth".into(),
            fmt::rate(cal.socket_bandwidth),
            "loopback TCP stream (socket transport W_node_remote)".into(),
        ]);
    }
    println!("{}", t.render());
    cal.save(&save)?;
    println!("[calibration took {}]", fmt::secs(t0.elapsed().as_secs_f64()));
    println!("[saved {} — reuse it with --hw file:{}]", save.display(), save.display());
    Ok(())
}

/// Parse `--chaos kill@EPOCH | slow@EPOCH:MS | none` for `repro launch`.
fn parse_chaos(s: Option<&str>) -> Result<upcsim::transport::ChaosAction> {
    use upcsim::transport::ChaosAction;
    let Some(s) = s else { return Ok(ChaosAction::None) };
    if s == "none" {
        return Ok(ChaosAction::None);
    }
    if let Some(e) = s.strip_prefix("kill@") {
        return Ok(ChaosAction::KillAt(e.parse()?));
    }
    if let Some(rest) = s.strip_prefix("slow@") {
        let (e, ms) = rest
            .split_once(':')
            .ok_or_else(|| anyhow!("--chaos slow@EPOCH:MS needs a duration"))?;
        return Ok(ChaosAction::SlowAt(
            e.parse()?,
            std::time::Duration::from_millis(ms.parse()?),
        ));
    }
    bail!("unknown chaos action '{s}' (kill@EPOCH | slow@EPOCH:MS | none)")
}

fn cmd_launch(args: &Args) -> Result<()> {
    use upcsim::transport::{LaunchConfig, PlanMode, Proto, WorkloadSpec, WORKLOADS};
    let procs = args.usize_flag("procs", 2)?;
    let workload = args.str_flag("workload").unwrap_or("all").to_string();
    let proto_flag = args.str_flag("proto").map(str::to_string);
    let steps = args.usize_flag("steps", 4)? as u64;
    let depth_flag = parse_depth_flag(args)?;
    let deadline_ms = args.usize_flag("deadline-ms", 10_000)?;
    let chaos = parse_chaos(args.str_flag("chaos"))?;
    let verify = !args.bool_flag("no-verify");
    let plan_mode = match args.str_flag("plan") {
        None => PlanMode::Compiled,
        Some(m) => PlanMode::parse(m)
            .ok_or_else(|| anyhow!("unknown plan mode '{m}' (compiled | raw | optimized)"))?,
    };
    args.finish()?;
    let protos: Vec<Proto> = match proto_flag.as_deref() {
        None | Some("all") => Proto::ALL.to_vec(),
        Some(p) => vec![Proto::parse(p)
            .ok_or_else(|| anyhow!("unknown proto '{p}' (sync | overlap | pipeline | all)"))?],
    };
    let workloads: Vec<String> = if workload == "all" {
        WORKLOADS.iter().map(|s| s.to_string()).collect()
    } else {
        vec![workload]
    };
    // `--depth auto`: one socket ping-pong probe up front, then the model's
    // advisory pick per workload plan × socket transport.
    let auto_tm = if depth_flag.is_none() {
        let probe = upcsim::transport::socket_probe(true)
            .map_err(|e| anyhow!("--depth auto needs the socket probe: {e}"))?;
        Some(upcsim::machine::TransportModel::socket(probe.latency, probe.bandwidth))
    } else {
        None
    };
    for w in &workloads {
        let depth = match (depth_flag, &auto_tm) {
            (Some(d), _) => d,
            (None, Some(tm)) => {
                let spec = WorkloadSpec::for_name(w, procs)
                    .ok_or_else(|| anyhow!("unknown workload '{w}' (one of {WORKLOADS:?})"))?;
                let d = upcsim::transport::auto_depth(&spec, steps as usize, tm);
                println!("[{w}: --depth auto resolved to D = {d}]");
                d
            }
            (None, None) => unreachable!("probe runs whenever --depth auto"),
        };
        for &proto in &protos {
            let cfg = LaunchConfig {
                procs,
                workload: w.clone(),
                proto,
                steps,
                depth,
                deadline: std::time::Duration::from_millis(deadline_ms as u64),
                chaos,
                plan_mode,
                verify,
            };
            upcsim::transport::cmd_launch(&cfg)?;
        }
    }
    Ok(())
}

/// `repro plan`: compile each requested workload's raw, compiled, and
/// optimized exchange plans and report the [`PlanStats`] deltas — the
/// condensing/consolidation win — as a table plus JSON.
///
/// [`PlanStats`]: upcsim::comm::PlanStats
fn cmd_plan(args: &Args) -> Result<()> {
    use upcsim::comm::PlanStats;
    use upcsim::transport::{PlanMode, WorkloadSpec, WORKLOADS};
    use upcsim::util::json::Value;
    let procs = args.usize_flag("procs", 2)?;
    let workload = args.str_flag("workload").unwrap_or("all").to_string();
    let json_path = args.str_flag("json").map(std::path::PathBuf::from);
    args.finish()?;
    let workloads: Vec<String> = if workload == "all" {
        WORKLOADS.iter().map(|s| s.to_string()).collect()
    } else {
        vec![workload]
    };
    println!(
        "{:<9} {:<10} {:>7} {:>8} {:>10} {:>7} {:>9}  {:<16}",
        "workload", "plan", "msgs", "values", "bytes", "blocks", "arena B", "fingerprint"
    );
    let mut arr = Vec::with_capacity(workloads.len());
    for w in &workloads {
        let spec = WorkloadSpec::for_name(w, procs)
            .ok_or_else(|| anyhow!("unknown workload '{w}' (expected one of {WORKLOADS:?})"))?;
        let mut o = Value::obj();
        o.set("workload", Value::Str(w.clone()));
        let mut per_mode = Vec::with_capacity(3);
        for mode in [PlanMode::Raw, PlanMode::Compiled, PlanMode::Optimized] {
            let plan = spec.plan_with(mode);
            let stats = PlanStats::of(&plan);
            println!(
                "{:<9} {:<10} {:>7} {:>8} {:>10} {:>7} {:>9}  {:016x}",
                w,
                mode.name(),
                stats.messages,
                stats.values,
                stats.payload_bytes,
                stats.blocks,
                stats.index_arena_bytes,
                plan.fingerprint()
            );
            o.set(mode.name(), stats.to_json());
            per_mode.push(stats);
        }
        let (raw, opt) = (per_mode[0], per_mode[2]);
        println!(
            "{:<9} raw->optimized: messages {}, bytes {}, blocks {}, index arena {}",
            w,
            pct_delta(raw.messages as f64, opt.messages as f64),
            pct_delta(raw.payload_bytes as f64, opt.payload_bytes as f64),
            pct_delta(raw.blocks as f64, opt.blocks as f64),
            pct_delta(raw.index_arena_bytes as f64, opt.index_arena_bytes as f64),
        );
        arr.push(o);
    }
    let mut root = Value::obj();
    root.set("bench", Value::Str("plan".into()));
    root.set("procs", Value::Num(procs as f64));
    root.set("rows", Value::Arr(arr));
    match json_path {
        Some(p) => {
            std::fs::write(&p, root.pretty())
                .map_err(|e| anyhow!("cannot write {}: {e}", p.display()))?;
            println!("[plan statistics saved to {}]", p.display());
        }
        None => println!("{}", root.compact()),
    }
    Ok(())
}

/// `"-96.7%"`-style relative change for the `repro plan` delta rows.
fn pct_delta(before: f64, after: f64) -> String {
    if before == 0.0 {
        return "n/a".into();
    }
    format!("{:+.1}%", (after - before) / before * 100.0)
}

/// `repro mdlite`: the dynamic-pattern mini-MD workload. Runs the
/// incremental plan lifecycle (a [`PlanDelta`] every `--rebuild-every`
/// steps) on both engines plus the loopback socket world and demands every
/// arm be bitwise identical to the full-recompile oracle.
///
/// [`PlanDelta`]: upcsim::comm::PlanDelta
fn cmd_mdlite(args: &Args) -> Result<()> {
    use upcsim::mdlite::{self, Lifecycle, MdConfig};
    let quick = args.bool_flag("quick");
    let mut cfg = if quick {
        MdConfig::quick()
    } else {
        MdConfig {
            cells_x: 48,
            cells_y: 48,
            threads: 4,
            particles: 512,
            steps: 128,
            rebuild_every: 16,
            seed: 0x4d44,
        }
    };
    if let Some(c) = args.str_flag("cells") {
        let c: usize = c.parse().map_err(|_| anyhow!("--cells expects an integer, got '{c}'"))?;
        cfg.cells_x = c;
        cfg.cells_y = c;
    }
    cfg.threads = args.usize_flag("threads", cfg.threads)?;
    cfg.particles = args.usize_flag("particles", cfg.particles)?;
    cfg.steps = args.usize_flag("steps", cfg.steps)?;
    cfg.rebuild_every = args.usize_flag("rebuild-every", cfg.rebuild_every)?;
    cfg.seed = args.usize_flag("seed", cfg.seed as usize)? as u64;
    let no_socket = args.bool_flag("no-socket");
    args.finish()?;
    println!(
        "# mdlite: {}x{} cells, {} threads, {} particles, {} steps, rebuild every {}",
        cfg.cells_x, cfg.cells_y, cfg.threads, cfg.particles, cfg.steps, cfg.rebuild_every
    );
    let err = |e: String| anyhow!(e);
    let oracle = mdlite::run(&cfg, Engine::Sequential, Lifecycle::FullRecompile).map_err(err)?;
    println!(
        "{:<22} checksum {:016x}, {:>3} generations, plan fp {:016x}",
        "oracle (full/seq)",
        oracle.checksum(),
        oracle.generations,
        oracle.plan_fp
    );
    let mut arms: Vec<(&str, mdlite::MdResult)> = vec![
        (
            "incremental/seq",
            mdlite::run(&cfg, Engine::Sequential, Lifecycle::Incremental).map_err(err)?,
        ),
        (
            "incremental/par",
            mdlite::run(&cfg, Engine::Parallel, Lifecycle::Incremental).map_err(err)?,
        ),
    ];
    if !no_socket {
        let deadline = Some(std::time::Duration::from_secs(30));
        arms.push((
            "incremental/socket",
            mdlite::run_socket(&cfg, Lifecycle::Incremental, deadline).map_err(err)?,
        ));
    }
    let mut failures = 0usize;
    for (label, r) in &arms {
        let ok = r.checksum() == oracle.checksum();
        failures += usize::from(!ok);
        println!(
            "{label:<22} checksum {:016x}, {:>3} generations, {} dirty pairs, chain fp \
             {:016x} — {}",
            r.checksum(),
            r.generations,
            r.dirty_pairs,
            r.chain_fp,
            if ok { "bitwise identical" } else { "DIVERGED" }
        );
    }
    anyhow::ensure!(failures == 0, "{failures} mdlite arm(s) diverged from the oracle");
    println!("mdlite OK: every arm bitwise identical to the full-recompile oracle");
    Ok(())
}

/// `repro validate --transport socket`: all nine (workload × protocol)
/// combinations over the loopback socket world, measured against the model
/// with the socket probe's τ/bandwidth substituted. Exits nonzero when any
/// row (or the geomean) leaves the ratio budget.
fn cmd_validate_transport(args: &Args) -> Result<()> {
    let procs = args.usize_flag("procs", 2)?;
    let steps = args.usize_flag("steps", 6)? as u64;
    let budget = args.usize_flag("budget", 25)? as f64;
    let quick = args.bool_flag("quick");
    args.finish()?;
    upcsim::transport::validate_transport(procs, steps, quick, budget)?;
    println!("transport validation OK ({procs} ranks over loopback sockets)");
    Ok(())
}

/// `repro validate --optimize`: measured raw-vs-optimized per-step speedup
/// for every workload against the model's prediction from the condensed
/// message count and volume. Exits nonzero when any row (or the geomean)
/// leaves the ratio budget.
fn cmd_validate_planopt(args: &Args) -> Result<()> {
    let procs = args.usize_flag("procs", 2)?;
    let steps = args.usize_flag("steps", 4)? as u64;
    let budget = args.usize_flag("budget", 25)? as f64;
    let quick = args.bool_flag("quick");
    args.finish()?;
    upcsim::harness::validate_planopt(procs, steps, quick, budget)?;
    println!("plan-optimizer validation OK ({procs} ranks, in-process)");
    Ok(())
}

/// `repro validate --dynamic`: mdlite's measured per-step cost at static
/// and K ∈ {16, 64} rebuild periods against the rebuild-amortization
/// model. Exits nonzero when any row leaves the ratio budget.
fn cmd_validate_dynamic(args: &Args) -> Result<()> {
    let budget = args.usize_flag("budget", 25)? as f64;
    let quick = args.bool_flag("quick");
    args.finish()?;
    upcsim::harness::validate_dynamic(quick, budget)?;
    println!("dynamic-pattern validation OK (mdlite rebuild amortization)");
    Ok(())
}

fn cmd_validate_model(args: &Args) -> Result<()> {
    if args.bool_flag("optimize") {
        return cmd_validate_planopt(args);
    }
    if args.bool_flag("dynamic") {
        return cmd_validate_dynamic(args);
    }
    match args.str_flag("transport").unwrap_or("inproc") {
        "inproc" => {}
        "socket" => return cmd_validate_transport(args),
        other => bail!("unknown transport '{other}' (inproc | socket)"),
    }
    // Host parameters by default: validating the paper's Abel constants
    // against this machine's wall-clock would be comparing different
    // hardware. Likewise the engine defaults to the parallel pool — the
    // models predict concurrent execution — but `--engine seq` times the
    // sequential oracle for comparison.
    let mut cfg = harness_config_with_hw(args, HwSource::Host)?;
    if args.str_flag("engine").is_none() {
        cfg.engine = Engine::Parallel;
    }
    let steps = args.usize_flag("steps", 12)?;
    let pipeline = args.usize_flag("pipeline", 8)?.max(1);
    let depth = match parse_depth_flag(args)? {
        Some(d) => d,
        None => {
            let d = harness::model_chosen_depth(&cfg, pipeline);
            println!("[--depth auto resolved to D = {d} on the depth-sweep grid]");
            d
        }
    };
    let budget = args.usize_flag("budget", 0)? as f64;
    let json_path: std::path::PathBuf = args.str_flag("json").unwrap_or("BENCH_model.json").into();
    args.finish()?;
    let mut ws = Workspace::new();
    // A wedged exchange (deadlocked wait, stalled peer) surfaces as a
    // structured StallError panic from the worker pool; catch it here so
    // `repro validate` reports *which* wait stalled instead of a bare
    // abort.
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        harness::model_validation(&cfg, &mut ws, steps, pipeline, depth)
    }));
    let report = match caught {
        Ok(r) => r,
        Err(payload) => {
            if let Some(stall) = upcsim::engine::StallError::from_panic(payload.as_ref()) {
                eprintln!("validation aborted: {stall}");
                bail!("model validation stalled — see the stall report above");
            }
            std::panic::resume_unwind(payload);
        }
    };
    harness::emit(&cfg, "validate_model", &report.table);
    std::fs::write(&json_path, report.json.pretty())
        .map_err(|e| anyhow!("cannot write {}: {e}", json_path.display()))?;
    println!("[model accuracy saved to {}]", json_path.display());
    // The budget gate runs after every artifact (table + JSON) is emitted,
    // so a failing run still leaves its evidence behind. `--budget 0`
    // (the default) reports without gating.
    let mut outside = Vec::new();
    let mut check = |label: String, g: f64| {
        if budget > 1.0 && !(g.is_finite() && g <= budget && g >= 1.0 / budget) {
            outside.push(label);
        }
    };
    for variant in Variant::ALL {
        let g = report.geomean_ratio(variant);
        println!("{:<9} measured/predicted geomean = {g:.2}x", variant.name());
        check(format!("{} = {g:.2}x", variant.name()), g);
    }
    for workload in harness::WORKLOAD_LABELS {
        let g = report.workload_geomean(workload);
        println!("{workload:<13} measured/predicted geomean = {g:.2}x");
        check(format!("{workload} = {g:.2}x"), g);
    }
    if !outside.is_empty() {
        bail!(
            "measured/predicted geomeans outside the {budget:.0}x budget: {}",
            outside.join(", ")
        );
    }
    Ok(())
}

/// How an injected fault ended: a structured stall, a poisoned dispatch, or
/// a clean completion (which fails the drill — the fault went unnoticed).
enum ChaosOutcome {
    Stall(upcsim::engine::StallError),
    Poison(String),
    Clean,
}

impl ChaosOutcome {
    fn converted(&self) -> bool {
        !matches!(self, ChaosOutcome::Clean)
    }

    fn describe(&self) -> String {
        match self {
            ChaosOutcome::Stall(s) => format!("stall: {s}"),
            ChaosOutcome::Poison(msg) => format!("poison: {msg}"),
            ChaosOutcome::Clean => "completed cleanly".into(),
        }
    }
}

/// Classify a `catch_unwind` result from a fault-injected batch.
fn classify_chaos(result: std::thread::Result<()>) -> ChaosOutcome {
    use upcsim::engine::StallError;
    match result {
        Ok(()) => ChaosOutcome::Clean,
        Err(payload) => {
            if let Some(stall) = StallError::from_panic(payload.as_ref()) {
                return ChaosOutcome::Stall(stall.clone());
            }
            let msg = if let Some(s) = payload.downcast_ref::<&'static str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            ChaosOutcome::Poison(msg)
        }
    }
}

fn cmd_chaos(args: &Args) -> Result<()> {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::time::Duration;
    use upcsim::comm::Analysis;
    use upcsim::engine::{FaultKind, FaultPlan, Phase, SpmvEngine, INJECTED_DELAY};
    use upcsim::heat2d::Heat2dSolver;
    use upcsim::matrix::Ellpack;
    use upcsim::model::HeatGrid;
    use upcsim::pgas::{Layout, Topology};
    use upcsim::spmv::SpmvState;
    use upcsim::stencil3d::{Stencil3dGrid, Stencil3dSolver};

    let deadline_ms = args.usize_flag("deadline-ms", 150)?;
    let steps = args.usize_flag("steps", 6)?.max(4);
    let seed = args.str_flag("seed").map(|s| s.parse::<u64>()).transpose()?;
    args.finish()?;
    let deadline = Duration::from_millis(deadline_ms as u64);
    anyhow::ensure!(
        deadline < INJECTED_DELAY,
        "--deadline-ms must stay under the injected delay ({} ms) or delay faults cannot stall",
        INJECTED_DELAY.as_millis()
    );

    // Named scenarios: the four fault families, each injected into thread 0
    // at exchange epoch 2 of a pipelined batch.
    let mut scenarios: Vec<(String, FaultPlan)> = vec![
        (
            "delayed publish".into(),
            FaultPlan::none().with(0, 2, FaultKind::DelayPublish(INJECTED_DELAY)),
        ),
        ("dropped publish".into(), FaultPlan::none().with(0, 2, FaultKind::DropPublish)),
        ("panic at pack".into(), FaultPlan::none().with(0, 2, FaultKind::PanicAt(Phase::Pack))),
        (
            "slow receiver".into(),
            FaultPlan::none().with(0, 2, FaultKind::SlowReceiver(INJECTED_DELAY)),
        ),
    ];
    if let Some(seed) = seed {
        // Epochs capped at 2 so ack-side faults still have gated epochs
        // left in the batch to stall.
        let plan = FaultPlan::random(seed, 4, 2);
        scenarios.push((format!("random (seed {seed}): {:?}", plan.faults()[0]), plan));
    }

    // The drill intentionally panics workers; silence the default hook so
    // the table below is the report, not a wall of backtraces.
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));

    let mut rng = upcsim::util::Rng::new(13);
    let f2d: Vec<f64> = (0..32 * 32).map(|_| rng.f64_in(0.0, 100.0)).collect();
    let grid2d = HeatGrid::new(32, 32, 2, 2);
    let f3d: Vec<f64> = (0..16 * 16 * 16).map(|_| rng.f64_in(0.0, 100.0)).collect();
    let grid3d = Stencil3dGrid::new(16, 16, 16, 1, 2, 2);
    let mat = Ellpack::random(1500, 8, 5);
    let bs = mat.n.div_ceil(4 * 4);
    let layout = Layout::new(mat.n, bs, 4);
    let analysis = Analysis::build(&mat.j, mat.r_nz, layout, Topology::single_node(4), usize::MAX);
    let x0 = mat.initial_vector(9);

    let mut table = fmt::Table::new(
        format!("chaos drill — pipelined protocol, {steps}-step batches, {deadline_ms} ms deadline"),
        &["Workload", "Injected fault", "Outcome"],
    );
    let mut failures = 0usize;
    for (name, plan) in &scenarios {
        // heat2d.
        let mut heat = Heat2dSolver::new(grid2d, &f2d);
        heat.runtime_mut().set_wait_deadline(Some(deadline));
        heat.runtime_mut().set_fault_plan(plan.clone());
        let res = catch_unwind(AssertUnwindSafe(|| {
            heat.run_pipelined_with(Engine::Parallel, steps);
        }));
        let outcome = classify_chaos(res);
        failures += usize::from(!outcome.converted());
        table.row(vec!["heat2d".into(), name.clone(), outcome.describe()]);

        // stencil3d.
        let mut sten = Stencil3dSolver::new(grid3d, &f3d);
        sten.runtime_mut().set_wait_deadline(Some(deadline));
        sten.runtime_mut().set_fault_plan(plan.clone());
        let res = catch_unwind(AssertUnwindSafe(|| {
            sten.run_pipelined_with(Engine::Parallel, steps);
        }));
        let outcome = classify_chaos(res);
        failures += usize::from(!outcome.converted());
        table.row(vec!["stencil3d".into(), name.clone(), outcome.describe()]);

        // SpMV V3 pipelined.
        let mut engine = SpmvEngine::new(Engine::Parallel);
        engine.set_wait_deadline(Some(deadline));
        engine.set_fault_plan(plan.clone());
        let mut state = SpmvState::new(&mat, bs, 4, &x0);
        let res = catch_unwind(AssertUnwindSafe(|| {
            engine.run_pipelined(steps, &mut state, &analysis);
        }));
        let outcome = classify_chaos(res);
        failures += usize::from(!outcome.converted());
        table.row(vec!["spmv-v3".into(), name.clone(), outcome.describe()]);
    }
    std::panic::set_hook(hook);
    println!("{}", table.render());

    // Checkpoint/restart round-trip: checkpoint every 2 steps, kill the
    // continuation with a dropped publish, resume a fresh solver from the
    // last checkpoint, and demand bitwise identity with an uninterrupted
    // run.
    let total = 8usize;
    let mut reference = Heat2dSolver::new(grid2d, &f2d);
    reference.run_pipelined_with(Engine::Parallel, total);

    let mut victim = Heat2dSolver::new(grid2d, &f2d);
    victim.runtime_mut().set_wait_deadline(Some(deadline));
    let mut last = None;
    victim.run_pipelined_checkpointed_with(Engine::Parallel, total / 2, 2, &mut |c| {
        last = Some(c);
    });
    let kill_epoch = victim.runtime().epoch() + 1;
    let kill = FaultPlan::none().with(0, kill_epoch, FaultKind::DropPublish);
    victim.runtime_mut().set_fault_plan(kill);
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let killed = catch_unwind(AssertUnwindSafe(|| {
        victim.run_pipelined_with(Engine::Parallel, total - total / 2);
    }))
    .is_err();
    std::panic::set_hook(hook);

    let ck = last.expect("checkpointed run sank at least one checkpoint");
    let mut resumed = Heat2dSolver::new(grid2d, &f2d);
    let done = resumed.restore(&ck).map_err(|e| anyhow!(e))? as usize;
    resumed.run_pipelined_with(Engine::Parallel, total - done);
    let identical = reference
        .to_global()
        .iter()
        .zip(resumed.to_global().iter())
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "checkpoint/restart: killed mid-run = {killed}, resumed from step {done}, \
         bitwise identical to uninterrupted = {identical}, bytes {} vs {}",
        resumed.inter_thread_bytes, reference.inter_thread_bytes
    );

    anyhow::ensure!(killed, "the kill fault did not poison the continuation batch");
    anyhow::ensure!(identical, "resumed run diverged from the uninterrupted run");
    anyhow::ensure!(
        resumed.inter_thread_bytes == reference.inter_thread_bytes,
        "resumed byte counter diverged"
    );
    if failures > 0 {
        bail!("{failures} injected fault(s) completed without a stall or poison");
    }
    println!("chaos drill OK: every injected fault converted within the deadline");
    Ok(())
}

fn parse_problem(args: &Args) -> Result<Problem> {
    match args.str_flag("problem").unwrap_or("tp1") {
        "tp1" => Ok(Problem::Tp(TestProblem::Tp1)),
        "tp2" => Ok(Problem::Tp(TestProblem::Tp2)),
        "tp3" => Ok(Problem::Tp(TestProblem::Tp3)),
        "custom" => Ok(Problem::Custom(args.usize_flag("n", 100_000)?)),
        other => bail!("unknown problem '{other}'"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let problem = parse_problem(args)?;
    let mut cfg = RunConfig::default_for(problem);
    cfg.scale_div = if args.bool_flag("full-scale") { 1 } else { args.usize_flag("scale", 16)? };
    cfg.nodes = args.usize_flag("nodes", 2)?;
    cfg.threads_per_node = args.usize_flag("tpn", 16)?;
    cfg.iters = args.usize_flag("iters", 1000)?;
    cfg.exec_steps = args.usize_flag("steps", 100)?;
    let depth_flag = parse_depth_flag(args)?;
    cfg.depth = depth_flag.unwrap_or(2);
    cfg.auto_depth = depth_flag.is_none();
    if let Some(bs) = args.str_flag("blocksize") {
        cfg.block_size = Some(bs.parse().map_err(|_| anyhow!("--blocksize expects an integer"))?);
    }
    if let Some(v) = args.str_flag("variant") {
        cfg.variant = Variant::parse(v).ok_or_else(|| anyhow!("unknown variant '{v}'"))?;
    }
    if let Some(o) = args.str_flag("ordering") {
        cfg.ordering = Ordering::parse(o).ok_or_else(|| anyhow!("unknown ordering '{o}'"))?;
    }
    cfg.backend = match args.str_flag("backend").unwrap_or("native") {
        "native" => Backend::Native,
        "pjrt" => Backend::Pjrt,
        other => bail!("unknown backend '{other}'"),
    };
    cfg.engine = parse_engine(args)?;
    let (hw, hw_label) = resolve_hw(args, HwSource::Abel)?;
    cfg.hw = hw;
    args.finish()?;

    // The PJRT backend always runs the sequential oracle path; report the
    // engine that will actually execute, not the one requested.
    let effective_engine = match cfg.backend {
        Backend::Pjrt => Engine::Sequential,
        Backend::Native => cfg.engine,
    };
    if cfg.backend == Backend::Pjrt && cfg.engine == Engine::Parallel {
        eprintln!("note: --backend pjrt runs on the sequential engine; --engine par is ignored");
    }
    println!(
        "# end-to-end diffusion driver: {} on {:?}, {} nodes x {} threads, backend {:?}, engine {}, hw {}",
        cfg.variant.name(),
        cfg.problem,
        cfg.nodes,
        cfg.threads_per_node,
        cfg.backend,
        effective_engine.name(),
        hw_label
    );
    let iters = cfg.iters;
    let steps = cfg.exec_steps;
    let report = Runner::new(cfg).run()?;
    println!("n                = {}", fmt::int(report.n));
    println!("BLOCKSIZE        = {}", report.block_size);
    println!(
        "pipeline depth   = {}{}",
        report.depth,
        if depth_flag.is_none() { " (--depth auto, model pick)" } else { "" }
    );
    println!("simulated total  = {} ({} iters)", fmt::secs(report.sim_total), iters);
    println!("model predicted  = {}", fmt::secs(report.model_total));
    println!("sim/model ratio  = {:.3}", report.sim_total / report.model_total);
    println!("executed steps   = {} in {} host wall-clock", steps, fmt::secs(report.exec_wall));
    println!("inter-thread     = {} per step", fmt::bytes(report.step_bytes as f64));
    println!("checksum         = {:.9e}", report.checksum);
    println!("final max|x|     = {:.6}", report.final_max);
    let show = report.residuals.len().min(8);
    println!(
        "residuals        = {:?} ... (first {show} of {})",
        report.residuals[..show].iter().map(|r| format!("{r:.3e}")).collect::<Vec<_>>(),
        report.residuals.len()
    );
    Ok(())
}

/// Map a logical thread count onto a simulated cluster shape: the most
/// threads per node the Abel-style 16-core nodes can hold **while exactly
/// factoring `threads`** (the models assert `nodes · tpn == threads`, so
/// `threads/16` rounding is not an option for, say, 24 threads).
fn cluster_shape(threads: usize) -> (usize, usize) {
    let tpn = (1..=threads.min(16)).rev().find(|d| threads % d == 0).unwrap_or(1);
    (threads / tpn, tpn)
}

fn cmd_heat(args: &Args) -> Result<()> {
    use upcsim::heat2d::{seq_reference_step, simulate_heat_step, Heat2dSolver};
    use upcsim::model::{
        choose_depth, predict_heat2d, predict_heat2d_overlap, predict_heat2d_overlap_fused,
        predict_heat2d_pipelined, HeatGrid,
    };
    use upcsim::pgas::Topology;
    use upcsim::sim::SimParams;
    let mg = args.usize_flag("m", 512)?;
    let ng = args.usize_flag("n", mg)?;
    let mp = args.usize_flag("mprocs", 4)?;
    let np = args.usize_flag("nprocs", 4)?;
    let steps = args.usize_flag("steps", 50)?;
    let overlap = args.bool_flag("overlap");
    let fused = args.bool_flag("fused");
    let pipeline = args.usize_flag("pipeline", 0)?;
    let depth_flag = parse_depth_flag(args)?;
    let engine = parse_engine(args)?;
    let (hw, hw_label) = resolve_hw(args, HwSource::Abel)?;
    args.finish()?;
    anyhow::ensure!(
        usize::from(overlap) + usize::from(fused) + usize::from(pipeline > 0) <= 1,
        "--overlap, --fused and --pipeline are mutually exclusive step protocols"
    );
    let grid = HeatGrid::new(mg, ng, mp, np);
    let threads = grid.threads();
    let (nodes, tpn) = cluster_shape(threads);
    let topo = Topology::new(nodes, tpn);
    // Rescale the per-thread bandwidth share to the threads actually
    // sharing a node (§5.1), as the SpMV consumers do.
    let hw = hw.with_threads_per_node(tpn);
    // Resolve `--depth auto` before the solver exists: the same
    // `choose_depth` sweep reported at the bottom, on this run's own grid.
    let ovl = predict_heat2d_overlap(&grid, &topo, &hw);
    let batch = if pipeline > 0 { pipeline } else { 8 };
    let (d_star, best) = choose_depth(&ovl, batch, hw.tau);
    let buf_depth = depth_flag.unwrap_or(d_star);

    // Real numerics vs the sequential stencil.
    let mut rng = upcsim::util::Rng::new(7);
    let f0: Vec<f64> = (0..mg * ng).map(|_| rng.f64_in(0.0, 100.0)).collect();
    let mut solver = Heat2dSolver::new(grid, &f0);
    solver.set_depth(buf_depth);
    let mut reference = f0.clone();
    let t0 = std::time::Instant::now();
    if pipeline > 0 {
        // Multi-step pipelined batches: one pool dispatch per batch.
        let mut left = steps;
        while left > 0 {
            let batch = left.min(pipeline);
            solver.run_pipelined_with(engine, batch);
            left -= batch;
        }
    } else if fused {
        // The fused boundary step runs on the sequential oracle engine only
        // (the parallel pool has no fused arm yet).
        for _ in 0..steps {
            solver.step_fused();
        }
    } else {
        for _ in 0..steps {
            if overlap {
                solver.step_overlapped_with(engine);
            } else {
                solver.step_with(engine);
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    for _ in 0..steps {
        reference = seq_reference_step(mg, ng, &reference);
    }
    let err = solver
        .to_global()
        .iter()
        .zip(&reference)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let protocol = if pipeline > 0 {
        format!("pipelined ({pipeline}-step batches, depth {buf_depth}) ")
    } else if fused {
        "fused split-phase ".to_string()
    } else if overlap {
        "split-phase overlapped ".to_string()
    } else {
        String::new()
    };
    println!("{steps} {protocol}steps on {mg}x{ng} over {mp}x{np} threads in {}", fmt::secs(wall));
    println!("max |parallel − sequential| = {err:.3e}");
    anyhow::ensure!(err < 1e-9, "halo exchange diverged");
    println!("halo payload: {}", fmt::bytes(solver.inter_thread_bytes as f64));
    let sim = simulate_heat_step(&grid, &topo, &hw, &SimParams::from_hw(&hw));
    let model = predict_heat2d(&grid, &topo, &hw);
    println!(
        "per 1000 steps on the simulated cluster (hw {hw_label}): T_halo {} (model {}), T_comp {} (model {})",
        fmt::secs(sim.t_halo * 1000.0),
        fmt::secs(model.t_halo * 1000.0),
        fmt::secs(sim.t_comp * 1000.0),
        fmt::secs(model.t_comp * 1000.0),
    );
    println!(
        "overlap model: T_step {} vs sync {} per 1000 steps ({:.2}x modeled speedup)",
        fmt::secs(ovl.t_step * 1000.0),
        fmt::secs(ovl.t_step_sync * 1000.0),
        ovl.speedup(),
    );
    let fus = predict_heat2d_overlap_fused(&grid, &topo, &hw);
    println!(
        "fused model: T_step {} per 1000 steps ({:.2}x vs plain overlap)",
        fmt::secs(fus.t_step * 1000.0),
        ovl.t_step / fus.t_step,
    );
    let pipe = predict_heat2d_pipelined(&grid, &topo, &hw, batch);
    println!(
        "pipeline model ({batch}-step batches): {} per step steady-state ({:.2}x vs sync, {:.2}x vs overlapped)",
        fmt::secs(pipe.t_per_step),
        pipe.speedup_vs_sync(),
        pipe.speedup_vs_overlapped(),
    );
    println!(
        "buffer depth: running D = {buf_depth}{}; model prefers D = {d_star} ({} per step)",
        if depth_flag.is_none() { " (auto)" } else { "" },
        fmt::secs(best.t_per_step),
    );
    Ok(())
}

fn cmd_stencil(args: &Args) -> Result<()> {
    use upcsim::model::{
        choose_depth, predict_stencil3d, predict_stencil3d_overlap, predict_stencil3d_pipelined,
    };
    use upcsim::pgas::Topology;
    use upcsim::stencil3d::{seq_reference_step3d, Stencil3dGrid, Stencil3dSolver};
    let pg = args.usize_flag("p", 64)?;
    let mg = args.usize_flag("m", pg)?;
    let ng = args.usize_flag("n", mg)?;
    let pp = args.usize_flag("pprocs", 1)?;
    let mp = args.usize_flag("mprocs", 2)?;
    let np = args.usize_flag("nprocs", 2)?;
    let steps = args.usize_flag("steps", 20)?;
    let overlap = args.bool_flag("overlap");
    let pipeline = args.usize_flag("pipeline", 0)?;
    let depth_flag = parse_depth_flag(args)?;
    let engine = parse_engine(args)?;
    let (hw, hw_label) = resolve_hw(args, HwSource::Abel)?;
    args.finish()?;
    anyhow::ensure!(
        pg % pp == 0 && mg % mp == 0 && ng % np == 0,
        "box {pg}x{mg}x{ng} does not partition over {pp}x{mp}x{np} threads"
    );
    anyhow::ensure!(
        !(overlap && pipeline > 0),
        "--overlap and --pipeline are mutually exclusive step protocols"
    );
    let grid = Stencil3dGrid::new(pg, mg, ng, pp, mp, np);
    let threads = grid.threads();
    let (nodes, tpn) = cluster_shape(threads);
    let topo = Topology::new(nodes, tpn);
    let hw = hw.with_threads_per_node(tpn);
    // Resolve `--depth auto` before the solver exists (as `cmd_heat` does).
    let ovl = predict_stencil3d_overlap(&grid, &topo, &hw);
    let batch = if pipeline > 0 { pipeline } else { 8 };
    let (d_star, best) = choose_depth(&ovl, batch, hw.tau);
    let buf_depth = depth_flag.unwrap_or(d_star);

    // Real numerics vs the sequential 7-point stencil.
    let mut rng = upcsim::util::Rng::new(11);
    let f0: Vec<f64> = (0..pg * mg * ng).map(|_| rng.f64_in(0.0, 100.0)).collect();
    let mut solver = Stencil3dSolver::new(grid, &f0);
    solver.set_depth(buf_depth);
    let mut reference = f0.clone();
    let t0 = std::time::Instant::now();
    if pipeline > 0 {
        let mut left = steps;
        while left > 0 {
            let batch = left.min(pipeline);
            solver.run_pipelined_with(engine, batch);
            left -= batch;
        }
    } else {
        for _ in 0..steps {
            if overlap {
                solver.step_overlapped_with(engine);
            } else {
                solver.step_with(engine);
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    for _ in 0..steps {
        reference = seq_reference_step3d(pg, mg, ng, &reference);
    }
    let err = solver
        .to_global()
        .iter()
        .zip(&reference)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let protocol = if pipeline > 0 {
        format!("pipelined ({pipeline}-step batches, depth {buf_depth}) ")
    } else if overlap {
        "split-phase overlapped ".to_string()
    } else {
        String::new()
    };
    println!(
        "{steps} {protocol}steps on {pg}x{mg}x{ng} over {pp}x{mp}x{np} threads ({} engine) in {}",
        engine.name(),
        fmt::secs(wall)
    );
    println!("max |solver − sequential| = {err:.3e}");
    anyhow::ensure!(err < 1e-9, "face exchange diverged");
    println!("halo payload: {}", fmt::bytes(solver.inter_thread_bytes as f64));
    println!(
        "compiled plan: {} messages, {} doubles/step",
        solver.runtime().plan().num_messages(),
        solver.runtime().plan().total_values()
    );
    let model = predict_stencil3d(&grid, &topo, &hw);
    println!(
        "per 1000 steps on the simulated cluster (hw {hw_label}): T_halo {} T_comp {}",
        fmt::secs(model.t_halo * 1000.0),
        fmt::secs(model.t_comp * 1000.0),
    );
    println!(
        "overlap model: T_step {} vs sync {} per 1000 steps ({:.2}x modeled speedup)",
        fmt::secs(ovl.t_step * 1000.0),
        fmt::secs(ovl.t_step_sync * 1000.0),
        ovl.speedup(),
    );
    let pipe = predict_stencil3d_pipelined(&grid, &topo, &hw, batch);
    println!(
        "pipeline model ({batch}-step batches): {} per step steady-state ({:.2}x vs sync, {:.2}x vs overlapped)",
        fmt::secs(pipe.t_per_step),
        pipe.speedup_vs_sync(),
        pipe.speedup_vs_overlapped(),
    );
    println!(
        "buffer depth: running D = {buf_depth}{}; model prefers D = {d_star} ({} per step)",
        if depth_flag.is_none() { " (auto)" } else { "" },
        fmt::secs(best.t_per_step),
    );
    Ok(())
}

fn cmd_validate_pjrt(args: &Args) -> Result<()> {
    let scale = args.usize_flag("scale", 256)?;
    args.finish()?;
    let mut cfg = RunConfig::default_for(Problem::Tp(TestProblem::Tp1));
    cfg.scale_div = scale;
    cfg.exec_steps = 3;
    cfg.nodes = 1;
    cfg.threads_per_node = 8;
    cfg.backend = Backend::Native;
    let mesh = Runner::new(cfg.clone()).build_mesh();
    let native = Runner::new(cfg.clone()).run_on(&mesh)?;
    cfg.backend = Backend::Pjrt;
    let pjrt = Runner::new(cfg).run_on(&mesh)?;
    let rel = (native.checksum - pjrt.checksum).abs() / native.checksum.abs().max(1e-30);
    println!("native checksum = {:.12e}", native.checksum);
    println!("pjrt   checksum = {:.12e}", pjrt.checksum);
    println!("relative diff   = {rel:.3e}");
    if rel > 1e-4 {
        bail!("PJRT artifacts diverge from the native kernel (rel {rel:.3e})");
    }
    println!("validate OK (within f32 tolerance)");
    Ok(())
}
