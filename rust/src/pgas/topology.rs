//! Cluster topology: how UPC threads map onto compute nodes.
//!
//! The paper's §5.2.1 distinction between *local inter-thread* and *remote
//! inter-thread* memory operations hinges on this mapping. Threads are
//! packed onto nodes in consecutive runs (the standard `upcrun` placement on
//! Abel: threads 0..15 on node 0, 16..31 on node 1, …).

/// Node/thread topology of the (simulated) cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Number of compute nodes.
    pub nodes: usize,
    /// UPC threads per node (paper uses 16 on Abel).
    pub threads_per_node: usize,
}

impl Topology {
    pub fn new(nodes: usize, threads_per_node: usize) -> Topology {
        assert!(nodes > 0 && threads_per_node > 0);
        Topology { nodes, threads_per_node }
    }

    /// A single-node topology with `threads` threads (Table 2 scenarios).
    pub fn single_node(threads: usize) -> Topology {
        Topology::new(1, threads)
    }

    /// Total number of UPC threads.
    #[inline]
    pub fn threads(&self) -> usize {
        self.nodes * self.threads_per_node
    }

    /// Node hosting `thread`.
    #[inline]
    pub fn node_of_thread(&self, thread: usize) -> usize {
        debug_assert!(thread < self.threads());
        thread / self.threads_per_node
    }

    /// Whether two threads share a node (local inter-thread traffic).
    #[inline]
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of_thread(a) == self.node_of_thread(b)
    }

    /// Iterator over the threads hosted by `node`.
    pub fn threads_of_node(&self, node: usize) -> std::ops::Range<usize> {
        debug_assert!(node < self.nodes);
        node * self.threads_per_node..(node + 1) * self.threads_per_node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapping() {
        let t = Topology::new(4, 16);
        assert_eq!(t.threads(), 64);
        assert_eq!(t.node_of_thread(0), 0);
        assert_eq!(t.node_of_thread(15), 0);
        assert_eq!(t.node_of_thread(16), 1);
        assert_eq!(t.node_of_thread(63), 3);
        assert!(t.same_node(17, 31));
        assert!(!t.same_node(15, 16));
        assert_eq!(t.threads_of_node(2), 32..48);
    }

    #[test]
    fn single_node() {
        let t = Topology::single_node(8);
        assert_eq!(t.threads(), 8);
        assert!(t.same_node(0, 7));
    }
}
