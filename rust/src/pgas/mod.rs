//! PGAS substrate: UPC-style block-cyclic shared-array layout and storage.
//!
//! This module reproduces the semantics of `upc_all_alloc(nblks, nbytes)`
//! (paper §2): a shared array of `nblks` blocks of `block_size` elements,
//! whose blocks are distributed cyclically over threads; blocks owned by a
//! thread are stored contiguously in that thread's local memory. The
//! owner-thread formula is the paper's eq. (1):
//!
//! ```text
//! owner_thread_id = floor(global_index / block_size) mod THREADS
//! ```

mod layout;
mod shared_vec;
mod topology;

pub use layout::Layout;
pub use shared_vec::SharedVec;
pub use topology::Topology;
