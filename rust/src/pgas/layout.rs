//! Block-cyclic layout math (paper §2, eq. (1)).

use crate::util::{ceil_div, FastDiv};

/// The block-cyclic distribution of an `n`-element shared array over
/// `threads` UPC threads with a programmer-chosen `block_size`
/// (the paper's `BLOCKSIZE`).
///
/// All index math is centralized here; every other module (comm analysis,
/// models, executors) goes through this type, so eq. (1) exists exactly once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layout {
    /// Number of elements in the shared array (the paper's `n`).
    pub n: usize,
    /// Elements per block (the paper's `BLOCKSIZE`).
    pub block_size: usize,
    /// Number of UPC threads (the paper's `THREADS`).
    pub threads: usize,
    /// Reciprocal-multiply divider for `block_size` (§Perf: the analyzer
    /// performs one index→owner division per nonzero).
    bs_div: FastDiv,
    /// Reciprocal-multiply divider for `threads`.
    thr_div: FastDiv,
}

impl Layout {
    pub fn new(n: usize, block_size: usize, threads: usize) -> Layout {
        assert!(n > 0, "empty shared array");
        assert!(block_size > 0, "BLOCKSIZE must be positive");
        assert!(threads > 0, "THREADS must be positive");
        assert!(n <= u32::MAX as usize, "indices must fit u32");
        Layout {
            n,
            block_size,
            threads,
            bs_div: FastDiv::new(block_size),
            thr_div: FastDiv::new(threads),
        }
    }

    /// Total number of blocks (`nblks` in Listing 2).
    #[inline]
    pub fn nblks(&self) -> usize {
        ceil_div(self.n, self.block_size)
    }

    /// Owner thread of global block `b` (cyclic distribution).
    #[inline]
    pub fn owner_of_block(&self, b: usize) -> usize {
        debug_assert!(b < self.nblks());
        b % self.threads
    }

    /// Owner thread of global element index `i` — the paper's eq. (1).
    #[inline]
    pub fn owner_of_index(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        self.thr_div.rem(self.bs_div.div(i))
    }

    /// Global block id containing element `i`.
    #[inline]
    pub fn block_of_index(&self, i: usize) -> usize {
        self.bs_div.div(i)
    }

    /// Phase (offset within its block) of element `i`.
    #[inline]
    pub fn phase_of_index(&self, i: usize) -> usize {
        self.bs_div.rem(i)
    }

    /// Number of blocks owned by `thread` — the paper's
    /// `mythread_nblks = nblks/THREADS + (MYTHREAD < nblks%THREADS ? 1 : 0)`.
    #[inline]
    pub fn nblks_of_thread(&self, thread: usize) -> usize {
        let nblks = self.nblks();
        nblks / self.threads + usize::from(thread < nblks % self.threads)
    }

    /// Number of *elements* owned by `thread` (last block may be short).
    pub fn nelems_of_thread(&self, thread: usize) -> usize {
        self.blocks_of_thread(thread)
            .map(|b| self.block_len(b))
            .sum()
    }

    /// Iterator over the global block ids owned by `thread`, in storage order
    /// (the order they appear in the owner's contiguous local memory).
    pub fn blocks_of_thread(&self, thread: usize) -> impl Iterator<Item = usize> + '_ {
        let nblks = self.nblks();
        (thread..nblks).step_by(self.threads)
    }

    /// Global element range `[start, start+len)` covered by block `b`
    /// (`len < block_size` only for the tail block).
    #[inline]
    pub fn block_range(&self, b: usize) -> (usize, usize) {
        let start = b * self.block_size;
        (start, self.block_len(b))
    }

    /// Length of block `b` (tail block may be short).
    #[inline]
    pub fn block_len(&self, b: usize) -> usize {
        let start = b * self.block_size;
        debug_assert!(start < self.n);
        (self.n - start).min(self.block_size)
    }

    /// Position of block `b` within its owner's sequence of blocks
    /// (`mb` in Listing 3: block `b = mb*THREADS + owner`).
    #[inline]
    pub fn local_block_index(&self, b: usize) -> usize {
        self.thr_div.div(b)
    }

    /// Offset of element `i` inside its owner thread's contiguous local
    /// storage. Blocks owned by a thread are stored back to back, each
    /// occupying a full `block_size` stride except a tail block, which is
    /// stored at its natural (non-padded) offset since it is the final one.
    #[inline]
    pub fn local_offset_of_index(&self, i: usize) -> usize {
        let b = self.block_of_index(i);
        self.local_block_index(b) * self.block_size + self.phase_of_index(i)
    }

    /// Whether indices `i` and `j` live in the same block.
    #[inline]
    pub fn same_block(&self, i: usize, j: usize) -> bool {
        self.block_of_index(i) == self.block_of_index(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check_prop;

    #[test]
    fn eq1_matches_paper_example() {
        // n=10, BLOCKSIZE=3, THREADS=2 → blocks [0..3)(t0) [3..6)(t1)
        // [6..9)(t0) [9..10)(t1)
        let l = Layout::new(10, 3, 2);
        assert_eq!(l.nblks(), 4);
        assert_eq!(l.owner_of_index(0), 0);
        assert_eq!(l.owner_of_index(2), 0);
        assert_eq!(l.owner_of_index(3), 1);
        assert_eq!(l.owner_of_index(6), 0);
        assert_eq!(l.owner_of_index(9), 1);
        assert_eq!(l.nblks_of_thread(0), 2);
        assert_eq!(l.nblks_of_thread(1), 2);
        assert_eq!(l.nelems_of_thread(0), 6);
        assert_eq!(l.nelems_of_thread(1), 4);
    }

    #[test]
    fn blocks_of_thread_order() {
        let l = Layout::new(100, 10, 3);
        assert_eq!(l.blocks_of_thread(0).collect::<Vec<_>>(), vec![0, 3, 6, 9]);
        assert_eq!(l.blocks_of_thread(1).collect::<Vec<_>>(), vec![1, 4, 7]);
        assert_eq!(l.blocks_of_thread(2).collect::<Vec<_>>(), vec![2, 5, 8]);
    }

    #[test]
    fn tail_block_short() {
        let l = Layout::new(25, 10, 2);
        assert_eq!(l.nblks(), 3);
        assert_eq!(l.block_len(0), 10);
        assert_eq!(l.block_len(2), 5);
        assert_eq!(l.block_range(2), (20, 5));
    }

    #[test]
    fn local_offsets_are_contiguous_per_thread() {
        let l = Layout::new(35, 10, 2);
        // thread 0 owns blocks 0, 2 → global [0..10) ∪ [20..30)
        // storage offsets: block0 at 0..10, block2 at 10..20
        assert_eq!(l.local_offset_of_index(0), 0);
        assert_eq!(l.local_offset_of_index(9), 9);
        assert_eq!(l.local_offset_of_index(20), 10);
        assert_eq!(l.local_offset_of_index(29), 19);
        // thread 1 owns blocks 1, 3 → [10..20) ∪ [30..35)
        assert_eq!(l.local_offset_of_index(10), 0);
        assert_eq!(l.local_offset_of_index(30), 10);
        assert_eq!(l.local_offset_of_index(34), 14);
    }

    /// Property: thread-block ownership is an exact partition of all blocks,
    /// and per-thread element counts sum to n.
    #[test]
    fn prop_partition_is_exact_cover() {
        check_prop(
            "layout-partition",
            crate::testing::default_cases(),
            |r| {
                let n = r.usize_in(1, 5000);
                let bs = r.usize_in(1, 600);
                let t = r.usize_in(1, 40);
                Layout::new(n, bs, t)
            },
            |l| {
                let mut seen = vec![false; l.nblks()];
                let mut elems = 0usize;
                for t in 0..l.threads {
                    let mut count = 0;
                    for b in l.blocks_of_thread(t) {
                        if seen[b] {
                            return Err(format!("block {b} assigned twice"));
                        }
                        if l.owner_of_block(b) != t {
                            return Err(format!("block {b} owner mismatch"));
                        }
                        seen[b] = true;
                        count += 1;
                        elems += l.block_len(b);
                    }
                    if count != l.nblks_of_thread(t) {
                        return Err(format!("nblks_of_thread({t}) wrong"));
                    }
                    if l.nelems_of_thread(t)
                        != l.blocks_of_thread(t).map(|b| l.block_len(b)).sum::<usize>()
                    {
                        return Err("nelems_of_thread inconsistent".into());
                    }
                }
                if !seen.iter().all(|&s| s) {
                    return Err("unassigned block".into());
                }
                if elems != l.n {
                    return Err(format!("element count {} != n {}", elems, l.n));
                }
                Ok(())
            },
        );
    }

    /// Property: per-element owner (eq. 1) agrees with block ownership, and
    /// local storage offsets are a bijection per thread.
    #[test]
    fn prop_eq1_and_local_offsets() {
        check_prop(
            "layout-eq1-offsets",
            crate::testing::default_cases(),
            |r| {
                let n = r.usize_in(1, 2000);
                let bs = r.usize_in(1, 300);
                let t = r.usize_in(1, 17);
                Layout::new(n, bs, t)
            },
            |l| {
                let mut per_thread: Vec<Vec<usize>> = vec![Vec::new(); l.threads];
                for i in 0..l.n {
                    let o = l.owner_of_index(i);
                    if o != l.owner_of_block(l.block_of_index(i)) {
                        return Err(format!("eq1 disagrees at {i}"));
                    }
                    per_thread[o].push(l.local_offset_of_index(i));
                }
                for (t, offs) in per_thread.iter().enumerate() {
                    let mut s = offs.clone();
                    s.sort_unstable();
                    s.dedup();
                    if s.len() != offs.len() {
                        return Err(format!("thread {t}: local offsets collide"));
                    }
                }
                Ok(())
            },
        );
    }
}
