//! A UPC-style shared array with per-thread contiguous block storage.
//!
//! Mirrors `upc_all_alloc(nblks, BLOCKSIZE * sizeof(T))` (paper §2): each
//! thread's blocks live back to back in that thread's own buffer, exactly as
//! a UPC runtime lays out affinity blocks in the owner's local memory. All
//! executors (`spmv::*`) operate on this type so that "casting a
//! pointer-to-shared to a pointer-to-local" has a faithful analogue: handing
//! out a slice of the owner's buffer.

use super::Layout;

/// A shared array of `f64`/`u32`/… distributed block-cyclically over threads.
#[derive(Debug, Clone)]
pub struct SharedVec<T> {
    layout: Layout,
    /// `store[t]` is thread t's contiguous local storage holding its blocks
    /// in `blocks_of_thread(t)` order, each at a `block_size` stride (the
    /// tail block simply ends early).
    store: Vec<Vec<T>>,
}

impl<T: Copy + Default> SharedVec<T> {
    /// Collectively allocate (zero-initialized), like `upc_all_alloc`.
    pub fn alloc(layout: Layout) -> SharedVec<T> {
        let store = (0..layout.threads)
            .map(|t| vec![T::default(); layout.nelems_of_thread(t)])
            .collect();
        SharedVec { layout, store }
    }

    /// Build from a global vector (convenience for tests/drivers).
    pub fn from_global(layout: Layout, global: &[T]) -> SharedVec<T> {
        assert_eq!(global.len(), layout.n);
        let mut v = SharedVec::alloc(layout);
        for (i, x) in global.iter().enumerate() {
            *v.at_mut(i) = *x;
        }
        v
    }

    /// Gather into a global vector (inverse of [`from_global`]).
    pub fn to_global(&self) -> Vec<T> {
        let mut out = vec![T::default(); self.layout.n];
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = *self.at(i);
        }
        out
    }

    #[inline]
    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// Element access through the global index — the analogue of
    /// dereferencing a pointer-to-shared (the costly path the paper's naive
    /// code takes). The *cost* is accounted by the simulator, not here.
    #[inline]
    pub fn at(&self, i: usize) -> &T {
        let t = self.layout.owner_of_index(i);
        &self.store[t][self.layout.local_offset_of_index(i)]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize) -> &mut T {
        let t = self.layout.owner_of_index(i);
        &mut self.store[t][self.layout.local_offset_of_index(i)]
    }

    /// The owner thread's whole local storage — the analogue of casting a
    /// pointer-to-shared to a pointer-to-local (Listing 3).
    #[inline]
    pub fn local(&self, thread: usize) -> &[T] {
        &self.store[thread]
    }

    #[inline]
    pub fn local_mut(&mut self, thread: usize) -> &mut [T] {
        &mut self.store[thread]
    }

    /// Every thread's local storage at once, as disjoint mutable slices —
    /// what the parallel engine hands its workers so each UPC thread writes
    /// its own shard with no synchronization (the owner-computes rule).
    pub fn locals_mut(&mut self) -> Vec<&mut [T]> {
        self.store.iter_mut().map(|v| v.as_mut_slice()).collect()
    }

    /// Contiguous slice of global block `b` inside its owner's storage —
    /// what `upc_memget(dst, &x[b*BLOCKSIZE], len)` reads.
    pub fn block(&self, b: usize) -> &[T] {
        let owner = self.layout.owner_of_block(b);
        let mb = self.layout.local_block_index(b);
        let start = mb * self.layout.block_size;
        let len = self.layout.block_len(b);
        &self.store[owner][start..start + len]
    }

    /// Mutable counterpart of [`block`].
    pub fn block_mut(&mut self, b: usize) -> &mut [T] {
        let owner = self.layout.owner_of_block(b);
        let mb = self.layout.local_block_index(b);
        let start = mb * self.layout.block_size;
        let len = self.layout.block_len(b);
        &mut self.store[owner][start..start + len]
    }

    /// Swap the contents of two shared arrays with identical layout — the
    /// pointer-to-shared swap fenced by barriers in the paper's §6.1 driver.
    pub fn swap(&mut self, other: &mut SharedVec<T>) {
        assert_eq!(self.layout, other.layout);
        std::mem::swap(&mut self.store, &mut other.store);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check_prop;

    #[test]
    fn global_roundtrip() {
        let l = Layout::new(23, 4, 3);
        let data: Vec<f64> = (0..23).map(|i| i as f64).collect();
        let v = SharedVec::from_global(l, &data);
        assert_eq!(v.to_global(), data);
        // spot-check affinity storage
        assert_eq!(*v.at(0), 0.0);
        assert_eq!(*v.at(22), 22.0);
    }

    #[test]
    fn block_slices_match_global() {
        let l = Layout::new(23, 4, 3);
        let data: Vec<u32> = (0..23u32).collect();
        let v = SharedVec::from_global(l, &data);
        for b in 0..l.nblks() {
            let (start, len) = l.block_range(b);
            assert_eq!(v.block(b), &data[start..start + len], "block {b}");
        }
    }

    #[test]
    fn local_is_contiguous_blocks() {
        let l = Layout::new(10, 3, 2);
        let data: Vec<u32> = (0..10u32).collect();
        let v = SharedVec::from_global(l, &data);
        // thread 0 owns blocks 0 [0,1,2] and 2 [6,7,8]
        assert_eq!(v.local(0), &[0, 1, 2, 6, 7, 8]);
        // thread 1 owns blocks 1 [3,4,5] and 3 [9]
        assert_eq!(v.local(1), &[3, 4, 5, 9]);
    }

    #[test]
    fn swap_swaps() {
        let l = Layout::new(8, 2, 2);
        let mut a = SharedVec::from_global(l, &[1.0f64; 8]);
        let mut b = SharedVec::from_global(l, &[2.0f64; 8]);
        a.swap(&mut b);
        assert_eq!(a.to_global(), vec![2.0; 8]);
        assert_eq!(b.to_global(), vec![1.0; 8]);
    }

    /// Property: from_global → to_global is the identity for random layouts.
    #[test]
    fn prop_roundtrip() {
        check_prop(
            "sharedvec-roundtrip",
            32,
            |r| {
                let n = r.usize_in(1, 800);
                let bs = r.usize_in(1, 100);
                let t = r.usize_in(1, 9);
                let data: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect();
                (Layout::new(n, bs, t), data)
            },
            |(l, data)| {
                let v = SharedVec::from_global(*l, data);
                if v.to_global() != *data {
                    return Err("roundtrip mismatch".into());
                }
                Ok(())
            },
        );
    }
}
