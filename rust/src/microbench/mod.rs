//! The §6.2 microbenchmarks.
//!
//! Three of them characterize the (simulated) cluster and recover the four
//! hardware constants — a self-consistency check of the simulator's cost
//! accounting:
//!
//! * [`stream_sim`] — multi-threaded STREAM per node → `W_node ≈ 75 GB/s`,
//! * [`pingpong_sim`] — inter-node contiguous transfers → `W_node_remote`,
//! * [`tau_sim`] — the Listing-6 random-remote-read benchmark → `τ`.
//!
//! The `host` submodule adds *real host* counterparts of the same four
//! probes — [`stream_host`] / [`stream_host_threads`] (triad bandwidth,
//! also the §Perf roofline anchor), [`memcpy_cross_thread`] (contiguous
//! cross-thread bandwidth, the ping-pong analog), [`tau_cross_thread`]
//! (random individual cross-thread access latency, the Listing-6 analog)
//! and [`cache_line_host`] (strided-access knee) — which
//! [`crate::machine::Calibration`] composes into an [`HwParams`] for the
//! machine actually running the binary.

mod host;

pub use host::{
    cache_line_host, host_threads, memcpy_cross_thread, pack_bandwidth_host, stream_host,
    stream_host_threads, tau_cross_thread,
};

use crate::machine::HwParams;
use crate::sim::SimParams;

/// Result of a bandwidth-style microbenchmark.
#[derive(Debug, Clone, Copy)]
pub struct BandwidthResult {
    pub bytes: f64,
    pub seconds: f64,
}

impl BandwidthResult {
    pub fn bandwidth(&self) -> f64 {
        self.bytes / self.seconds
    }
}

/// Simulated multi-threaded STREAM: `threads` threads each stream
/// `elems_per_thread` doubles (read + write) through private memory.
/// Recovers `W_thread_private · threads`.
pub fn stream_sim(hw: &HwParams, threads: usize, elems_per_thread: usize) -> BandwidthResult {
    let bytes_per_thread = (elems_per_thread * 2 * 8) as f64; // triad-ish: load+store
    // All threads run concurrently; each takes bytes/W_thread.
    let seconds = bytes_per_thread / hw.w_thread_private;
    BandwidthResult { bytes: bytes_per_thread * threads as f64, seconds }
}

/// Simulated MPI-style ping-pong between two nodes with message size
/// `bytes`: recovers `W_node_remote` as size → ∞ and `τ` as size → 0.
pub fn pingpong_sim(hw: &HwParams, bytes: usize, reps: usize) -> BandwidthResult {
    let t_one_way = hw.t_remote_message(bytes as f64);
    BandwidthResult {
        bytes: (bytes * reps * 2) as f64,
        seconds: t_one_way * (reps * 2) as f64,
    }
}

/// Simulated Listing-6 benchmark: `concurrent` threads per node each perform
/// `ops` random individual remote reads. Returns the measured per-op latency
/// — equals `τ` when `concurrent == 8` (the paper's calibration point).
pub fn tau_sim(params: &SimParams, concurrent: usize, ops: usize) -> f64 {
    let per_thread = ops as f64 * params.tau_eff(concurrent);
    per_thread / ops as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_recovers_node_bandwidth() {
        let hw = HwParams::abel();
        let r = stream_sim(&hw, 16, 1 << 20);
        assert!((r.bandwidth() - 75.0e9).abs() / 75.0e9 < 1e-9, "{}", r.bandwidth());
    }

    #[test]
    fn pingpong_recovers_remote_bandwidth() {
        let hw = HwParams::abel();
        // Large messages → bandwidth-dominated.
        let r = pingpong_sim(&hw, 64 << 20, 4);
        assert!((r.bandwidth() - 6.0e9).abs() / 6.0e9 < 0.01, "{}", r.bandwidth());
        // Small messages → latency-dominated, way below peak.
        let r8 = pingpong_sim(&hw, 8, 100);
        assert!(r8.bandwidth() < 0.01 * 6.0e9);
    }

    #[test]
    fn tau_recovered_at_calibration_point() {
        let hw = HwParams::abel();
        let params = SimParams::from_hw(&hw);
        let tau = tau_sim(&params, 8, 10_000);
        assert!((tau - hw.tau).abs() < 1e-12, "{tau}");
        // Fewer communicating threads → smaller effective τ (paper §6.4).
        assert!(tau_sim(&params, 2, 1000) < tau);
    }

    #[test]
    fn host_stream_reports_something_sane() {
        let r = stream_host(1 << 18);
        let bw = r.bandwidth();
        // Any machine (even a debug build) lands between 0.05 GB/s and 10 TB/s.
        assert!(bw > 5e7 && bw < 1e13, "{bw}");
    }
}
