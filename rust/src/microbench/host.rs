//! Real-host counterparts of the §6.2 microbenchmarks.
//!
//! The simulated benchmarks in the parent module recover the four hardware
//! characteristic parameters from the *simulator's* cost accounting — a
//! self-consistency check. The probes here measure the same four parameters
//! on the machine actually running the binary, so the eqs. (5)–(18) models
//! can predict the wall-clock behaviour of the parallel engine
//! (`crate::engine`) instead of only replaying the paper's Abel numbers:
//!
//! * [`stream_host_threads`] — multi-threaded STREAM triad →
//!   `W_thread_private` (aggregate / threads) and, at one thread, the
//!   `W_node(1)` calibration point of the saturation curve,
//! * [`memcpy_cross_thread`] — contiguous copy out of another thread's
//!   working set → the host analog of the MPI ping-pong (`W_node_remote`):
//!   on the shared-memory engine a "remote" bulk transfer *is* a memcpy
//!   between per-thread segments,
//! * [`tau_cross_thread`] — dependent random loads through an arena faulted
//!   by another thread → the Listing-6 analog of `τ`,
//! * [`cache_line_host`] — strided-access knee → last-level cache line size.
//!
//! `std` exposes no CPU-affinity API, so unlike the paper's pinned UPC
//! threads these probes rely on the OS scheduler keeping threads put for
//! the few milliseconds each measurement lasts; every probe takes a
//! best-of-`reps` minimum to shed migration and interference noise.

use super::BandwidthResult;
use crate::engine::kernels;
use crate::util::Rng;
use std::time::Instant;

/// Number of hardware threads the host reports (fallback 4).
pub fn host_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
}

/// Host STREAM triad (`a[i] = b[i] + s·c[i]`) over `threads` OS threads.
/// `threads = 1` measures the `W_node(1)` saturation-curve calibration
/// point; `threads = host_threads()` the saturated aggregate.
pub fn stream_host_threads(threads: usize, elems_per_thread: usize) -> BandwidthResult {
    let threads = threads.max(1);
    let reps = 5usize;
    // Allocate and fault in all buffers OUTSIDE the timed region.
    let mut buffers: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)> = (0..threads)
        .map(|_| {
            (
                vec![0.0f64; elems_per_thread],
                vec![1.0f64; elems_per_thread],
                vec![2.0f64; elems_per_thread],
            )
        })
        .collect();
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let start = Instant::now();
        std::thread::scope(|scope| {
            for (a, b, c) in buffers.iter_mut() {
                scope.spawn(move || {
                    for ((ai, bi), ci) in a.iter_mut().zip(b.iter()).zip(c.iter()) {
                        *ai = *bi + 3.0 * *ci;
                    }
                    std::hint::black_box(&a[0]);
                });
            }
        });
        best = best.min(start.elapsed().as_secs_f64());
    }
    // Triad traffic: 3 arrays × 8 bytes each (2 loads + 1 store).
    BandwidthResult { bytes: (elems_per_thread * threads * 3 * 8) as f64, seconds: best }
}

/// Real host STREAM triad over all host cores. Used as the roofline anchor
/// for the native hot path and as the aggregate `W_node` calibration point.
pub fn stream_host(elems_per_thread: usize) -> BandwidthResult {
    stream_host_threads(host_threads(), elems_per_thread)
}

/// Cross-thread contiguous-copy bandwidth — the host analog of the MPI
/// ping-pong (`W_node_remote`). An owner thread allocates and faults the
/// source buffer so it lives in *its* cache/NUMA domain, exactly like a
/// peer's shared block; the measuring thread then bulk-copies it into its
/// own destination. This is precisely what `Engine::Parallel` pays for a
/// "remote" `upc_memget`/`upc_memput` (a memcpy between per-thread
/// segments), so it is the bandwidth the eq. (11)/(13) terms should use on
/// this machine.
pub fn memcpy_cross_thread(bytes: usize, reps: usize) -> BandwidthResult {
    let elems = (bytes / 8).max(1 << 10);
    let mut dst = vec![0.0f64; elems];
    for x in dst.iter_mut() {
        *x = -1.0; // fault the destination on the measuring thread
    }
    let mut best = f64::INFINITY;
    for rep in 0..reps.max(1) {
        // A *fresh* owner thread faults a fresh source every rep: timing a
        // repeat copy of the same buffer would measure the measuring core's
        // own warm cache, not a pull out of another thread's working set.
        let src = std::thread::spawn(move || {
            let mut v = vec![0.0f64; elems];
            for (i, x) in v.iter_mut().enumerate() {
                *x = (i + rep) as f64; // fault every page on the owner thread
            }
            v
        })
        .join()
        .expect("memcpy owner thread");
        let t0 = Instant::now();
        dst.copy_from_slice(&src);
        std::hint::black_box(&dst[elems - 1]);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    BandwidthResult { bytes: (elems * 8) as f64, seconds: best }
}

/// Pack/unpack bandwidth through a compiled index list — the probe behind
/// [`HwParams::w_pack`](crate::machine::HwParams::w_pack), i.e. what the
/// kernel-tier gather/scatter ([`kernels::pack_gather`] /
/// [`kernels::scatter_indexed`]) actually sustains on this host, as
/// opposed to the straight-line STREAM figure eq. (19) divides by. The
/// index list is deterministic (fixed-seed [`Rng`]) and shuffled within
/// 64-element windows: irregular enough inside a window to defeat pure
/// streaming, monotone across windows like a real compiled halo plan.
/// Times a gather + scatter round trip, best-of-`reps`; each direction
/// moves one load + one store per element.
pub fn pack_bandwidth_host(elems: usize, reps: usize) -> BandwidthResult {
    let elems = elems.max(1 << 10);
    let src: Vec<f64> = (0..elems).map(|i| i as f64).collect();
    let mut packed = vec![0.0f64; elems];
    let mut unpacked = vec![0.0f64; elems];
    let mut idx: Vec<u32> = (0..elems as u32).collect();
    let mut rng = Rng::new(0x9AC4_BA4D);
    for window in idx.chunks_mut(64) {
        for i in (1..window.len()).rev() {
            let j = rng.usize_in(0, i);
            window.swap(i, j);
        }
    }
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        kernels::pack_gather(&src, &idx, &mut packed);
        kernels::scatter_indexed(&mut unpacked, &idx, &packed);
        std::hint::black_box((&packed[0], &unpacked[0]));
        best = best.min(t0.elapsed().as_secs_f64());
    }
    BandwidthResult { bytes: (elems * 2 * 2 * 8) as f64, seconds: best }
}

/// Slot stride of the τ arena, in `usize` elements: 128 B keeps slots on
/// distinct cache lines even with adjacent-line prefetch enabled.
const TAU_STRIDE: usize = 128 / std::mem::size_of::<usize>();

/// Random individual cross-thread access latency — the Listing-6 analog of
/// `τ`. An owner thread builds and faults a pointer-chase arena (one slot
/// per 128 B, linked as a single random cycle by Sattolo's algorithm); the
/// measuring thread then performs `ops` *dependent* loads through it, which
/// defeats both the prefetcher and out-of-order overlap the same way
/// Listing 6's random `upc_threadof`-remote reads do. Returns seconds per
/// individual access.
///
/// For a *remote*-latency reading, pick `slots` so `slots × 128 B` exceeds
/// the last-level cache (the `Calibration` profiles use 16–32 MiB): a
/// cache-resident arena would measure the measuring core's own L2 hit
/// latency, not the cost of pulling a line out of another thread's working
/// set, which is what the engine's remote individual ops actually pay.
pub fn tau_cross_thread(slots: usize, ops: usize) -> f64 {
    let slots = slots.max(16);
    let arena = std::thread::spawn(move || {
        // Sattolo's algorithm: a uniformly random single-cycle permutation,
        // so a chase visits every slot before repeating.
        let mut next: Vec<usize> = (0..slots).collect();
        let mut rng = Rng::new(0x7A57E15);
        for i in (1..slots).rev() {
            let j = rng.usize_in(0, i);
            next.swap(i, j);
        }
        let mut arena = vec![0usize; slots * TAU_STRIDE];
        for (s, &nxt) in next.iter().enumerate() {
            arena[s * TAU_STRIDE] = nxt * TAU_STRIDE;
        }
        arena
    })
    .join()
    .expect("tau owner thread");
    let ops = ops.max(1);
    // A short warmup primes the page tables; with an above-LLC arena it
    // cannot make the chase cache-resident, so the measured laps still pay
    // the cold line transfer per access.
    let mut idx = 0usize;
    for _ in 0..slots.min(ops) {
        idx = arena[idx];
    }
    let t0 = Instant::now();
    for _ in 0..ops {
        idx = arena[idx];
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(idx);
    dt / ops as f64
}

/// Cache-line size via the strided-access knee. Walking a buffer at stride
/// `s` misses once per *line* while `s ≤ line` — per-access time grows
/// proportionally to `s` — and once per *access* beyond, where it plateaus.
/// The detected line size is the stride at which doubling stops raising the
/// per-access cost. Returns a power of two in `[16, 256]`; falls back to 64
/// when the knee is not clearly visible (e.g. debug builds, where loop
/// overhead flattens the small-stride ratios).
pub fn cache_line_host(buf_bytes: usize) -> usize {
    let buf_bytes = buf_bytes.max(1 << 20);
    let buf = vec![1u8; buf_bytes];
    const STRIDES: [usize; 7] = [8, 16, 32, 64, 128, 256, 512];
    let mut per_access = [0.0f64; STRIDES.len()];
    for (si, &s) in STRIDES.iter().enumerate() {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            let mut sum = 0u64;
            let mut i = 0usize;
            while i < buf_bytes {
                sum = sum.wrapping_add(buf[i] as u64);
                i += s;
            }
            std::hint::black_box(sum);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        per_access[si] = best / (buf_bytes / s) as f64;
    }
    // The knee is the last doubling that still grew per-access cost
    // meaningfully; scanning from the top end makes the detection immune to
    // constant per-access overhead flattening the small-stride ratios.
    for w in (0..STRIDES.len() - 1).rev() {
        if per_access[w + 1] >= 1.4 * per_access[w] {
            let line = STRIDES[w + 1];
            if (16..=256).contains(&line) {
                return line;
            }
            break;
        }
    }
    64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memcpy_cross_thread_sane() {
        let r = memcpy_cross_thread(1 << 22, 3);
        let bw = r.bandwidth();
        // Any machine (even a debug build) lands between 0.05 GB/s and 10 TB/s.
        assert!(bw > 5e7 && bw < 1e13, "{bw}");
    }

    #[test]
    fn tau_cross_thread_sane() {
        let tau = tau_cross_thread(1 << 12, 20_000);
        // A dependent load costs somewhere between 0.2 ns (absurdly fast)
        // and 100 µs (absurdly slow, even interpreted).
        assert!(tau > 2e-10 && tau < 1e-4, "{tau}");
    }

    #[test]
    fn cache_line_detection_in_range() {
        let line = cache_line_host(1 << 22);
        assert!(line.is_power_of_two(), "{line}");
        assert!((16..=256).contains(&line), "{line}");
    }

    #[test]
    fn pack_bandwidth_sane() {
        let r = pack_bandwidth_host(1 << 14, 2);
        let bw = r.bandwidth();
        assert!(bw > 5e7 && bw < 1e13, "{bw}");
    }

    #[test]
    fn pack_round_trip_restores_source() {
        // The probe's index list is a permutation (window-local shuffle of
        // the identity), so gather-then-scatter must restore the source.
        let elems = 1 << 12;
        let src: Vec<f64> = (0..elems).map(|i| i as f64).collect();
        let mut packed = vec![0.0f64; elems];
        let mut unpacked = vec![0.0f64; elems];
        let mut idx: Vec<u32> = (0..elems as u32).collect();
        let mut rng = Rng::new(0x9AC4_BA4D);
        for window in idx.chunks_mut(64) {
            for i in (1..window.len()).rev() {
                let j = rng.usize_in(0, i);
                window.swap(i, j);
            }
        }
        kernels::pack_gather(&src, &idx, &mut packed);
        kernels::scatter_indexed(&mut unpacked, &idx, &packed);
        assert_eq!(unpacked, src);
    }

    #[test]
    fn single_thread_stream_below_aggregate() {
        let one = stream_host_threads(1, 1 << 16);
        assert!(one.bandwidth() > 5e7, "{}", one.bandwidth());
    }
}
