//! PJRT-backed [`BlockCompute`]: executes the AOT-compiled Pallas EllPack
//! kernel from `artifacts/spmv_block.hlo.txt`.
//!
//! The artifact has a fixed row-tile size `B` (manifest `meta.block`) and
//! fixed `r_nz`; the backend chops arbitrary blocks into `B`-row tiles,
//! gathers the needed `x` values into a dense `(B, r_nz)` tile (the gather
//! *is* the communication and therefore belongs to this layer — DESIGN.md
//! §Hardware-Adaptation), pads the tail, and runs the executable.
//!
//! The artifacts are f32 (Pallas/interpret + PJRT-CPU path); the runner
//! compares f32 results against the f64 native path with a tolerance.

use crate::runtime::Engine;
use crate::spmv::BlockCompute;
use anyhow::{anyhow, Result};

/// Name of the SpMV artifact in the manifest.
pub const SPMV_ARTIFACT: &str = "spmv_block";

/// A [`BlockCompute`] that runs the L1 Pallas kernel through PJRT.
pub struct PjrtCompute {
    engine: Engine,
    /// Row-tile size of the compiled executable.
    b: usize,
    r_nz: usize,
    // Reused staging buffers (f32).
    d_buf: Vec<f32>,
    xd_buf: Vec<f32>,
    a_buf: Vec<f32>,
    xg_buf: Vec<f32>,
    /// Executions performed (for reporting).
    pub calls: u64,
}

impl PjrtCompute {
    /// Build from a discovered artifacts directory.
    pub fn discover() -> Result<PjrtCompute> {
        Self::new(Engine::discover()?)
    }

    pub fn new(mut engine: Engine) -> Result<PjrtCompute> {
        let spec = engine.spec(SPMV_ARTIFACT)?.clone();
        let b = *spec
            .meta
            .get("block")
            .ok_or_else(|| anyhow!("{SPMV_ARTIFACT}: manifest missing meta.block"))?;
        let r_nz = *spec
            .meta
            .get("r_nz")
            .ok_or_else(|| anyhow!("{SPMV_ARTIFACT}: manifest missing meta.r_nz"))?;
        engine.load(SPMV_ARTIFACT)?;
        Ok(PjrtCompute {
            engine,
            b,
            r_nz,
            d_buf: vec![0.0; b],
            xd_buf: vec![0.0; b],
            a_buf: vec![0.0; b * r_nz],
            xg_buf: vec![0.0; b * r_nz],
            calls: 0,
        })
    }

    /// Tile size of the compiled kernel.
    pub fn tile_rows(&self) -> usize {
        self.b
    }
}

impl BlockCompute for PjrtCompute {
    fn block(
        &mut self,
        offset: usize,
        d: &[f64],
        a: &[f64],
        j: &[u32],
        r_nz: usize,
        x_copy: &[f64],
        y: &mut [f64],
    ) {
        assert_eq!(r_nz, self.r_nz, "artifact compiled for r_nz={}", self.r_nz);
        let b = self.b;
        let len = y.len();
        let mut k0 = 0usize;
        while k0 < len {
            let tile = (len - k0).min(b);
            // Stage f32 inputs, zero-padding the tail tile. Padded rows have
            // D = A = 0 → y = 0, discarded on copy-back.
            self.d_buf[..tile].iter_mut().zip(&d[k0..k0 + tile]).for_each(|(o, &v)| *o = v as f32);
            self.d_buf[tile..].fill(0.0);
            self.xd_buf[..tile]
                .iter_mut()
                .zip(&x_copy[offset + k0..offset + k0 + tile])
                .for_each(|(o, &v)| *o = v as f32);
            self.xd_buf[tile..].fill(0.0);
            self.a_buf[..tile * r_nz]
                .iter_mut()
                .zip(&a[k0 * r_nz..(k0 + tile) * r_nz])
                .for_each(|(o, &v)| *o = v as f32);
            self.a_buf[tile * r_nz..].fill(0.0);
            // The gather — the coordinator-side half of the kernel.
            for (g, &col) in self.xg_buf[..tile * r_nz]
                .iter_mut()
                .zip(&j[k0 * r_nz..(k0 + tile) * r_nz])
            {
                *g = x_copy[col as usize] as f32;
            }
            self.xg_buf[tile * r_nz..].fill(0.0);

            let outs = self
                .engine
                .run_f32(
                    SPMV_ARTIFACT,
                    &[&self.d_buf, &self.xd_buf, &self.a_buf, &self.xg_buf],
                )
                .expect("PJRT execution failed");
            self.calls += 1;
            for (slot, &v) in y[k0..k0 + tile].iter_mut().zip(outs[0].iter()) {
                *slot = v as f64;
            }
            k0 += tile;
        }
    }
}

impl std::fmt::Debug for PjrtCompute {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtCompute")
            .field("tile_rows", &self.b)
            .field("r_nz", &self.r_nz)
            .field("calls", &self.calls)
            .finish()
    }
}
