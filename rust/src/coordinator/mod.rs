//! The coordinator — configuration, the end-to-end runner, and the
//! PJRT-backed compute backend.
//!
//! This is the layer a downstream user scripts against: build a
//! [`RunConfig`], call [`Runner::run`], get a [`RunReport`] containing the
//! simulated-cluster time, the model prediction, the numeric result of
//! actually integrating `v^ℓ = M v^{ℓ−1}` (§6.1), and traffic statistics.
//! The CLI (`repro run`) and the examples are thin wrappers over this.

mod backend;
mod runner;

pub use backend::PjrtCompute;
pub use runner::{Backend, Problem, RunConfig, RunReport, Runner};
