//! Run configuration and the end-to-end runner.

use crate::comm::Analysis;
use crate::engine::{Engine, SpmvEngine};
use crate::machine::HwParams;
use crate::matrix::Ellpack;
use crate::mesh::{Ordering, TestProblem, TetGridSpec, TetMesh};
use crate::model::{self, SpmvInputs};
use crate::pgas::{Layout, Topology};
use crate::sim::{ClusterSim, SimMeasurement};
use crate::spmv::{run_variant_with, SpmvState, Variant};
use anyhow::Result;
use std::time::Instant;

/// Which workload to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Problem {
    /// One of the paper's Table 1 test problems, scaled down by
    /// `scale_div` (see `RunConfig`).
    Tp(TestProblem),
    /// A custom mesh size (target tetrahedra, unscaled).
    Custom(usize),
}

/// Compute backend for the numeric part.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Optimized Rust kernel.
    Native,
    /// AOT-compiled Pallas kernel through PJRT (requires `make artifacts`).
    Pjrt,
}

/// Everything a run needs. Construct with [`RunConfig::default_for`] and
/// override fields.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub problem: Problem,
    /// Divide the paper-scale problem (and BLOCKSIZE schedule) by this.
    pub scale_div: usize,
    /// BLOCKSIZE for x/y/D (already scaled). `None` → paper schedule.
    pub block_size: Option<usize>,
    pub nodes: usize,
    pub threads_per_node: usize,
    pub variant: Variant,
    /// Iterations of `v^ℓ = M v^{ℓ−1}` to *account* (simulated time scales
    /// linearly; the paper uses 1000).
    pub iters: usize,
    /// Iterations to actually execute numerically (≤ iters; numeric result
    /// is per-step identical in structure, so a handful suffices for
    /// validation while the driver can run hundreds).
    pub exec_steps: usize,
    pub ordering: Ordering,
    pub backend: Backend,
    /// Execution engine for the numeric time loop (native backend only —
    /// the PJRT backend always runs on the sequential oracle path).
    pub engine: Engine,
    /// Pipeline depth D for the engine's buffered V3 exchange (staging
    /// slots; the `e − D` ack-gate distance). Depth never changes numerics
    /// — only how much sender/receiver skew the pipeline absorbs.
    pub depth: usize,
    /// `--depth auto`: ignore `depth` and resolve D through the
    /// depth-aware pipeline model (`choose_depth` over the run's own
    /// overlap prediction) once the plan is compiled.
    pub auto_depth: bool,
    pub hw: HwParams,
    pub seed: u64,
}

impl RunConfig {
    /// Paper-like defaults: TP1 at 1/16 scale, UPCv3, 2 nodes × 16 threads,
    /// 1000 accounted iterations, 5 executed steps.
    pub fn default_for(problem: Problem) -> RunConfig {
        RunConfig {
            problem,
            scale_div: 16,
            block_size: None,
            nodes: 2,
            threads_per_node: 16,
            variant: Variant::V3,
            iters: 1000,
            exec_steps: 5,
            ordering: Ordering::Natural,
            backend: Backend::Native,
            engine: Engine::Sequential,
            depth: 2,
            auto_depth: false,
            hw: HwParams::abel(),
            seed: 0xC0FFEE,
        }
    }

    pub fn threads(&self) -> usize {
        self.nodes * self.threads_per_node
    }

    /// The paper's BLOCKSIZE schedule (Table 4), scaled by `scale_div`.
    pub fn paper_blocksize(threads: usize, scale_div: usize) -> usize {
        let paper = match threads {
            0..=64 => 65_536,
            65..=128 => 53_200,
            129..=256 => 26_600,
            257..=512 => 13_300,
            _ => 6_650,
        };
        (paper / scale_div).max(1)
    }

    fn resolve_blocksize(&self, n: usize) -> usize {
        let bs = self
            .block_size
            .unwrap_or_else(|| Self::paper_blocksize(self.threads(), self.scale_div));
        // A layout needs at least one block; degenerate configs clamp.
        bs.min(n).max(1)
    }
}

/// The result of a run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub n: usize,
    pub threads: usize,
    pub block_size: usize,
    pub variant: Variant,
    /// Simulated ("measured") time for `iters` iterations.
    pub sim_total: f64,
    /// Model-predicted time for `iters` iterations.
    pub model_total: f64,
    /// Per-iteration simulated measurement (per-thread series etc.).
    pub sim_iter: SimMeasurement,
    /// ∞-norm of x after the executed steps (stability check).
    pub final_max: f64,
    /// Σ x after the executed steps (regression checksum).
    pub checksum: f64,
    /// ∞-norm of (x_ℓ − x_{ℓ−1}) per executed step (decays for diffusion).
    pub residuals: Vec<f64>,
    /// Host wall-clock seconds spent in the numeric loop.
    pub exec_wall: f64,
    /// Inter-thread payload bytes per executed step.
    pub step_bytes: u64,
    /// Backend actually used.
    pub backend: Backend,
    /// Pipeline buffer depth the engine actually ran with (the flag value,
    /// or the model's pick under `--depth auto`).
    pub depth: usize,
}

/// The end-to-end runner.
pub struct Runner {
    pub config: RunConfig,
}

impl Runner {
    pub fn new(config: RunConfig) -> Runner {
        Runner { config }
    }

    /// Build the mesh for the configured problem.
    pub fn build_mesh(&self) -> TetMesh {
        let cfg = &self.config;
        let mesh = match cfg.problem {
            Problem::Tp(tp) => tp.generate(cfg.scale_div),
            Problem::Custom(target) => {
                TetMesh::generate(&TetGridSpec::ventricle(target, cfg.seed))
            }
        };
        cfg.ordering.apply(&mesh)
    }

    /// Run the full pipeline: mesh → matrix → analysis → model + sim →
    /// numeric time integration.
    pub fn run(&self) -> Result<RunReport> {
        let mesh = self.build_mesh();
        self.run_on(&mesh)
    }

    /// Run on a pre-built mesh (lets callers share a mesh across configs).
    pub fn run_on(&self, mesh: &TetMesh) -> Result<RunReport> {
        let cfg = &self.config;
        let m = Ellpack::diffusion_from_mesh(mesh);
        let bs = cfg.resolve_blocksize(m.n);
        let layout = Layout::new(m.n, bs, cfg.threads());
        let topo = Topology::new(cfg.nodes, cfg.threads_per_node);
        let window = crate::harness::scaled_cache_window(self.config.scale_div.max(1));
        let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, window);
        // Per-thread bandwidth share depends on how many threads actually
        // run on a node (§5.1): rescale the injected parameter set to the
        // run's topology, as the harness consumers do (table2, ablations,
        // validate).
        let hw = cfg.hw.with_threads_per_node(cfg.threads_per_node);
        let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };

        // Timing: simulated-actual and model-predicted.
        let sim = ClusterSim::new(hw);
        let sim_iter = sim.spmv_iteration(cfg.variant, &inp);
        let model_iter = model::predict(cfg.variant, &inp).total;

        // Numerics: execute `exec_steps` real steps of v = Mv.
        let x0 = m.initial_vector(cfg.seed ^ 0x11);
        let mut state = SpmvState::new(&m, bs, cfg.threads(), &x0);
        let mut residuals = Vec::with_capacity(cfg.exec_steps);
        let mut step_bytes = 0u64;
        let t0 = Instant::now();
        let mut pjrt = match cfg.backend {
            Backend::Pjrt => Some(super::PjrtCompute::discover()?),
            Backend::Native => None,
        };
        // One engine for the whole loop so the parallel pool's workspaces
        // persist across time steps.
        let mut engine = SpmvEngine::new(match cfg.backend {
            Backend::Pjrt => Engine::Sequential,
            Backend::Native => cfg.engine,
        });
        // `--depth auto`: resolve D through the same `choose_depth` sweep
        // the grid drivers print, evaluated on this run's actual plan and
        // topology. Only V3 has a compiled exchange to buffer, so the
        // other variants keep the flag value (depth is inert for them).
        let depth = if cfg.auto_depth && cfg.variant == Variant::V3 {
            let ovl = model::predict_v3_overlap(&inp);
            model::choose_depth(&ovl, cfg.exec_steps.max(1), hw.tau).0
        } else {
            cfg.depth.max(1)
        };
        engine.set_depth(depth);
        for _ in 0..cfg.exec_steps {
            let out = match &mut pjrt {
                Some(p) => run_variant_with(cfg.variant, &mut state, Some(&analysis), p),
                None => engine.run(cfg.variant, &mut state, Some(&analysis)),
            };
            step_bytes = out.inter_thread_bytes;
            // Residual ‖y − x‖∞ before the swap.
            let xg = state.x_global();
            let res = out
                .y
                .iter()
                .zip(&xg)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            residuals.push(res);
            state.swap_xy();
        }
        let exec_wall = t0.elapsed().as_secs_f64();
        let xf = state.x_global();
        let final_max = xf.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        let checksum = xf.iter().sum();

        Ok(RunReport {
            n: m.n,
            threads: cfg.threads(),
            block_size: bs,
            variant: cfg.variant,
            sim_total: sim_iter.total * cfg.iters as f64,
            model_total: model_iter * cfg.iters as f64,
            sim_iter,
            final_max,
            checksum,
            residuals,
            exec_wall,
            step_bytes,
            backend: cfg.backend,
            depth,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_config() -> RunConfig {
        let mut cfg = RunConfig::default_for(Problem::Custom(2_000));
        cfg.block_size = Some(64);
        cfg.nodes = 2;
        cfg.threads_per_node = 4;
        cfg.iters = 100;
        cfg.exec_steps = 3;
        cfg
    }

    #[test]
    fn runner_produces_consistent_report() {
        let report = Runner::new(quick_config()).run().unwrap();
        assert!(report.n > 1000);
        assert_eq!(report.threads, 8);
        assert!(report.sim_total > 0.0 && report.model_total > 0.0);
        assert_eq!(report.residuals.len(), 3);
        // Diffusion is stable and smoothing: residual decays.
        assert!(report.residuals[2] <= report.residuals[0]);
        assert!(report.final_max.is_finite());
    }

    #[test]
    fn variants_share_checksum() {
        let mesh = Runner::new(quick_config()).build_mesh();
        let mut sums = Vec::new();
        for v in Variant::ALL {
            let mut cfg = quick_config();
            cfg.variant = v;
            let r = Runner::new(cfg).run_on(&mesh).unwrap();
            sums.push(r.checksum);
        }
        for w in sums.windows(2) {
            assert_eq!(w[0].to_bits(), w[1].to_bits(), "checksum drift across variants");
        }
    }

    #[test]
    fn engine_choice_does_not_change_numerics() {
        let mesh = Runner::new(quick_config()).build_mesh();
        let mut cfg = quick_config();
        cfg.engine = Engine::Sequential;
        let seq = Runner::new(cfg).run_on(&mesh).unwrap();
        let mut cfg = quick_config();
        cfg.engine = Engine::Parallel;
        let par = Runner::new(cfg).run_on(&mesh).unwrap();
        assert_eq!(seq.checksum.to_bits(), par.checksum.to_bits());
        assert_eq!(seq.step_bytes, par.step_bytes);
        assert_eq!(seq.residuals, par.residuals);
    }

    #[test]
    fn depth_does_not_change_numerics() {
        let mesh = Runner::new(quick_config()).build_mesh();
        let mut cfg = quick_config();
        cfg.engine = Engine::Parallel;
        let d2 = Runner::new(cfg).run_on(&mesh).unwrap();
        for depth in [1, 3, 4] {
            let mut cfg = quick_config();
            cfg.engine = Engine::Parallel;
            cfg.depth = depth;
            let r = Runner::new(cfg).run_on(&mesh).unwrap();
            assert_eq!(d2.checksum.to_bits(), r.checksum.to_bits(), "depth {depth}");
            assert_eq!(d2.step_bytes, r.step_bytes, "depth {depth}");
        }
    }

    #[test]
    fn paper_blocksize_schedule() {
        assert_eq!(RunConfig::paper_blocksize(16, 1), 65_536);
        assert_eq!(RunConfig::paper_blocksize(64, 1), 65_536);
        assert_eq!(RunConfig::paper_blocksize(128, 1), 53_200);
        assert_eq!(RunConfig::paper_blocksize(1024, 1), 6_650);
        assert_eq!(RunConfig::paper_blocksize(16, 16), 4_096);
    }
}
