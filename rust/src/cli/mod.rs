//! Hand-rolled CLI argument parsing (no `clap` in the offline environment).
//!
//! Grammar: `repro <subcommand> [--flag value]...`. Flags are typed through
//! the accessor methods; unknown flags are an error so typos fail loudly.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags that were consumed by an accessor (for unknown-flag detection).
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from `std::env::args` (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(name) = item.strip_prefix("--") {
                let (key, value) = match name.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => {
                        // boolean flags: next token missing or another flag
                        match iter.peek() {
                            Some(next) if !next.starts_with("--") => {
                                (name.to_string(), iter.next().unwrap())
                            }
                            _ => (name.to_string(), "true".to_string()),
                        }
                    }
                };
                if out.flags.insert(key.clone(), value).is_some() {
                    bail!("duplicate flag --{key}");
                }
            } else if out.subcommand.is_empty() {
                out.subcommand = item;
            } else {
                out.positional.push(item);
            }
        }
        Ok(out)
    }

    pub fn str_flag(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_flag(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.str_flag(key), Some("true") | Some("1") | Some("yes"))
    }

    /// After all accessors ran: error on flags nobody consumed.
    pub fn finish(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for key in self.flags.keys() {
            if !seen.contains(key) {
                bail!("unknown flag --{key} for subcommand '{}'", self.subcommand);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse("bench table3 --scale 16 --iters=500 --full");
        assert_eq!(a.subcommand, "bench");
        assert_eq!(a.positional, vec!["table3"]);
        assert_eq!(a.usize_flag("scale", 1).unwrap(), 16);
        assert_eq!(a.usize_flag("iters", 1).unwrap(), 500);
        assert!(a.bool_flag("full"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse("run --oops 3");
        let _ = a.usize_flag("scale", 1);
        assert!(a.finish().is_err());
    }

    #[test]
    fn duplicate_flag_rejected() {
        assert!(Args::parse(
            ["x", "--a", "1", "--a", "2"].iter().map(|s| s.to_string())
        )
        .is_err());
    }

    #[test]
    fn bad_int_rejected() {
        let a = parse("x --n abc");
        assert!(a.usize_flag("n", 0).is_err());
    }
}
