//! Deterministic xorshift64* PRNG.
//!
//! Used for mesh generation, workload synthesis and property tests. Fully
//! deterministic from its seed so every experiment in EXPERIMENTS.md is
//! reproducible bit-for-bit.

/// A xorshift64* generator (Vigna 2016). Not cryptographic; statistically
/// plenty for workload synthesis.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has a fixed point at 0).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // bias is < 2^-32 for the bounds we use (all << 2^32).
        ((self.next_u64() >> 32).wrapping_mul(bound)) >> 32
    }

    /// Uniform usize in `[lo, hi)`. Requires `lo < hi`.
    #[inline]
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element by reference. Panics on empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_ok() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn bounds_respected() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.usize_in(3, 17);
            assert!((3..17).contains(&v));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn f64_mean_is_centered() {
        let mut r = Rng::new(1234);
        let n = 100_000;
        let mean = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
