//! Minimal JSON value type with an emitter and a recursive-descent parser.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and for machine-readable harness reports. The
//! subset implemented is full JSON minus `\u` surrogate pairs (the manifest
//! and reports are ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so emission is
/// deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, v: Value) -> &mut Self {
        match self {
            Value::Obj(m) => {
                m.insert(key.to_string(), v);
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, 0, true);
        s
    }

    /// Serialize compactly.
    pub fn compact(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, 0, false);
        s
    }

    fn emit(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                out.push_str(&"  ".repeat(n));
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Value::Str(s) => emit_string(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    it.emit(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    emit_string(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.emit(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn emit_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> anyhow::Result<Value> {
    let mut p = Parser { s: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.s.len() {
        anyhow::bail!("trailing characters at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at offset {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> anyhow::Result<Value> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> anyhow::Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.i])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => anyhow::bail!("bad escape \\{}", c as char),
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.s[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => anyhow::bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut v = Value::obj();
        v.set("name", Value::Str("spmv_block".into()));
        v.set("block", Value::Num(4096.0));
        v.set(
            "shapes",
            Value::Arr(vec![Value::Num(4096.0), Value::Num(16.0)]),
        );
        v.set("ok", Value::Bool(true));
        v.set("none", Value::Null);
        let text = v.pretty();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
        let back2 = parse(&v.compact()).unwrap();
        assert_eq!(v, back2);
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a":[1,2,{"b":"x\ny"}],"c":-1.5e3}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), -1500.0);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn integers_emit_without_dot() {
        assert_eq!(Value::Num(4096.0).compact(), "4096");
        assert_eq!(Value::Num(0.5).compact(), "0.5");
    }

    #[test]
    fn string_escapes() {
        let v = Value::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.compact()).unwrap(), v);
    }
}
