//! A tiny FNV-1a 64-bit hasher for structural fingerprints.
//!
//! Used to fingerprint compiled communication plans so a checkpoint can
//! prove it is being restored onto the same exchange structure it was taken
//! from. Not cryptographic — it only needs to be deterministic across runs
//! (no RNG, no address-dependent state) and sensitive to any change in the
//! hashed structure.

/// FNV-1a over explicitly fed words. Feed order matters, so callers should
/// hash fields in a fixed, documented order.
#[derive(Debug, Clone)]
pub struct Fnv64 {
    state: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Default for Fnv64 {
    fn default() -> Fnv64 {
        Fnv64 { state: FNV_OFFSET }
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64::default()
    }

    /// Feed one byte.
    #[inline]
    pub fn write_u8(&mut self, b: u8) {
        self.state ^= b as u64;
        self.state = self.state.wrapping_mul(FNV_PRIME);
    }

    /// Feed a u64 as 8 little-endian bytes.
    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.write_u8(b);
        }
    }

    /// Feed a usize (widened to u64 so 32- and 64-bit hosts agree).
    #[inline]
    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    /// The digest so far.
    pub fn finish(&self) -> u64 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_order_sensitive() {
        let mut a = Fnv64::new();
        a.write_u64(1);
        a.write_u64(2);
        let mut b = Fnv64::new();
        b.write_u64(1);
        b.write_u64(2);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv64::new();
        c.write_u64(2);
        c.write_u64(1);
        assert_ne!(a.finish(), c.finish(), "order must matter");
    }

    #[test]
    fn known_vector() {
        // FNV-1a of the empty input is the offset basis; of b"a" it is the
        // published 64-bit test vector.
        assert_eq!(Fnv64::new().finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv64::new();
        h.write_u8(b'a');
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn usize_matches_u64() {
        let mut a = Fnv64::new();
        a.write_usize(77);
        let mut b = Fnv64::new();
        b.write_u64(77);
        assert_eq!(a.finish(), b.finish());
    }
}
