//! ASCII bar/series plots for the figure reports.
//!
//! The paper's Figures 1–2 are bar charts over thread ids; the harness
//! emits CSVs for external plotting, plus these terminal renderings so the
//! shape is visible in CI logs and reports/*.txt.

/// Render one horizontal-bar chart: one bar per (label, value).
pub fn bar_chart(title: &str, series: &[(String, f64)], width: usize) -> String {
    let max = series.iter().map(|(_, v)| *v).fold(0.0f64, f64::max);
    let label_w = series.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("## {title}\n");
    if max <= 0.0 {
        out.push_str("(all zero)\n");
        return out;
    }
    for (label, v) in series {
        let filled = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "{label:<label_w$} |{}{} {v:.4}\n",
            "█".repeat(filled),
            " ".repeat(width - filled),
        ));
    }
    out
}

/// Render grouped bars: for each row label, one bar per column (prefixed
/// with the column's name), groups separated by blank lines.
pub fn grouped_bars(
    title: &str,
    columns: &[&str],
    rows: &[(String, Vec<f64>)],
    width: usize,
) -> String {
    let max = rows
        .iter()
        .flat_map(|(_, vs)| vs.iter().copied())
        .fold(0.0f64, f64::max);
    let col_w = columns.iter().map(|c| c.len()).max().unwrap_or(0);
    let mut out = format!("## {title}\n");
    if max <= 0.0 {
        out.push_str("(all zero)\n");
        return out;
    }
    for (label, vs) in rows {
        out.push_str(&format!("{label}\n"));
        for (c, v) in columns.iter().zip(vs) {
            let filled = ((v / max) * width as f64).round() as usize;
            out.push_str(&format!("  {c:<col_w$} |{} {v:.4}\n", "█".repeat(filled)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bars_scale_to_max() {
        let s = bar_chart(
            "t",
            &[("a".into(), 1.0), ("b".into(), 2.0)],
            10,
        );
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[1].contains("█████ ")); // a = half of b
        assert!(lines[2].contains("██████████"));
    }

    #[test]
    fn empty_and_zero_safe() {
        assert!(bar_chart("t", &[], 10).contains("(all zero)"));
        let z = bar_chart("t", &[("x".into(), 0.0)], 10);
        assert!(z.contains("(all zero)"));
    }

    #[test]
    fn grouped_renders_every_column() {
        let g = grouped_bars(
            "g",
            &["v1", "v2"],
            &[("thread 0".into(), vec![1.0, 3.0])],
            8,
        );
        assert!(g.contains("thread 0"));
        assert!(g.contains("v1"));
        assert!(g.contains("v2"));
    }
}
