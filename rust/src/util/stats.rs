//! Summary statistics over f64 samples (used by `benchlib` and the harness).

/// Summary statistics of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
}

impl Stats {
    /// Compute statistics from samples. Returns an all-NaN record for an
    /// empty slice (callers treat that as "no data").
    pub fn from(samples: &[f64]) -> Stats {
        let n = samples.len();
        if n == 0 {
            return Stats { n: 0, mean: f64::NAN, std: f64::NAN, min: f64::NAN, max: f64::NAN, p50: f64::NAN };
        }
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted: Vec<f64> = samples.to_vec();
        // total_cmp is NaN-safe: a stray NaN sample (e.g. a failed wall-clock
        // probe) sorts last instead of panicking the whole harness.
        sorted.sort_by(f64::total_cmp);
        let p50 = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50,
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.std / self.mean.abs() }
    }
}

/// Max-abs relative error between two series (used when comparing model
/// predictions against simulated measurements). Pairs where either side is
/// NaN are skipped rather than propagated — one bad sample must not poison
/// the whole comparison.
pub fn max_rel_err(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    actual
        .iter()
        .zip(predicted)
        .filter(|(a, p)| !a.is_nan() && !p.is_nan())
        .map(|(a, p)| if *a == 0.0 { 0.0 } else { ((a - p) / a).abs() })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_simple() {
        let s = Stats::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
        // sample std of 1,2,3,4 = sqrt(5/3)
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn stats_single() {
        let s = Stats::from(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 7.0);
    }

    #[test]
    fn stats_empty() {
        let s = Stats::from(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn rel_err() {
        assert!((max_rel_err(&[2.0, 4.0], &[1.0, 4.4]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn stats_survive_nan_samples() {
        // Regression: the old partial_cmp().unwrap() sort panicked here.
        let s = Stats::from(&[3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(s.n, 4);
        // total_cmp sorts NaN last, so min and p50 stay finite.
        assert_eq!(s.min, 1.0);
        assert!(s.p50.is_finite());
    }

    #[test]
    fn rel_err_skips_nan_pairs() {
        let e = max_rel_err(&[2.0, f64::NAN, 4.0], &[1.0, 9.9, f64::NAN]);
        assert!((e - 0.5).abs() < 1e-12, "{e}");
        // All-NaN input: nothing to compare, error is zero, not NaN.
        assert_eq!(max_rel_err(&[f64::NAN], &[f64::NAN]), 0.0);
    }
}
