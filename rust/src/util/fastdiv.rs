//! Branch-free division by a runtime constant (libdivide-style).
//!
//! The traffic analyzer performs one `index / BLOCKSIZE` per nonzero —
//! tens of millions of divisions per analysis. A 64-bit reciprocal multiply
//! replaces the hardware divide (§Perf: see EXPERIMENTS.md).
//!
//! Correctness: for a divisor `d ≥ 1` and numerators `n < 2^32`, computing
//! `m = ⌊2^64 / d⌋ + 1` gives `⌊n/d⌋ = (n · m) >> 64` exactly (standard
//! round-up-magic argument: the error of `m·d − 2^64 ∈ (0, d]` scaled by
//! `n < 2^32 ≤ 2^64/d · …` never reaches the next integer). The property
//! test below exercises the edges.

/// Precomputed reciprocal for dividing `u32`-ranged numerators by a fixed
/// divisor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FastDiv {
    d: u64,
    magic: u64,
}

impl FastDiv {
    pub fn new(d: usize) -> FastDiv {
        assert!(d >= 1 && d <= u32::MAX as usize, "divisor out of range");
        let d = d as u64;
        // ⌊2^64 / d⌋ + 1, computed in u128 to avoid overflow.
        let magic = ((1u128 << 64) / d as u128) as u64 + 1;
        FastDiv { d, magic }
    }

    /// `n / d` for `n < 2^32`.
    #[inline(always)]
    pub fn div(&self, n: usize) -> usize {
        debug_assert!(n <= u32::MAX as usize);
        if self.d == 1 {
            return n; // magic overflows for d = 1
        }
        ((n as u64 as u128 * self.magic as u128) >> 64) as usize
    }

    /// `n % d` for `n < 2^32`.
    #[inline(always)]
    pub fn rem(&self, n: usize) -> usize {
        n - self.div(n) * self.d as usize
    }

    pub fn divisor(&self) -> usize {
        self.d as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check_prop;

    #[test]
    fn edges() {
        for d in [1usize, 2, 3, 7, 415, 831, 4096, 65_536, u32::MAX as usize] {
            let f = FastDiv::new(d);
            let candidates = [0usize, 1, d - 1, d, d + 1, 2 * d, u32::MAX as usize];
            for n in candidates.into_iter().map(|n| n.min(u32::MAX as usize)) {
                assert_eq!(f.div(n), n / d, "{n}/{d}");
                assert_eq!(f.rem(n), n % d, "{n}%{d}");
            }
        }
    }

    #[test]
    fn prop_matches_hardware_division() {
        check_prop(
            "fastdiv",
            256,
            |r| {
                let d = r.usize_in(1, u32::MAX as usize);
                let n = r.usize_in(0, u32::MAX as usize);
                (d, n)
            },
            |&(d, n)| {
                let f = FastDiv::new(d);
                if f.div(n) != n / d {
                    return Err(format!("{n}/{d}: got {}", f.div(n)));
                }
                if f.rem(n) != n % d {
                    return Err(format!("{n}%{d}: got {}", f.rem(n)));
                }
                Ok(())
            },
        );
    }
}
