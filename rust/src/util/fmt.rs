//! Human-readable formatting helpers and a plain-text table renderer used by
//! the harness to print paper tables.

/// Format seconds with adaptive units (`ns`, `µs`, `ms`, `s`).
pub fn secs(t: f64) -> String {
    let a = t.abs();
    if !t.is_finite() {
        format!("{t}")
    } else if a == 0.0 {
        "0 s".to_string()
    } else if a < 1e-6 {
        format!("{:.2} ns", t * 1e9)
    } else if a < 1e-3 {
        format!("{:.2} µs", t * 1e6)
    } else if a < 1.0 {
        format!("{:.2} ms", t * 1e3)
    } else {
        format!("{t:.2} s")
    }
}

/// Format a byte count with adaptive units.
pub fn bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b;
    let mut u = 0;
    while v.abs() >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a rate in bytes/second.
pub fn rate(bps: f64) -> String {
    format!("{}/s", bytes(bps))
}

/// Format a large integer with thousands separators (e.g. `6,810,586`).
pub fn int(n: usize) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let digits = s.as_bytes();
    for (i, d) in digits.iter().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*d as char);
    }
    out
}

/// A plain-text table with a title, column headers and rows; renders with
/// per-column alignment. Mirrors the layout of the paper's tables so the
/// harness output is directly comparable.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                // Right-align all but the first column (first is labels).
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for `reports/*.csv`).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn secs_units() {
        assert_eq!(secs(2.0), "2.00 s");
        assert_eq!(secs(2.5e-3), "2.50 ms");
        assert_eq!(secs(3.4e-6), "3.40 µs");
        assert_eq!(secs(5e-9), "5.00 ns");
    }

    #[test]
    fn int_separators() {
        assert_eq!(int(6_810_586), "6,810,586");
        assert_eq!(int(999), "999");
        assert_eq!(int(1_000), "1,000");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512.0), "512 B");
        assert_eq!(bytes(75e9), "69.85 GiB");
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("demo", &["name", "v"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["bb".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("bb    22")); // col0 width 4 ("name"), col1 width 2
        let csv = t.to_csv();
        assert!(csv.starts_with("name,v\n"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
