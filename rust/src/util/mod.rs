//! Small self-contained infrastructure: PRNG, statistics, JSON, formatting.
//!
//! The offline build environment ships no `rand`/`serde`/`serde_json`, so the
//! crate carries its own minimal, well-tested replacements.

pub mod fastdiv;
pub mod fmt;
pub mod hash;
pub mod json;
pub mod plot;
pub mod rng;
pub mod stats;

pub use fastdiv::FastDiv;
pub use hash::Fnv64;
pub use rng::Rng;
pub use stats::Stats;

/// Integer ceiling division.
#[inline]
pub const fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b` (`b > 0`).
#[inline]
pub const fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }
}
