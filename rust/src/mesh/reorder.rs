//! Row (tetrahedron) orderings.
//!
//! The paper's meshes were "re-ordered … for achieving good cache behavior"
//! (§6.1). The ordering determines both the cache behaviour of the compute
//! phase and — decisively — the between-thread communication pattern, since
//! thread affinity is a function of the row index (eq. (1)). We provide four
//! orderings so the ordering ablation can quantify that effect.

use super::tetgrid::TetMesh;
use super::R_NZ;
use crate::util::Rng;

/// Available row orderings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ordering {
    /// Generation order (z-major spatial scan) — the baseline, already
    /// cache-friendly, analogous to the paper's "proper" ordering.
    Natural,
    /// Reverse Cuthill–McKee over the adjacency graph.
    Rcm,
    /// Morton (Z-order) curve over tet centroids.
    Morton,
    /// Uniform random permutation — the worst case.
    Random,
}

impl Ordering {
    pub const ALL: [Ordering; 4] =
        [Ordering::Natural, Ordering::Rcm, Ordering::Morton, Ordering::Random];

    pub fn name(self) -> &'static str {
        match self {
            Ordering::Natural => "natural",
            Ordering::Rcm => "rcm",
            Ordering::Morton => "morton",
            Ordering::Random => "random",
        }
    }

    pub fn parse(s: &str) -> Option<Ordering> {
        Ordering::ALL.iter().copied().find(|o| o.name() == s)
    }

    /// Compute the permutation `perm` with `perm[old] = new`.
    pub fn permutation(self, mesh: &TetMesh) -> Vec<u32> {
        match self {
            Ordering::Natural => (0..mesh.n as u32).collect(),
            Ordering::Rcm => rcm(mesh),
            Ordering::Morton => morton(mesh),
            Ordering::Random => {
                let mut new_of_old: Vec<u32> = (0..mesh.n as u32).collect();
                let mut rng = Rng::new(mesh.seed ^ 0xDEAD_BEEF);
                rng.shuffle(&mut new_of_old);
                new_of_old
            }
        }
    }

    /// Return a re-ordered copy of the mesh.
    pub fn apply(self, mesh: &TetMesh) -> TetMesh {
        if self == Ordering::Natural {
            return mesh.clone();
        }
        apply_permutation(mesh, &self.permutation(mesh))
    }
}

/// Apply a permutation (`perm[old] = new`) to a mesh: rows move, neighbour
/// ids are relabeled, per-row genuine entries stay sorted by the ranking the
/// generator chose (we keep their relative order).
pub fn apply_permutation(mesh: &TetMesh, perm: &[u32]) -> TetMesh {
    assert_eq!(perm.len(), mesh.n);
    debug_assert!(is_permutation(perm));
    let n = mesh.n;
    let mut adj = vec![0u32; n * R_NZ];
    let mut degree = vec![0u8; n];
    let mut centroids = vec![[0f32; 3]; n];
    for old in 0..n {
        let new = perm[old] as usize;
        degree[new] = mesh.degree[old];
        centroids[new] = mesh.centroids[old];
        let d = mesh.degree[old] as usize;
        for k in 0..R_NZ {
            let col_old = mesh.adj[old * R_NZ + k] as usize;
            adj[new * R_NZ + k] = if k < d {
                perm[col_old]
            } else {
                new as u32 // padding follows the row
            };
        }
    }
    TetMesh { n, adj, degree, centroids, seed: mesh.seed }
}

fn is_permutation(perm: &[u32]) -> bool {
    let mut seen = vec![false; perm.len()];
    for &p in perm {
        if (p as usize) >= perm.len() || seen[p as usize] {
            return false;
        }
        seen[p as usize] = true;
    }
    true
}

/// Reverse Cuthill–McKee: BFS from a low-degree seed, neighbours visited in
/// increasing-degree order, final order reversed.
fn rcm(mesh: &TetMesh) -> Vec<u32> {
    let n = mesh.n;
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut queue: std::collections::VecDeque<u32> = std::collections::VecDeque::new();
    // Process every connected component, seeded from min degree.
    let mut by_degree: Vec<u32> = (0..n as u32).collect();
    by_degree.sort_unstable_by_key(|&i| mesh.degree[i as usize]);
    let mut nbrs: Vec<u32> = Vec::with_capacity(R_NZ);
    for &seed in &by_degree {
        if visited[seed as usize] {
            continue;
        }
        visited[seed as usize] = true;
        queue.push_back(seed);
        while let Some(i) = queue.pop_front() {
            order.push(i);
            let d = mesh.degree[i as usize] as usize;
            nbrs.clear();
            nbrs.extend(
                mesh.adj[i as usize * R_NZ..i as usize * R_NZ + d]
                    .iter()
                    .copied()
                    .filter(|&j| !visited[j as usize]),
            );
            nbrs.sort_unstable_by_key(|&j| mesh.degree[j as usize]);
            for &j in &nbrs {
                if !visited[j as usize] {
                    visited[j as usize] = true;
                    queue.push_back(j);
                }
            }
        }
    }
    debug_assert_eq!(order.len(), n);
    // order[k] = old index of the k-th row; reversed for RCM. Build perm.
    let mut perm = vec![0u32; n];
    for (k, &old) in order.iter().rev().enumerate() {
        perm[old as usize] = k as u32;
    }
    perm
}

/// Morton order: quantize centroids to a 21-bit lattice and sort by the
/// interleaved key.
fn morton(mesh: &TetMesh) -> Vec<u32> {
    let n = mesh.n;
    // Bounding box.
    let mut lo = [f32::INFINITY; 3];
    let mut hi = [f32::NEG_INFINITY; 3];
    for c in &mesh.centroids {
        for a in 0..3 {
            lo[a] = lo[a].min(c[a]);
            hi[a] = hi[a].max(c[a]);
        }
    }
    let bits = 21u32;
    let scale: Vec<f64> = (0..3)
        .map(|a| {
            let span = (hi[a] - lo[a]) as f64;
            if span > 0.0 { (((1u64 << bits) - 1) as f64) / span } else { 0.0 }
        })
        .collect();
    let mut keyed: Vec<(u64, u32)> = (0..n)
        .map(|i| {
            let c = mesh.centroids[i];
            let q: Vec<u64> = (0..3)
                .map(|a| (((c[a] - lo[a]) as f64) * scale[a]) as u64)
                .collect();
            (interleave3(q[0], q[1], q[2]), i as u32)
        })
        .collect();
    keyed.sort_unstable();
    let mut perm = vec![0u32; n];
    for (new, &(_, old)) in keyed.iter().enumerate() {
        perm[old as usize] = new as u32;
    }
    perm
}

/// Spread the low 21 bits of `x` so consecutive bits are 3 apart.
fn spread3(mut x: u64) -> u64 {
    x &= (1 << 21) - 1;
    x = (x | (x << 32)) & 0x1F00000000FFFF;
    x = (x | (x << 16)) & 0x1F0000FF0000FF;
    x = (x | (x << 8)) & 0x100F00F00F00F00F;
    x = (x | (x << 4)) & 0x10C30C30C30C30C3;
    x = (x | (x << 2)) & 0x1249249249249249;
    x
}

fn interleave3(x: u64, y: u64, z: u64) -> u64 {
    spread3(x) | (spread3(y) << 1) | (spread3(z) << 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::tetgrid::tiny_mesh;

    #[test]
    fn all_orderings_preserve_structure() {
        let m = tiny_mesh();
        for o in Ordering::ALL {
            let r = o.apply(&m);
            r.validate().unwrap_or_else(|e| panic!("{}: {e}", o.name()));
            assert_eq!(r.n, m.n);
            // Degree multiset preserved.
            let mut a: Vec<u8> = m.degree.clone();
            let mut b: Vec<u8> = r.degree.clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "{}", o.name());
        }
    }

    #[test]
    fn permutation_relabels_edges_consistently() {
        let m = tiny_mesh();
        let perm = Ordering::Rcm.permutation(&m);
        let r = apply_permutation(&m, &perm);
        // Edge (i → j) in m must appear as (perm[i] → perm[j]) in r.
        for i in 0..m.n.min(500) {
            let d = m.degree[i] as usize;
            let mut expect: Vec<u32> =
                m.adj[i * R_NZ..i * R_NZ + d].iter().map(|&j| perm[j as usize]).collect();
            let ni = perm[i] as usize;
            let mut got: Vec<u32> = r.adj[ni * R_NZ..ni * R_NZ + d].to_vec();
            expect.sort_unstable();
            got.sort_unstable();
            assert_eq!(expect, got, "row {i}");
        }
    }

    #[test]
    fn random_order_destroys_locality() {
        let m = tiny_mesh();
        let natural = m.mean_index_distance();
        let random = Ordering::Random.apply(&m).mean_index_distance();
        assert!(
            random > 4.0 * natural,
            "random {random} should be far worse than natural {natural}"
        );
    }

    #[test]
    fn rcm_improves_or_matches_bandwidth_vs_random() {
        let m = Ordering::Random.apply(&tiny_mesh());
        let rcm = Ordering::Rcm.apply(&m);
        assert!(rcm.mean_index_distance() < 0.5 * m.mean_index_distance());
    }

    #[test]
    fn morton_key_interleave() {
        assert_eq!(interleave3(1, 0, 0), 1);
        assert_eq!(interleave3(0, 1, 0), 2);
        assert_eq!(interleave3(0, 0, 1), 4);
        assert_eq!(interleave3(3, 0, 0), 0b1001);
    }
}
