//! Synthetic unstructured tetrahedral meshes.
//!
//! The paper's three test problems are tetrahedral meshes of a human left
//! cardiac ventricle generated with TetGen (Table 1: 6.8M / 13.0M / 25.6M
//! tetrahedra), with up to `r_nz = 16` off-diagonal nonzeros per row after a
//! second-order finite-volume discretization, and rows re-ordered for cache
//! locality.
//!
//! We do not have those meshes (or TetGen output at that scale), so this
//! module builds the closest synthetic equivalent (see DESIGN.md
//! §Substitution record): a **half-ellipsoid shell** (ventricle-like wall)
//! voxelized into hexahedra, each split into 6 Kuhn tetrahedra; the sparsity
//! pattern couples every tetrahedron to up to 16 others chosen from those
//! sharing ≥ 2 vertices (face/edge neighbours — the second-order FV stencil
//! reaches exactly this neighbourhood). The generated pattern is irregular,
//! spatially local under the natural ordering, and has the fixed-degree-16
//! EllPack structure the paper's kernels assume.

mod reorder;
mod tetgrid;

pub use reorder::{apply_permutation, Ordering};
pub use tetgrid::{TetGridSpec, TetMesh};

/// The paper's fixed number of off-diagonal nonzeros per row (§6.1).
pub const R_NZ: usize = 16;

/// The three test problems of Table 1 with their paper-scale sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TestProblem {
    Tp1,
    Tp2,
    Tp3,
}

impl TestProblem {
    /// Number of tetrahedra at paper scale (Table 1).
    pub fn paper_n(self) -> usize {
        match self {
            TestProblem::Tp1 => 6_810_586,
            TestProblem::Tp2 => 13_009_527,
            TestProblem::Tp3 => 25_587_400,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TestProblem::Tp1 => "Test problem 1",
            TestProblem::Tp2 => "Test problem 2",
            TestProblem::Tp3 => "Test problem 3",
        }
    }

    pub const ALL: [TestProblem; 3] = [TestProblem::Tp1, TestProblem::Tp2, TestProblem::Tp3];

    /// Generate the mesh at `1/scale_div` of paper size (natural ordering).
    /// `scale_div = 16` is the default used throughout EXPERIMENTS.md.
    pub fn generate(self, scale_div: usize) -> TetMesh {
        assert!(scale_div >= 1);
        let target = (self.paper_n() / scale_div).max(1000);
        TetMesh::generate(&TetGridSpec::ventricle(target, 0x5EED ^ self.paper_n() as u64))
    }
}
pub use tetgrid::tiny_mesh;
