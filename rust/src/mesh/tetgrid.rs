//! Tetrahedral mesh generator: half-ellipsoid shell → hexahedra → Kuhn tets.

use super::R_NZ;

/// Generation parameters for a synthetic tetrahedral mesh.
#[derive(Debug, Clone)]
pub struct TetGridSpec {
    /// Target number of tetrahedra (actual count will be within ~5 %).
    pub target_tets: usize,
    /// Outer ellipsoid semi-axes (in normalized coordinates).
    pub outer: [f64; 3],
    /// Inner cavity semi-axes as a fraction of `outer`.
    pub inner_frac: f64,
    /// Cut plane: keep cells with normalized z below this (opens the "base"
    /// of the ventricle).
    pub z_cut: f64,
    /// Fraction of the `R_NZ` adjacency slots rewired to *long-range*
    /// couplings. Real second-order FV meshes (after cache reordering) are
    /// not perfectly banded: a small fraction of each row's stencil reaches
    /// far-away row indices, which is what makes every thread *sparsely*
    /// touch many blocks — the regime behind the paper's Figure 2 volumes
    /// (UPCv2 transporting ~25 MB/thread of whole blocks while UPCv3 ships
    /// ~1 MB of condensed values) and the single-node UPCv1 < UPCv2
    /// exception in Table 3.
    pub long_range_frac: f64,
    /// RNG seed (weights / jitter downstream).
    pub seed: u64,
}

impl TetGridSpec {
    /// Ventricle-like wall: thick half-ellipsoid shell.
    pub fn ventricle(target_tets: usize, seed: u64) -> TetGridSpec {
        TetGridSpec {
            target_tets,
            outer: [0.75, 0.75, 1.0],
            inner_frac: 0.62,
            z_cut: 0.35,
            long_range_frac: 0.005,
            seed,
        }
    }

    /// A perfectly banded variant (no long-range couplings) for ablations.
    pub fn ventricle_banded(target_tets: usize, seed: u64) -> TetGridSpec {
        TetGridSpec { long_range_frac: 0.0, ..Self::ventricle(target_tets, seed) }
    }
}

/// An unstructured tetrahedral mesh reduced to what SpMV needs: the
/// fixed-degree adjacency structure (the sparsity pattern of `A`) plus
/// centroids (used by orderings and by the cache-locality estimate).
#[derive(Debug, Clone)]
pub struct TetMesh {
    /// Number of tetrahedra (the paper's `n`).
    pub n: usize,
    /// Row-major `n × R_NZ` neighbour table; rows with fewer than `R_NZ`
    /// genuine neighbours are padded with the row's own index (the matrix
    /// builder assigns weight 0 to padded entries, mirroring the "modified
    /// EllPack" convention of §3.1).
    pub adj: Vec<u32>,
    /// Genuine (un-padded) degree per row.
    pub degree: Vec<u8>,
    /// Tet centroids, used by Morton ordering and locality statistics.
    pub centroids: Vec<[f32; 3]>,
    /// Seed the mesh was generated with (weights reuse it).
    pub seed: u64,
}

impl TetMesh {
    /// Generate a mesh per `spec`. Deterministic for a given spec.
    pub fn generate(spec: &TetGridSpec) -> TetMesh {
        // 1. Find a grid resolution whose masked-cell count lands near the
        //    target (6 tets per kept cell).
        let target_cells = (spec.target_tets / 6).max(8);
        let mut res = estimate_resolution(spec, target_cells);
        for _ in 0..8 {
            let cells = count_cells(spec, res);
            if cells == 0 {
                res += 2;
                continue;
            }
            let ratio = target_cells as f64 / cells as f64;
            if (0.95..=1.05).contains(&ratio) {
                break;
            }
            let next = ((res as f64) * ratio.cbrt()).round() as usize;
            if next == res {
                break;
            }
            res = next.max(4);
        }
        build_mesh(spec, res)
    }

    /// Total nonzero (padded) entries, `n · R_NZ`.
    pub fn nnz(&self) -> usize {
        self.n * R_NZ
    }

    /// Neighbour row `i` (padded to R_NZ).
    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.adj[i * R_NZ..(i + 1) * R_NZ]
    }

    /// Mean |i − j| over genuine adjacency entries — the locality statistic
    /// used by the simulator's cache-reuse estimate and by the ordering
    /// ablation.
    pub fn mean_index_distance(&self) -> f64 {
        let mut sum = 0.0f64;
        let mut cnt = 0.0f64;
        for i in 0..self.n {
            for k in 0..self.degree[i] as usize {
                let j = self.adj[i * R_NZ + k] as i64;
                sum += (i as i64 - j).unsigned_abs() as f64;
                cnt += 1.0;
            }
        }
        if cnt == 0.0 { 0.0 } else { sum / cnt }
    }

    /// Structural sanity check used by tests and after reordering.
    pub fn validate(&self) -> Result<(), String> {
        if self.adj.len() != self.n * R_NZ {
            return Err("adj length".into());
        }
        if self.degree.len() != self.n || self.centroids.len() != self.n {
            return Err("degree/centroid length".into());
        }
        for i in 0..self.n {
            let d = self.degree[i] as usize;
            if d > R_NZ {
                return Err(format!("row {i} degree {d} > {R_NZ}"));
            }
            let row = self.row(i);
            for (k, &j) in row.iter().enumerate() {
                if j as usize >= self.n {
                    return Err(format!("row {i} col {j} out of range"));
                }
                if k < d && j as usize == i {
                    return Err(format!("row {i} has self in genuine entries"));
                }
                if k >= d && j as usize != i {
                    return Err(format!("row {i} padding not self"));
                }
            }
            // genuine entries distinct
            let mut g: Vec<u32> = row[..d].to_vec();
            g.sort_unstable();
            g.dedup();
            if g.len() != d {
                return Err(format!("row {i} duplicate neighbours"));
            }
        }
        Ok(())
    }
}

fn inside(spec: &TetGridSpec, u: f64, v: f64, w: f64) -> bool {
    if w > spec.z_cut {
        return false;
    }
    let q = |a: [f64; 3]| -> f64 {
        (u / a[0]).powi(2) + (v / a[1]).powi(2) + (w / a[2]).powi(2)
    };
    let outer = q(spec.outer);
    let inner = q([
        spec.outer[0] * spec.inner_frac,
        spec.outer[1] * spec.inner_frac,
        spec.outer[2] * spec.inner_frac,
    ]);
    outer <= 1.0 && inner >= 1.0
}

fn cell_center(res: usize, ix: usize, iy: usize, iz: usize) -> (f64, f64, f64) {
    let h = 2.0 / res as f64;
    (
        -1.0 + (ix as f64 + 0.5) * h,
        -1.0 + (iy as f64 + 0.5) * h,
        -1.0 + (iz as f64 + 0.5) * h,
    )
}

fn count_cells(spec: &TetGridSpec, res: usize) -> usize {
    let mut cells = 0usize;
    for iz in 0..res {
        for iy in 0..res {
            for ix in 0..res {
                let (u, v, w) = cell_center(res, ix, iy, iz);
                if inside(spec, u, v, w) {
                    cells += 1;
                }
            }
        }
    }
    cells
}

fn estimate_resolution(spec: &TetGridSpec, target_cells: usize) -> usize {
    // Shell volume fraction of the [-1,1]^3 cube, roughly: half-ellipsoid
    // shell ≈ (2π/3)·abc·(1 − f³) / 8 of the cube … just probe coarsely.
    let probe = 32;
    let frac = count_cells(spec, probe) as f64 / (probe * probe * probe) as f64;
    let frac = frac.max(1e-3);
    ((target_cells as f64 / frac).cbrt().round() as usize).max(4)
}

/// Kuhn subdivision of the unit hexahedron into 6 tetrahedra around the main
/// diagonal (corner 0 → corner 7). Corner numbering: bit0 = +x, bit1 = +y,
/// bit2 = +z.
const KUHN_TETS: [[usize; 4]; 6] = [
    [0, 1, 3, 7],
    [0, 3, 2, 7],
    [0, 2, 6, 7],
    [0, 6, 4, 7],
    [0, 4, 5, 7],
    [0, 5, 1, 7],
];

fn build_mesh(spec: &TetGridSpec, res: usize) -> TetMesh {
    // Pass 1: assign ids to kept cells (z-major scan keeps natural order
    // spatially local, standing in for the paper's cache-oriented
    // reordering).
    let mut cell_id = vec![-1i64; res * res * res];
    let mut kept: Vec<(u32, u32, u32)> = Vec::new();
    for iz in 0..res {
        for iy in 0..res {
            for ix in 0..res {
                let (u, v, w) = cell_center(res, ix, iy, iz);
                if inside(spec, u, v, w) {
                    cell_id[(iz * res + iy) * res + ix] = kept.len() as i64;
                    kept.push((ix as u32, iy as u32, iz as u32));
                }
            }
        }
    }
    let ncells = kept.len();
    let n = ncells * 6;
    assert!(n > 0, "mesh generation produced no cells");

    // Pass 2: tet → 4 global grid-vertex ids; vertex incidence lists.
    let vres = res + 1;
    let vid = |ix: usize, iy: usize, iz: usize| -> u64 { ((iz * vres + iy) * vres + ix) as u64 };
    let mut tet_verts: Vec<[u64; 4]> = Vec::with_capacity(n);
    let mut centroids: Vec<[f32; 3]> = Vec::with_capacity(n);
    let h = 2.0 / res as f64;
    for &(ix, iy, iz) in &kept {
        let (ix, iy, iz) = (ix as usize, iy as usize, iz as usize);
        // corner c: bit0→x+1, bit1→y+1, bit2→z+1
        let corner = |c: usize| -> (usize, usize, usize) {
            (ix + (c & 1), iy + ((c >> 1) & 1), iz + ((c >> 2) & 1))
        };
        for t in KUHN_TETS.iter() {
            let mut vs = [0u64; 4];
            let mut cx = 0.0f64;
            let mut cy = 0.0f64;
            let mut cz = 0.0f64;
            for (k, &c) in t.iter().enumerate() {
                let (x, y, z) = corner(c);
                vs[k] = vid(x, y, z);
                cx += -1.0 + x as f64 * h;
                cy += -1.0 + y as f64 * h;
                cz += -1.0 + z as f64 * h;
            }
            tet_verts.push(vs);
            centroids.push([(cx / 4.0) as f32, (cy / 4.0) as f32, (cz / 4.0) as f32]);
        }
    }

    // Vertex incidence via two-pass counting sort over the 4n (vertex, tet)
    // pairs. Vertex ids are grid ids (sparse) → compress them first.
    let mut vkeys: Vec<u64> = tet_verts.iter().flatten().copied().collect();
    vkeys.sort_unstable();
    vkeys.dedup();
    let vindex = |v: u64| -> usize { vkeys.binary_search(&v).unwrap() };
    let nv = vkeys.len();
    let mut counts = vec![0u32; nv + 1];
    for vs in &tet_verts {
        for &v in vs {
            counts[vindex(v) + 1] += 1;
        }
    }
    for i in 0..nv {
        counts[i + 1] += counts[i];
    }
    let mut incidence = vec![0u32; 4 * n];
    let mut cursor = counts.clone();
    for (tet, vs) in tet_verts.iter().enumerate() {
        for &v in vs {
            let vi = vindex(v);
            incidence[cursor[vi] as usize] = tet as u32;
            cursor[vi] += 1;
        }
    }

    // Pass 3: per tet, candidates = tets sharing ≥ 2 vertices; rank by
    // (shared count desc, |id distance| asc) and keep up to R_NZ.
    let mut adj = vec![0u32; n * R_NZ];
    let mut degree = vec![0u8; n];
    let mut cand: Vec<u32> = Vec::with_capacity(64);
    for i in 0..n {
        cand.clear();
        for &v in &tet_verts[i] {
            let vi = vindex(v);
            let (lo, hi) = (counts[vi] as usize, counts[vi + 1] as usize);
            cand.extend_from_slice(&incidence[lo..hi]);
        }
        cand.sort_unstable();
        // Count multiplicities (shared vertex count) over the sorted list.
        let mut ranked: Vec<(u32, u32)> = Vec::with_capacity(16); // (shared, tet)
        let mut k = 0;
        while k < cand.len() {
            let t = cand[k];
            let mut m = 1;
            while k + m < cand.len() && cand[k + m] == t {
                m += 1;
            }
            if t as usize != i && m >= 2 {
                ranked.push((m as u32, t));
            }
            k += m;
        }
        ranked.sort_unstable_by_key(|&(shared, t)| {
            (std::cmp::Reverse(shared), (t as i64 - i as i64).unsigned_abs())
        });
        let d = ranked.len().min(R_NZ);
        for (slot, &(_, t)) in ranked.iter().take(d).enumerate() {
            adj[i * R_NZ + slot] = t;
        }
        for slot in d..R_NZ {
            adj[i * R_NZ + slot] = i as u32; // self padding
        }
        degree[i] = d as u8;
    }

    // Long-range rewiring (see `TetGridSpec::long_range_frac`): each genuine
    // slot is redirected with small probability to a target at a
    // **log-uniform distance** in [16, n/2]. Distance-decaying long links
    // are what real reordered FV meshes exhibit: they make every thread
    // sparsely touch many *nearby-ish* blocks (UPCv2's inflated volume,
    // Figure 2) while keeping each thread's distinct communication-peer
    // count roughly constant as THREADS grows — which is why the paper's
    // UPCv3 keeps scaling to 32 nodes. Uniform rewiring would instead give
    // all-to-all traffic and destroy that scaling.
    if spec.long_range_frac > 0.0 && n > 64 {
        let mut rng = crate::util::Rng::new(spec.seed ^ 0x4C4F4E47);
        let ln_lo = 16f64.ln();
        let ln_hi = (n as f64 / 2.0).ln();
        for i in 0..n {
            let d = degree[i] as usize;
            for slot in 0..d {
                if rng.bool(spec.long_range_frac) {
                    // Log-uniform distance, random direction (wrapping).
                    for _ in 0..8 {
                        let dist = (ln_lo + rng.f64() * (ln_hi - ln_lo)).exp() as usize;
                        let t = if rng.bool(0.5) {
                            (i + dist) % n
                        } else {
                            (i + n - dist % n) % n
                        } as u32;
                        let row = &adj[i * R_NZ..i * R_NZ + d];
                        if t as usize != i && !row.contains(&t) {
                            adj[i * R_NZ + slot] = t;
                            break;
                        }
                    }
                }
            }
        }
    }

    TetMesh { n, adj, degree, centroids, seed: spec.seed }
}

/// Convenience: an intentionally tiny mesh for unit tests.
pub fn tiny_mesh() -> TetMesh {
    TetMesh::generate(&TetGridSpec::ventricle(2000, 42))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_near_target() {
        let m = TetMesh::generate(&TetGridSpec::ventricle(20_000, 1));
        assert!(
            (m.n as f64) > 20_000.0 * 0.8 && (m.n as f64) < 20_000.0 * 1.25,
            "n = {}",
            m.n
        );
    }

    #[test]
    fn structure_valid() {
        let m = tiny_mesh();
        m.validate().unwrap();
    }

    #[test]
    fn degrees_are_mostly_full() {
        let m = TetMesh::generate(&TetGridSpec::ventricle(20_000, 1));
        let full = m.degree.iter().filter(|&&d| d as usize == R_NZ).count();
        // Interior tets have ≥ 16 face/edge neighbours; the vast majority of
        // rows should be at full degree, like the paper's FV matrices.
        assert!(
            full as f64 > 0.5 * m.n as f64,
            "only {}/{} rows at full degree",
            full,
            m.n
        );
        let mean_deg =
            m.degree.iter().map(|&d| d as f64).sum::<f64>() / m.n as f64;
        assert!(mean_deg > 12.0, "mean degree {mean_deg}");
    }

    #[test]
    fn natural_order_is_local() {
        let m = TetMesh::generate(&TetGridSpec::ventricle(20_000, 1));
        let d = m.mean_index_distance();
        // Neighbours should be within a few grid planes of each other, far
        // below the random-order expectation of n/3.
        assert!(d < m.n as f64 / 20.0, "mean index distance {d} vs n={}", m.n);
    }

    #[test]
    fn deterministic() {
        let a = TetMesh::generate(&TetGridSpec::ventricle(5_000, 9));
        let b = TetMesh::generate(&TetGridSpec::ventricle(5_000, 9));
        assert_eq!(a.n, b.n);
        assert_eq!(a.adj, b.adj);
    }
}
