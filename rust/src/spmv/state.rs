//! The five shared arrays of Listing 2, allocated with consistent layouts.

use crate::matrix::Ellpack;
use crate::pgas::{Layout, SharedVec};

/// UPC-side state for SpMV: `x`, `y`, `D` with block size `BLOCKSIZE`, and
/// `A`, `J` with block size `r_nz · BLOCKSIZE` (Listing 2's allocation).
#[derive(Debug, Clone)]
pub struct SpmvState {
    /// Layout of `x`, `y`, `D`.
    pub layout: Layout,
    /// Layout of `A`, `J` (`n·r_nz` elements, `r_nz·BLOCKSIZE` blocks).
    pub layout_aj: Layout,
    pub r_nz: usize,
    pub x: SharedVec<f64>,
    pub y: SharedVec<f64>,
    pub d: SharedVec<f64>,
    pub a: SharedVec<f64>,
    pub j: SharedVec<u32>,
}

impl SpmvState {
    /// Distribute a matrix over `threads` UPC threads with the given
    /// `BLOCKSIZE`, and load `x0` as the initial vector.
    pub fn new(m: &Ellpack, block_size: usize, threads: usize, x0: &[f64]) -> SpmvState {
        assert_eq!(x0.len(), m.n);
        let layout = Layout::new(m.n, block_size, threads);
        let layout_aj = Layout::new(m.n * m.r_nz, block_size * m.r_nz, threads);
        // The consistent distribution of Listing 2: row i's A/J entries live
        // on the same thread as y[i] — guaranteed because block k of x/y/D
        // maps to block k of A/J.
        SpmvState {
            layout,
            layout_aj,
            r_nz: m.r_nz,
            x: SharedVec::from_global(layout, x0),
            y: SharedVec::alloc(layout),
            d: SharedVec::from_global(layout, &m.diag),
            a: SharedVec::from_global(layout_aj, &m.a),
            j: SharedVec::from_global(layout_aj, &m.j),
        }
    }

    /// Swap `x` and `y` (the §6.1 time-stepping pointer swap).
    pub fn swap_xy(&mut self) {
        self.x.swap(&mut self.y);
    }

    /// Current `x` as a global vector (drivers/tests).
    pub fn x_global(&self) -> Vec<f64> {
        self.x.to_global()
    }

    /// Current `y` as a global vector (drivers/tests).
    pub fn y_global(&self) -> Vec<f64> {
        self.y.to_global()
    }

    /// Rebuild `x` and `y` from global vectors — the restore half of the
    /// SpMV checkpoint. The static arrays (`D`, `A`, `J`) are untouched:
    /// they never change over a run, so a checkpoint does not carry them.
    pub fn restore_from(&mut self, x: &[f64], y: &[f64]) {
        assert_eq!(x.len(), self.layout.n, "checkpoint x length mismatch");
        assert_eq!(y.len(), self.layout.n, "checkpoint y length mismatch");
        self.x = SharedVec::from_global(self.layout, x);
        self.y = SharedVec::from_global(self.layout, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consistent_distribution() {
        let m = Ellpack::random(100, 4, 3);
        let x0 = vec![1.0; 100];
        let s = SpmvState::new(&m, 8, 4, &x0);
        // Row i's A/J data must be owned by the same thread as y[i].
        for i in 0..100 {
            let ty = s.layout.owner_of_index(i);
            for k in 0..4 {
                let taj = s.layout_aj.owner_of_index(i * 4 + k);
                assert_eq!(ty, taj, "row {i} slot {k}");
            }
        }
    }

    #[test]
    fn arrays_roundtrip() {
        let m = Ellpack::random(57, 3, 2);
        let x0: Vec<f64> = (0..57).map(|i| i as f64).collect();
        let s = SpmvState::new(&m, 10, 4, &x0);
        assert_eq!(s.x_global(), x0);
        assert_eq!(s.d.to_global(), m.diag);
        assert_eq!(s.a.to_global(), m.a);
        assert_eq!(s.j.to_global(), m.j);
    }
}
