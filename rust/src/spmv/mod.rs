//! Executable SpMV variants — the paper's Listings 2–5 with faithful data
//! movement.
//!
//! Every variant computes `y = Mx` over the same [`SpmvState`] (the five
//! UPC shared arrays of Listing 2) and produces **bitwise identical** `y`
//! vectors — the transformations change *where data moves*, never the
//! floating-point evaluation order. The executors move real bytes (block
//! copies, packed messages) so tests can verify the communication plans, and
//! the simulated clock accounting lives in [`crate::sim`], driven by the
//! same [`Analysis`](crate::comm::Analysis).
//!
//! The functions here are the sequential oracle; [`crate::engine`] runs the
//! same variants on a real worker pool (one OS thread per UPC thread) with
//! bitwise-identical results.
//!
//! | Variant | Paper listing | x access |
//! |---|---|---|
//! | [`Variant::Naive`] | Listing 2 | element-wise through pointer-to-shared, `upc_forall` |
//! | [`Variant::V1`] | Listing 3 | element-wise; `y,D,A,J` privatized |
//! | [`Variant::V2`] | Listing 4 | whole needed blocks `upc_memget` into a private copy |
//! | [`Variant::V3`] | Listing 5 | condensed + consolidated messages, pack/put/barrier/unpack |

mod exec;
mod kernel;
mod mpi;
mod state;

pub use exec::{run_variant, run_variant_with, BlockCompute, ExecOutcome, NativeCompute};
pub use kernel::{spmv_block_gathered, spmv_block_global, spmv_parallel};
pub use mpi::{ContigPartition, MpiSolver};
pub use state::SpmvState;

/// The four implementations studied in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Naive,
    V1,
    V2,
    V3,
}

impl Variant {
    pub const ALL: [Variant; 4] = [Variant::Naive, Variant::V1, Variant::V2, Variant::V3];
    /// The three *transformed* implementations (Tables 3 & 4).
    pub const TRANSFORMED: [Variant; 3] = [Variant::V1, Variant::V2, Variant::V3];

    pub fn name(self) -> &'static str {
        match self {
            Variant::Naive => "Naive UPC",
            Variant::V1 => "UPCv1",
            Variant::V2 => "UPCv2",
            Variant::V3 => "UPCv3",
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Some(Variant::Naive),
            "v1" | "upcv1" => Some(Variant::V1),
            "v2" | "upcv2" => Some(Variant::V2),
            "v3" | "upcv3" => Some(Variant::V3),
            _ => None,
        }
    }
}
