//! The four executors. Each moves real data with the movement pattern of its
//! listing and records the inter-thread byte traffic it generated, which
//! tests cross-check against the [`Analysis`] predictions.

use super::kernel::{spmv_block_gathered, spmv_block_global};
use super::{SpmvState, Variant};
use crate::comm::Analysis;
use crate::machine::SIZEOF_DOUBLE;

/// Pluggable block-level compute backend for the bulk variants (V2/V3).
///
/// The coordinator provides a PJRT-backed implementation that executes the
/// AOT-compiled Pallas kernel; the default [`NativeCompute`] runs the
/// optimized Rust kernel. The naive/V1 variants are element-wise by
/// definition and always run natively.
pub trait BlockCompute {
    /// Compute `y[k] = D[k]·x_copy[offset+k] + Σ_j A[k·r+j]·x_copy[J[k·r+j]]`
    /// for one block of rows.
    fn block(
        &mut self,
        offset: usize,
        d: &[f64],
        a: &[f64],
        j: &[u32],
        r_nz: usize,
        x_copy: &[f64],
        y: &mut [f64],
    );
}

/// The native Rust hot path ([`spmv_block_gathered`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeCompute;

impl BlockCompute for NativeCompute {
    #[inline]
    fn block(
        &mut self,
        offset: usize,
        d: &[f64],
        a: &[f64],
        j: &[u32],
        r_nz: usize,
        x_copy: &[f64],
        y: &mut [f64],
    ) {
        spmv_block_gathered(offset, d, a, j, r_nz, x_copy, y);
    }
}

/// What an executor reports back.
#[derive(Debug, Clone)]
pub struct ExecOutcome {
    /// The result vector `y`, gathered to global indexing.
    pub y: Vec<f64>,
    /// Bytes that crossed a thread boundary (any direction, payload only).
    pub inter_thread_bytes: u64,
    /// Consolidated messages sent (v3) / blocks transferred from other
    /// threads (v2) / individual off-owner reads (naive, v1).
    pub transfers: u64,
}

/// Run one SpMV `y = Mx` with the chosen variant on the **sequential
/// oracle engine** (all logical UPC threads replayed on the calling
/// thread). `analysis` must be built for the same layout/topology and is
/// required by V2 (needed blocks) and V3 (communication plan).
///
/// For real parallel execution — one OS thread per UPC thread — go through
/// [`crate::engine::SpmvEngine`] / [`crate::engine::run_variant_on`], which
/// dispatch to this function for [`crate::engine::Engine::Sequential`].
pub fn run_variant(
    variant: Variant,
    state: &mut SpmvState,
    analysis: Option<&Analysis>,
) -> ExecOutcome {
    run_variant_with(variant, state, analysis, &mut NativeCompute)
}

/// [`run_variant`] with an explicit compute backend for the bulk variants.
pub fn run_variant_with(
    variant: Variant,
    state: &mut SpmvState,
    analysis: Option<&Analysis>,
    compute: &mut dyn BlockCompute,
) -> ExecOutcome {
    match variant {
        Variant::Naive => run_naive(state),
        Variant::V1 => run_v1(state),
        Variant::V2 => run_v2(state, analysis.expect("V2 needs an Analysis"), compute),
        Variant::V3 => run_v3(state, analysis.expect("V3 needs an Analysis"), compute),
    }
}

/// Listing 2: `upc_forall` over all rows; every array access goes through
/// the shared-array interface (`SharedVec::at`).
fn run_naive(state: &mut SpmvState) -> ExecOutcome {
    let layout = state.layout;
    let r = state.r_nz;
    let n = layout.n;
    let mut inter = 0u64;
    let mut transfers = 0u64;
    let mut y_new = vec![0.0f64; n];
    for t in 0..layout.threads {
        // upc_forall: every thread scans the whole iteration space and
        // executes the rows with matching affinity.
        for (i, slot) in y_new.iter_mut().enumerate() {
            if layout.owner_of_index(i) != t {
                continue;
            }
            let mut tmp = 0.0f64;
            for jj in 0..r {
                let col = *state.j.at(i * r + jj) as usize;
                if col != i && layout.owner_of_index(col) != t {
                    inter += SIZEOF_DOUBLE as u64;
                    transfers += 1;
                }
                tmp += *state.a.at(i * r + jj) * *state.x.at(col);
            }
            *slot = *state.d.at(i) * *state.x.at(i) + tmp;
        }
    }
    write_y(state, &y_new);
    ExecOutcome { y: y_new, inter_thread_bytes: inter, transfers }
}

/// Listing 3: explicit thread privatization — per-thread block loop with
/// `y,D,A,J` accessed as pointer-to-local slices; `x` stays shared.
fn run_v1(state: &mut SpmvState) -> ExecOutcome {
    let layout = state.layout;
    let r = state.r_nz;
    let mut inter = 0u64;
    let mut transfers = 0u64;
    let mut y_new = vec![0.0f64; layout.n];
    for t in 0..layout.threads {
        for b in layout.blocks_of_thread(t) {
            let (offset, len) = layout.block_range(b);
            // Count off-owner x accesses (the communication this variant
            // performs element-wise).
            for i in offset..offset + len {
                for jj in 0..r {
                    let col = *state.j.at(i * r + jj) as usize;
                    if col != i && layout.owner_of_index(col) != t {
                        inter += SIZEOF_DOUBLE as u64;
                        transfers += 1;
                    }
                }
            }
            let x = &state.x;
            spmv_block_global(
                offset,
                state.d.block(b),
                block_aj(&state.a, b, r, len),
                block_aj(&state.j, b, r, len),
                r,
                |i| *x.at(i),
                &mut y_new[offset..offset + len],
            );
        }
    }
    write_y(state, &y_new);
    ExecOutcome { y: y_new, inter_thread_bytes: inter, transfers }
}

/// Listing 4: block-wise `upc_memget` of every needed block into a private
/// full-length copy, then fully private compute.
fn run_v2(state: &mut SpmvState, analysis: &Analysis, compute: &mut dyn BlockCompute) -> ExecOutcome {
    let layout = state.layout;
    let r = state.r_nz;
    let mut inter = 0u64;
    let mut transfers = 0u64;
    let mut y_new = vec![0.0f64; layout.n];
    // One private copy reused across logical threads. No zero-fill between
    // threads: every position thread t's rows read is freshly transported
    // for t (its own blocks plus every needed block), so stale values from
    // the previous logical thread are never observed. This removes the
    // O(threads·n) refill traffic the seed executor paid per iteration.
    let mut x_copy = vec![0.0f64; layout.n];
    for t in 0..layout.threads {
        // Transport the needed blocks (own blocks included, as Listing 4
        // does) — upc_memget is a straight contiguous copy.
        for b in 0..layout.nblks() {
            if !analysis.block_needed(t, b) {
                continue;
            }
            let (start, len) = layout.block_range(b);
            x_copy[start..start + len].copy_from_slice(state.x.block(b));
            if layout.owner_of_block(b) != t {
                inter += (len * SIZEOF_DOUBLE) as u64;
                transfers += 1;
            }
        }
        for b in layout.blocks_of_thread(t) {
            let (offset, len) = layout.block_range(b);
            compute.block(
                offset,
                state.d.block(b),
                block_aj(&state.a, b, r, len),
                block_aj(&state.j, b, r, len),
                r,
                &x_copy,
                &mut y_new[offset..offset + len],
            );
        }
    }
    write_y(state, &y_new);
    ExecOutcome { y: y_new, inter_thread_bytes: inter, transfers }
}

/// Listing 5: pack condensed messages → `upc_memput` → barrier → unpack +
/// copy own blocks → compute.
fn run_v3(state: &mut SpmvState, analysis: &Analysis, compute: &mut dyn BlockCompute) -> ExecOutcome {
    let layout = state.layout;
    let r = state.r_nz;
    let threads = layout.threads;
    let plan = &analysis.plan;
    let mut inter = 0u64;
    let mut transfers = 0u64;

    // Phase 1 (before the barrier): every thread packs and "puts" its
    // outgoing messages into the flat staging arena. The compiled plan's
    // per-message ranges *are* the receivers' shared_recv_buffer slots, and
    // the pre-translated `local_src` offsets replace the per-value layout
    // translation (and the per-message heap allocation plus the
    // receiver-slot search) the seed executor performed on every pack.
    let mut staging = vec![0.0f64; plan.total_values()];
    for t in 0..threads {
        let local_x = state.x.local(t);
        for msg in plan.send_msgs(t) {
            // upc_memput into the receiver's arena range for this sender.
            let buf = &mut staging[msg.range()];
            for (slot, &src) in buf.iter_mut().zip(msg.local_src) {
                *slot = local_x[src as usize];
            }
            inter += (buf.len() * SIZEOF_DOUBLE) as u64;
            transfers += 1;
        }
    }

    // ---- upc_barrier ----

    // Phase 2: copy own blocks + unpack incoming, then compute. As in V2,
    // `x_copy` is reused across logical threads without a zero-fill: thread
    // t's rows only ever read its own blocks (copied below) and the
    // condensed indices its recv messages scatter.
    let mut y_new = vec![0.0f64; layout.n];
    let mut x_copy = vec![0.0f64; layout.n];
    for t in 0..threads {
        for b in layout.blocks_of_thread(t) {
            let (start, len) = layout.block_range(b);
            x_copy[start..start + len].copy_from_slice(state.x.block(b));
        }
        for msg in plan.recv_msgs(t) {
            let vals = &staging[msg.range()];
            for (&gidx, &v) in msg.indices.iter().zip(vals) {
                x_copy[gidx as usize] = v;
            }
        }
        for b in layout.blocks_of_thread(t) {
            let (offset, len) = layout.block_range(b);
            compute.block(
                offset,
                state.d.block(b),
                block_aj(&state.a, b, r, len),
                block_aj(&state.j, b, r, len),
                r,
                &x_copy,
                &mut y_new[offset..offset + len],
            );
        }
    }
    write_y(state, &y_new);
    ExecOutcome { y: y_new, inter_thread_bytes: inter, transfers }
}

/// Slice block `b` of the A/J tables (their blocks are `r_nz ×` longer).
fn block_aj<T: Copy + Default>(
    v: &crate::pgas::SharedVec<T>,
    b: usize,
    _r_nz: usize,
    _len: usize,
) -> &[T] {
    v.block(b)
}

fn write_y(state: &mut SpmvState, y_new: &[f64]) {
    let layout = state.layout;
    for b in 0..layout.nblks() {
        let (start, len) = layout.block_range(b);
        state.y.block_mut(b).copy_from_slice(&y_new[start..start + len]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Ellpack;
    use crate::pgas::{Layout, Topology};
    use crate::testing::check_prop;

    fn analysis_for(m: &Ellpack, bs: usize, nodes: usize, tpn: usize) -> Analysis {
        let layout = Layout::new(m.n, bs, nodes * tpn);
        Analysis::build(&m.j, m.r_nz, layout, Topology::new(nodes, tpn), usize::MAX)
    }

    #[test]
    fn all_variants_match_oracle_bitwise() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let x0 = m.initial_vector(11);
        let mut want = vec![0.0; m.n];
        m.spmv_seq(&x0, &mut want);
        let analysis = analysis_for(&m, 128, 2, 4);
        for v in Variant::ALL {
            let mut state = SpmvState::new(&m, 128, 8, &x0);
            let out = run_variant(v, &mut state, Some(&analysis));
            assert_eq!(out.y, want, "{} diverges from the oracle", v.name());
            assert_eq!(state.y_global(), want, "{} shared y mismatch", v.name());
        }
    }

    #[test]
    fn traffic_matches_analysis() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let x0 = m.initial_vector(1);
        let analysis = analysis_for(&m, 128, 2, 4);
        // v1 executor's byte count = Σ occurrences · 8.
        let mut s = SpmvState::new(&m, 128, 8, &x0);
        let v1 = run_variant(Variant::V1, &mut s, Some(&analysis));
        let occurrences: u64 =
            analysis.per_thread.iter().map(|t| t.c_total_indv()).sum();
        assert_eq!(v1.inter_thread_bytes, occurrences * 8);
        // v3 executor's byte count = Σ unique incoming values · 8.
        let mut s = SpmvState::new(&m, 128, 8, &x0);
        let v3 = run_variant(Variant::V3, &mut s, Some(&analysis));
        let unique: u64 = analysis.per_thread.iter().map(|t| t.s_total_in()).sum();
        assert_eq!(v3.inter_thread_bytes, unique * 8);
        // v3 message count = total messages in the plan.
        let msgs: usize = (0..8).map(|t| analysis.plan.messages_from(t)).sum();
        assert_eq!(v3.transfers as usize, msgs);
        // v2 moves whole blocks: strictly more bytes than v3's condensed.
        let mut s = SpmvState::new(&m, 128, 8, &x0);
        let v2 = run_variant(Variant::V2, &mut s, Some(&analysis));
        assert!(v2.inter_thread_bytes >= v3.inter_thread_bytes);
    }

    #[test]
    fn time_loop_stays_consistent_across_variants() {
        // Run 5 steps of v = Mv with each variant; all must agree bitwise.
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let x0 = m.initial_vector(2);
        let analysis = analysis_for(&m, 64, 1, 4);
        let mut finals: Vec<Vec<f64>> = Vec::new();
        for v in Variant::ALL {
            let mut state = SpmvState::new(&m, 64, 4, &x0);
            for _ in 0..5 {
                run_variant(v, &mut state, Some(&analysis));
                state.swap_xy();
            }
            finals.push(state.x_global());
        }
        for w in finals.windows(2) {
            assert_eq!(w[0], w[1]);
        }
    }

    /// Property: variants agree on random matrices, block sizes, topologies.
    #[test]
    fn prop_variants_agree() {
        check_prop(
            "variants-agree",
            16,
            |r| {
                let n = r.usize_in(10, 300);
                let rnz = r.usize_in(1, 6);
                let bs = r.usize_in(1, 50);
                let tpn = r.usize_in(1, 3);
                let nodes = r.usize_in(1, 3);
                let m = Ellpack::random(n, rnz, r.next_u64());
                let x0: Vec<f64> = (0..n).map(|_| r.f64_in(-1.0, 1.0)).collect();
                (m, x0, bs, nodes, tpn)
            },
            |(m, x0, bs, nodes, tpn)| {
                let threads = nodes * tpn;
                let analysis = analysis_for(m, *bs, *nodes, *tpn);
                analysis.validate()?;
                let mut want = vec![0.0; m.n];
                m.spmv_seq(x0, &mut want);
                for v in Variant::ALL {
                    let mut state = SpmvState::new(m, *bs, threads, x0);
                    let out = run_variant(v, &mut state, Some(&analysis));
                    if out.y != want {
                        return Err(format!("{} diverges", v.name()));
                    }
                }
                Ok(())
            },
        );
    }
}
