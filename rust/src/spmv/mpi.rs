//! MPI-style two-sided baseline (the comparator of the paper's §9).
//!
//! The paper's concluding discussion contrasts UPCv3 with "an MPI
//! counterpart, where all arrays are explicitly partitioned among processes
//! [and] have to map the global indices to local indices", noting MPI's
//! "persistent advantages … better data locality and more flexible data
//! partitionings". This module implements that counterpart so the claim is
//! measurable:
//!
//! * **contiguous partitioning** — rank `r` owns rows
//!   `[r·⌈n/P⌉, (r+1)·⌈n/P⌉)` (no block-cyclic constraint);
//! * **global→local relabeling** — at setup, each rank rewrites its slice
//!   of `J` into local row indices, with off-rank references pointing into a
//!   **ghost region** appended after the owned rows (the programming cost
//!   the paper says UPC avoids);
//! * **two-sided exchange** — per step, each rank packs the owned values its
//!   neighbours need (same condensed lists as UPCv3) and receives its ghost
//!   values as one contiguous append — no scattered unpack, which is exactly
//!   where the MPI model beats eq. (15)'s cache-line-per-value term.
//!
//! The executor produces bitwise-identical results to the UPC variants.

use crate::engine::{kernels, Engine, EpochFlags, PerWorker, Phase, WaitTuning, DEFAULT_WAIT_DEADLINE};
use crate::machine::{HwParams, SIZEOF_DOUBLE, SIZEOF_INT};
use crate::matrix::Ellpack;
use crate::pgas::Topology;
use crate::sim::SimParams;
use crate::transport::{must, wait_epoch_flag};
use crate::util::FastDiv;

/// Contiguous partition of `n` rows over `ranks`.
#[derive(Debug, Clone, Copy)]
pub struct ContigPartition {
    pub n: usize,
    pub ranks: usize,
    chunk: usize,
    /// §Perf: `owner()` runs once per nonzero during setup; the
    /// reciprocal-multiply divider avoids a hardware `div` per call
    /// (same treatment as [`crate::pgas::Layout::owner_of_index`]).
    chunk_div: FastDiv,
}

impl ContigPartition {
    pub fn new(n: usize, ranks: usize) -> ContigPartition {
        assert!(n > 0 && ranks > 0);
        assert!(n <= u32::MAX as usize, "row indices must fit u32");
        let chunk = n.div_ceil(ranks);
        ContigPartition { n, ranks, chunk, chunk_div: FastDiv::new(chunk) }
    }

    #[inline]
    pub fn owner(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        self.chunk_div.div(i)
    }

    /// Row range `[start, end)` of `rank`.
    pub fn range(&self, rank: usize) -> (usize, usize) {
        let start = (rank * self.chunk).min(self.n);
        ((start), ((rank + 1) * self.chunk).min(self.n))
    }

    pub fn len(&self, rank: usize) -> usize {
        let (s, e) = self.range(rank);
        e - s
    }
}

/// Per-rank state after setup: relabeled matrix slice + ghost map + plan.
#[derive(Debug, Clone)]
struct RankState {
    start: usize,
    rows: usize,
    diag: Vec<f64>,
    a: Vec<f64>,
    /// Local column indices: `< rows` → owned, `rows + g` → ghost slot g.
    jl: Vec<u32>,
    /// Global index of each ghost slot (sorted).
    ghosts: Vec<u32>,
    /// Send lists: (peer, local offsets of owned values to pack).
    send: Vec<(u32, Vec<u32>)>,
    /// Receive counts per peer (ghost slots arrive sorted by peer,global).
    recv: Vec<(u32, u32)>,
}

/// The MPI-style solver: setup once, then `step` repeatedly.
///
/// All exchange buffers are persistent: `send_bufs[rank][k]` is the packed
/// payload of rank's k-th send list and `recv_route` pre-resolves which
/// buffer each expected incoming message lives in, so a steady-state step
/// performs **zero heap allocations** on the transport path — the same
/// discipline as the engine paths.
#[derive(Debug, Clone)]
pub struct MpiSolver {
    part: ContigPartition,
    r_nz: usize,
    ranks: Vec<RankState>,
    /// Local x per rank: owned values followed by ghost values.
    x: Vec<Vec<f64>>,
    /// Persistent per-send message payloads, parallel to `RankState::send`.
    send_bufs: Vec<Vec<Vec<f64>>>,
    /// `recv_route[r][j] = (peer, k)`: receiver r's j-th expected message
    /// (the order of `RankState::recv`) is `send_bufs[peer][k]`.
    recv_route: Vec<Vec<(u32, u32)>>,
    /// Persistent per-rank compute scratch (the Jacobi commit buffer).
    y_scratch: Vec<Vec<f64>>,
    /// Traffic statistics (per step, constant).
    pub values_exchanged: u64,
    pub messages: u64,
}

impl MpiSolver {
    /// Partition + relabel + build the exchange plan (the paper's "map the
    /// global indices to local indices" cost, paid once).
    pub fn new(m: &Ellpack, ranks: usize, x0: &[f64]) -> MpiSolver {
        assert_eq!(x0.len(), m.n);
        let part = ContigPartition::new(m.n, ranks);
        let mut states = Vec::with_capacity(ranks);
        let mut xs = Vec::with_capacity(ranks);
        let mut values_exchanged = 0u64;
        let mut messages = 0u64;

        // Pass 1: per rank, find unique external references.
        let mut needs: Vec<Vec<(u32, u32)>> = Vec::with_capacity(ranks); // (owner, global)
        for rank in 0..ranks {
            let (s, e) = part.range(rank);
            let mut ext: Vec<(u32, u32)> = Vec::new();
            for i in s..e {
                for &c in m.row_cols(i) {
                    let cu = c as usize;
                    if (cu < s || cu >= e) && cu != i {
                        ext.push((part.owner(cu) as u32, c));
                    }
                }
            }
            ext.sort_unstable();
            ext.dedup();
            needs.push(ext);
        }

        // Pass 2: transpose into send lists.
        let mut send: Vec<Vec<(u32, Vec<u32>)>> = vec![Vec::new(); ranks];
        for (rank, ext) in needs.iter().enumerate() {
            let mut k = 0;
            while k < ext.len() {
                let owner = ext[k].0;
                let mut vals = Vec::new();
                while k < ext.len() && ext[k].0 == owner {
                    let (os, _) = part.range(owner as usize);
                    vals.push(ext[k].1 - os as u32); // local offset at owner
                    k += 1;
                }
                values_exchanged += vals.len() as u64;
                messages += 1;
                send[owner as usize].push((rank as u32, vals));
            }
        }

        // Persistent message payload buffers, and the receive routing:
        // iterating owners in ascending order hands every receiver its
        // `(peer, send-index)` pairs sorted by peer — exactly the order of
        // its ghost region and its `recv` count list.
        let send_bufs: Vec<Vec<Vec<f64>>> = send
            .iter()
            .map(|sends| sends.iter().map(|(_, vals)| vec![0.0f64; vals.len()]).collect())
            .collect();
        let mut recv_route: Vec<Vec<(u32, u32)>> = vec![Vec::new(); ranks];
        for (owner, sends) in send.iter().enumerate() {
            for (k, (peer, _)) in sends.iter().enumerate() {
                recv_route[*peer as usize].push((owner as u32, k as u32));
            }
        }

        // Pass 3: relabel J and build per-rank state + local x.
        for rank in 0..ranks {
            let (s, e) = part.range(rank);
            let rows = e - s;
            let ghosts: Vec<u32> = needs[rank].iter().map(|&(_, g)| g).collect();
            let ghost_slot = |g: u32| -> u32 {
                rows as u32 + ghosts.binary_search(&g).expect("ghost listed") as u32
            };
            let mut jl = Vec::with_capacity(rows * m.r_nz);
            for i in s..e {
                for &c in m.row_cols(i) {
                    let cu = c as usize;
                    jl.push(if cu >= s && cu < e {
                        (cu - s) as u32
                    } else if cu == i {
                        (i - s) as u32 // padding keeps pointing at the row
                    } else {
                        ghost_slot(c)
                    });
                }
            }
            let recv: Vec<(u32, u32)> = {
                let mut counts: Vec<(u32, u32)> = Vec::new();
                for &(owner, _) in &needs[rank] {
                    match counts.last_mut() {
                        Some((o, c)) if *o == owner => *c += 1,
                        _ => counts.push((owner, 1)),
                    }
                }
                counts
            };
            let mut x_local: Vec<f64> = x0[s..e].to_vec();
            x_local.resize(rows + ghosts.len(), 0.0);
            xs.push(x_local);
            states.push(RankState {
                start: s,
                rows,
                diag: m.diag[s..e].to_vec(),
                a: m.a[s * m.r_nz..e * m.r_nz].to_vec(),
                jl,
                ghosts,
                send: std::mem::take(&mut send[rank]),
                recv,
            });
        }
        let y_scratch = states.iter().map(|st| vec![0.0f64; st.rows]).collect();
        MpiSolver {
            part,
            r_nz: m.r_nz,
            ranks: states,
            x: xs,
            send_bufs,
            recv_route,
            y_scratch,
            values_exchanged,
            messages,
        }
    }

    /// One step `x ← Mx`: exchange ghosts, compute locally (on the
    /// sequential oracle engine).
    pub fn step(&mut self) {
        self.step_with(Engine::Sequential);
    }

    /// One step on the chosen engine. Both engines are bitwise identical;
    /// [`Engine::Parallel`] runs one OS thread per MPI-style rank with the
    /// same pack → exchange → compute phase structure.
    pub fn step_with(&mut self, engine: Engine) {
        match engine {
            Engine::Sequential => self.step_seq(),
            Engine::Parallel => self.step_par(),
        }
    }

    fn step_seq(&mut self) {
        // Exchange: pack from owners into the persistent payload buffers
        // ("receive" is a contiguous ghost fill through the routing table).
        for ((st, bufs), x) in self.ranks.iter().zip(&mut self.send_bufs).zip(&self.x) {
            for ((_, offsets), buf) in st.send.iter().zip(bufs.iter_mut()) {
                for (slot, &o) in buf.iter_mut().zip(offsets) {
                    *slot = x[o as usize];
                }
            }
        }
        // Ghost fill + compute + commit per rank. The compute reads only the
        // rank's own buffer (owned values are old until its own commit), so
        // the per-rank fusion is order-independent across ranks.
        for (rank, st) in self.ranks.iter().enumerate() {
            Self::rank_step(
                st,
                self.r_nz,
                &self.recv_route[rank],
                &self.send_bufs,
                &mut self.x[rank],
                &mut self.y_scratch[rank],
            );
        }
    }

    /// Ghost fill + ELLPACK compute + commit for one rank (shared by both
    /// engines). `route` resolves the rank's expected incoming messages
    /// (the order of `st.recv`, sorted by sender) to packed payloads in
    /// `bufs`; `x` is the rank's owned-then-ghost buffer; `y` its persistent
    /// commit scratch.
    fn rank_step(
        st: &RankState,
        r_nz: usize,
        route: &[(u32, u32)],
        bufs: &[Vec<Vec<f64>>],
        x: &mut [f64],
        y: &mut [f64],
    ) {
        debug_assert_eq!(route.len(), st.recv.len(), "routing table arity");
        let mut cursor = st.rows;
        for (&(peer, k), (want_peer, want_len)) in route.iter().zip(&st.recv) {
            let buf = &bufs[peer as usize][k as usize];
            assert_eq!(peer, *want_peer, "unexpected sender");
            assert_eq!(buf.len() as u32, *want_len, "short message");
            x[cursor..cursor + buf.len()].copy_from_slice(buf);
            cursor += buf.len();
        }
        Self::rank_compute(st, r_nz, x, y);
    }

    /// ELLPACK compute into the persistent scratch, then commit (Jacobi
    /// semantics). Shared by both engines — one FP order.
    fn rank_compute(st: &RankState, r_nz: usize, x: &mut [f64], y: &mut [f64]) {
        for k in 0..st.rows {
            let mut tmp = 0.0;
            for jj in 0..r_nz {
                tmp += st.a[k * r_nz + jj] * x[st.jl[k * r_nz + jj] as usize];
            }
            y[k] = st.diag[k] * x[k] + tmp;
        }
        x[..st.rows].copy_from_slice(y);
    }

    /// Parallel step on scoped rank threads, synchronized by the transport
    /// layer's epoch-flag primitives instead of a scope-wide barrier: each
    /// rank packs its persistent payload buffers and publishes its epoch
    /// flag (Release), then waits per expected sender (Acquire, deadline-
    /// and stall-aware) before filling its ghosts straight from that
    /// sender's buffers — the same split-phase structure as the engine
    /// protocols, so a dead peer converts into a structured
    /// [`StallError`](crate::engine::StallError) panic, never a hang. No
    /// per-step allocation on the transport path: the payload buffers,
    /// routing table and commit scratch all persist.
    fn step_par(&mut self) {
        let r = self.r_nz;
        let route = &self.recv_route;
        let states = &self.ranks;
        let flags = EpochFlags::new(states.len());
        let bufs_view = PerWorker::new(&mut self.send_bufs);
        let x_view = PerWorker::new(&mut self.x);
        let y_view = PerWorker::new(&mut self.y_scratch);
        std::thread::scope(|s| {
            for rank in 0..states.len() {
                let (flags, bufs_view) = (&flags, &bufs_view);
                let (x_view, y_view) = (&x_view, &y_view);
                s.spawn(move || {
                    let st = &states[rank];
                    // SAFETY: rank claims only its own payload buffers,
                    // x buffer and scratch, exactly once per step.
                    let bufs = unsafe { bufs_view.take(rank) };
                    let x = unsafe { x_view.take(rank) }.as_mut_slice();
                    let y = unsafe { y_view.take(rank) }.as_mut_slice();
                    // begin: pack + publish. Publish even with nothing to
                    // send — peers wait on the flag, not the payload.
                    for ((_, offsets), buf) in st.send.iter().zip(bufs.iter_mut()) {
                        kernels::pack_gather(x, offsets, buf);
                    }
                    flags.publish(rank, 1);
                    // finish: per-sender waits + contiguous ghost append.
                    let mut cursor = st.rows;
                    for (&(peer, k), (want_peer, want_len)) in route[rank].iter().zip(&st.recv) {
                        let p = peer as usize;
                        must(wait_epoch_flag(
                            flags.flag(p),
                            1,
                            Some(DEFAULT_WAIT_DEADLINE),
                            WaitTuning::default(),
                            rank,
                            p,
                            Phase::Transfer,
                            &format!("mpi:rank-{p}"),
                        ));
                        // SAFETY: read-only view of the sender's payloads,
                        // taken only after its Release publish was observed
                        // by the Acquire wait above; the sender never
                        // rewrites them within this step.
                        let buf = &unsafe { bufs_view.peek(p) }[k as usize];
                        assert_eq!(peer, *want_peer, "unexpected sender");
                        assert_eq!(buf.len() as u32, *want_len, "short message");
                        x[cursor..cursor + buf.len()].copy_from_slice(buf);
                        cursor += buf.len();
                    }
                    Self::rank_compute(st, r, x, y);
                });
            }
        });
    }

    /// Gather the current solution to global indexing.
    pub fn x_global(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.part.n];
        for (rank, st) in self.ranks.iter().enumerate() {
            out[st.start..st.start + st.rows].copy_from_slice(&self.x[rank][..st.rows]);
        }
        out
    }

    /// Per-step time on the simulated cluster + the eq.(12)-(18)-style
    /// closed-form model, adapted to two-sided contiguous semantics:
    /// unpack is a contiguous append (no per-value cache-line penalty) and
    /// there is no own-block copy (x is already local).
    pub fn predict_step(&self, topo: &Topology, hw: &HwParams, params: &SimParams) -> (f64, f64) {
        assert_eq!(topo.threads(), self.ranks.len());
        const D: f64 = SIZEOF_DOUBLE as f64;
        const I: f64 = SIZEOF_INT as f64;
        let w = hw.w_thread_private;
        let d_min = (self.r_nz * (SIZEOF_DOUBLE + SIZEOF_INT) + 3 * SIZEOF_DOUBLE) as f64;

        let mut phase1_model = 0.0f64;
        let mut phase1_sim = 0.0f64;
        for node in 0..topo.nodes {
            let communicating = topo
                .threads_of_node(node)
                .filter(|&t| {
                    self.ranks[t].send.iter().any(|(p, _)| !topo.same_node(t, *p as usize))
                })
                .count();
            let tau_eff = params.tau_eff(communicating);
            let mut pack_max = 0.0f64;
            let mut local_max = 0.0f64;
            let mut remote = 0.0f64;
            let mut remote_sim = 0.0f64;
            for t in topo.threads_of_node(node) {
                let st = &self.ranks[t];
                let mut s_local = 0usize;
                let mut s_remote = 0usize;
                let mut c_remote = 0usize;
                for (peer, vals) in &st.send {
                    if topo.same_node(t, *peer as usize) {
                        s_local += vals.len();
                    } else {
                        s_remote += vals.len();
                        c_remote += 1;
                    }
                }
                let pack = (s_local + s_remote) as f64 * (2.0 * D + I) / w;
                pack_max = pack_max.max(pack);
                local_max = local_max.max(2.0 * s_local as f64 * D / w);
                remote += c_remote as f64 * hw.tau + s_remote as f64 * D / hw.w_node_remote;
                remote_sim += c_remote as f64 * tau_eff + s_remote as f64 * D / hw.w_node_remote;
            }
            phase1_model = phase1_model.max(pack_max + local_max + remote);
            phase1_sim = phase1_sim.max(pack_max + local_max + remote_sim);
        }
        // Phase 2: contiguous ghost append (D+I per value, no cache-line
        // scatter) + compute. No own-copy term.
        let mut phase2 = 0.0f64;
        for st in &self.ranks {
            let unpack = st.ghosts.len() as f64 * (D + I) / w;
            let comp = st.rows as f64 * d_min / w;
            phase2 = phase2.max(unpack + comp);
        }
        (phase1_sim + phase2, phase1_model + phase2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check_prop;

    #[test]
    fn contig_partition_covers() {
        let p = ContigPartition::new(103, 8);
        let mut total = 0;
        for r in 0..8 {
            total += p.len(r);
            let (s, e) = p.range(r);
            for i in s..e {
                assert_eq!(p.owner(i), r);
            }
        }
        assert_eq!(total, 103);
    }

    #[test]
    fn mpi_matches_upc_variants_bitwise() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let x0 = m.initial_vector(9);
        // Reference: 5 steps of the sequential oracle.
        let mut xref = x0.clone();
        let mut y = vec![0.0; m.n];
        for _ in 0..5 {
            m.spmv_seq(&xref, &mut y);
            std::mem::swap(&mut xref, &mut y);
        }
        let mut solver = MpiSolver::new(&m, 8, &x0);
        for _ in 0..5 {
            solver.step();
        }
        assert_eq!(solver.x_global(), xref, "MPI baseline diverged");
    }

    #[test]
    fn exchange_is_condensed() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let x0 = m.initial_vector(1);
        let solver = MpiSolver::new(&m, 8, &x0);
        // Unique external references only: strictly fewer values than total
        // off-rank occurrences.
        let part = ContigPartition::new(m.n, 8);
        let occurrences: u64 = (0..m.n)
            .map(|i| {
                m.row_cols(i)
                    .iter()
                    .filter(|&&c| c as usize != i && part.owner(c as usize) != part.owner(i))
                    .count() as u64
            })
            .sum();
        assert!(solver.values_exchanged > 0);
        assert!(solver.values_exchanged <= occurrences);
    }

    #[test]
    fn prop_mpi_equals_oracle_random() {
        check_prop(
            "mpi-baseline",
            12,
            |r| {
                let n = r.usize_in(20, 300);
                let rnz = r.usize_in(1, 5);
                let ranks = r.usize_in(1, 7);
                let m = Ellpack::random(n, rnz, r.next_u64());
                let x0: Vec<f64> = (0..n).map(|_| r.f64_in(-1.0, 1.0)).collect();
                (m, ranks, x0)
            },
            |(m, ranks, x0)| {
                let mut want = vec![0.0; m.n];
                m.spmv_seq(x0, &mut want);
                let mut solver = MpiSolver::new(m, *ranks, x0);
                solver.step();
                if solver.x_global() != want {
                    return Err("one step diverged".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn parallel_step_matches_sequential_bitwise() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let x0 = m.initial_vector(3);
        let mut seq = MpiSolver::new(&m, 8, &x0);
        let mut par = MpiSolver::new(&m, 8, &x0);
        for _ in 0..4 {
            seq.step_with(Engine::Sequential);
            par.step_with(Engine::Parallel);
            assert_eq!(seq.x_global(), par.x_global());
        }
    }

    #[test]
    fn prediction_is_positive_and_model_close_to_sim() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let x0 = m.initial_vector(1);
        let solver = MpiSolver::new(&m, 32, &x0);
        let topo = Topology::new(2, 16);
        let hw = HwParams::abel();
        let params = SimParams::from_hw(&hw);
        let (sim, model) = solver.predict_step(&topo, &hw, &params);
        assert!(sim > 0.0 && model > 0.0);
        assert!((sim / model) < 2.0 && (sim / model) > 0.5);
    }
}
