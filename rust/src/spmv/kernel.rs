//! The block-local compute kernels (the hot path).
//!
//! Two flavours:
//! * [`spmv_block_gathered`] — UPCv2/v3 path: all `x` values already sit in
//!   a thread-private, globally-indexed copy. This is the kernel the L1
//!   Pallas artifact mirrors (with the gather hoisted to the coordinator,
//!   see `python/compile/kernels/ellpack_spmv.py`), and the one the §Perf
//!   pass optimizes.
//! * [`spmv_block_global`] — naive/UPCv1 path: `x` accessed element-wise
//!   through an accessor closure (pointer-to-shared semantics).
//!
//! Both must produce bitwise identical results: same order of additions.

/// Compute `y[k] = D[k]·x[offset+k] + Σ_j A[k·r+j]·x[J[k·r+j]]` for one
/// block of rows, reading `x` from a private full-length copy.
///
/// `d`, `a`, `j`, `y` are the block-local slices; `offset` is the block's
/// first global row.
#[inline]
pub fn spmv_block_gathered(
    offset: usize,
    d: &[f64],
    a: &[f64],
    j: &[u32],
    r_nz: usize,
    x_copy: &[f64],
    y: &mut [f64],
) {
    let len = y.len();
    assert_eq!(d.len(), len);
    assert!(a.len() >= len * r_nz);
    assert!(j.len() >= len * r_nz);
    assert!(offset + len <= x_copy.len());
    // §Perf: the r_nz = 16 case (every paper workload) takes a specialized
    // fully-unrolled path; see EXPERIMENTS.md §Perf for the measured effect.
    if r_nz == 16 {
        return spmv_block_gathered_16(offset, d, a, j, x_copy, y);
    }
    for k in 0..len {
        let row_a = &a[k * r_nz..(k + 1) * r_nz];
        let row_j = &j[k * r_nz..(k + 1) * r_nz];
        let mut tmp = 0.0f64;
        for jj in 0..r_nz {
            tmp += row_a[jj] * x_copy[row_j[jj] as usize];
        }
        y[k] = d[k] * x_copy[offset + k] + tmp;
    }
}

/// The r_nz = 16 specialization: fixed-size row slices let the compiler
/// unroll the FMA chain and schedule the 16 gathers ahead of the reduction.
/// FP accumulation order is identical to the generic path (sequential sum),
/// preserving bitwise equality with the Listing-1 oracle.
fn spmv_block_gathered_16(
    offset: usize,
    d: &[f64],
    a: &[f64],
    j: &[u32],
    x_copy: &[f64],
    y: &mut [f64],
) {
    const R: usize = 16;
    let len = y.len();
    for k in 0..len {
        // SAFETY: bounds were asserted by the caller wrapper:
        // a.len() ≥ len·R, j.len() ≥ len·R, and every j value indexes
        // x_copy (validated at matrix construction).
        let row_a: &[f64; R] = unsafe { &*(a.as_ptr().add(k * R) as *const [f64; R]) };
        let row_j: &[u32; R] = unsafe { &*(j.as_ptr().add(k * R) as *const [u32; R]) };
        // Gather first (the loads are independent), then reduce in the same
        // sequential order as the generic path.
        let mut g = [0.0f64; R];
        for jj in 0..R {
            g[jj] = unsafe { *x_copy.get_unchecked(row_j[jj] as usize) };
        }
        let mut tmp = 0.0f64;
        for jj in 0..R {
            tmp += row_a[jj] * g[jj];
        }
        y[k] = d[k] * x_copy[offset + k] + tmp;
    }
}

/// Host-parallel whole-matrix SpMV: shards rows over OS threads, each shard
/// running [`spmv_block_gathered`]. Used by the §Perf bench and available to
/// drivers that want wall-clock speed rather than per-UPC-thread semantics.
pub fn spmv_parallel(
    d: &[f64],
    a: &[f64],
    j: &[u32],
    r_nz: usize,
    x_copy: &[f64],
    y: &mut [f64],
) {
    let n = y.len();
    let host = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let shard = n.div_ceil(host);
    std::thread::scope(|scope| {
        let mut rest = &mut y[..];
        let mut start = 0usize;
        while !rest.is_empty() {
            let take = rest.len().min(shard);
            let (head, tail) = rest.split_at_mut(take);
            let offset = start;
            scope.spawn(move || {
                spmv_block_gathered(
                    offset,
                    &d[offset..offset + take],
                    &a[offset * r_nz..(offset + take) * r_nz],
                    &j[offset * r_nz..(offset + take) * r_nz],
                    r_nz,
                    x_copy,
                    head,
                );
            });
            rest = tail;
            start += take;
        }
    });
}

/// Same computation with `x` behind an accessor (shared-array semantics for
/// the naive/UPCv1 executors). Must keep the exact FP order of
/// [`spmv_block_gathered`].
#[inline]
pub fn spmv_block_global<F: Fn(usize) -> f64>(
    offset: usize,
    d: &[f64],
    a: &[f64],
    j: &[u32],
    r_nz: usize,
    x_at: F,
    y: &mut [f64],
) {
    let len = y.len();
    for k in 0..len {
        let row_a = &a[k * r_nz..(k + 1) * r_nz];
        let row_j = &j[k * r_nz..(k + 1) * r_nz];
        let mut tmp = 0.0f64;
        for jj in 0..r_nz {
            tmp += row_a[jj] * x_at(row_j[jj] as usize);
        }
        y[k] = d[k] * x_at(offset + k) + tmp;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Ellpack;

    #[test]
    fn gathered_matches_seq_oracle() {
        let m = Ellpack::random(64, 5, 11);
        let x: Vec<f64> = (0..64).map(|i| (i as f64).sin()).collect();
        let mut want = vec![0.0; 64];
        m.spmv_seq(&x, &mut want);
        // Run as one big block.
        let mut got = vec![0.0; 64];
        spmv_block_gathered(0, &m.diag, &m.a, &m.j, m.r_nz, &x, &mut got);
        assert_eq!(got, want); // bitwise
    }

    #[test]
    fn global_accessor_bitwise_equal() {
        let m = Ellpack::random(40, 3, 5);
        let x: Vec<f64> = (0..40).map(|i| (i as f64).cos()).collect();
        let mut a = vec![0.0; 40];
        let mut b = vec![0.0; 40];
        spmv_block_gathered(0, &m.diag, &m.a, &m.j, m.r_nz, &x, &mut a);
        spmv_block_global(0, &m.diag, &m.a, &m.j, m.r_nz, |i| x[i], &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn specialized_16_bitwise_equals_generic_order() {
        // r_nz = 16 takes the unrolled path; compare against a manual
        // generic-order evaluation.
        let m = Ellpack::random(300, 16, 12);
        let x: Vec<f64> = (0..300).map(|i| (i as f64 * 0.7).sin()).collect();
        let mut want = vec![0.0; 300];
        m.spmv_seq(&x, &mut want);
        let mut got = vec![0.0; 300];
        spmv_block_gathered(0, &m.diag, &m.a, &m.j, 16, &x, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let m = Ellpack::random(5000, 16, 5);
        let x: Vec<f64> = (0..5000).map(|i| (i as f64 * 0.3).cos()).collect();
        let mut serial = vec![0.0; 5000];
        spmv_block_gathered(0, &m.diag, &m.a, &m.j, 16, &x, &mut serial);
        let mut par = vec![0.0; 5000];
        spmv_parallel(&m.diag, &m.a, &m.j, 16, &x, &mut par);
        assert_eq!(serial, par);
    }

    #[test]
    fn blocked_equals_monolithic() {
        let m = Ellpack::random(50, 4, 2);
        let x: Vec<f64> = (0..50).map(|i| i as f64 * 0.1).collect();
        let mut mono = vec![0.0; 50];
        spmv_block_gathered(0, &m.diag, &m.a, &m.j, m.r_nz, &x, &mut mono);
        let mut blocked = vec![0.0; 50];
        for (start, len) in [(0usize, 13usize), (13, 17), (30, 20)] {
            let r = m.r_nz;
            spmv_block_gathered(
                start,
                &m.diag[start..start + len],
                &m.a[start * r..(start + len) * r],
                &m.j[start * r..(start + len) * r],
                r,
                &x,
                &mut blocked[start..start + len],
            );
        }
        assert_eq!(mono, blocked);
    }
}
