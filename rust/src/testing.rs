//! Property-test driver (proptest-lite).
//!
//! The offline build ships no `proptest`; this module provides a small
//! deterministic harness: a seeded [`Rng`]-backed case generator runs a
//! property closure over many random cases and reports the first failing
//! case's seed so it can be replayed exactly.

use crate::util::Rng;

/// Number of cases per property, overridable via `UPCSIM_PROP_CASES`.
pub fn default_cases() -> usize {
    std::env::var("UPCSIM_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` randomized inputs produced by `gen`.
///
/// On failure, panics with the property name, the case index and the exact
/// per-case seed (replay with [`replay`]). `gen` receives a fresh
/// deterministic RNG per case so shrinking-by-seed is trivial.
pub fn check_prop<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base_seed = 0xC0FFEE_u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Replay a single case of a property by seed (for debugging failures).
pub fn replay<T: std::fmt::Debug>(
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    prop(&input)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check_prop(
            "add-commutes",
            32,
            |r| (r.usize_in(0, 1000), r.usize_in(0, 1000)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math is broken".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        check_prop(
            "always-fails",
            4,
            |r| r.usize_in(0, 10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn replay_reproduces() {
        // The same seed regenerates the same input.
        let gen = |r: &mut Rng| r.usize_in(0, 1_000_000);
        let mut first = None;
        replay(1234, gen, |&x| {
            first = Some(x);
            Ok(())
        })
        .unwrap();
        replay(1234, gen, |&x| {
            assert_eq!(Some(x), first);
            Ok(())
        })
        .unwrap();
    }
}
