//! Modified EllPack storage (paper §3.1): `M = D + A` with the main diagonal
//! `D` stored as a length-`n` array and the fixed-degree off-diagonal part
//! `A` stored as two row-major `n × r_nz` tables (values + column indices),
//! flattened to 1D arrays exactly as the paper's Listing 1 lays them out.

use crate::mesh::{TetMesh, R_NZ};
use crate::util::Rng;

/// A square sparse matrix in modified EllPack format.
#[derive(Debug, Clone)]
pub struct Ellpack {
    /// Matrix dimension (`n`).
    pub n: usize,
    /// Fixed number of off-diagonal slots per row (`r_nz`).
    pub r_nz: usize,
    /// Main diagonal `D`, length `n`.
    pub diag: Vec<f64>,
    /// Off-diagonal values `A`, length `n · r_nz`, row-major; padded slots
    /// hold 0.0.
    pub a: Vec<f64>,
    /// Column indices `J`, length `n · r_nz`; padded slots hold the row
    /// index itself (self-reference with zero weight, as in §3.1).
    pub j: Vec<u32>,
}

impl Ellpack {
    /// Build the diffusion time-stepping operator `M = I − Δt·L` from a mesh,
    /// where `L` is a weighted graph Laplacian over the tet adjacency.
    /// Row sums of `M` equal 1 and Gershgorin bounds all eigenvalues inside
    /// `(−1, 1]` (we pick `Δt·Σw < 1`), so the §6.1 time integration
    /// `v^ℓ = M v^{ℓ−1}` is stable — the end-to-end driver checks this.
    pub fn diffusion_from_mesh(mesh: &TetMesh) -> Ellpack {
        let n = mesh.n;
        let r_nz = R_NZ;
        let mut diag = vec![0.0f64; n];
        let mut a = vec![0.0f64; n * r_nz];
        let mut j = vec![0u32; n * r_nz];
        let mut rng = Rng::new(mesh.seed ^ 0x5147_AB3D);
        const DT: f64 = 0.9;
        for i in 0..n {
            let d = mesh.degree[i] as usize;
            let mut wsum = 0.0f64;
            // Weights mimic FV transmissibilities: positive, O(1/degree),
            // mildly random (the paper's weights depend on tet geometry).
            for k in 0..r_nz {
                let col = mesh.adj[i * r_nz + k];
                j[i * r_nz + k] = col;
                if k < d {
                    let w = rng.f64_in(0.5, 1.5) / (d as f64);
                    a[i * r_nz + k] = DT * w;
                    wsum += w;
                } // padded slots stay 0.0 with col == i
            }
            diag[i] = 1.0 - DT * wsum;
        }
        Ellpack { n, r_nz, diag, a, j }
    }

    /// A small random matrix for tests: `n` rows, degree ≤ `r_nz`, arbitrary
    /// (possibly long-range) column pattern.
    pub fn random(n: usize, r_nz: usize, seed: u64) -> Ellpack {
        assert!(n > 1);
        let mut rng = Rng::new(seed);
        let mut diag = vec![0.0f64; n];
        let mut a = vec![0.0f64; n * r_nz];
        let mut j = vec![0u32; n * r_nz];
        for i in 0..n {
            // A row can have at most n−1 distinct off-diagonal columns.
            let d = rng.usize_in(0, r_nz + 1).min(n - 1);
            let mut cols = std::collections::BTreeSet::new();
            while cols.len() < d {
                let c = rng.usize_in(0, n);
                if c != i {
                    cols.insert(c as u32);
                }
            }
            for (k, c) in cols.iter().enumerate() {
                j[i * r_nz + k] = *c;
                a[i * r_nz + k] = rng.f64_in(-1.0, 1.0);
            }
            for k in cols.len()..r_nz {
                j[i * r_nz + k] = i as u32;
            }
            diag[i] = rng.f64_in(1.0, 2.0);
        }
        Ellpack { n, r_nz, diag, a, j }
    }

    /// Sequential SpMV, the paper's Listing 1:
    /// `y[i] = D[i]·x[i] + Σ_j A[i·r+j]·x[J[i·r+j]]`.
    ///
    /// This is the *oracle*: every parallel variant must produce bitwise
    /// identical results because all variants accumulate in the same order.
    pub fn spmv_seq(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        let r = self.r_nz;
        for i in 0..self.n {
            let mut tmp = 0.0f64;
            for k in 0..r {
                tmp += self.a[i * r + k] * x[self.j[i * r + k] as usize];
            }
            y[i] = self.diag[i] * x[i] + tmp;
        }
    }

    /// Row slice of values.
    #[inline]
    pub fn row_vals(&self, i: usize) -> &[f64] {
        &self.a[i * self.r_nz..(i + 1) * self.r_nz]
    }

    /// Row slice of column indices.
    #[inline]
    pub fn row_cols(&self, i: usize) -> &[u32] {
        &self.j[i * self.r_nz..(i + 1) * self.r_nz]
    }

    /// Memory a row's data occupies in the paper's traffic model (eq. (6)):
    /// `r_nz·(8+4) + 3·8` bytes.
    pub fn d_min_comp_bytes(&self) -> f64 {
        (self.r_nz * (8 + 4) + 3 * 8) as f64
    }

    /// Structural check: column indices in range; padded slots self-refer
    /// with zero value.
    pub fn validate(&self) -> Result<(), String> {
        if self.a.len() != self.n * self.r_nz || self.j.len() != self.n * self.r_nz {
            return Err("table sizes".into());
        }
        for i in 0..self.n {
            for k in 0..self.r_nz {
                let c = self.j[i * self.r_nz + k] as usize;
                if c >= self.n {
                    return Err(format!("row {i}: col {c} out of range"));
                }
                if c == i && self.a[i * self.r_nz + k] != 0.0 {
                    return Err(format!("row {i}: self column with nonzero weight"));
                }
            }
        }
        Ok(())
    }

    /// An initial vector for the diffusion driver: a smooth blob plus noise,
    /// deterministic.
    pub fn initial_vector(&self, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..self.n)
            .map(|i| {
                let t = i as f64 / self.n as f64;
                (2.0 * std::f64::consts::PI * t).sin() + 0.1 * rng.f64()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::TestProblem;

    fn mesh() -> TetMesh {
        crate::mesh::TetMesh::generate(&crate::mesh::TetGridSpec::ventricle(3000, 7))
    }

    #[test]
    fn diffusion_matrix_valid() {
        let m = Ellpack::diffusion_from_mesh(&mesh());
        m.validate().unwrap();
    }

    #[test]
    fn diffusion_rows_sum_to_one() {
        let m = Ellpack::diffusion_from_mesh(&mesh());
        for i in (0..m.n).step_by(97) {
            let s: f64 = m.diag[i] + m.row_vals(i).iter().sum::<f64>();
            assert!((s - 1.0).abs() < 1e-12, "row {i} sums to {s}");
        }
    }

    #[test]
    fn diffusion_iteration_is_stable() {
        let m = Ellpack::diffusion_from_mesh(&mesh());
        let mut x = m.initial_vector(3);
        let mut y = vec![0.0; m.n];
        let max0 = x.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        for _ in 0..50 {
            m.spmv_seq(&x, &mut y);
            std::mem::swap(&mut x, &mut y);
        }
        let max50 = x.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        assert!(max50 <= max0 * 1.0 + 1e-9, "diffusion grew: {max0} -> {max50}");
    }

    #[test]
    fn spmv_seq_tiny_known() {
        // 2x2: M = [[2, 0.5], [0, 3]] in EllPack with r_nz=1.
        let m = Ellpack {
            n: 2,
            r_nz: 1,
            diag: vec![2.0, 3.0],
            a: vec![0.5, 0.0],
            j: vec![1, 1],
        };
        let mut y = vec![0.0; 2];
        m.spmv_seq(&[1.0, 2.0], &mut y);
        assert_eq!(y, vec![2.0 * 1.0 + 0.5 * 2.0, 3.0 * 2.0]);
    }

    #[test]
    fn d_min_comp_matches_eq6() {
        let m = Ellpack::random(10, 16, 1);
        // 16·12 + 24 = 216 bytes (paper's r_nz = 16 case).
        assert_eq!(m.d_min_comp_bytes(), 216.0);
    }

    #[test]
    fn random_matrix_valid() {
        Ellpack::random(500, 16, 99).validate().unwrap();
    }

    #[test]
    #[ignore] // ~seconds; run with --ignored
    fn tp1_scaled_builds() {
        let mesh = TestProblem::Tp1.generate(64);
        let m = Ellpack::diffusion_from_mesh(&mesh);
        m.validate().unwrap();
    }
}
