//! Sparse-matrix substrate: the paper's modified EllPack format (§3.1) plus
//! CSR (for conversion tests) and the sequential SpMV oracle (Listing 1).

mod csr;
mod ellpack;

pub use csr::Csr;
pub use ellpack::Ellpack;
