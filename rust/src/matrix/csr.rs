//! Compressed Sparse Row format — used as an independent representation to
//! cross-check EllPack (conversion round-trips and SpMV equivalence), and by
//! downstream users who want a general-degree matrix.

use super::Ellpack;

/// A CSR matrix (diagonal stored inline like any other entry).
#[derive(Debug, Clone)]
pub struct Csr {
    pub n: usize,
    pub row_ptr: Vec<usize>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Csr {
    /// Convert from modified EllPack; padded (zero-weight self) slots are
    /// dropped, the diagonal becomes an explicit entry.
    pub fn from_ellpack(m: &Ellpack) -> Csr {
        let mut row_ptr = Vec::with_capacity(m.n + 1);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for i in 0..m.n {
            cols.push(i as u32);
            vals.push(m.diag[i]);
            for k in 0..m.r_nz {
                let c = m.j[i * m.r_nz + k];
                let v = m.a[i * m.r_nz + k];
                if c as usize != i {
                    cols.push(c);
                    vals.push(v);
                }
            }
            row_ptr.push(cols.len());
        }
        Csr { n: m.n, row_ptr, cols, vals }
    }

    /// Standard CSR SpMV.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        for i in 0..self.n {
            let mut acc = 0.0;
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += self.vals[k] * x[self.cols[k] as usize];
            }
            y[i] = acc;
        }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check_prop;

    #[test]
    fn csr_matches_ellpack_spmv() {
        check_prop(
            "csr-vs-ellpack",
            24,
            |r| {
                let n = r.usize_in(2, 200);
                let rnz = r.usize_in(1, 8);
                let m = Ellpack::random(n, rnz, r.next_u64());
                let x: Vec<f64> = (0..n).map(|_| r.f64_in(-1.0, 1.0)).collect();
                (m, x)
            },
            |(m, x)| {
                let csr = Csr::from_ellpack(m);
                let mut y1 = vec![0.0; m.n];
                let mut y2 = vec![0.0; m.n];
                m.spmv_seq(x, &mut y1);
                csr.spmv(x, &mut y2);
                for i in 0..m.n {
                    if (y1[i] - y2[i]).abs() > 1e-12 * (1.0 + y1[i].abs()) {
                        return Err(format!("row {i}: {} vs {}", y1[i], y2[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nnz_counts_diagonal_plus_genuine() {
        let m = Ellpack::random(50, 4, 3);
        let csr = Csr::from_ellpack(&m);
        let genuine: usize = (0..m.n)
            .map(|i| m.row_cols(i).iter().filter(|&&c| c as usize != i).count())
            .sum();
        assert_eq!(csr.nnz(), genuine + m.n);
    }
}
