//! Communication-traffic analysis.
//!
//! Everything the paper's §5 models need is a function of *who accesses
//! what*: given the sparsity pattern `J`, the shared-array [`Layout`] and the
//! cluster [`Topology`], this module derives, per thread,
//!
//! * `C_thread^{local,indv}` / `C_thread^{remote,indv}` — occurrence counts
//!   of individual off-owner accesses (§5.2.3, UPCv1),
//! * `B_thread^{local}` / `B_thread^{remote}` — needed-block counts
//!   (§5.2.4, UPCv2),
//! * `S_thread^{local,out}` / `S_thread^{remote,out}` /
//!   `S_thread^{local,in}` / `S_thread^{remote,in}` and message counts —
//!   condensed/consolidated message sizes (§5.2.5, UPCv3),
//!
//! plus the actual [`CommPlan`] (per-pair unique index lists) that the UPCv3
//! executor uses to pack/unpack real messages — the paper's "preparation
//! step" of §4.3.1.
//!
//! The compiled-plan idea is workload-agnostic: [`ExchangePlan`] unifies the
//! irregular gather form ([`CommPlan`], SpMV) with the regular strided
//! block-copy form ([`StridedPlan`], heat-2D / 3D-stencil halos) behind one
//! staging-arena contract, so a single engine executes any compiled
//! workload.
//!
//! [`Layout`]: crate::pgas::Layout
//! [`Topology`]: crate::pgas::Topology

mod analysis;
mod delta;
mod exchange;
mod optimize;
mod plan;

pub use analysis::{Analysis, RowRun, RowSplit, ThreadTraffic};
pub use delta::{chain_fingerprint, GatherPatch, PlanDelta, StridedPatch};
pub use exchange::{ComputeSplit, ExchangePlan, StridedBlock, StridedMsg, StridedPlan};
pub use optimize::{refine_strided, PlanOptimizer, PlanStats};
pub use plan::{CommPlan, PlanMsg};
