//! The traffic analyzer: one pass over each thread's owned rows of `J`.
//!
//! This is the paper's "one-time preparation step" (§4.2 pre-screening and
//! §4.3.1), generalized to produce in a single sweep every quantity all three
//! models need. It is deliberately implemented over the *global* `J` array +
//! [`Layout`] rather than over executor state, so models, simulator and
//! executors all consume the same counts (DESIGN.md §5).

use super::plan::CommPlan;
use crate::pgas::{Layout, Topology};

/// Per-thread traffic statistics (counts of values/blocks/messages; byte
/// conversions happen in the models).
#[derive(Debug, Clone, Default)]
pub struct ThreadTraffic {
    /// §5.2.3: off-owner access occurrences whose owner shares the node.
    pub c_local_indv: u64,
    /// §5.2.3: off-owner access occurrences whose owner is on another node.
    pub c_remote_indv: u64,
    /// §5.2.4: needed blocks residing on this thread's node (own blocks
    /// included — Listing 4 transports those too).
    pub b_local: u32,
    /// §5.2.4: needed blocks residing on other nodes.
    pub b_remote: u32,
    /// §5.2.5: Σ sizes (in values) of outgoing messages to same-node peers.
    pub s_local_out: u64,
    /// §5.2.5: Σ sizes of outgoing messages to other-node peers.
    pub s_remote_out: u64,
    /// §5.2.5: Σ sizes of incoming messages from same-node peers.
    pub s_local_in: u64,
    /// §5.2.5: Σ sizes of incoming messages from other-node peers.
    pub s_remote_in: u64,
    /// Number of outgoing messages to same-node peers.
    pub c_local_out: u32,
    /// §5.2.5 `C_thread^{remote,out}`: outgoing inter-node messages.
    pub c_remote_out: u32,
    /// Incoming message counts (for symmetry checks / reporting).
    pub c_local_in: u32,
    pub c_remote_in: u32,
    /// Cache-locality statistic for the simulator: genuine `x` accesses
    /// whose |row − col| exceeds the LLC reuse window (see `sim`).
    pub far_accesses: u64,
    /// Total genuine (non-padding) off-diagonal accesses by this thread.
    pub total_accesses: u64,
}

impl ThreadTraffic {
    /// All off-owner access occurrences (v1 traffic volume measure:
    /// `(c_local_indv + c_remote_indv) · sizeof(double)` bytes move).
    pub fn c_total_indv(&self) -> u64 {
        self.c_local_indv + self.c_remote_indv
    }

    /// Total unique values this thread must receive (v3 traffic volume).
    pub fn s_total_in(&self) -> u64 {
        self.s_local_in + self.s_remote_in
    }
}

/// One contiguous run of rows inside a single block (`start` is the global
/// index of the first row). Runs never cross block boundaries, so a run maps
/// to contiguous slices of the block-cyclic `D`/`A`/`J`/`y` storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowRun {
    pub start: u32,
    pub len: u32,
}

impl RowRun {
    /// Total rows across a run list.
    pub fn total(runs: &[RowRun]) -> usize {
        runs.iter().map(|r| r.len as usize).sum()
    }
}

/// The interior/boundary decomposition of one thread's owned rows — the
/// irregular-gather counterpart of [`crate::comm::ComputeSplit`], computed
/// once during the analysis sweep.
///
/// *Interior* rows reference only owner-local `x` values, so the split-phase
/// executor can compute them while the condensed messages are still in
/// flight; *boundary* rows read at least one off-owner value and must wait
/// for `finish_exchange`. Together the runs cover every owned row exactly
/// once, in ascending order.
#[derive(Debug, Clone, Default)]
pub struct RowSplit {
    pub interior: Vec<RowRun>,
    pub boundary: Vec<RowRun>,
}

/// The complete analysis for one (matrix pattern, layout, topology) triple.
#[derive(Debug, Clone)]
pub struct Analysis {
    pub layout: Layout,
    pub topo: Topology,
    pub per_thread: Vec<ThreadTraffic>,
    pub plan: CommPlan,
    /// `needed_blocks[t]` — bitmap over global block ids (v2's
    /// `block_is_needed` array, Listing 4).
    pub needed_blocks: Vec<Vec<u64>>,
    /// `row_split[t]` — thread t's interior/boundary row decomposition for
    /// the overlapped UPCv3 executor.
    pub row_split: Vec<RowSplit>,
}

impl Analysis {
    /// Run the analysis. `j` is the flattened `n × r_nz` column-index table;
    /// `layout` describes `x`/`y` (the paper couples `A`/`J` layouts to it by
    /// construction). `cache_window`: |row−col| beyond which an `x` access
    /// is counted as a likely LLC miss (simulator input; use
    /// [`crate::sim::DEFAULT_CACHE_WINDOW`]).
    pub fn build(
        j: &[u32],
        r_nz: usize,
        layout: Layout,
        topo: Topology,
        cache_window: usize,
    ) -> Analysis {
        assert_eq!(topo.threads(), layout.threads);
        assert_eq!(j.len(), layout.n * r_nz);
        let threads = layout.threads;
        let nblks = layout.nblks();
        let bitmap_words = crate::util::ceil_div(nblks, 64);

        // Per-thread scan, parallelized across host cores in chunks of UPC
        // threads. Each scan produces (traffic, needed-bitmap, recv-needs,
        // row-split).
        let mut results: Vec<Option<(ThreadTraffic, Vec<u64>, Vec<(u32, u32)>, RowSplit)>> =
            (0..threads).map(|_| None).collect();
        let host = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        let chunk = crate::util::ceil_div(threads, host.min(threads));
        std::thread::scope(|scope| {
            for slab in results.chunks_mut(chunk).enumerate() {
                let (ci, slab) = slab;
                let first_t = ci * chunk;
                scope.spawn(move || {
                    for (off, slot) in slab.iter_mut().enumerate() {
                        let t = first_t + off;
                        *slot = Some(scan_thread(t, j, r_nz, layout, topo, cache_window, bitmap_words));
                    }
                });
            }
        });

        let mut per_thread = Vec::with_capacity(threads);
        let mut needed_blocks = Vec::with_capacity(threads);
        let mut recv_needs = Vec::with_capacity(threads);
        let mut row_split = Vec::with_capacity(threads);
        for r in results {
            let (traffic, bitmap, needs, split) = r.unwrap();
            per_thread.push(traffic);
            needed_blocks.push(bitmap);
            recv_needs.push(needs);
            row_split.push(split);
        }

        let plan = CommPlan::from_recv_needs(&layout, &recv_needs);

        // Fill in the derived send-side and recv-side S/C statistics.
        for t in 0..threads {
            for m in plan.send_msgs(t) {
                let local = topo.same_node(t, m.peer as usize);
                let tt = &mut per_thread[t];
                if local {
                    tt.s_local_out += m.len() as u64;
                    tt.c_local_out += 1;
                } else {
                    tt.s_remote_out += m.len() as u64;
                    tt.c_remote_out += 1;
                }
            }
            for m in plan.recv_msgs(t) {
                let local = topo.same_node(t, m.peer as usize);
                let tt = &mut per_thread[t];
                if local {
                    tt.s_local_in += m.len() as u64;
                    tt.c_local_in += 1;
                } else {
                    tt.s_remote_in += m.len() as u64;
                    tt.c_remote_in += 1;
                }
            }
        }

        debug_assert!(plan.validate().is_ok(), "compiled CommPlan failed validation");
        Analysis { layout, topo, per_thread, plan, needed_blocks, row_split }
    }

    /// The paper's fine-grained baseline plan for the same pattern: every
    /// off-owner reference in row-scan order, duplicates included, no
    /// condensing. [`CommPlan::from_occurrence_needs`] keeps it runnable on
    /// the same executors, and the plan optimizer's condensing pass turns it
    /// back into exactly [`Analysis::plan`] — which is what the
    /// `planopt_equivalence` suite pins.
    pub fn raw_gather_plan(j: &[u32], r_nz: usize, layout: &Layout) -> CommPlan {
        assert_eq!(j.len(), layout.n * r_nz);
        let threads = layout.threads;
        let mut needs: Vec<Vec<(u32, u32)>> = Vec::with_capacity(threads);
        for t in 0..threads {
            let mut occ: Vec<(u32, u32)> = Vec::new();
            for b in layout.blocks_of_thread(t) {
                let (start, len) = layout.block_range(b);
                for i in start..start + len {
                    for &col in &j[i * r_nz..(i + 1) * r_nz] {
                        let c = col as usize;
                        // Same skip rules as `scan_thread`: EllPack padding,
                        // the row's own block, other private blocks.
                        if c == i || (c >= start && c < start + len) {
                            continue;
                        }
                        let owner = layout.owner_of_index(c);
                        if owner == t {
                            continue;
                        }
                        occ.push((owner as u32, col));
                    }
                }
            }
            needs.push(occ);
        }
        CommPlan::from_occurrence_needs(layout, &needs)
    }

    /// Is global block `b` needed by thread `t`?
    #[inline]
    pub fn block_needed(&self, t: usize, b: usize) -> bool {
        self.needed_blocks[t][b / 64] >> (b % 64) & 1 == 1
    }

    /// Communication volume per thread in bytes for each variant, as plotted
    /// in Figure 2 (top): v1 moves every off-owner occurrence individually;
    /// v2 moves every needed non-own block in its entirety; v3 moves the
    /// condensed unique values (incoming side).
    pub fn volume_bytes(&self, t: usize) -> (f64, f64, f64) {
        const D: f64 = 8.0;
        let tt = &self.per_thread[t];
        let v1 = tt.c_total_indv() as f64 * D;
        // v2: needed blocks excluding the thread's own blocks (those move
        // within private memory; Figure 2 plots between-thread volume).
        let mut v2_blocks = 0.0f64;
        for b in 0..self.layout.nblks() {
            if self.layout.owner_of_block(b) != t && self.block_needed(t, b) {
                v2_blocks += self.layout.block_len(b) as f64;
            }
        }
        let v2 = v2_blocks * D;
        let v3 = tt.s_total_in() as f64 * D;
        (v1, v2, v3)
    }

    /// Global conservation / sanity checks (used by tests).
    pub fn validate(&self) -> Result<(), String> {
        self.plan.validate()?;
        let sum = |f: fn(&ThreadTraffic) -> u64| -> u64 { self.per_thread.iter().map(f).sum() };
        if sum(|t| t.s_local_out) != sum(|t| t.s_local_in) {
            return Err("local out/in volume mismatch".into());
        }
        if sum(|t| t.s_remote_out) != sum(|t| t.s_remote_in) {
            return Err("remote out/in volume mismatch".into());
        }
        for (t, tt) in self.per_thread.iter().enumerate() {
            // v3 never moves more values than v1 touches occurrences.
            if tt.s_total_in() > tt.c_total_indv() {
                return Err(format!("thread {t}: condensed volume exceeds occurrences"));
            }
            if tt.far_accesses > tt.total_accesses {
                return Err(format!("thread {t}: far > total accesses"));
            }
        }
        // Interior/boundary row runs cover each owned row exactly once and
        // never cross a block boundary.
        for (t, split) in self.row_split.iter().enumerate() {
            let covered = RowRun::total(&split.interior) + RowRun::total(&split.boundary);
            if covered != self.layout.nelems_of_thread(t) {
                return Err(format!(
                    "thread {t}: row split covers {covered} of {} rows",
                    self.layout.nelems_of_thread(t)
                ));
            }
            for run in split.interior.iter().chain(&split.boundary) {
                if run.len == 0 {
                    return Err(format!("thread {t}: zero-length run at {}", run.start));
                }
                let (i0, last) = (run.start as usize, run.start as usize + run.len as usize - 1);
                if self.layout.owner_of_index(i0) != t {
                    return Err(format!("thread {t}: run at {i0} starts on a foreign row"));
                }
                if !self.layout.same_block(i0, last) {
                    return Err(format!("thread {t}: run at {i0} crosses a block boundary"));
                }
            }
        }
        Ok(())
    }
}

/// Scan one UPC thread's owned rows.
fn scan_thread(
    t: usize,
    j: &[u32],
    r_nz: usize,
    layout: Layout,
    topo: Topology,
    cache_window: usize,
    bitmap_words: usize,
) -> (ThreadTraffic, Vec<u64>, Vec<(u32, u32)>, RowSplit) {
    let mut traffic = ThreadTraffic::default();
    let mut bitmap = vec![0u64; bitmap_words];
    let mut off_owner: Vec<(u32, u32)> = Vec::new();
    let mut split = RowSplit::default();
    let my_node = topo.node_of_thread(t);
    let mark = |bitmap: &mut Vec<u64>, b: usize| bitmap[b / 64] |= 1 << (b % 64);

    for b in layout.blocks_of_thread(t) {
        // Own block is always needed: every row i reads x[i] (Listing 4
        // copies own blocks into mythread_x_copy as well).
        mark(&mut bitmap, b);
        let (start, len) = layout.block_range(b);
        // Current (interior?, start, len) run; flushed on class change and
        // at the block boundary so runs stay block-contiguous.
        let mut cur: Option<(bool, u32, u32)> = None;
        for i in start..start + len {
            let row = &j[i * r_nz..(i + 1) * r_nz];
            let mut row_is_interior = true;
            for &col in row {
                let c = col as usize;
                if c == i {
                    continue; // EllPack padding — never a real access
                }
                traffic.total_accesses += 1;
                if c.abs_diff(i) > cache_window {
                    traffic.far_accesses += 1;
                }
                // §Perf fast path: with a spatially local ordering most
                // references land in the row's own block — skip the
                // owner computation entirely (EXPERIMENTS.md §Perf).
                if c >= start && c < start + len {
                    continue;
                }
                let owner = layout.owner_of_index(c);
                if owner == t {
                    continue; // private (a different own block)
                }
                row_is_interior = false;
                mark(&mut bitmap, layout.block_of_index(c));
                if topo.node_of_thread(owner) == my_node {
                    traffic.c_local_indv += 1;
                } else {
                    traffic.c_remote_indv += 1;
                }
                off_owner.push((owner as u32, col));
            }
            match cur {
                Some((interior, _, ref mut run_len)) if interior == row_is_interior => {
                    *run_len += 1
                }
                _ => {
                    flush_run(&mut split, cur.take());
                    cur = Some((row_is_interior, i as u32, 1));
                }
            }
        }
        flush_run(&mut split, cur.take());
    }

    // Needed-block counts by residence (B_local includes own blocks).
    for b in 0..layout.nblks() {
        if bitmap[b / 64] >> (b % 64) & 1 == 1 {
            let owner = layout.owner_of_block(b);
            if topo.node_of_thread(owner) == my_node {
                traffic.b_local += 1;
            } else {
                traffic.b_remote += 1;
            }
        }
    }

    // Unique (owner, index) needs, sorted by owner then index — the paper's
    // condensing step.
    off_owner.sort_unstable();
    off_owner.dedup();
    (traffic, bitmap, off_owner, split)
}

/// Append a finished run to its class list. Runs stay within one block by
/// construction — the caller flushes at every block end.
fn flush_run(split: &mut RowSplit, cur: Option<(bool, u32, u32)>) {
    if let Some((interior, start, len)) = cur {
        let list = if interior { &mut split.interior } else { &mut split.boundary };
        list.push(RowRun { start, len });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Ellpack;
    use crate::testing::check_prop;

    /// Hand-checkable case: n=8, BLOCKSIZE=2, THREADS=2, 1 node.
    /// Blocks: b0=[0,1](t0) b1=[2,3](t1) b2=[4,5](t0) b3=[6,7](t1).
    #[test]
    fn tiny_hand_example() {
        let layout = Layout::new(8, 2, 2);
        let topo = Topology::single_node(2);
        // Row i references (i+2) % 8 — exactly one genuine access per row,
        // always one block to the "right", hence always the other thread.
        let r_nz = 2;
        let mut j = vec![0u32; 8 * r_nz];
        for i in 0..8 {
            j[i * r_nz] = ((i + 2) % 8) as u32;
            j[i * r_nz + 1] = i as u32; // padding
        }
        let a = Analysis::build(&j, r_nz, layout, topo, usize::MAX);
        a.validate().unwrap();
        // Every genuine access is off-owner and local (single node).
        for t in 0..2 {
            let tt = &a.per_thread[t];
            assert_eq!(tt.c_local_indv, 4, "thread {t}");
            assert_eq!(tt.c_remote_indv, 0);
            assert_eq!(tt.b_remote, 0);
            // Needs 2 own + 2 other blocks.
            assert_eq!(tt.b_local, 4);
            // Condensed: 4 unique values in, in 2 messages (one per peer
            // block... both foreign blocks owned by the single other thread
            // → exactly 1 consolidated message of 4 values).
            assert_eq!(tt.s_total_in(), 4);
            assert_eq!(a.plan.messages_to(t), 1);
            assert_eq!(a.plan.recv_msgs(t).next().unwrap().len(), 4);
        }
    }

    #[test]
    fn remote_vs_local_split_follows_topology() {
        let layout = Layout::new(8, 2, 4);
        let topo = Topology::new(2, 2); // t0,t1 node0; t2,t3 node1
        let r_nz = 1;
        // Row 0 (t0) references index 2 (t1, same node) — local.
        // Row 1 (t0) references index 4 (t2, other node) — remote.
        let mut j: Vec<u32> = (0..8u32).collect(); // default self (padding)
        j[0] = 2;
        j[1] = 4;
        let a = Analysis::build(&j, r_nz, layout, topo, usize::MAX);
        a.validate().unwrap();
        let t0 = &a.per_thread[0];
        assert_eq!(t0.c_local_indv, 1);
        assert_eq!(t0.c_remote_indv, 1);
        assert_eq!(t0.b_local, 2); // own block 0 + t1's block 1
        assert_eq!(t0.b_remote, 1); // t2's block 2
        assert_eq!(t0.s_local_in, 1);
        assert_eq!(t0.s_remote_in, 1);
        // Senders see the transposed statistics.
        assert_eq!(a.per_thread[1].s_local_out, 1);
        assert_eq!(a.per_thread[2].s_remote_out, 1);
        assert_eq!(a.per_thread[2].c_remote_out, 1);
    }

    #[test]
    fn condensing_dedups_repeated_references() {
        // Two rows of t0 both reference index 3 (owned by t1): v1 counts 2
        // occurrences, v3 moves 1 value.
        // With block_size=1, owner(i) = i % THREADS; use two slots in one
        // row so one thread references the same remote value twice.
        let layout = Layout::new(4, 1, 2); // owners: 0,1,0,1
        let topo = Topology::single_node(2);
        let r_nz = 2;
        let mut j = vec![0u32; 8];
        for i in 0..4 {
            j[i * 2] = i as u32;
            j[i * 2 + 1] = i as u32;
        }
        j[0] = 3; // row 0 (t0) → idx 3 (t1)
        j[1] = 3; // row 0 again
        let a = Analysis::build(&j, r_nz, layout, topo, usize::MAX);
        a.validate().unwrap();
        assert_eq!(a.per_thread[0].c_local_indv, 2);
        assert_eq!(a.per_thread[0].s_total_in(), 1);
        // The raw occurrence plan still moves both occurrences, in a
        // runnable (valid) uncondensed plan.
        let raw = Analysis::raw_gather_plan(&j, r_nz, &layout);
        raw.validate().unwrap();
        assert!(!raw.is_condensed());
        let occurrences: u64 = a.per_thread.iter().map(|t| t.c_total_indv()).sum();
        assert_eq!(raw.total_values() as u64, occurrences);
        assert!(a.plan.total_values() < raw.total_values());
    }

    #[test]
    fn figure2_volume_ordering_v3_leq_v2() {
        // On a mesh-like local pattern v3 ≤ v2 (condensed ≤ whole blocks)
        // and typically v1 ≥ v3 (occurrences ≥ unique).
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let layout = Layout::new(m.n, 256, 8);
        let topo = Topology::new(2, 4);
        let a = Analysis::build(&m.j, m.r_nz, layout, topo, usize::MAX);
        a.validate().unwrap();
        for t in 0..8 {
            let (v1, v2, v3) = a.volume_bytes(t);
            assert!(v3 <= v2 + 1e-9, "t{t}: v3 {v3} > v2 {v2}");
            assert!(v3 <= v1 + 1e-9, "t{t}: v3 {v3} > v1 {v1}");
        }
    }

    #[test]
    fn cache_window_counts_far_accesses() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let layout = Layout::new(m.n, 512, 4);
        let topo = Topology::single_node(4);
        let near = Analysis::build(&m.j, m.r_nz, layout, topo, usize::MAX);
        let far = Analysis::build(&m.j, m.r_nz, layout, topo, 0);
        let nf: u64 = near.per_thread.iter().map(|t| t.far_accesses).sum();
        let ff: u64 = far.per_thread.iter().map(|t| t.far_accesses).sum();
        let tot: u64 = far.per_thread.iter().map(|t| t.total_accesses).sum();
        assert_eq!(nf, 0);
        assert_eq!(ff, tot);
    }

    #[test]
    fn row_split_classifies_rows() {
        // Same hand example as `tiny_hand_example`: row i references
        // (i+2) % 8, which always lands on the other thread → every row is
        // boundary.
        let layout = Layout::new(8, 2, 2);
        let topo = Topology::single_node(2);
        let r_nz = 2;
        let mut j = vec![0u32; 8 * r_nz];
        for i in 0..8 {
            j[i * r_nz] = ((i + 2) % 8) as u32;
            j[i * r_nz + 1] = i as u32;
        }
        let a = Analysis::build(&j, r_nz, layout, topo, usize::MAX);
        a.validate().unwrap();
        for t in 0..2 {
            assert!(a.row_split[t].interior.is_empty());
            assert_eq!(RowRun::total(&a.row_split[t].boundary), 4);
        }
        // Pure-diagonal pattern: every row is interior.
        let j: Vec<u32> = (0..8u32).flat_map(|i| [i, i]).collect();
        let a = Analysis::build(&j, r_nz, layout, topo, usize::MAX);
        a.validate().unwrap();
        for t in 0..2 {
            assert!(a.row_split[t].boundary.is_empty());
            assert_eq!(RowRun::total(&a.row_split[t].interior), 4);
            // Two own blocks → two runs (runs never cross blocks).
            assert_eq!(a.row_split[t].interior.len(), 2);
        }
        // Mixed: only row 0 references off-owner (idx 2, owned by t1).
        let mut j: Vec<u32> = (0..8u32).flat_map(|i| [i, i]).collect();
        j[0] = 2;
        let a = Analysis::build(&j, r_nz, layout, topo, usize::MAX);
        a.validate().unwrap();
        assert_eq!(a.row_split[0].boundary, vec![RowRun { start: 0, len: 1 }]);
        assert_eq!(RowRun::total(&a.row_split[0].interior), 3);
    }

    /// Property: conservation + volume ordering hold for random patterns.
    #[test]
    fn prop_conservation_random_patterns() {
        check_prop(
            "analysis-conservation",
            24,
            |r| {
                let n = r.usize_in(8, 600);
                let rnz = r.usize_in(1, 6);
                let bs = r.usize_in(1, 64);
                let tpn = r.usize_in(1, 4);
                let nodes = r.usize_in(1, 4);
                let m = Ellpack::random(n, rnz, r.next_u64());
                (m, Layout::new(n, bs, tpn * nodes), Topology::new(nodes, tpn))
            },
            |(m, layout, topo)| {
                let a = Analysis::build(&m.j, m.r_nz, *layout, *topo, 100);
                a.validate().map_err(|e| e)?;
                // every thread's own blocks are needed
                for t in 0..layout.threads {
                    for b in layout.blocks_of_thread(t) {
                        if !a.block_needed(t, b) {
                            return Err(format!("thread {t} misses own block {b}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
