//! The condensed + consolidated communication plan (paper §4.3.1).
//!
//! For every ordered pair of threads `(sender, receiver)` the plan holds the
//! sorted list of *unique* global `x`-indices owned by `sender` that
//! `receiver`'s rows reference. This is exactly the content of the paper's
//! `mythread_send_value_list` / `mythread_recv_value_list` arrays, except we
//! keep global indices and let executors translate to local offsets through
//! the [`Layout`](crate::pgas::Layout) (the paper does the same translation
//! when casting `&x[MYTHREAD*BLOCKSIZE]` to a pointer-to-local).

/// One consolidated message between a thread pair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Message {
    /// The peer thread (receiver in a send list, sender in a recv list).
    pub peer: u32,
    /// Sorted unique global indices of the `x` values carried.
    pub indices: Vec<u32>,
}

/// Send/receive lists for all threads.
#[derive(Debug, Clone, Default)]
pub struct CommPlan {
    /// `send[t]` — messages thread `t` packs and `upc_memput`s, sorted by
    /// `peer`.
    pub send: Vec<Vec<Message>>,
    /// `recv[t]` — messages thread `t` unpacks, sorted by `peer`.
    /// `recv[t][k].indices` are positions in `mythread_x_copy` (global
    /// indices) the incoming values land in.
    pub recv: Vec<Vec<Message>>,
}

impl CommPlan {
    /// Build the send side as the transpose of per-thread receive needs.
    /// `recv_needs[t]` = sorted unique `(owner, index)` pairs thread `t`
    /// requires from other threads.
    pub fn from_recv_needs(threads: usize, recv_needs: Vec<Vec<(u32, u32)>>) -> CommPlan {
        assert_eq!(recv_needs.len(), threads);
        let mut recv: Vec<Vec<Message>> = Vec::with_capacity(threads);
        for needs in &recv_needs {
            let mut msgs: Vec<Message> = Vec::new();
            for &(owner, idx) in needs {
                match msgs.last_mut() {
                    Some(m) if m.peer == owner => m.indices.push(idx),
                    _ => msgs.push(Message { peer: owner, indices: vec![idx] }),
                }
            }
            recv.push(msgs);
        }
        // Transpose: sender side.
        let mut send: Vec<Vec<Message>> = vec![Vec::new(); threads];
        for (t, msgs) in recv.iter().enumerate() {
            for m in msgs {
                send[m.peer as usize].push(Message { peer: t as u32, indices: m.indices.clone() });
            }
        }
        for s in &mut send {
            s.sort_by_key(|m| m.peer);
        }
        CommPlan { send, recv }
    }

    /// Total values exchanged (Σ message lengths, counted once per message).
    pub fn total_values(&self) -> usize {
        self.send.iter().flatten().map(|m| m.indices.len()).sum()
    }

    /// Number of messages thread `t` sends.
    pub fn messages_from(&self, t: usize) -> usize {
        self.send[t].len()
    }

    /// Consistency check: send is the exact transpose of recv, lists sorted
    /// and unique, and no self-messages.
    pub fn validate(&self) -> Result<(), String> {
        let threads = self.send.len();
        if self.recv.len() != threads {
            return Err("send/recv arity".into());
        }
        for (t, msgs) in self.recv.iter().enumerate() {
            for m in msgs {
                if m.peer as usize == t {
                    return Err(format!("thread {t} receives from itself"));
                }
                if m.indices.is_empty() {
                    return Err(format!("empty message {} → {t}", m.peer));
                }
                if !m.indices.windows(2).all(|w| w[0] < w[1]) {
                    return Err(format!("recv list {} → {t} not sorted/unique", m.peer));
                }
                // matching send entry
                let s = &self.send[m.peer as usize];
                match s.iter().find(|sm| sm.peer as usize == t) {
                    Some(sm) if sm.indices == m.indices => {}
                    _ => return Err(format!("transpose mismatch {} → {t}", m.peer)),
                }
            }
        }
        // No send without matching recv.
        let sends: usize = self.send.iter().map(|v| v.len()).sum();
        let recvs: usize = self.recv.iter().map(|v| v.len()).sum();
        if sends != recvs {
            return Err(format!("{sends} sends vs {recvs} recvs"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        // t0 needs idx 5,7 from t1; t2 needs idx 5 from t1 and 0 from t0.
        let needs = vec![
            vec![(1u32, 5u32), (1, 7)],
            vec![],
            vec![(0, 0), (1, 5)],
        ];
        let plan = CommPlan::from_recv_needs(3, needs);
        plan.validate().unwrap();
        assert_eq!(plan.send[1].len(), 2);
        assert_eq!(plan.send[1][0], Message { peer: 0, indices: vec![5, 7] });
        assert_eq!(plan.send[1][1], Message { peer: 2, indices: vec![5] });
        assert_eq!(plan.send[0], vec![Message { peer: 2, indices: vec![0] }]);
        assert_eq!(plan.total_values(), 4);
        assert_eq!(plan.messages_from(1), 2);
    }

    #[test]
    fn validate_catches_corruption() {
        let needs = vec![vec![(1u32, 5u32)], vec![]];
        let mut plan = CommPlan::from_recv_needs(2, needs);
        plan.send[1][0].indices = vec![6]; // corrupt
        assert!(plan.validate().is_err());
    }
}
