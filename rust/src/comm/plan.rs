//! The condensed + consolidated communication plan (paper §4.3.1), compiled
//! into a flat CSR-style arena.
//!
//! For every ordered pair of threads `(sender, receiver)` the plan holds the
//! sorted list of *unique* global `x`-indices owned by `sender` that
//! `receiver`'s rows reference — the content of the paper's
//! `mythread_send_value_list` / `mythread_recv_value_list` arrays. Unlike
//! the original `Vec<Vec<Message>>` representation (per-message heap
//! allocations built with a cloning transpose), the compiled plan stores
//! **one** `indices` arena plus per-`(thread, peer)` offset ranges:
//!
//! * `indices[start..end]` — global `x`-indices of one message, receiver-major
//!   order (all of receiver 0's messages first, sorted by sender, then
//!   receiver 1's, …);
//! * `local_src[start..end]` — the same values translated **once** to the
//!   sender's owner-local storage offsets (the paper translates through
//!   `&x[MYTHREAD*BLOCKSIZE]` on every pack; here the translation is paid at
//!   plan-compile time, never per iteration);
//! * the send side is a CSR permutation (`send_off`/`send_ids`) over the same
//!   message descriptors — no index list is ever duplicated.
//!
//! A message's `start..end` range doubles as its slot range in a *staging
//! arena* of `total_values()` doubles: executors exchange values by writing
//! disjoint slices of one flat buffer (the shared-memory analogue of POSH's
//! per-thread segments), which is what makes the parallel engine's
//! pack/put/unpack phases zero-copy and lock-free.

use crate::pgas::Layout;
use crate::util::json::Value;
use std::ops::Range;

/// Encode a `u32` list as a JSON number array (wire form of the plan).
pub(crate) fn u32s_to_json(vals: &[u32]) -> Value {
    Value::Arr(vals.iter().map(|&x| Value::Num(x as f64)).collect())
}

/// Decode one JSON number as a `u32`, rejecting fractions and overflow.
pub(crate) fn num_u32(v: &Value, what: &str) -> Result<u32, String> {
    let f = v.as_f64().ok_or_else(|| format!("{what}: not a number"))?;
    if f.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&f) {
        return Err(format!("{what}: {f} is not a u32"));
    }
    Ok(f as u32)
}

/// Decode a named `u32`-array field of a JSON object.
pub(crate) fn json_u32s(v: &Value, key: &str) -> Result<Vec<u32>, String> {
    let arr = v.get(key).and_then(Value::as_arr).ok_or_else(|| format!("{key}: not an array"))?;
    arr.iter().map(|x| num_u32(x, key)).collect()
}

/// Decode a named nonnegative-integer field of a JSON object.
pub(crate) fn json_usize(v: &Value, key: &str) -> Result<usize, String> {
    let f = v.get(key).ok_or_else(|| format!("{key}: missing"))?;
    Ok(num_u32(f, key)? as usize)
}

/// One message's descriptor: who talks to whom, and where its values live
/// in the arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct MsgDesc {
    sender: u32,
    receiver: u32,
    start: u32,
    end: u32,
}

/// A borrowed view of one consolidated message.
#[derive(Debug, Clone, Copy)]
pub struct PlanMsg<'a> {
    /// The peer thread (receiver in a send list, sender in a recv list).
    pub peer: u32,
    /// Sorted unique global `x`-indices carried by this message.
    pub indices: &'a [u32],
    /// The same values as offsets into the **sender's** contiguous local
    /// storage (pre-translated through the [`Layout`] at compile time).
    pub local_src: &'a [u32],
    /// First slot of this message in a staging arena of
    /// [`CommPlan::total_values`] doubles.
    pub start: usize,
}

impl PlanMsg<'_> {
    /// Number of values carried.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// This message's slot range in the staging arena.
    pub fn range(&self) -> Range<usize> {
        self.start..self.start + self.indices.len()
    }
}

/// The compiled send/receive plan for all threads.
#[derive(Debug, Clone, Default)]
pub struct CommPlan {
    threads: usize,
    /// Global `x`-indices, one contiguous range per message, receiver-major.
    indices: Vec<u32>,
    /// Owner-local offsets of the same values (parallel to `indices`).
    local_src: Vec<u32>,
    /// Message descriptors sorted by `(receiver, sender)`; ranges are
    /// consecutive and partition `0..indices.len()`.
    msgs: Vec<MsgDesc>,
    /// `msgs[recv_off[t]..recv_off[t+1]]` are the messages received by `t`.
    recv_off: Vec<u32>,
    /// `send_ids[send_off[t]..send_off[t+1]]` are the ids (into `msgs`) of
    /// the messages sent by `t`, sorted by receiver.
    send_off: Vec<u32>,
    send_ids: Vec<u32>,
    /// Whether the plan carries the paper's condensed invariants (per-message
    /// indices sorted + unique, one message per `(receiver, sender)` pair,
    /// peer lists sorted). Raw occurrence-order plans
    /// ([`CommPlan::from_occurrence_needs`]) set this to `false` and skip
    /// those checks in [`validate`](CommPlan::validate); the executors only
    /// rely on the arena tiling, which both forms guarantee.
    condensed: bool,
}

impl CommPlan {
    /// Compile the plan from per-thread receive needs.
    /// `recv_needs[t]` = sorted unique `(owner, index)` pairs thread `t`
    /// requires from other threads. The send side is derived as a CSR
    /// permutation over the same arena — no index list is cloned.
    pub fn from_recv_needs(layout: &Layout, recv_needs: &[Vec<(u32, u32)>]) -> CommPlan {
        CommPlan::from_triples(layout.threads, &translate(layout, recv_needs), true)
    }

    /// Compile an **uncondensed** plan straight from occurrence-order needs:
    /// `needs[t]` lists `(owner, index)` pairs in the order the workload
    /// touches them, duplicates included, a new message opening whenever the
    /// owner changes between consecutive occurrences. This is the paper's
    /// fine-grained baseline — the traffic *before* the condensing pass —
    /// kept runnable so the optimizer's win is measurable on the same
    /// executors.
    pub fn from_occurrence_needs(layout: &Layout, needs: &[Vec<(u32, u32)>]) -> CommPlan {
        CommPlan::from_triples(layout.threads, &translate(layout, needs), false)
    }

    /// Assemble a plan from per-thread receive lists of
    /// `(owner, index, owner_local_offset)` triples, already in the order
    /// the arena should carry them. A new message opens whenever the owner
    /// changes between consecutive triples, so condensed inputs (sorted by
    /// owner, unique) yield one message per `(receiver, sender)` pair and
    /// occurrence-order inputs yield one message per same-owner run.
    pub(crate) fn from_triples(
        threads: usize,
        recv: &[Vec<(u32, u32, u32)>],
        condensed: bool,
    ) -> CommPlan {
        assert_eq!(recv.len(), threads);
        let total: usize = recv.iter().map(|v| v.len()).sum();
        let mut indices = Vec::with_capacity(total);
        let mut local_src = Vec::with_capacity(total);
        let mut msgs: Vec<MsgDesc> = Vec::new();
        let mut recv_off = Vec::with_capacity(threads + 1);
        recv_off.push(0u32);
        for (t, needs) in recv.iter().enumerate() {
            let mut run_start = true;
            for &(owner, idx, loc) in needs {
                debug_assert_ne!(owner as usize, t, "thread {t} receives from itself");
                match msgs.last_mut() {
                    Some(m) if !run_start && m.sender == owner => m.end += 1,
                    _ => {
                        let s = indices.len() as u32;
                        msgs.push(MsgDesc { sender: owner, receiver: t as u32, start: s, end: s + 1 });
                    }
                }
                run_start = false;
                indices.push(idx);
                local_src.push(loc);
            }
            recv_off.push(msgs.len() as u32);
        }
        // Sender-side CSR over message ids. Iterating receiver-major keeps
        // each sender's id list sorted by receiver (for condensed plans).
        let mut send_count = vec![0u32; threads];
        for m in &msgs {
            send_count[m.sender as usize] += 1;
        }
        let mut send_off = Vec::with_capacity(threads + 1);
        send_off.push(0u32);
        for t in 0..threads {
            send_off.push(send_off[t] + send_count[t]);
        }
        let mut cursor: Vec<u32> = send_off[..threads].to_vec();
        let mut send_ids = vec![0u32; msgs.len()];
        for (id, m) in msgs.iter().enumerate() {
            let c = &mut cursor[m.sender as usize];
            send_ids[*c as usize] = id as u32;
            *c += 1;
        }
        CommPlan { threads, indices, local_src, msgs, recv_off, send_off, send_ids, condensed }
    }

    fn view<'a>(&'a self, m: &MsgDesc, peer: u32) -> PlanMsg<'a> {
        let (s, e) = (m.start as usize, m.end as usize);
        PlanMsg { peer, indices: &self.indices[s..e], local_src: &self.local_src[s..e], start: s }
    }

    /// Number of UPC threads the plan was compiled for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// `true` when the plan carries the condensed invariants (each remote
    /// value fetched once, one message per peer pair, sorted lists).
    pub fn is_condensed(&self) -> bool {
        self.condensed
    }

    /// Messages thread `t` unpacks, sorted by sending peer.
    pub fn recv_msgs(&self, t: usize) -> impl Iterator<Item = PlanMsg<'_>> + '_ {
        self.msgs[self.recv_off[t] as usize..self.recv_off[t + 1] as usize]
            .iter()
            .map(move |m| self.view(m, m.sender))
    }

    /// Messages thread `t` packs and puts, sorted by receiving peer.
    pub fn send_msgs(&self, t: usize) -> impl Iterator<Item = PlanMsg<'_>> + '_ {
        self.send_ids[self.send_off[t] as usize..self.send_off[t + 1] as usize]
            .iter()
            .map(move |&id| {
                let m = &self.msgs[id as usize];
                self.view(m, m.receiver)
            })
    }

    /// All messages in arena (staging-buffer) order as
    /// `(sender, receiver, msg)` — what the parallel engine uses to carve
    /// the staging buffer into disjoint per-message slices.
    pub fn arena_msgs(&self) -> impl Iterator<Item = (usize, usize, PlanMsg<'_>)> + '_ {
        self.msgs
            .iter()
            .map(move |m| (m.sender as usize, m.receiver as usize, self.view(m, m.receiver)))
    }

    /// Total values exchanged (Σ message lengths, counted once per message).
    pub fn total_values(&self) -> usize {
        self.indices.len()
    }

    /// Total number of consolidated messages.
    pub fn num_messages(&self) -> usize {
        self.msgs.len()
    }

    /// Number of messages thread `t` sends.
    pub fn messages_from(&self, t: usize) -> usize {
        (self.send_off[t + 1] - self.send_off[t]) as usize
    }

    /// Number of messages thread `t` receives.
    pub fn messages_to(&self, t: usize) -> usize {
        (self.recv_off[t + 1] - self.recv_off[t]) as usize
    }

    /// Structural FNV-1a fingerprint of the plan: thread count plus every
    /// message's endpoints and index lists, in arena order. RNG-free and
    /// address-free, so two plans compiled from the same needs hash equal
    /// across runs and processes — the checkpoint/restart layer uses this
    /// to refuse restoring a snapshot onto a different decomposition.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::Fnv64::new();
        h.write_usize(self.threads);
        h.write_u8(self.condensed as u8);
        h.write_usize(self.msgs.len());
        for m in &self.msgs {
            h.write_u64(m.sender as u64);
            h.write_u64(m.receiver as u64);
            let (s, e) = (m.start as usize, m.end as usize);
            h.write_usize(e - s);
            for &idx in &self.indices[s..e] {
                h.write_u64(idx as u64);
            }
            for &off in &self.local_src[s..e] {
                h.write_u64(off as u64);
            }
        }
        h.finish()
    }

    /// Serialize for shipping to worker processes (`repro launch`). The
    /// wire form carries every structural field verbatim, so the
    /// deserialized plan fingerprints identically to this one.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("threads", Value::Num(self.threads as f64));
        v.set("condensed", Value::Bool(self.condensed));
        v.set("indices", u32s_to_json(&self.indices));
        v.set("local_src", u32s_to_json(&self.local_src));
        let msgs: Vec<Value> = self
            .msgs
            .iter()
            .map(|m| {
                Value::Arr(vec![
                    Value::Num(m.sender as f64),
                    Value::Num(m.receiver as f64),
                    Value::Num(m.start as f64),
                    Value::Num(m.end as f64),
                ])
            })
            .collect();
        v.set("msgs", Value::Arr(msgs));
        v.set("recv_off", u32s_to_json(&self.recv_off));
        v.set("send_off", u32s_to_json(&self.send_off));
        v.set("send_ids", u32s_to_json(&self.send_ids));
        v
    }

    /// Deserialize a shipped plan, re-running
    /// [`validate`](CommPlan::validate) so a tampered or truncated wire
    /// form is rejected instead of trusted.
    pub fn from_json(v: &Value) -> Result<CommPlan, String> {
        let threads = json_usize(v, "threads")?;
        // Wire forms predating the optimizer carry no flag; they were all
        // condensed by construction.
        let condensed = match v.get("condensed") {
            None => true,
            Some(Value::Bool(b)) => *b,
            Some(_) => return Err("condensed: not a bool".into()),
        };
        let indices = json_u32s(v, "indices")?;
        let local_src = json_u32s(v, "local_src")?;
        let raw = v.get("msgs").and_then(Value::as_arr).ok_or("msgs: not an array")?;
        let mut msgs = Vec::with_capacity(raw.len());
        for (i, m) in raw.iter().enumerate() {
            let q = m
                .as_arr()
                .filter(|q| q.len() == 4)
                .ok_or_else(|| format!("msgs[{i}]: want [sender, receiver, start, end]"))?;
            msgs.push(MsgDesc {
                sender: num_u32(&q[0], "msgs.sender")?,
                receiver: num_u32(&q[1], "msgs.receiver")?,
                start: num_u32(&q[2], "msgs.start")?,
                end: num_u32(&q[3], "msgs.end")?,
            });
        }
        let recv_off = json_u32s(v, "recv_off")?;
        let send_off = json_u32s(v, "send_off")?;
        let send_ids = json_u32s(v, "send_ids")?;
        // Bounds guards [`validate`](CommPlan::validate) assumes: it slices
        // by these tables, so a hostile wire form must fail here, not panic.
        if msgs.iter().any(|m| m.end as usize > indices.len()) {
            return Err("msgs range exceeds the index arena".into());
        }
        if send_ids.iter().any(|&id| id as usize >= msgs.len()) {
            return Err("send_ids names a message out of range".into());
        }
        let bounded = |off: &[u32], n: usize| {
            off.len() == threads + 1
                && off.windows(2).all(|w| w[0] <= w[1])
                && off.last().is_some_and(|&e| e as usize == n)
        };
        if !bounded(&recv_off, msgs.len()) || !bounded(&send_off, send_ids.len()) {
            return Err("offset tables malformed".into());
        }
        let plan =
            CommPlan { threads, indices, local_src, msgs, recv_off, send_off, send_ids, condensed };
        plan.validate().map_err(|e| format!("shipped gather plan invalid: {e}"))?;
        Ok(plan)
    }

    /// Consistency check: descriptors partition the arena, no self-messages,
    /// and the send side is an exact permutation of the receive side. Plans
    /// flagged [`is_condensed`](CommPlan::is_condensed) additionally require
    /// sorted unique per-message indices and peer-sorted message lists —
    /// raw occurrence-order plans legitimately violate those.
    pub fn validate(&self) -> Result<(), String> {
        let threads = self.threads;
        if self.recv_off.len() != threads + 1 || self.send_off.len() != threads + 1 {
            return Err("offset table arity".into());
        }
        if self.indices.len() != self.local_src.len() {
            return Err("indices/local_src length mismatch".into());
        }
        if self.send_ids.len() != self.msgs.len() {
            return Err("send permutation arity".into());
        }
        if self.recv_off[threads] as usize != self.msgs.len()
            || self.send_off[threads] as usize != self.send_ids.len()
        {
            return Err("offset tables do not cover all messages".into());
        }
        let mut cursor = 0u32;
        for (id, m) in self.msgs.iter().enumerate() {
            if m.sender == m.receiver {
                return Err(format!("message {id} is a self-message ({})", m.sender));
            }
            if m.sender as usize >= threads || m.receiver as usize >= threads {
                return Err(format!("message {id} names an out-of-range thread"));
            }
            if m.start != cursor || m.end <= m.start {
                return Err(format!("message {id} range [{}, {}) breaks the arena", m.start, m.end));
            }
            cursor = m.end;
            let idx = &self.indices[m.start as usize..m.end as usize];
            if self.condensed && !idx.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("message {} → {} not sorted/unique", m.sender, m.receiver));
            }
        }
        if cursor as usize != self.indices.len() {
            return Err("arena not fully covered by messages".into());
        }
        for t in 0..threads {
            if self.recv_off[t] > self.recv_off[t + 1] || self.send_off[t] > self.send_off[t + 1] {
                return Err(format!("offsets not monotone at thread {t}"));
            }
            let mut prev: Option<u32> = None;
            for m in &self.msgs[self.recv_off[t] as usize..self.recv_off[t + 1] as usize] {
                if m.receiver as usize != t {
                    return Err(format!("recv list of {t} holds a foreign message"));
                }
                if self.condensed && prev.is_some_and(|p| p >= m.sender) {
                    return Err(format!("recv list of {t} not sorted by sender"));
                }
                prev = Some(m.sender);
            }
            let mut prev: Option<u32> = None;
            for &id in &self.send_ids[self.send_off[t] as usize..self.send_off[t + 1] as usize] {
                let m = &self.msgs[id as usize];
                if m.sender as usize != t {
                    return Err(format!("send list of {t} holds a foreign message"));
                }
                if self.condensed && prev.is_some_and(|p| p >= m.receiver) {
                    return Err(format!("send list of {t} not sorted by receiver"));
                }
                prev = Some(m.receiver);
            }
        }
        // Every message appears exactly once on the send side.
        let mut seen = vec![false; self.msgs.len()];
        for &id in &self.send_ids {
            let slot = &mut seen[id as usize];
            if *slot {
                return Err(format!("message {id} sent twice"));
            }
            *slot = true;
        }
        Ok(())
    }
}

/// Translate `(owner, index)` needs into `(owner, index, local_offset)`
/// triples through the layout, checking ownership in debug builds.
fn translate(layout: &Layout, needs: &[Vec<(u32, u32)>]) -> Vec<Vec<(u32, u32, u32)>> {
    needs
        .iter()
        .map(|v| {
            v.iter()
                .map(|&(owner, idx)| {
                    debug_assert_eq!(
                        layout.owner_of_index(idx as usize),
                        owner as usize,
                        "need ({owner}, {idx}) names the wrong owner"
                    );
                    (owner, idx, layout.local_offset_of_index(idx as usize) as u32)
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Layout 12 elements × blocksize 2 × 3 threads:
    /// b0[0,1]→t0 b1[2,3]→t1 b2[4,5]→t2 b3[6,7]→t0 b4[8,9]→t1 b5[10,11]→t2.
    fn layout() -> Layout {
        Layout::new(12, 2, 3)
    }

    #[test]
    fn transpose_roundtrip() {
        // t0 needs idx 2,3 from t1 and 4 from t2; t2 needs 0 from t0 and 8
        // from t1.
        let needs = vec![
            vec![(1u32, 2u32), (1, 3), (2, 4)],
            vec![],
            vec![(0, 0), (1, 8)],
        ];
        let plan = CommPlan::from_recv_needs(&layout(), &needs);
        plan.validate().unwrap();
        assert_eq!(plan.total_values(), 5);
        assert_eq!(plan.num_messages(), 4);
        assert_eq!(plan.messages_from(0), 1);
        assert_eq!(plan.messages_from(1), 2);
        assert_eq!(plan.messages_from(2), 1);
        assert_eq!(plan.messages_to(0), 2);
        assert_eq!(plan.messages_to(1), 0);
        assert_eq!(plan.messages_to(2), 2);

        let r0: Vec<_> = plan.recv_msgs(0).collect();
        assert_eq!(r0[0].peer, 1);
        assert_eq!(r0[0].indices, &[2, 3]);
        assert_eq!(r0[1].peer, 2);
        assert_eq!(r0[1].indices, &[4]);

        // Send side is the exact transpose, sharing the same arena ranges.
        let s1: Vec<_> = plan.send_msgs(1).collect();
        assert_eq!(s1[0].peer, 0);
        assert_eq!(s1[0].indices, &[2, 3]);
        assert_eq!(s1[0].range(), 0..2);
        assert_eq!(s1[1].peer, 2);
        assert_eq!(s1[1].indices, &[8]);

        // Owner-local offsets were pre-translated: idx 2,3 are t1's first
        // block (offsets 0,1); idx 8 is t1's second block (offset 2); idx 4
        // is t2's first block (offset 0); idx 0 is t0's offset 0.
        assert_eq!(s1[0].local_src, &[0, 1]);
        assert_eq!(s1[1].local_src, &[2]);
        let s2: Vec<_> = plan.send_msgs(2).collect();
        assert_eq!(s2[0].local_src, &[0]);
    }

    #[test]
    fn arena_order_is_receiver_major() {
        let needs = vec![
            vec![(1u32, 2u32), (2, 4)],
            vec![(2, 10)],
            vec![(0, 6)],
        ];
        let plan = CommPlan::from_recv_needs(&layout(), &needs);
        plan.validate().unwrap();
        let order: Vec<(usize, usize)> =
            plan.arena_msgs().map(|(s, r, _)| (s, r)).collect();
        assert_eq!(order, vec![(1, 0), (2, 0), (2, 1), (0, 2)]);
        // Ranges tile the arena consecutively.
        let mut cursor = 0;
        for (_, _, m) in plan.arena_msgs() {
            assert_eq!(m.range().start, cursor);
            cursor = m.range().end;
        }
        assert_eq!(cursor, plan.total_values());
    }

    #[test]
    fn fingerprint_is_structural() {
        let needs = vec![
            vec![(1u32, 2u32), (1, 3), (2, 4)],
            vec![],
            vec![(0, 0), (1, 8)],
        ];
        let a = CommPlan::from_recv_needs(&layout(), &needs);
        let b = CommPlan::from_recv_needs(&layout(), &needs);
        assert_eq!(a.fingerprint(), b.fingerprint(), "same needs must hash equal");
        let shrunk = vec![
            vec![(1u32, 2u32), (1, 3)],
            vec![],
            vec![(0, 0), (1, 8)],
        ];
        let c = CommPlan::from_recv_needs(&layout(), &shrunk);
        assert_ne!(a.fingerprint(), c.fingerprint(), "different needs must hash apart");
    }

    #[test]
    fn json_roundtrip_preserves_fingerprint() {
        let needs = vec![
            vec![(1u32, 2u32), (1, 3), (2, 4)],
            vec![],
            vec![(0, 0), (1, 8)],
        ];
        let plan = CommPlan::from_recv_needs(&layout(), &needs);
        let text = plan.to_json().compact();
        let back = CommPlan::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.fingerprint(), plan.fingerprint());
        assert_eq!(back.total_values(), plan.total_values());
        back.validate().unwrap();
    }

    #[test]
    fn tampered_json_is_rejected_not_trusted() {
        let needs = vec![vec![(1u32, 2u32), (1, 3)], vec![]];
        let l = Layout::new(4, 2, 2);
        let plan = CommPlan::from_recv_needs(&l, &needs);
        // Reorder an index list so it is no longer sorted.
        let mut v = plan.to_json();
        v.set("indices", u32s_to_json(&[3, 2]));
        assert!(CommPlan::from_json(&v).is_err());
        // Truncate the arena under the message descriptors.
        let mut v = plan.to_json();
        v.set("indices", u32s_to_json(&[2]));
        assert!(CommPlan::from_json(&v).is_err());
        // Point the send permutation out of range.
        let mut v = plan.to_json();
        v.set("send_ids", u32s_to_json(&[9]));
        assert!(CommPlan::from_json(&v).is_err());
        // Non-integer where a u32 belongs.
        let mut v = plan.to_json();
        v.set("threads", Value::Num(1.5));
        assert!(CommPlan::from_json(&v).is_err());
    }

    #[test]
    fn validate_catches_corruption() {
        let needs = vec![vec![(1u32, 2u32)], vec![]];
        let l = Layout::new(4, 2, 2);
        let mut plan = CommPlan::from_recv_needs(&l, &needs);
        plan.validate().unwrap();
        plan.indices = vec![3, 2]; // unsorted + wrong arity for the message
        assert!(plan.validate().is_err());
        let mut plan = CommPlan::from_recv_needs(&l, &needs);
        plan.msgs[0].receiver = 1; // self-message
        assert!(plan.validate().is_err());
    }

    #[test]
    fn occurrence_plan_is_raw_but_consistent() {
        // t0 touches t1's idx 3, then t2's idx 4, then t1's 3 (again) and 2:
        // duplicates and owner interleaving survive, message boundaries
        // follow the owner runs.
        let needs = vec![vec![(1u32, 3u32), (2, 4), (1, 3), (1, 2)], vec![], vec![]];
        let plan = CommPlan::from_occurrence_needs(&layout(), &needs);
        plan.validate().unwrap();
        assert!(!plan.is_condensed());
        assert_eq!(plan.total_values(), 4);
        assert_eq!(plan.num_messages(), 3);
        let r0: Vec<_> = plan.recv_msgs(0).collect();
        assert_eq!(r0[0].indices, &[3]);
        assert_eq!(r0[1].indices, &[4]);
        assert_eq!(r0[2].indices, &[3, 2]);
        // Local offsets still pre-translated per occurrence: idx 3 is t1's
        // offset 1, idx 2 its offset 0.
        assert_eq!(r0[2].local_src, &[1, 0]);
        // The condensed plan over the same unique needs hashes apart.
        let condensed = vec![vec![(1u32, 2u32), (1, 3), (2, 4)], vec![], vec![]];
        let c = CommPlan::from_recv_needs(&layout(), &condensed);
        assert!(c.is_condensed());
        assert_ne!(c.fingerprint(), plan.fingerprint());
        // JSON round-trip preserves the raw flag and the fingerprint.
        let text = plan.to_json().compact();
        let back = CommPlan::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert!(!back.is_condensed());
        assert_eq!(back.fingerprint(), plan.fingerprint());
    }

    /// Property: for random layouts and synthetic needs, the compiled plan
    /// validates, local offsets agree with the layout, and per-pair lists
    /// survive the send-side permutation intact.
    #[test]
    fn prop_compiled_plan_is_faithful() {
        crate::testing::check_prop(
            "commplan-compile",
            48,
            |r| {
                let n = r.usize_in(4, 2000);
                let bs = r.usize_in(1, 100);
                let threads = r.usize_in(2, 12);
                let l = Layout::new(n, bs, threads);
                // Synthesize needs: every thread samples some off-owner
                // indices, then sorts/dedups by (owner, index) like the
                // analyzer does.
                let mut needs: Vec<Vec<(u32, u32)>> = Vec::with_capacity(threads);
                for t in 0..threads {
                    let mut v: Vec<(u32, u32)> = (0..r.usize_in(0, 50))
                        .filter_map(|_| {
                            let idx = r.usize_in(0, n);
                            let owner = l.owner_of_index(idx);
                            (owner != t).then_some((owner as u32, idx as u32))
                        })
                        .collect();
                    v.sort_unstable();
                    v.dedup();
                    needs.push(v);
                }
                (l, needs)
            },
            |(l, needs)| {
                let plan = CommPlan::from_recv_needs(l, needs);
                plan.validate()?;
                let total: usize = needs.iter().map(|v| v.len()).sum();
                if plan.total_values() != total {
                    return Err(format!("{} values, want {total}", plan.total_values()));
                }
                for t in 0..l.threads {
                    // Receive side reproduces the needs exactly.
                    let flat: Vec<(u32, u32)> = plan
                        .recv_msgs(t)
                        .flat_map(|m| m.indices.iter().map(move |&i| (m.peer, i)))
                        .collect();
                    if flat != needs[t] {
                        return Err(format!("thread {t}: recv lists diverge from needs"));
                    }
                    for m in plan.send_msgs(t) {
                        for (&g, &loc) in m.indices.iter().zip(m.local_src) {
                            if l.owner_of_index(g as usize) != t {
                                return Err(format!("send list of {t} carries a foreign index"));
                            }
                            if l.local_offset_of_index(g as usize) != loc as usize {
                                return Err(format!("local offset of {g} mistranslated"));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
