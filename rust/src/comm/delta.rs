//! The versioned plan lifecycle: [`PlanDelta`] diffs two plan generations
//! and [`ExchangePlan::apply_delta`] patches the compiled arena in place of
//! a full recompile.
//!
//! Every layer below this one was built around "compile once, immutable"
//! (fingerprints enforce it). Real irregular workloads re-inspect and
//! re-plan — molecular dynamics rebuilds its neighbor lists every few
//! hundred steps (the UPC-MD evaluation), inspector/executor compilers
//! re-run the inspector when the access pattern drifts — so the lifecycle
//! becomes: compile generation 0, then advance generations by **deltas**.
//!
//! A delta is a list of dirty `(receiver, sender)` pairs, each carrying the
//! pair's full replacement content (empty content = the pair disappears).
//! Untouched pairs are copied from the previous generation's arena verbatim;
//! only dirty pairs pay the condense/consolidate work. Applying a delta is
//! therefore `O(arena memmove + |delta|)` — no global index sort, no
//! re-inspection — versus a full compile's sort/dedup over every value
//! (`benches/plan_optimize.rs` gates the ratio).
//!
//! Generations are named by a **fingerprint chain**:
//! `fp(gen N) = hash(fp(gen N−1), delta_N)`. Two endpoints that started
//! from the same generation-0 plan and applied the same delta sequence
//! agree on the chain value, so the socket transport ships deltas (one
//! `KIND_DELTA` frame), not whole plans, and both sides verify the chain.
//!
//! Canonical-order contract: dirty-pair patching is only well-defined when
//! each `(receiver, sender)` pair owns one contiguous arena run and pairs
//! are sorted by sender within a receiver. Condensed gather plans guarantee
//! this by construction; strided plans must be in the consolidated
//! `(receiver, sender)`-sorted order ([`PlanOptimizer::consolidate_strided`]
//! emits it, as do the halo compilers). [`PlanDelta::diff`] and
//! [`ExchangePlan::apply_delta`] reject other layouts instead of silently
//! reordering them.
//!
//! [`PlanOptimizer::consolidate_strided`]: super::PlanOptimizer::consolidate_strided

use super::plan::{json_u32s, num_u32, u32s_to_json};
use super::{CommPlan, ExchangePlan, StridedBlock, StridedPlan};
use crate::util::json::Value;
use crate::util::Fnv64;

/// Replacement content for one dirty gather pair: the sorted unique global
/// indices `receiver` needs from `sender`, with their pre-translated
/// sender-local offsets. Empty lists remove the pair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GatherPatch {
    pub receiver: u32,
    pub sender: u32,
    pub indices: Vec<u32>,
    pub local_src: Vec<u32>,
}

/// Replacement content for one dirty strided pair: the `(src, dst)` block
/// copies from `sender` to `receiver`, in unpack order. Empty removes the
/// pair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StridedPatch {
    pub receiver: u32,
    pub sender: u32,
    pub copies: Vec<(StridedBlock, StridedBlock)>,
}

/// A diff between two plan generations: the dirty `(receiver, sender)`
/// pairs with their replacement content, stamped with the base generation's
/// fingerprint so it can only be applied to the generation it was diffed
/// against.
#[derive(Debug, Clone, Default)]
pub struct PlanDelta {
    threads: usize,
    /// Fingerprint of the [`ExchangePlan`] this delta applies to.
    base_fp: u64,
    /// Dirty gather pairs, sorted by `(receiver, sender)`; empty for
    /// strided deltas.
    gather: Vec<GatherPatch>,
    /// Dirty strided pairs, sorted by `(receiver, sender)`; empty for
    /// gather deltas.
    strided: Vec<StridedPatch>,
}

/// Advance the generation fingerprint chain by one delta:
/// `fp(gen N) = hash(fp(gen N−1), delta_N)`. Both endpoints of a shipped
/// delta compute this independently; agreement proves they hold the same
/// plan history without ever re-shipping a whole plan.
pub fn chain_fingerprint(prev: u64, delta: &PlanDelta) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(prev);
    h.write_u64(delta.fingerprint());
    h.finish()
}

impl PlanDelta {
    /// Build a gather-form delta from dirty-pair patches (any order; sorted
    /// and validated here). `base_fp` names the generation the delta
    /// applies to ([`ExchangePlan::fingerprint`] of the base plan).
    pub fn from_gather_patches(
        threads: usize,
        base_fp: u64,
        mut patches: Vec<GatherPatch>,
    ) -> Result<PlanDelta, String> {
        patches.sort_by_key(|p| (p.receiver, p.sender));
        let d = PlanDelta { threads, base_fp, gather: patches, strided: Vec::new() };
        d.validate()?;
        Ok(d)
    }

    /// Build a strided-form delta from dirty-pair patches (any order).
    pub fn from_strided_patches(
        threads: usize,
        base_fp: u64,
        mut patches: Vec<StridedPatch>,
    ) -> Result<PlanDelta, String> {
        patches.sort_by_key(|p| (p.receiver, p.sender));
        let d = PlanDelta { threads, base_fp, gather: Vec::new(), strided: patches };
        d.validate()?;
        Ok(d)
    }

    /// Diff two plan generations into the dirty-pair delta that takes `old`
    /// to `new`: `old.apply_delta(&diff(old, new))` fingerprints identically
    /// to `new`. Both plans must share form, thread count and the canonical
    /// pair order (see the module docs).
    pub fn diff(old: &ExchangePlan, new: &ExchangePlan) -> Result<PlanDelta, String> {
        if old.threads() != new.threads() {
            return Err(format!(
                "plan generations disagree on thread count ({} vs {})",
                old.threads(),
                new.threads()
            ));
        }
        match (old, new) {
            (ExchangePlan::Gather(a), ExchangePlan::Gather(b)) => {
                diff_gather(a, b, old.fingerprint())
            }
            (ExchangePlan::Strided(a), ExchangePlan::Strided(b)) => {
                diff_strided(a, b, old.fingerprint())
            }
            _ => Err(format!("plan generations changed form ({} vs {})", old.name(), new.name())),
        }
    }

    /// Number of UPC threads the delta's generations were compiled for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fingerprint of the generation this delta applies to.
    pub fn base_fingerprint(&self) -> u64 {
        self.base_fp
    }

    /// `true` when the two generations were identical.
    pub fn is_empty(&self) -> bool {
        self.gather.is_empty() && self.strided.is_empty()
    }

    /// Number of dirty `(receiver, sender)` pairs — the |delta| the
    /// incremental-recompile cost scales with.
    pub fn dirty_pairs(&self) -> usize {
        self.gather.len() + self.strided.len()
    }

    /// Total replacement values carried by the dirty pairs (the payload
    /// side of |delta|; removals contribute 0).
    pub fn patch_values(&self) -> usize {
        let g: usize = self.gather.iter().map(|p| p.indices.len()).sum();
        let s: usize =
            self.strided.iter().map(|p| p.copies.iter().map(|(b, _)| b.len()).sum::<usize>()).sum();
        g + s
    }

    /// Which plan form this delta patches.
    pub fn form_name(&self) -> &'static str {
        if self.strided.is_empty() {
            "gather"
        } else {
            "strided"
        }
    }

    /// Structural FNV-1a fingerprint of the delta content (threads, every
    /// dirty pair, every replacement value). Feeds the generation chain —
    /// see [`chain_fingerprint`].
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_usize(self.threads);
        h.write_u8(if self.strided.is_empty() { 1 } else { 2 });
        h.write_usize(self.gather.len());
        for p in &self.gather {
            h.write_u64(p.receiver as u64);
            h.write_u64(p.sender as u64);
            h.write_usize(p.indices.len());
            for &i in &p.indices {
                h.write_u64(i as u64);
            }
            for &o in &p.local_src {
                h.write_u64(o as u64);
            }
        }
        h.write_usize(self.strided.len());
        for p in &self.strided {
            h.write_u64(p.receiver as u64);
            h.write_u64(p.sender as u64);
            h.write_usize(p.copies.len());
            for (src, dst) in &p.copies {
                for b in [src, dst] {
                    h.write_usize(b.offset);
                    h.write_usize(b.rows);
                    h.write_usize(b.row_stride);
                    h.write_usize(b.cols);
                    h.write_usize(b.col_stride);
                }
            }
        }
        h.finish()
    }

    /// Structural consistency: in-range endpoints, no self-pairs, parallel
    /// index/offset lists, condensed per-pair invariants, strict
    /// `(receiver, sender)` order. `O(|delta|)` — cheap enough to run on
    /// every wire receive.
    pub fn validate(&self) -> Result<(), String> {
        if !self.gather.is_empty() && !self.strided.is_empty() {
            return Err("delta mixes gather and strided patches".into());
        }
        let mut prev: Option<(u32, u32)> = None;
        for p in &self.gather {
            check_pair(self.threads, p.receiver, p.sender, &mut prev)?;
            if p.indices.len() != p.local_src.len() {
                return Err(format!(
                    "patch ({}, {}): indices/local_src length mismatch",
                    p.receiver, p.sender
                ));
            }
            if !p.indices.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!(
                    "patch ({}, {}): indices not sorted/unique",
                    p.receiver, p.sender
                ));
            }
        }
        let mut prev: Option<(u32, u32)> = None;
        for p in &self.strided {
            check_pair(self.threads, p.receiver, p.sender, &mut prev)?;
            for (src, dst) in &p.copies {
                if src.len() != dst.len() || src.is_empty() {
                    return Err(format!(
                        "patch ({}, {}): block copy length mismatch or empty",
                        p.receiver, p.sender
                    ));
                }
            }
        }
        Ok(())
    }

    /// Serialize for the wire (`KIND_DELTA` frames): form tag, thread
    /// count, base fingerprint (hex — u64 does not survive a JSON double),
    /// and every dirty pair verbatim.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("form", Value::Str(self.form_name().to_string()));
        v.set("threads", Value::Num(self.threads as f64));
        v.set("base_fp", Value::Str(format!("{:016x}", self.base_fp)));
        if self.strided.is_empty() {
            let pairs: Vec<Value> = self
                .gather
                .iter()
                .map(|p| {
                    let mut o = Value::obj();
                    o.set("receiver", Value::Num(p.receiver as f64));
                    o.set("sender", Value::Num(p.sender as f64));
                    o.set("indices", u32s_to_json(&p.indices));
                    o.set("local_src", u32s_to_json(&p.local_src));
                    o
                })
                .collect();
            v.set("pairs", Value::Arr(pairs));
        } else {
            let pairs: Vec<Value> = self
                .strided
                .iter()
                .map(|p| {
                    let mut o = Value::obj();
                    o.set("receiver", Value::Num(p.receiver as f64));
                    o.set("sender", Value::Num(p.sender as f64));
                    let copies: Vec<Value> = p
                        .copies
                        .iter()
                        .map(|(src, dst)| {
                            let mut nums = Vec::with_capacity(10);
                            for b in [src, dst] {
                                nums.extend([
                                    b.offset as f64,
                                    b.rows as f64,
                                    b.row_stride as f64,
                                    b.cols as f64,
                                    b.col_stride as f64,
                                ]);
                            }
                            Value::Arr(nums.into_iter().map(Value::Num).collect())
                        })
                        .collect();
                    o.set("copies", Value::Arr(copies));
                    o
                })
                .collect();
            v.set("pairs", Value::Arr(pairs));
        }
        v
    }

    /// Deserialize a shipped delta, re-running [`validate`](Self::validate)
    /// so a tampered or truncated wire form is rejected instead of trusted.
    pub fn from_json(v: &Value) -> Result<PlanDelta, String> {
        let form = v.get("form").and_then(Value::as_str).ok_or("form: missing")?;
        let threads = super::plan::json_usize(v, "threads")?;
        let fp_hex = v.get("base_fp").and_then(Value::as_str).ok_or("base_fp: missing")?;
        let base_fp = u64::from_str_radix(fp_hex, 16)
            .map_err(|_| format!("base_fp: {fp_hex:?} is not a hex u64"))?;
        let raw = v.get("pairs").and_then(Value::as_arr).ok_or("pairs: not an array")?;
        let (mut gather, mut strided) = (Vec::new(), Vec::new());
        for (i, p) in raw.iter().enumerate() {
            let receiver = num_u32(p.get("receiver").ok_or("receiver: missing")?, "receiver")?;
            let sender = num_u32(p.get("sender").ok_or("sender: missing")?, "sender")?;
            match form {
                "gather" => gather.push(GatherPatch {
                    receiver,
                    sender,
                    indices: json_u32s(p, "indices")?,
                    local_src: json_u32s(p, "local_src")?,
                }),
                "strided" => {
                    let raw_copies =
                        p.get("copies").and_then(Value::as_arr).ok_or("copies: not an array")?;
                    let mut copies = Vec::with_capacity(raw_copies.len());
                    for c in raw_copies {
                        let q = c
                            .as_arr()
                            .filter(|q| q.len() == 10)
                            .ok_or_else(|| format!("pairs[{i}]: copy wants 10 numbers"))?;
                        let block = |at: usize| -> Result<StridedBlock, String> {
                            Ok(StridedBlock {
                                offset: num_u32(&q[at], "block.offset")? as usize,
                                rows: num_u32(&q[at + 1], "block.rows")? as usize,
                                row_stride: num_u32(&q[at + 2], "block.row_stride")? as usize,
                                cols: num_u32(&q[at + 3], "block.cols")? as usize,
                                col_stride: num_u32(&q[at + 4], "block.col_stride")? as usize,
                            })
                        };
                        copies.push((block(0)?, block(5)?));
                    }
                    strided.push(StridedPatch { receiver, sender, copies });
                }
                other => return Err(format!("unknown delta form {other:?}")),
            }
        }
        let d = PlanDelta { threads, base_fp, gather, strided };
        d.validate().map_err(|e| format!("shipped delta invalid: {e}"))?;
        Ok(d)
    }
}

fn check_pair(
    threads: usize,
    receiver: u32,
    sender: u32,
    prev: &mut Option<(u32, u32)>,
) -> Result<(), String> {
    if receiver as usize >= threads || sender as usize >= threads {
        return Err(format!("patch ({receiver}, {sender}) names an out-of-range thread"));
    }
    if receiver == sender {
        return Err(format!("patch ({receiver}, {sender}) is a self-pair"));
    }
    if prev.is_some_and(|p| p >= (receiver, sender)) {
        return Err("patches not sorted by (receiver, sender)".into());
    }
    *prev = Some((receiver, sender));
    Ok(())
}

/// Per-receiver content of a condensed gather plan as a sorted pair list:
/// `(sender, indices, local_src)`.
fn gather_pairs(plan: &CommPlan, t: usize) -> Vec<(u32, Vec<u32>, Vec<u32>)> {
    plan.recv_msgs(t).map(|m| (m.peer, m.indices.to_vec(), m.local_src.to_vec())).collect()
}

fn diff_gather(old: &CommPlan, new: &CommPlan, base_fp: u64) -> Result<PlanDelta, String> {
    for (name, p) in [("old", old), ("new", new)] {
        if !p.is_condensed() {
            return Err(format!("{name} generation is not condensed; delta needs one msg per pair"));
        }
    }
    let threads = old.threads();
    let mut patches = Vec::new();
    for t in 0..threads {
        let a = gather_pairs(old, t);
        let b = gather_pairs(new, t);
        let (mut i, mut j) = (0, 0);
        while i < a.len() || j < b.len() {
            match (a.get(i), b.get(j)) {
                (Some(x), Some(y)) if x.0 == y.0 => {
                    if x.1 != y.1 || x.2 != y.2 {
                        patches.push(GatherPatch {
                            receiver: t as u32,
                            sender: y.0,
                            indices: y.1.clone(),
                            local_src: y.2.clone(),
                        });
                    }
                    i += 1;
                    j += 1;
                }
                (Some(x), Some(y)) if x.0 < y.0 => {
                    patches.push(removed_gather(t as u32, x.0));
                    i += 1;
                }
                (Some(_), Some(y)) => {
                    patches.push(GatherPatch {
                        receiver: t as u32,
                        sender: y.0,
                        indices: y.1.clone(),
                        local_src: y.2.clone(),
                    });
                    j += 1;
                }
                (Some(x), None) => {
                    patches.push(removed_gather(t as u32, x.0));
                    i += 1;
                }
                (None, Some(y)) => {
                    patches.push(GatherPatch {
                        receiver: t as u32,
                        sender: y.0,
                        indices: y.1.clone(),
                        local_src: y.2.clone(),
                    });
                    j += 1;
                }
                (None, None) => unreachable!(),
            }
        }
    }
    PlanDelta::from_gather_patches(threads, base_fp, patches)
}

fn removed_gather(receiver: u32, sender: u32) -> GatherPatch {
    GatherPatch { receiver, sender, indices: Vec::new(), local_src: Vec::new() }
}

/// Group a strided plan's copies into per-`(receiver, sender)` runs,
/// rejecting plans that are not in the canonical consolidated order.
#[allow(clippy::type_complexity)]
fn strided_pairs(
    plan: &StridedPlan,
) -> Result<Vec<(u32, u32, Vec<(StridedBlock, StridedBlock)>)>, String> {
    let mut pairs: Vec<(u32, u32, Vec<(StridedBlock, StridedBlock)>)> = Vec::new();
    for (sender, receiver, src, dst) in plan.copies() {
        let key = (receiver as u32, sender as u32);
        match pairs.last_mut() {
            Some(last) if (last.0, last.1) == key => last.2.push((src, dst)),
            _ => {
                if pairs.iter().any(|p| (p.0, p.1) == key)
                    || pairs.last().is_some_and(|p| (p.0, p.1) > key)
                {
                    return Err(
                        "strided plan not in canonical (receiver, sender) order; \
                         consolidate it before entering the delta lifecycle"
                            .into(),
                    );
                }
                pairs.push((key.0, key.1, vec![(src, dst)]));
            }
        }
    }
    Ok(pairs)
}

fn diff_strided(old: &StridedPlan, new: &StridedPlan, base_fp: u64) -> Result<PlanDelta, String> {
    let threads = old.threads();
    let a = strided_pairs(old)?;
    let b = strided_pairs(new)?;
    let mut patches = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        match (a.get(i), b.get(j)) {
            (Some(x), Some(y)) if (x.0, x.1) == (y.0, y.1) => {
                if x.2 != y.2 {
                    patches.push(StridedPatch { receiver: y.0, sender: y.1, copies: y.2.clone() });
                }
                i += 1;
                j += 1;
            }
            (Some(x), Some(y)) if (x.0, x.1) < (y.0, y.1) => {
                patches.push(StridedPatch { receiver: x.0, sender: x.1, copies: Vec::new() });
                i += 1;
            }
            (Some(_), Some(y)) => {
                patches.push(StridedPatch { receiver: y.0, sender: y.1, copies: y.2.clone() });
                j += 1;
            }
            (Some(x), None) => {
                patches.push(StridedPatch { receiver: x.0, sender: x.1, copies: Vec::new() });
                i += 1;
            }
            (None, Some(y)) => {
                patches.push(StridedPatch { receiver: y.0, sender: y.1, copies: y.2.clone() });
                j += 1;
            }
            (None, None) => unreachable!(),
        }
    }
    PlanDelta::from_strided_patches(threads, base_fp, patches)
}

fn apply_gather(plan: &CommPlan, delta: &PlanDelta) -> Result<CommPlan, String> {
    if !plan.is_condensed() {
        return Err("incremental recompile requires a condensed gather plan".into());
    }
    let threads = plan.threads();
    let mut recv: Vec<Vec<(u32, u32, u32)>> = Vec::with_capacity(threads);
    let mut at = 0usize;
    for t in 0..threads {
        let begin = at;
        while at < delta.gather.len() && (delta.gather[at].receiver as usize) == t {
            at += 1;
        }
        let mut patches = delta.gather[begin..at].iter().peekable();
        let mut triples: Vec<(u32, u32, u32)> = Vec::new();
        // Sorted merge by sender: patched pairs replace (or remove) the old
        // pair's run, added pairs splice in at their sender position, clean
        // pairs copy straight out of the old arena.
        for m in plan.recv_msgs(t) {
            while patches.peek().is_some_and(|p| p.sender < m.peer) {
                push_gather_patch(patches.next().unwrap(), &mut triples);
            }
            if patches.peek().is_some_and(|p| p.sender == m.peer) {
                push_gather_patch(patches.next().unwrap(), &mut triples);
                continue;
            }
            for (&idx, &loc) in m.indices.iter().zip(m.local_src) {
                triples.push((m.peer, idx, loc));
            }
        }
        for p in patches {
            push_gather_patch(p, &mut triples);
        }
        recv.push(triples);
    }
    Ok(CommPlan::from_triples(threads, &recv, true))
}

fn push_gather_patch(p: &GatherPatch, triples: &mut Vec<(u32, u32, u32)>) {
    for (&idx, &loc) in p.indices.iter().zip(&p.local_src) {
        triples.push((p.sender, idx, loc));
    }
}

fn apply_strided(plan: &StridedPlan, delta: &PlanDelta) -> Result<StridedPlan, String> {
    let threads = plan.threads();
    let old = strided_pairs(plan)?;
    let mut patches = delta.strided.iter().peekable();
    let mut copies: Vec<(usize, usize, StridedBlock, StridedBlock)> = Vec::new();
    let mut push_pair = |receiver: u32, sender: u32, content: &[(StridedBlock, StridedBlock)]| {
        for &(src, dst) in content {
            copies.push((sender as usize, receiver as usize, src, dst));
        }
    };
    for (receiver, sender, content) in &old {
        let key = (*receiver, *sender);
        while patches.peek().is_some_and(|p| (p.receiver, p.sender) < key) {
            let p = patches.next().unwrap();
            push_pair(p.receiver, p.sender, &p.copies);
        }
        if patches.peek().is_some_and(|p| (p.receiver, p.sender) == key) {
            let p = patches.next().unwrap();
            push_pair(p.receiver, p.sender, &p.copies);
            continue;
        }
        push_pair(*receiver, *sender, content);
    }
    for p in patches {
        push_pair(p.receiver, p.sender, &p.copies);
    }
    Ok(StridedPlan::from_msgs(threads, &copies))
}

impl ExchangePlan {
    /// Patch this generation into the next: replace each dirty
    /// `(receiver, sender)` pair's arena run with the delta's content, copy
    /// every clean pair verbatim, and rebuild the offset tables. The result
    /// is fingerprint-identical to compiling the new generation from
    /// scratch (the property suite in `rust/tests/plan_delta.rs` pins
    /// this), at `O(arena memmove + |delta|)` cost instead of a global
    /// sort over every value.
    pub fn apply_delta(&self, delta: &PlanDelta) -> Result<ExchangePlan, String> {
        delta.validate()?;
        if delta.threads() != self.threads() {
            return Err(format!(
                "delta compiled for {} threads, plan has {}",
                delta.threads(),
                self.threads()
            ));
        }
        if delta.base_fingerprint() != self.fingerprint() {
            return Err(format!(
                "delta base fingerprint {:016x} does not match plan generation {:016x}",
                delta.base_fingerprint(),
                self.fingerprint()
            ));
        }
        match self {
            ExchangePlan::Gather(p) => {
                if !delta.strided.is_empty() {
                    return Err("strided delta applied to a gather plan".into());
                }
                Ok(ExchangePlan::Gather(apply_gather(p, delta)?))
            }
            ExchangePlan::Strided(p) => {
                if !delta.gather.is_empty() {
                    return Err("gather delta applied to a strided plan".into());
                }
                Ok(ExchangePlan::Strided(apply_strided(p, delta)?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pgas::Layout;

    fn layout() -> Layout {
        Layout::new(12, 2, 3)
    }

    fn gather_plan(needs: &[Vec<(u32, u32)>]) -> ExchangePlan {
        CommPlan::from_recv_needs(&layout(), needs).into()
    }

    #[test]
    fn gather_diff_apply_matches_from_scratch() {
        let old = gather_plan(&[vec![(1, 2), (1, 3), (2, 4)], vec![], vec![(0, 0), (1, 8)]]);
        // Mutations: t0 drops one index from t1 and gains t2's 5; t2's pair
        // with t0 disappears; t1 gains a new pair with t2.
        let new = gather_plan(&[vec![(1, 2), (2, 4), (2, 5)], vec![(2, 10)], vec![(1, 8)]]);
        let d = PlanDelta::diff(&old, &new).unwrap();
        assert!(!d.is_empty());
        assert_eq!(d.base_fingerprint(), old.fingerprint());
        let patched = old.apply_delta(&d).unwrap();
        assert_eq!(patched.fingerprint(), new.fingerprint());
        patched.validate(&|_| usize::MAX).unwrap();
    }

    #[test]
    fn empty_diff_is_identity() {
        let a = gather_plan(&[vec![(1, 2), (2, 4)], vec![], vec![(0, 0)]]);
        let b = gather_plan(&[vec![(1, 2), (2, 4)], vec![], vec![(0, 0)]]);
        let d = PlanDelta::diff(&a, &b).unwrap();
        assert!(d.is_empty());
        assert_eq!(d.dirty_pairs(), 0);
        assert_eq!(a.apply_delta(&d).unwrap().fingerprint(), a.fingerprint());
    }

    #[test]
    fn stale_delta_is_rejected() {
        let a = gather_plan(&[vec![(1, 2)], vec![], vec![]]);
        let b = gather_plan(&[vec![(1, 2), (1, 3)], vec![], vec![]]);
        let c = gather_plan(&[vec![(2, 4)], vec![], vec![]]);
        let d = PlanDelta::diff(&a, &b).unwrap();
        // Applying a's delta to c (a different generation) must fail.
        let err = c.apply_delta(&d).unwrap_err();
        assert!(err.contains("does not match"), "{err}");
    }

    #[test]
    fn strided_diff_apply_matches_from_scratch() {
        let row = StridedBlock::row;
        // Canonical (receiver, sender) order.
        let old = ExchangePlan::Strided(StridedPlan::from_msgs(
            3,
            &[
                (1, 0, row(0, 2), row(4, 2)),
                (2, 0, row(2, 2), row(6, 2)),
                (0, 1, row(0, 2), row(4, 2)),
            ],
        ));
        let new = ExchangePlan::Strided(StridedPlan::from_msgs(
            3,
            &[
                (1, 0, row(0, 3), row(4, 3)),
                (0, 1, row(0, 2), row(4, 2)),
                (0, 2, row(1, 2), row(6, 2)),
            ],
        ));
        let d = PlanDelta::diff(&old, &new).unwrap();
        assert_eq!(d.form_name(), "strided");
        let patched = old.apply_delta(&d).unwrap();
        assert_eq!(patched.fingerprint(), new.fingerprint());
    }

    #[test]
    fn chain_fingerprint_tracks_history() {
        let g0 = gather_plan(&[vec![(1, 2)], vec![], vec![]]);
        let g1 = gather_plan(&[vec![(1, 2), (1, 3)], vec![], vec![]]);
        let g2 = gather_plan(&[vec![(1, 3)], vec![], vec![]]);
        let d1 = PlanDelta::diff(&g0, &g1).unwrap();
        let d2 = PlanDelta::diff(&g1, &g2).unwrap();
        let c1 = chain_fingerprint(g0.fingerprint(), &d1);
        let c2 = chain_fingerprint(c1, &d2);
        // Replaying the same history reproduces the chain; a different
        // history diverges.
        assert_eq!(chain_fingerprint(c1, &d2), c2);
        assert_ne!(chain_fingerprint(g0.fingerprint(), &d2), c1);
        assert_ne!(c1, c2);
    }

    #[test]
    fn delta_json_roundtrip_preserves_fingerprint() {
        let old = gather_plan(&[vec![(1, 2), (2, 4)], vec![], vec![(0, 0)]]);
        let new = gather_plan(&[vec![(1, 2), (1, 3)], vec![], vec![(0, 0), (1, 8)]]);
        let d = PlanDelta::diff(&old, &new).unwrap();
        let text = d.to_json().compact();
        let back = PlanDelta::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.fingerprint(), d.fingerprint());
        assert_eq!(back.base_fingerprint(), d.base_fingerprint());
        assert_eq!(old.apply_delta(&back).unwrap().fingerprint(), new.fingerprint());

        let row = StridedBlock::row;
        let s_old = ExchangePlan::Strided(StridedPlan::from_msgs(
            2,
            &[(1, 0, row(0, 2), row(4, 2)), (0, 1, row(0, 2), row(4, 2))],
        ));
        let s_new = ExchangePlan::Strided(StridedPlan::from_msgs(
            2,
            &[(1, 0, row(0, 4), row(4, 4)), (0, 1, row(0, 2), row(4, 2))],
        ));
        let d = PlanDelta::diff(&s_old, &s_new).unwrap();
        let text = d.to_json().compact();
        let back = PlanDelta::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(s_old.apply_delta(&back).unwrap().fingerprint(), s_new.fingerprint());
    }

    #[test]
    fn tampered_delta_is_rejected() {
        let old = gather_plan(&[vec![(1, 2), (2, 4)], vec![], vec![]]);
        let new = gather_plan(&[vec![(1, 3)], vec![], vec![]]);
        let d = PlanDelta::diff(&old, &new).unwrap();
        let mut v = d.to_json();
        v.set("base_fp", Value::Str("zz".into()));
        assert!(PlanDelta::from_json(&v).is_err());
        let mut v = d.to_json();
        v.set("form", Value::Str("mystery".into()));
        assert!(PlanDelta::from_json(&v).is_err());
    }

    #[test]
    fn patch_accounting_reports_delta_size() {
        let old = gather_plan(&[vec![(1, 2), (1, 3), (2, 4)], vec![], vec![(0, 0)]]);
        let new = gather_plan(&[vec![(1, 2), (1, 3), (2, 4), (2, 5)], vec![], vec![]]);
        let d = PlanDelta::diff(&old, &new).unwrap();
        // Dirty pairs: (0, 2) content change + (2, 0) removal.
        assert_eq!(d.dirty_pairs(), 2);
        assert_eq!(d.patch_values(), 2); // indices 4, 5; the removal adds 0
    }
}
