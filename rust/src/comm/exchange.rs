//! Workload-agnostic exchange plans: the compiled-communication idea of
//! §4.3.1 generalized past SpMV.
//!
//! The paper's methodology — analyze the access pattern once, compile it
//! into condensed/consolidated bulk messages, execute those messages through
//! per-thread local buffers every step — is "not limited to UPC" and, as §8
//! shows with the heat solver, not limited to irregular gathers either.
//! [`ExchangePlan`] captures that: one staging-arena contract with two
//! compiled forms.
//!
//! * [`ExchangePlan::Gather`] — the irregular form ([`CommPlan`]): sorted
//!   unique `x`-indices per `(sender, receiver)` pair, packed through
//!   pre-translated owner-local offsets (SpMV UPCv3, Listing 5).
//! * [`ExchangePlan::Strided`] — the regular form ([`StridedPlan`]): halo
//!   strips/faces as `(offset, stride, count)` block-copy descriptors
//!   compiled once from the grid geometry (heat-2D's Listing 7 pack /
//!   `upc_memget` / unpack, and the 3D stencil's faces).
//!
//! Both forms share the arena contract of [`CommPlan`]: every message owns a
//! `start..start+len` slot range in a flat staging buffer of
//! `total_values()` doubles; ranges tile the arena receiver-major. Senders
//! fill their ranges before the barrier, receivers drain them after — which
//! is what lets one engine ([`crate::engine::WorkerPool`] +
//! [`crate::engine::ArenaView`]) execute any compiled workload.

use super::plan::{json_u32s, json_usize, num_u32, u32s_to_json};
use super::CommPlan;
use crate::machine::SIZEOF_DOUBLE;
use crate::util::json::Value;
use std::ops::Range;

/// Decode one JSON number as a nonnegative integer index (fits `usize`).
fn num_us(v: &Value, what: &str) -> Result<usize, String> {
    let f = v.as_f64().ok_or_else(|| format!("{what}: not a number"))?;
    if f.fract() != 0.0 || !(0.0..=9.007_199_254_740_992e15).contains(&f) {
        return Err(format!("{what}: {f} is not an index"));
    }
    Ok(f as usize)
}

/// A strided 2-level block inside one thread's local field: element `(r, c)`
/// lives at `offset + r·row_stride + c·col_stride`.
///
/// Covers every halo shape the grid workloads need: a contiguous row strip
/// (`rows = 1, col_stride = 1`), a strided column (`cols = 1`), a 3D face
/// plane (both levels strided).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StridedBlock {
    pub offset: usize,
    pub rows: usize,
    pub row_stride: usize,
    pub cols: usize,
    pub col_stride: usize,
}

impl StridedBlock {
    /// A contiguous strip of `cols` elements at `offset`.
    pub fn row(offset: usize, cols: usize) -> StridedBlock {
        StridedBlock { offset, rows: 1, row_stride: 0, cols, col_stride: 1 }
    }

    /// A single strided column: `rows` elements spaced `stride` apart.
    pub fn column(offset: usize, rows: usize, stride: usize) -> StridedBlock {
        StridedBlock { offset, rows, row_stride: stride, cols: 1, col_stride: 1 }
    }

    /// A general 2-level plane (3D faces).
    pub fn plane(
        offset: usize,
        rows: usize,
        row_stride: usize,
        cols: usize,
        col_stride: usize,
    ) -> StridedBlock {
        StridedBlock { offset, rows, row_stride, cols, col_stride }
    }

    /// Number of elements the block covers.
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Highest element index touched, plus one (for bounds validation).
    pub fn end(&self) -> usize {
        if self.is_empty() {
            return self.offset;
        }
        self.offset + (self.rows - 1) * self.row_stride + (self.cols - 1) * self.col_stride + 1
    }

    /// Gather this block from `field` into `out` (the pack side of
    /// Listing 7). `out.len()` must equal `self.len()`.
    pub fn gather(&self, field: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.len());
        if self.col_stride == 1 {
            // Row chunks are contiguous — the `upc_memget` fast path.
            for (r, dst) in out.chunks_exact_mut(self.cols).enumerate() {
                let base = self.offset + r * self.row_stride;
                dst.copy_from_slice(&field[base..base + self.cols]);
            }
        } else {
            let mut k = 0;
            for r in 0..self.rows {
                let base = self.offset + r * self.row_stride;
                for c in 0..self.cols {
                    out[k] = field[base + c * self.col_stride];
                    k += 1;
                }
            }
        }
    }

    /// Scatter `vals` into this block of `field` (the unpack side).
    pub fn scatter(&self, vals: &[f64], field: &mut [f64]) {
        debug_assert_eq!(vals.len(), self.len());
        if self.col_stride == 1 {
            for (r, src) in vals.chunks_exact(self.cols).enumerate() {
                let base = self.offset + r * self.row_stride;
                field[base..base + self.cols].copy_from_slice(src);
            }
        } else {
            let mut k = 0;
            for r in 0..self.rows {
                let base = self.offset + r * self.row_stride;
                for c in 0..self.cols {
                    field[base + c * self.col_stride] = vals[k];
                    k += 1;
                }
            }
        }
    }
}

/// One compiled block-copy message's descriptor.
#[derive(Debug, Clone, Copy)]
struct StridedDesc {
    sender: u32,
    receiver: u32,
    /// Block in the sender's local field.
    src: StridedBlock,
    /// Block in the receiver's local field.
    dst: StridedBlock,
    /// First slot in the staging arena.
    start: u32,
}

/// A borrowed view of one compiled block-copy message.
#[derive(Debug, Clone, Copy)]
pub struct StridedMsg<'a> {
    /// The peer thread (receiver in a send list, sender in a recv list).
    pub peer: u32,
    /// Source block in the **sender's** local field.
    pub src: &'a StridedBlock,
    /// Destination block in the **receiver's** local field.
    pub dst: &'a StridedBlock,
    /// First slot of this message in the staging arena.
    pub start: usize,
}

impl StridedMsg<'_> {
    /// Number of values carried.
    pub fn len(&self) -> usize {
        self.src.len()
    }

    pub fn is_empty(&self) -> bool {
        self.src.is_empty()
    }

    /// This message's slot range in the staging arena.
    pub fn range(&self) -> Range<usize> {
        self.start..self.start + self.len()
    }

    /// Pack: gather the source block from the sender's field into this
    /// message's arena slots.
    pub fn pack(&self, sender_field: &[f64], arena_slots: &mut [f64]) {
        self.src.gather(sender_field, arena_slots);
    }

    /// Unpack: scatter this message's arena slots into the destination
    /// block of the receiver's field.
    pub fn unpack(&self, arena_slots: &[f64], receiver_field: &mut [f64]) {
        self.dst.scatter(arena_slots, receiver_field);
    }
}

/// The compiled strided block-copy plan: the regular-workload counterpart of
/// [`CommPlan`], sharing its arena contract.
#[derive(Debug, Clone, Default)]
pub struct StridedPlan {
    threads: usize,
    /// Descriptors in arena (receiver-major) order; ranges are consecutive
    /// and partition `0..total`.
    msgs: Vec<StridedDesc>,
    /// `msgs[recv_off[t]..recv_off[t+1]]` are the messages received by `t`.
    recv_off: Vec<u32>,
    /// `send_ids[send_off[t]..send_off[t+1]]` index the messages sent by `t`.
    send_off: Vec<u32>,
    send_ids: Vec<u32>,
    total: usize,
}

impl StridedPlan {
    /// Compile from `(sender, receiver, src, dst)` copies. Messages are laid
    /// out receiver-major in the arena (stable within a receiver, so the
    /// caller's neighbour order is the unpack order). Each `src`/`dst` pair
    /// must carry the same number of values.
    pub fn from_msgs(
        threads: usize,
        copies: &[(usize, usize, StridedBlock, StridedBlock)],
    ) -> StridedPlan {
        let mut order: Vec<usize> = (0..copies.len()).collect();
        order.sort_by_key(|&i| copies[i].1); // stable: keeps per-receiver order
        let mut msgs = Vec::with_capacity(copies.len());
        let mut recv_off = vec![0u32; threads + 1];
        let mut total = 0usize;
        for &i in &order {
            let (sender, receiver, src, dst) = copies[i];
            assert!(sender < threads && receiver < threads, "thread id out of range");
            assert_ne!(sender, receiver, "self-message in a strided plan");
            assert_eq!(src.len(), dst.len(), "src/dst length mismatch");
            msgs.push(StridedDesc {
                sender: sender as u32,
                receiver: receiver as u32,
                src,
                dst,
                start: total as u32,
            });
            total += src.len();
            recv_off[receiver + 1] += 1;
        }
        for t in 0..threads {
            recv_off[t + 1] += recv_off[t];
        }
        // Sender-side CSR permutation over message ids, arena order within a
        // sender.
        let mut send_off = vec![0u32; threads + 1];
        for m in &msgs {
            send_off[m.sender as usize + 1] += 1;
        }
        for t in 0..threads {
            send_off[t + 1] += send_off[t];
        }
        let mut cursor = send_off[..threads].to_vec();
        let mut send_ids = vec![0u32; msgs.len()];
        for (id, m) in msgs.iter().enumerate() {
            let c = &mut cursor[m.sender as usize];
            send_ids[*c as usize] = id as u32;
            *c += 1;
        }
        StridedPlan { threads, msgs, recv_off, send_off, send_ids, total }
    }

    fn view<'a>(&'a self, m: &'a StridedDesc, peer: u32) -> StridedMsg<'a> {
        StridedMsg { peer, src: &m.src, dst: &m.dst, start: m.start as usize }
    }

    /// Number of threads the plan was compiled for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Messages thread `t` unpacks, in compile (neighbour) order.
    pub fn recv_msgs(&self, t: usize) -> impl Iterator<Item = StridedMsg<'_>> + '_ {
        self.msgs[self.recv_off[t] as usize..self.recv_off[t + 1] as usize]
            .iter()
            .map(move |m| self.view(m, m.sender))
    }

    /// Every compiled copy as `(sender, receiver, src, dst)` in arena
    /// (receiver-major) order — the inverse of
    /// [`from_msgs`](StridedPlan::from_msgs). The plan optimizer uses this
    /// to regroup blocks and re-emit a consolidated plan.
    pub fn copies(&self) -> Vec<(usize, usize, StridedBlock, StridedBlock)> {
        self.msgs.iter().map(|m| (m.sender as usize, m.receiver as usize, m.src, m.dst)).collect()
    }

    /// Messages thread `t` packs, in arena order.
    pub fn send_msgs(&self, t: usize) -> impl Iterator<Item = StridedMsg<'_>> + '_ {
        self.send_ids[self.send_off[t] as usize..self.send_off[t + 1] as usize]
            .iter()
            .map(move |&id| {
                let m = &self.msgs[id as usize];
                self.view(m, m.receiver)
            })
    }

    /// Total values exchanged per step (the staging-arena length).
    pub fn total_values(&self) -> usize {
        self.total
    }

    /// Total number of compiled messages.
    pub fn num_messages(&self) -> usize {
        self.msgs.len()
    }

    /// Number of messages thread `t` sends.
    pub fn messages_from(&self, t: usize) -> usize {
        (self.send_off[t + 1] - self.send_off[t]) as usize
    }

    /// Number of messages thread `t` receives.
    pub fn messages_to(&self, t: usize) -> usize {
        (self.recv_off[t + 1] - self.recv_off[t]) as usize
    }

    /// Payload bytes crossing thread boundaries per executed step.
    pub fn payload_bytes(&self) -> u64 {
        (self.total * SIZEOF_DOUBLE) as u64
    }

    /// Structural FNV-1a fingerprint: thread count plus every message's
    /// endpoints and src/dst block geometry, in arena order. Stable across
    /// runs (no RNG, no addresses) — the counterpart of
    /// [`CommPlan::fingerprint`](crate::comm::CommPlan::fingerprint) for the
    /// checkpoint/restart layer.
    pub fn fingerprint(&self) -> u64 {
        fn write_block(h: &mut crate::util::Fnv64, b: &StridedBlock) {
            h.write_usize(b.offset);
            h.write_usize(b.rows);
            h.write_usize(b.row_stride);
            h.write_usize(b.cols);
            h.write_usize(b.col_stride);
        }
        let mut h = crate::util::Fnv64::new();
        h.write_usize(self.threads);
        h.write_usize(self.msgs.len());
        for m in &self.msgs {
            h.write_u64(m.sender as u64);
            h.write_u64(m.receiver as u64);
            write_block(&mut h, &m.src);
            write_block(&mut h, &m.dst);
        }
        h.finish()
    }

    /// Serialize for shipping to worker processes (`repro launch`): every
    /// structural field verbatim, so the deserialized plan fingerprints
    /// identically. Each message is a flat 13-number array
    /// `[sender, receiver, start, src×5, dst×5]` (blocks as
    /// `offset, rows, row_stride, cols, col_stride`).
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("threads", Value::Num(self.threads as f64));
        v.set("total", Value::Num(self.total as f64));
        let msgs: Vec<Value> = self
            .msgs
            .iter()
            .map(|m| {
                let mut nums = vec![m.sender as f64, m.receiver as f64, m.start as f64];
                for b in [&m.src, &m.dst] {
                    nums.extend([
                        b.offset as f64,
                        b.rows as f64,
                        b.row_stride as f64,
                        b.cols as f64,
                        b.col_stride as f64,
                    ]);
                }
                Value::Arr(nums.into_iter().map(Value::Num).collect())
            })
            .collect();
        v.set("msgs", Value::Arr(msgs));
        v.set("recv_off", u32s_to_json(&self.recv_off));
        v.set("send_off", u32s_to_json(&self.send_off));
        v.set("send_ids", u32s_to_json(&self.send_ids));
        v
    }

    /// Deserialize a shipped plan, re-running the structural half of
    /// [`validate`](StridedPlan::validate) (field lengths are unknown here)
    /// so a tampered or truncated wire form is rejected instead of trusted.
    pub fn from_json(v: &Value) -> Result<StridedPlan, String> {
        let threads = json_usize(v, "threads")?;
        let total = num_us(v.get("total").ok_or("total: missing")?, "total")?;
        let raw = v.get("msgs").and_then(Value::as_arr).ok_or("msgs: not an array")?;
        let mut msgs = Vec::with_capacity(raw.len());
        for (i, m) in raw.iter().enumerate() {
            let q = m
                .as_arr()
                .filter(|q| q.len() == 13)
                .ok_or_else(|| format!("msgs[{i}]: want 13 numbers"))?;
            let block = |at: usize| -> Result<StridedBlock, String> {
                Ok(StridedBlock {
                    offset: num_us(&q[at], "block.offset")?,
                    rows: num_us(&q[at + 1], "block.rows")?,
                    row_stride: num_us(&q[at + 2], "block.row_stride")?,
                    cols: num_us(&q[at + 3], "block.cols")?,
                    col_stride: num_us(&q[at + 4], "block.col_stride")?,
                })
            };
            msgs.push(StridedDesc {
                sender: num_u32(&q[0], "msgs.sender")?,
                receiver: num_u32(&q[1], "msgs.receiver")?,
                start: num_u32(&q[2], "msgs.start")?,
                src: block(3)?,
                dst: block(8)?,
            });
        }
        let recv_off = json_u32s(v, "recv_off")?;
        let send_off = json_u32s(v, "send_off")?;
        let send_ids = json_u32s(v, "send_ids")?;
        // Bounds guards [`validate`](StridedPlan::validate) assumes: it
        // slices by these tables, so a hostile wire form must fail here.
        if send_ids.iter().any(|&id| id as usize >= msgs.len()) {
            return Err("send_ids names a message out of range".into());
        }
        let bounded = |off: &[u32], n: usize| {
            off.len() == threads + 1
                && off.windows(2).all(|w| w[0] <= w[1])
                && off.last().is_some_and(|&e| e as usize == n)
        };
        if !bounded(&recv_off, msgs.len()) || !bounded(&send_off, send_ids.len()) {
            return Err("offset tables malformed".into());
        }
        let plan = StridedPlan { threads, msgs, recv_off, send_off, send_ids, total };
        plan.validate(&|_| usize::MAX)
            .map_err(|e| format!("shipped strided plan invalid: {e}"))?;
        Ok(plan)
    }

    /// Consistency check: arena tiling, offset tables, block bounds against
    /// per-thread field lengths, the send-side permutation, no zero-count
    /// blocks, and per-receiver destination blocks that never overlap.
    pub fn validate(&self, field_len: &dyn Fn(usize) -> usize) -> Result<(), String> {
        let threads = self.threads;
        if self.recv_off.len() != threads + 1 || self.send_off.len() != threads + 1 {
            return Err("offset table arity".into());
        }
        if self.send_ids.len() != self.msgs.len() {
            return Err("send permutation arity".into());
        }
        if self.recv_off[threads] as usize != self.msgs.len()
            || self.send_off[threads] as usize != self.send_ids.len()
        {
            return Err("offset tables do not cover all messages".into());
        }
        let mut cursor = 0usize;
        for (id, m) in self.msgs.iter().enumerate() {
            if m.sender == m.receiver {
                return Err(format!("message {id} is a self-message ({})", m.sender));
            }
            if m.sender as usize >= threads || m.receiver as usize >= threads {
                return Err(format!("message {id} names an out-of-range thread"));
            }
            if m.src.is_empty() || m.dst.is_empty() {
                return Err(format!("message {id} carries a zero-count block"));
            }
            if m.start as usize != cursor {
                return Err(format!("message {id} breaks the arena tiling"));
            }
            if m.src.len() != m.dst.len() {
                return Err(format!("message {id} src/dst length mismatch"));
            }
            if m.src.end() > field_len(m.sender as usize) {
                return Err(format!("message {id} src block exceeds the sender's field"));
            }
            if m.dst.end() > field_len(m.receiver as usize) {
                return Err(format!("message {id} dst block exceeds the receiver's field"));
            }
            cursor += m.src.len();
        }
        if cursor != self.total {
            return Err("arena not fully covered by messages".into());
        }
        for t in 0..threads {
            if self.recv_off[t] > self.recv_off[t + 1] || self.send_off[t] > self.send_off[t + 1] {
                return Err(format!("offsets not monotone at thread {t}"));
            }
            for m in &self.msgs[self.recv_off[t] as usize..self.recv_off[t + 1] as usize] {
                if m.receiver as usize != t {
                    return Err(format!("recv list of {t} holds a foreign message"));
                }
            }
            for &id in &self.send_ids[self.send_off[t] as usize..self.send_off[t + 1] as usize] {
                if self.msgs[id as usize].sender as usize != t {
                    return Err(format!("send list of {t} holds a foreign message"));
                }
            }
        }
        let mut seen = vec![false; self.msgs.len()];
        for &id in &self.send_ids {
            let slot = &mut seen[id as usize];
            if *slot {
                return Err(format!("message {id} sent twice"));
            }
            *slot = true;
        }
        // No receiver's destination blocks may overlap: the unpack order
        // would silently decide which value wins, and the optimizer's
        // regrouping relies on destination cells being disjoint.
        for t in 0..threads {
            let mut cells: Vec<usize> = self.msgs
                [self.recv_off[t] as usize..self.recv_off[t + 1] as usize]
                .iter()
                .flat_map(|m| block_cells(&m.dst))
                .collect();
            cells.sort_unstable();
            if let Some(w) = cells.windows(2).find(|w| w[0] == w[1]) {
                return Err(format!("receiver {t}: destination cell {} written twice", w[0]));
            }
        }
        Ok(())
    }
}

/// The interior/boundary decomposition of one thread's owned compute cells,
/// compiled once from the subdomain geometry alongside the exchange plan.
///
/// *Interior* cells read no halo value, so their update can overlap the
/// in-flight exchange of a split-phase step (`begin_exchange` → interior
/// compute → `finish_exchange` → boundary compute). *Boundary* cells sit
/// within stencil reach of the halo and must wait for `finish_exchange`.
/// The split is purely geometric — every owned cell appears in exactly one
/// block of exactly one of the two sets — so an overlapped step computes
/// each cell once with the same expression as the synchronous step, keeping
/// the results bitwise identical.
#[derive(Debug, Clone, Default)]
pub struct ComputeSplit {
    /// Cells with no halo dependence (safe to update before the exchange
    /// completes). Empty when the owned region is too thin to have any.
    pub interior: Vec<StridedBlock>,
    /// Cells within one stencil radius of the subdomain edge.
    pub boundary: Vec<StridedBlock>,
}

impl ComputeSplit {
    /// Split a 2D halo-extended `m × n` subdomain (owned region
    /// `(1..m−1) × (1..n−1)`, 5-point stencil). Handles degenerate shapes:
    /// a 1-cell-thick owned region is all boundary.
    pub fn grid2d(m: usize, n: usize) -> ComputeSplit {
        assert!(m >= 3 && n >= 3, "subdomain {m}x{n} has no owned cells");
        let mut split = ComputeSplit::default();
        split.push_plane_split(0, n, m, n);
        split
    }

    /// Split a 3D halo-extended `p × m × n` subdomain (owned region
    /// `(1..p−1) × (1..m−1) × (1..n−1)`, 7-point stencil). The outermost
    /// owned x-planes are boundary; each middle x-slab splits like a 2D
    /// plane.
    pub fn grid3d(p: usize, m: usize, n: usize) -> ComputeSplit {
        assert!(p >= 3 && m >= 3 && n >= 3, "subdomain {p}x{m}x{n} has no owned cells");
        let mn = m * n;
        let mut split = ComputeSplit::default();
        // Owned interior of plane x: rows 1..m−1, cols 1..n−1.
        let owned_plane = |x: usize| StridedBlock::plane(x * mn + n + 1, m - 2, n, n - 2, 1);
        split.boundary.push(owned_plane(1));
        if p - 2 > 1 {
            split.boundary.push(owned_plane(p - 2));
        }
        for x in 2..p.saturating_sub(2) {
            split.push_plane_split(x * mn, n, m, n);
        }
        split
    }

    /// Split one owned plane at `base` (rows `1..m−1` × cols `1..n−1`, row
    /// stride `stride`): the one-cell ring goes to boundary, the rest to
    /// interior.
    fn push_plane_split(&mut self, base: usize, stride: usize, m: usize, n: usize) {
        // Top owned row; bottom owned row when distinct.
        self.boundary.push(StridedBlock::row(base + stride + 1, n - 2));
        if m - 2 > 1 {
            self.boundary.push(StridedBlock::row(base + (m - 2) * stride + 1, n - 2));
        }
        let mid_rows = m.saturating_sub(4); // rows 2..=m−3
        if mid_rows == 0 {
            return;
        }
        self.boundary.push(StridedBlock::column(base + 2 * stride + 1, mid_rows, stride));
        if n - 2 > 1 {
            self.boundary.push(StridedBlock::column(base + 2 * stride + (n - 2), mid_rows, stride));
        }
        let mid_cols = n.saturating_sub(4);
        if mid_cols > 0 {
            let inner = StridedBlock::plane(base + 2 * stride + 2, mid_rows, stride, mid_cols, 1);
            self.interior.push(inner);
        }
    }

    /// The owned compute region of a 2D halo-extended `m × n` subdomain
    /// (rows `1..m−1` × cols `1..n−1`) — the canonical reference
    /// [`ComputeSplit::validate`] checks a [`grid2d`](ComputeSplit::grid2d)
    /// split against.
    pub fn owned2d(m: usize, n: usize) -> Vec<StridedBlock> {
        vec![StridedBlock::plane(n + 1, m - 2, n, n - 2, 1)]
    }

    /// The owned compute region of a 3D halo-extended `p × m × n` box: the
    /// interior of every owned x-plane.
    pub fn owned3d(p: usize, m: usize, n: usize) -> Vec<StridedBlock> {
        let mn = m * n;
        (1..p - 1).map(|x| StridedBlock::plane(x * mn + n + 1, m - 2, n, n - 2, 1)).collect()
    }

    /// Cells in the interior set.
    pub fn interior_cells(&self) -> usize {
        self.interior.iter().map(StridedBlock::len).sum()
    }

    /// Cells in the boundary set.
    pub fn boundary_cells(&self) -> usize {
        self.boundary.iter().map(StridedBlock::len).sum()
    }

    /// The split validator: every block within `field_len`, and
    /// interior ∪ boundary covers each cell of `owned` **exactly once**
    /// (no overlap, no gap). O(field_len) — debug builds and tests.
    pub fn validate(&self, owned: &[StridedBlock], field_len: usize) -> Result<(), String> {
        let mut count = vec![0u8; field_len];
        for (what, blocks) in [("interior", &self.interior), ("boundary", &self.boundary)] {
            for b in blocks {
                if b.is_empty() {
                    return Err(format!("{what} holds an empty block {b:?}"));
                }
                if b.end() > field_len {
                    return Err(format!("{what} block {b:?} exceeds field length {field_len}"));
                }
                for c in block_cells(b) {
                    if count[c] != 0 {
                        return Err(format!("cell {c} covered twice (second in {what})"));
                    }
                    count[c] = 1;
                }
            }
        }
        let mut owned_cells = 0usize;
        for b in owned {
            if b.end() > field_len {
                return Err(format!("owned block {b:?} exceeds field length {field_len}"));
            }
            for c in block_cells(b) {
                owned_cells += 1;
                if count[c] == 0 {
                    return Err(format!("owned cell {c} not covered by the split"));
                }
            }
        }
        let covered = self.interior_cells() + self.boundary_cells();
        if covered != owned_cells {
            return Err(format!("split covers {covered} cells, owned region has {owned_cells}"));
        }
        Ok(())
    }
}

/// All cell indices a block touches, in gather order.
pub(crate) fn block_cells(b: &StridedBlock) -> impl Iterator<Item = usize> + '_ {
    (0..b.rows).flat_map(move |r| {
        (0..b.cols).map(move |c| b.offset + r * b.row_stride + c * b.col_stride)
    })
}

/// A compiled exchange plan in one of its two forms. The common interface
/// is the accounting + arena contract; executors match on the form for the
/// pack/unpack semantics.
#[derive(Debug, Clone)]
pub enum ExchangePlan {
    /// Irregular indexed gather (SpMV UPCv3).
    Gather(CommPlan),
    /// Regular strided block copies (halo exchange).
    Strided(StridedPlan),
}

impl ExchangePlan {
    pub fn name(&self) -> &'static str {
        match self {
            ExchangePlan::Gather(_) => "gather",
            ExchangePlan::Strided(_) => "strided",
        }
    }

    /// Number of threads the plan was compiled for.
    pub fn threads(&self) -> usize {
        match self {
            ExchangePlan::Gather(p) => p.threads(),
            ExchangePlan::Strided(p) => p.threads(),
        }
    }

    /// Total values exchanged per step — the staging-arena length shared by
    /// both forms.
    pub fn total_values(&self) -> usize {
        match self {
            ExchangePlan::Gather(p) => p.total_values(),
            ExchangePlan::Strided(p) => p.total_values(),
        }
    }

    /// Total number of consolidated messages per step.
    pub fn num_messages(&self) -> usize {
        match self {
            ExchangePlan::Gather(p) => p.num_messages(),
            ExchangePlan::Strided(p) => p.num_messages(),
        }
    }

    /// Payload bytes crossing thread boundaries per executed step.
    pub fn payload_bytes(&self) -> u64 {
        (self.total_values() * SIZEOF_DOUBLE) as u64
    }

    /// Form-dispatched consistency check. `field_len(t)` bounds thread t's
    /// local field for the strided form (pass `|_| usize::MAX` when the
    /// field lengths are unknown — structural checks still run); the gather
    /// form validates against its own layout-derived invariants.
    pub fn validate(&self, field_len: &dyn Fn(usize) -> usize) -> Result<(), String> {
        match self {
            ExchangePlan::Gather(p) => p.validate(),
            ExchangePlan::Strided(p) => p.validate(field_len),
        }
    }

    /// Structural FNV-1a fingerprint: a form tag followed by the form's own
    /// fingerprint, so a gather plan and a strided plan can never collide by
    /// construction. Stable across runs and processes; used by the
    /// checkpoint layer to refuse restoring onto a different plan.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::Fnv64::new();
        match self {
            ExchangePlan::Gather(p) => {
                h.write_u8(1);
                h.write_u64(p.fingerprint());
            }
            ExchangePlan::Strided(p) => {
                h.write_u8(2);
                h.write_u64(p.fingerprint());
            }
        }
        h.finish()
    }

    /// Serialize for shipping to worker processes: a `form` tag plus the
    /// form's own wire object. Round-trips to an identical
    /// [`fingerprint`](ExchangePlan::fingerprint).
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("form", Value::Str(self.name().to_string()));
        let plan = match self {
            ExchangePlan::Gather(p) => p.to_json(),
            ExchangePlan::Strided(p) => p.to_json(),
        };
        v.set("plan", plan);
        v
    }

    /// Deserialize a shipped plan of either form; the form's `from_json`
    /// re-validates, so tampered wire forms are rejected.
    pub fn from_json(v: &Value) -> Result<ExchangePlan, String> {
        let form = v.get("form").and_then(Value::as_str).ok_or("form: missing")?;
        let plan = v.get("plan").ok_or("plan: missing")?;
        match form {
            "gather" => Ok(ExchangePlan::Gather(CommPlan::from_json(plan)?)),
            "strided" => Ok(ExchangePlan::Strided(StridedPlan::from_json(plan)?)),
            other => Err(format!("unknown plan form {other:?}")),
        }
    }

    pub fn as_strided(&self) -> Option<&StridedPlan> {
        match self {
            ExchangePlan::Strided(p) => Some(p),
            _ => None,
        }
    }

    pub fn as_gather(&self) -> Option<&CommPlan> {
        match self {
            ExchangePlan::Gather(p) => Some(p),
            _ => None,
        }
    }
}

impl From<CommPlan> for ExchangePlan {
    fn from(p: CommPlan) -> ExchangePlan {
        ExchangePlan::Gather(p)
    }
}

impl From<StridedPlan> for ExchangePlan {
    fn from(p: StridedPlan) -> ExchangePlan {
        ExchangePlan::Strided(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_gather_scatter_roundtrip() {
        // A 4×5 field; gather its strided column 2 and scatter it back into
        // column 0.
        let field: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let col2 = StridedBlock::column(2, 4, 5);
        assert_eq!(col2.len(), 4);
        assert_eq!(col2.end(), 18);
        let mut buf = vec![0.0; 4];
        col2.gather(&field, &mut buf);
        assert_eq!(buf, vec![2.0, 7.0, 12.0, 17.0]);
        let mut dst = field.clone();
        StridedBlock::column(0, 4, 5).scatter(&buf, &mut dst);
        assert_eq!(dst[0], 2.0);
        assert_eq!(dst[5], 7.0);
        assert_eq!(dst[15], 17.0);

        // A contiguous row strip.
        let row = StridedBlock::row(6, 3);
        let mut buf = vec![0.0; 3];
        row.gather(&field, &mut buf);
        assert_eq!(buf, vec![6.0, 7.0, 8.0]);

        // A doubly-strided plane (every other element of two rows).
        let plane = StridedBlock::plane(0, 2, 10, 3, 2);
        let mut buf = vec![0.0; 6];
        plane.gather(&field, &mut buf);
        assert_eq!(buf, vec![0.0, 2.0, 4.0, 10.0, 12.0, 14.0]);
        let mut dst = vec![0.0; 20];
        plane.scatter(&buf, &mut dst);
        assert_eq!(dst[2], 2.0);
        assert_eq!(dst[14], 14.0);
        assert_eq!(dst[1], 0.0);
    }

    #[test]
    fn strided_plan_compiles_receiver_major() {
        // 3 threads in a ring of length-2 row strips.
        let strip = |o| StridedBlock::row(o, 2);
        let copies = vec![
            (1usize, 0usize, strip(0), strip(4)),
            (2, 1, strip(0), strip(4)),
            (0, 2, strip(0), strip(4)),
        ];
        let plan = StridedPlan::from_msgs(3, &copies);
        plan.validate(&|_| 6).unwrap();
        assert_eq!(plan.total_values(), 6);
        assert_eq!(plan.num_messages(), 3);
        assert_eq!(plan.payload_bytes(), 48);
        // Receiver-major arena order.
        let starts: Vec<usize> = (0..3).flat_map(|t| plan.recv_msgs(t).map(|m| m.start)).collect();
        assert_eq!(starts, vec![0, 2, 4]);
        // Send side is a permutation of the same descriptors.
        let s0: Vec<_> = plan.send_msgs(0).collect();
        assert_eq!(s0.len(), 1);
        assert_eq!(s0[0].peer, 2);
        assert_eq!(s0[0].range(), 4..6);
        assert_eq!(plan.messages_from(1), 1);
        assert_eq!(plan.messages_to(1), 1);
    }

    #[test]
    fn strided_plan_moves_values_end_to_end() {
        // Two threads exchange their first interior column (3×4 fields).
        let n = 4;
        let copies = vec![
            (0usize, 1usize, StridedBlock::column(2, 3, n), StridedBlock::column(0, 3, n)),
            (1, 0, StridedBlock::column(1, 3, n), StridedBlock::column(3, 3, n)),
        ];
        let plan = StridedPlan::from_msgs(2, &copies);
        plan.validate(&|_| 12).unwrap();
        let mut fields = vec![
            (0..12).map(|i| i as f64).collect::<Vec<_>>(),
            (0..12).map(|i| (100 + i) as f64).collect::<Vec<_>>(),
        ];
        let mut arena = vec![0.0; plan.total_values()];
        for t in 0..2 {
            for m in plan.send_msgs(t) {
                let r = m.range();
                m.pack(&fields[t], &mut arena[r]);
            }
        }
        for t in 0..2 {
            for m in plan.recv_msgs(t) {
                let r = m.range();
                let vals = arena[r].to_vec();
                m.unpack(&vals, &mut fields[t]);
            }
        }
        // Thread 1's column 0 got thread 0's column 2: values 2, 6, 10.
        assert_eq!(fields[1][0], 2.0);
        assert_eq!(fields[1][4], 6.0);
        assert_eq!(fields[1][8], 10.0);
        // Thread 0's column 3 got thread 1's column 1: 101, 105, 109.
        assert_eq!(fields[0][3], 101.0);
        assert_eq!(fields[0][7], 105.0);
        assert_eq!(fields[0][11], 109.0);
    }

    #[test]
    fn validate_catches_out_of_bounds_blocks() {
        let copies =
            vec![(0usize, 1usize, StridedBlock::row(0, 4), StridedBlock::row(0, 4))];
        let plan = StridedPlan::from_msgs(2, &copies);
        assert!(plan.validate(&|_| 4).is_ok());
        assert!(plan.validate(&|_| 3).is_err());
    }

    #[test]
    fn validate_rejects_overlapping_destinations_and_empty_blocks() {
        // Two messages to thread 1 whose destination rows share cell 4.
        let copies = vec![
            (0usize, 1usize, StridedBlock::row(0, 3), StridedBlock::row(2, 3)),
            (2, 1, StridedBlock::row(0, 3), StridedBlock::row(4, 3)),
        ];
        let plan = StridedPlan::from_msgs(3, &copies);
        let err = plan.validate(&|_| 16).unwrap_err();
        assert!(err.contains("written twice"), "{err}");
        // Overlapping *source* blocks are legal (two receivers may want the
        // same cells); only destinations must stay disjoint.
        let copies = vec![
            (0usize, 1usize, StridedBlock::row(0, 3), StridedBlock::row(2, 3)),
            (0, 2, StridedBlock::row(0, 3), StridedBlock::row(2, 3)),
        ];
        StridedPlan::from_msgs(3, &copies).validate(&|_| 16).unwrap();
        // A zero-count block is rejected explicitly.
        let copies = vec![(0usize, 1usize, StridedBlock::row(0, 0), StridedBlock::row(0, 0))];
        let plan = StridedPlan::from_msgs(2, &copies);
        let err = plan.validate(&|_| 16).unwrap_err();
        assert!(err.contains("zero-count"), "{err}");
    }

    /// Property: randomly generated disjoint-destination plans validate, and
    /// injecting an overlapping or zero-count block is always caught.
    #[test]
    fn prop_random_block_sets_validate() {
        crate::testing::check_prop(
            "strided-validate-blocks",
            32,
            |r| {
                let threads = r.usize_in(2, 5);
                let grid_rows = r.usize_in(4, 16);
                let cols = r.usize_in(4, 16);
                let mut copies: Vec<(usize, usize, StridedBlock, StridedBlock)> = Vec::new();
                for recv in 0..threads {
                    // Disjoint row bands per receiver guarantee disjoint
                    // destinations; sources may overlap freely.
                    let mut row = 0usize;
                    while row < grid_rows && r.bool(0.8) {
                        let h = r.usize_in(1, 4).min(grid_rows - row);
                        let w = r.usize_in(1, cols);
                        let off = r.usize_in(0, cols - w + 1);
                        let sender = (recv + r.usize_in(1, threads)) % threads;
                        let dst = StridedBlock::plane(row * cols + off, h, cols, w, 1);
                        let src = StridedBlock::plane(off, h, cols, w, 1);
                        copies.push((sender, recv, src, dst));
                        row += h;
                    }
                }
                (threads, grid_rows * cols, copies)
            },
            |(threads, field_len, copies)| {
                let plan = StridedPlan::from_msgs(*threads, copies);
                plan.validate(&|_| *field_len)
                    .map_err(|e| format!("clean plan rejected: {e}"))?;
                if copies.is_empty() {
                    return Ok(());
                }
                // Duplicate a copy → its destination cells are written twice.
                let mut dup = copies.clone();
                dup.push(dup[0]);
                let plan = StridedPlan::from_msgs(*threads, &dup);
                if plan.validate(&|_| *field_len).is_ok() {
                    return Err("duplicated destination not caught".into());
                }
                // Zero-count block → explicit rejection.
                let mut empty = copies.clone();
                let (s, rcv, _, _) = empty[0];
                empty[0] = (s, rcv, StridedBlock::row(0, 0), StridedBlock::row(0, 0));
                let plan = StridedPlan::from_msgs(*threads, &empty);
                if plan.validate(&|_| *field_len).is_ok() {
                    return Err("zero-count block not caught".into());
                }
                Ok(())
            },
        );
    }

    fn owned2d(m: usize, n: usize) -> Vec<StridedBlock> {
        ComputeSplit::owned2d(m, n)
    }

    fn owned3d(p: usize, m: usize, n: usize) -> Vec<StridedBlock> {
        ComputeSplit::owned3d(p, m, n)
    }

    #[test]
    fn split2d_covers_exactly() {
        for (m, n) in [(5usize, 7usize), (3, 3), (3, 9), (9, 3), (4, 4), (5, 4), (64, 48)] {
            let split = ComputeSplit::grid2d(m, n);
            split.validate(&owned2d(m, n), m * n).unwrap_or_else(|e| panic!("{m}x{n}: {e}"));
            assert_eq!(split.interior_cells() + split.boundary_cells(), (m - 2) * (n - 2));
        }
        // Known interior size on a comfortable subdomain.
        let split = ComputeSplit::grid2d(10, 12);
        assert_eq!(split.interior_cells(), 6 * 8);
        assert_eq!(split.boundary_cells(), 8 * 10 - 6 * 8);
        // 1-cell-thick owned regions have no interior.
        assert_eq!(ComputeSplit::grid2d(3, 20).interior_cells(), 0);
        assert_eq!(ComputeSplit::grid2d(20, 3).interior_cells(), 0);
        // The degenerate 1×1 owned region (1-cell interior of the issue
        // statement: a single owned cell, all boundary).
        let tiny = ComputeSplit::grid2d(3, 3);
        assert_eq!(tiny.boundary_cells(), 1);
    }

    #[test]
    fn split3d_covers_exactly() {
        for (p, m, n) in [
            (5usize, 6usize, 7usize),
            (3, 3, 3),
            (3, 8, 8),
            (8, 3, 8),
            (8, 8, 3),
            (4, 4, 4),
            (6, 5, 9),
        ] {
            let split = ComputeSplit::grid3d(p, m, n);
            split
                .validate(&owned3d(p, m, n), p * m * n)
                .unwrap_or_else(|e| panic!("{p}x{m}x{n}: {e}"));
            assert_eq!(
                split.interior_cells() + split.boundary_cells(),
                (p - 2) * (m - 2) * (n - 2)
            );
        }
        let split = ComputeSplit::grid3d(8, 8, 8);
        assert_eq!(split.interior_cells(), 4 * 4 * 4);
    }

    #[test]
    fn split_validator_catches_overlap_and_gap() {
        let mut split = ComputeSplit::grid2d(6, 6);
        let owned = owned2d(6, 6);
        split.validate(&owned, 36).unwrap();
        // Duplicate a boundary block → double coverage.
        let dup = split.boundary[0];
        split.boundary.push(dup);
        assert!(split.validate(&owned, 36).is_err());
        // Drop the interior → gap.
        let mut split = ComputeSplit::grid2d(6, 6);
        split.interior.clear();
        assert!(split.validate(&owned, 36).is_err());
        // Out-of-bounds field.
        let split = ComputeSplit::grid2d(6, 6);
        assert!(split.validate(&owned, 20).is_err());
    }

    #[test]
    fn exchange_plan_validate_dispatches() {
        let strided = StridedPlan::from_msgs(
            2,
            &[(0, 1, StridedBlock::row(0, 3), StridedBlock::row(3, 3))],
        );
        let plan: ExchangePlan = strided.into();
        assert!(plan.validate(&|_| 6).is_ok());
        assert!(plan.validate(&|_| 2).is_err());
        let layout = crate::pgas::Layout::new(4, 2, 2);
        let gather = CommPlan::from_recv_needs(&layout, &[vec![(1u32, 2u32)], vec![]]);
        let plan: ExchangePlan = gather.into();
        assert!(plan.validate(&|_| usize::MAX).is_ok());
    }

    #[test]
    fn strided_json_roundtrip_preserves_fingerprint() {
        let n = 4;
        let copies = vec![
            (0usize, 1usize, StridedBlock::column(2, 3, n), StridedBlock::column(0, 3, n)),
            (1, 0, StridedBlock::column(1, 3, n), StridedBlock::column(3, 3, n)),
        ];
        let plan = StridedPlan::from_msgs(2, &copies);
        let text = plan.to_json().compact();
        let back = StridedPlan::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.fingerprint(), plan.fingerprint());
        assert_eq!(back.total_values(), plan.total_values());
        back.validate(&|_| 12).unwrap();
    }

    #[test]
    fn exchange_plan_json_roundtrip_both_forms() {
        let strided: ExchangePlan = StridedPlan::from_msgs(
            2,
            &[(0, 1, StridedBlock::row(0, 3), StridedBlock::row(3, 3))],
        )
        .into();
        let layout = crate::pgas::Layout::new(4, 2, 2);
        let gather: ExchangePlan =
            CommPlan::from_recv_needs(&layout, &[vec![(1u32, 2u32)], vec![]]).into();
        for plan in [strided, gather] {
            let text = plan.to_json().compact();
            let back = ExchangePlan::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.fingerprint(), plan.fingerprint(), "{} round-trip", plan.name());
            assert_eq!(back.name(), plan.name());
        }
    }

    #[test]
    fn tampered_strided_json_is_rejected() {
        let plan = StridedPlan::from_msgs(
            2,
            &[(0, 1, StridedBlock::row(0, 3), StridedBlock::row(3, 3))],
        );
        // Arena total no longer matches the message tiling.
        let mut v = plan.to_json();
        v.set("total", Value::Num(99.0));
        assert!(StridedPlan::from_json(&v).is_err());
        // Send permutation points out of range.
        let mut v = plan.to_json();
        v.set("send_ids", u32s_to_json(&[7]));
        assert!(StridedPlan::from_json(&v).is_err());
        // Unknown form tag at the ExchangePlan level.
        let mut v = Value::obj();
        v.set("form", Value::Str("mystery".into()));
        v.set("plan", plan.to_json());
        assert!(ExchangePlan::from_json(&v).is_err());
    }

    #[test]
    fn exchange_plan_unifies_both_forms() {
        let strided = StridedPlan::from_msgs(
            2,
            &[(0, 1, StridedBlock::row(0, 3), StridedBlock::row(3, 3))],
        );
        let plan: ExchangePlan = strided.into();
        assert_eq!(plan.name(), "strided");
        assert_eq!(plan.threads(), 2);
        assert_eq!(plan.total_values(), 3);
        assert_eq!(plan.num_messages(), 1);
        assert_eq!(plan.payload_bytes(), 24);
        assert!(plan.as_strided().is_some());
        assert!(plan.as_gather().is_none());

        let layout = crate::pgas::Layout::new(4, 2, 2);
        let gather = CommPlan::from_recv_needs(&layout, &[vec![(1u32, 2u32)], vec![]]);
        let plan: ExchangePlan = gather.into();
        assert_eq!(plan.name(), "gather");
        assert_eq!(plan.total_values(), 1);
        assert!(plan.as_gather().is_some());
    }
}
