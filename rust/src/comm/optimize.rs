//! The plan optimizer: message condensing & consolidation as a compile pass
//! over [`ExchangePlan`] (the paper's third enhancement strategy, §4.3).
//!
//! The inspector/executor literature (Rolinger et al., PAPERS.md) argues the
//! right place for these optimizations is the communication *plan*, not the
//! runtime — a pass pipeline that takes any compiled plan and returns a
//! semantically equivalent but condensed one:
//!
//! 1. **Condense** (gather form): flatten every receiver's `(owner, index)`
//!    occurrence list, sort, dedup — each remote element is fetched once and
//!    unpacked through the scatter map that the sorted index list *is*
//!    (§4.3.1's `mythread_recv_value_list` construction).
//! 2. **Consolidate** (strided form): flatten same-`(receiver, sender)`
//!    blocks to element pairs, re-infer the strided structure as maximal
//!    constant-stride pencils, stack congruent pencils into planes, and pick
//!    slab-vs-pencil granularity per block from the machine model — the
//!    decision SNIPPETS.md's hand-tuned `#define SLABS` made at compile
//!    time, made per-plan from (τ, W) instead.
//! 3. **Arena reorder**: messages are re-emitted receiver-major, sorted by
//!    sender and destination offset, so pack and unpack walk both the
//!    staging arena and the destination field sequentially.
//!
//! The optimized plan runs bitwise-identically on the same executors:
//! destination cells are disjoint ([`StridedPlan::validate`] enforces it)
//! and every (src cell → dst cell) assignment survives the regrouping, so
//! only message boundaries and arena order change — never the values.
//!
//! [`PlanStats`] is the before/after report (message count, bytes, blocks,
//! index-arena size) that `repro plan` prints and `repro validate
//! --optimize` feeds to the model: the predicted win comes from the reduced
//! message count and volume alone.

use super::exchange::{block_cells, ExchangePlan, StridedBlock, StridedPlan};
use super::CommPlan;
use crate::machine::{HwParams, TransportModel, SIZEOF_DOUBLE, SIZEOF_INT};
use crate::util::json::Value;
use std::collections::BTreeMap;

/// Size accounting for one compiled plan — the quantities the paper's
/// models charge for, measurable before and after optimization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanStats {
    /// Total messages per exchange.
    pub messages: usize,
    /// Total values carried per exchange (the staging-arena length).
    pub values: usize,
    /// Payload bytes crossing thread boundaries per exchange.
    pub payload_bytes: u64,
    /// Contiguous memory segments touched on the unpack side: runs of
    /// consecutive indices for gather plans, contiguous block rows for
    /// strided ones. The fewer, the more sequential the unpack walk.
    pub blocks: usize,
    /// Plan metadata footprint: the index arena (`indices` + `local_src`,
    /// [`SIZEOF_INT`] each) for gather plans, the 13-word wire descriptors
    /// for strided ones.
    pub index_arena_bytes: usize,
    /// The busiest receiver's message count — the per-message latency term
    /// of the model prediction is charged to the critical-path thread.
    pub max_thread_messages: usize,
    /// The busiest receiver's incoming value count — the volume term.
    pub max_thread_values: usize,
}

impl PlanStats {
    /// Measure a plan of either form.
    pub fn of(plan: &ExchangePlan) -> PlanStats {
        match plan {
            ExchangePlan::Gather(p) => PlanStats::of_gather(p),
            ExchangePlan::Strided(p) => PlanStats::of_strided(p),
        }
    }

    fn of_gather(p: &CommPlan) -> PlanStats {
        let mut blocks = 0usize;
        for (_, _, m) in p.arena_msgs() {
            blocks += 1 + m.indices.windows(2).filter(|w| w[1] != w[0] + 1).count();
        }
        let per_thread = |t: usize| p.recv_msgs(t).map(|m| m.len()).sum::<usize>();
        PlanStats {
            messages: p.num_messages(),
            values: p.total_values(),
            payload_bytes: (p.total_values() * SIZEOF_DOUBLE) as u64,
            blocks,
            index_arena_bytes: 2 * p.total_values() * SIZEOF_INT,
            max_thread_messages: (0..p.threads()).map(|t| p.messages_to(t)).max().unwrap_or(0),
            max_thread_values: (0..p.threads()).map(per_thread).max().unwrap_or(0),
        }
    }

    fn of_strided(p: &StridedPlan) -> PlanStats {
        let seg = |b: &StridedBlock| if b.col_stride == 1 { b.rows } else { b.rows * b.cols };
        let blocks = p.copies().iter().map(|(_, _, _, dst)| seg(dst)).sum();
        let per_thread = |t: usize| p.recv_msgs(t).map(|m| m.len()).sum::<usize>();
        PlanStats {
            messages: p.num_messages(),
            values: p.total_values(),
            payload_bytes: p.payload_bytes(),
            blocks,
            index_arena_bytes: p.num_messages() * 13 * SIZEOF_INT,
            max_thread_messages: (0..p.threads()).map(|t| p.messages_to(t)).max().unwrap_or(0),
            max_thread_values: (0..p.threads()).map(per_thread).max().unwrap_or(0),
        }
    }

    /// JSON row for BENCH artifacts and `repro plan --json`.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("messages", Value::Num(self.messages as f64));
        v.set("values", Value::Num(self.values as f64));
        v.set("payload_bytes", Value::Num(self.payload_bytes as f64));
        v.set("blocks", Value::Num(self.blocks as f64));
        v.set("index_arena_bytes", Value::Num(self.index_arena_bytes as f64));
        v.set("max_thread_messages", Value::Num(self.max_thread_messages as f64));
        v.set("max_thread_values", Value::Num(self.max_thread_values as f64));
        v
    }

    /// `true` when `self` is no worse than `other` on every axis and
    /// strictly better on at least one — what the equivalence suite asserts
    /// for irregular patterns.
    pub fn improves_on(&self, other: &PlanStats) -> bool {
        let no_worse = self.messages <= other.messages
            && self.values <= other.values
            && self.payload_bytes <= other.payload_bytes
            && self.blocks <= other.blocks
            && self.max_thread_messages <= other.max_thread_messages
            && self.max_thread_values <= other.max_thread_values;
        no_worse
            && (self.messages < other.messages
                || self.values < other.values
                || self.blocks < other.blocks)
    }
}

/// The pass pipeline. Holds the machine model that decides message
/// granularity; [`PlanOptimizer::default`] is deliberately
/// calibration-free so that every process compiling the same plan reaches
/// the same optimized plan (the launch-time fingerprint drift check relies
/// on this).
#[derive(Debug, Clone)]
pub struct PlanOptimizer {
    hw: HwParams,
    tm: TransportModel,
}

impl Default for PlanOptimizer {
    fn default() -> PlanOptimizer {
        PlanOptimizer::new(HwParams::abel(), TransportModel::inproc())
    }
}

impl PlanOptimizer {
    pub fn new(hw: HwParams, tm: TransportModel) -> PlanOptimizer {
        PlanOptimizer { hw, tm }
    }

    /// Run the pass pipeline on a plan of either form. The input must be
    /// valid (destination-disjoint); the output is semantically equivalent —
    /// same (source cell → destination cell) assignments — with condensed
    /// indices, consolidated messages, and a sequential arena walk.
    pub fn optimize(&self, plan: &ExchangePlan) -> ExchangePlan {
        debug_assert!(plan.validate(&|_| usize::MAX).is_ok(), "optimizing an invalid plan");
        match plan {
            ExchangePlan::Gather(p) => ExchangePlan::Gather(condense_gather(p)),
            ExchangePlan::Strided(p) => ExchangePlan::Strided(self.consolidate_strided(p)),
        }
    }

    /// Optimize and report [`PlanStats`] before and after.
    pub fn optimize_with_stats(
        &self,
        plan: &ExchangePlan,
    ) -> (ExchangePlan, PlanStats, PlanStats) {
        let before = PlanStats::of(plan);
        let optimized = self.optimize(plan);
        let after = PlanStats::of(&optimized);
        (optimized, before, after)
    }

    /// Passes 2+3 for the strided form: structure inference over element
    /// pairs, model-driven granularity, receiver-major re-emission.
    fn consolidate_strided(&self, p: &StridedPlan) -> StridedPlan {
        // Group every (src cell → dst cell) assignment by (receiver, sender);
        // the BTreeMap makes the emission order deterministic and
        // receiver-major.
        let mut groups: BTreeMap<(usize, usize), Vec<(usize, usize)>> = BTreeMap::new();
        for (sender, receiver, src, dst) in p.copies() {
            let pairs = groups.entry((receiver, sender)).or_default();
            pairs.extend(block_cells(&src).zip(block_cells(&dst)));
        }
        let mut copies: Vec<(usize, usize, StridedBlock, StridedBlock)> = Vec::new();
        for ((receiver, sender), mut pairs) in groups {
            // Destination cells are unique per receiver (validated), so this
            // orders the group for a sequential unpack walk.
            pairs.sort_unstable_by_key(|&(_, d)| d);
            for (src, dst) in stack_pencils(&extract_pencils(&pairs)) {
                if src.rows > 1 && !self.slab_wins(src.rows, (src.cols * SIZEOF_DOUBLE) as f64) {
                    // Pencils win: one message per row.
                    for r in 0..src.rows {
                        copies.push((sender, receiver, pencil_row(&src, r), pencil_row(&dst, r)));
                    }
                } else {
                    copies.push((sender, receiver, src, dst));
                }
            }
        }
        StridedPlan::from_msgs(p.threads(), &copies)
    }

    /// The granularity decision that replaces SNIPPETS.md's hand-tuned
    /// `#define SLABS`: one consolidated message for a `rows`-row block
    /// costs one latency plus the full volume plus a per-row strided-access
    /// penalty, while per-row pencils pay the latency `rows` times but
    /// stream each row contiguously:
    ///
    /// ```text
    /// T_slab    = τ_eff + rows·row_bytes / W_eff + rows·L / W_private
    /// T_pencils = rows·(τ_eff + row_bytes / W_eff)
    /// ```
    ///
    /// Slabs win whenever `τ_eff·(rows − 1) > rows·L / W_private` — on any
    /// measured transport τ dwarfs a cache-line fill, so consolidation wins;
    /// the crossover only flips for a hypothetical sub-`L/W` latency
    /// transport (pinned by a unit test, not by hardware we have).
    fn slab_wins(&self, rows: usize, row_bytes: f64) -> bool {
        let eff = self.tm.apply(&self.hw);
        let r = rows as f64;
        let line = self.hw.cache_line as f64 / self.hw.w_thread_private;
        let t_slab = eff.tau + r * row_bytes / eff.w_node_remote + r * line;
        let t_pencils = r * (eff.tau + row_bytes / eff.w_node_remote);
        t_slab <= t_pencils
    }
}

/// Pass 1 — condensing (gather form): each receiver's occurrence list
/// sorted by `(owner, index)` and deduplicated, so every remote element is
/// fetched exactly once. Condensing a plan that the analyzer already
/// condensed reproduces it bit-for-bit (same fingerprint): the pass is
/// idempotent and raw/compiled inputs converge.
fn condense_gather(p: &CommPlan) -> CommPlan {
    let threads = p.threads();
    let mut recv: Vec<Vec<(u32, u32, u32)>> = Vec::with_capacity(threads);
    for t in 0..threads {
        let mut triples: Vec<(u32, u32, u32)> = p
            .recv_msgs(t)
            .flat_map(|m| {
                m.indices.iter().zip(m.local_src).map(move |(&idx, &loc)| (m.peer, idx, loc))
            })
            .collect();
        triples.sort_unstable();
        triples.dedup();
        recv.push(triples);
    }
    CommPlan::from_triples(threads, &recv, true)
}

/// Row `r` of a multi-row block as a standalone single-row pencil.
fn pencil_row(b: &StridedBlock, r: usize) -> StridedBlock {
    StridedBlock::plane(b.offset + r * b.row_stride, 1, 0, b.cols, b.col_stride)
}

/// The fine-grained strided baseline: every cell of every block as its own
/// single-value message, in the compiled plan's order — the element-wise
/// "before" world the paper's consolidation improves on, kept runnable on
/// the same executors so the win is measurable.
pub fn refine_strided(p: &StridedPlan) -> StridedPlan {
    let mut copies = Vec::new();
    for (sender, receiver, src, dst) in p.copies() {
        for (s, d) in block_cells(&src).zip(block_cells(&dst)) {
            copies.push((sender, receiver, StridedBlock::row(s, 1), StridedBlock::row(d, 1)));
        }
    }
    StridedPlan::from_msgs(p.threads(), &copies)
}

/// Structure inference, step 1: maximal runs of element pairs with constant
/// `(src, dst)` deltas become single-row pencil blocks. `pairs` must be
/// sorted by destination (strictly increasing); source deltas must be
/// non-negative to stay representable as `usize` strides, so descending
/// sources break a run.
fn extract_pencils(pairs: &[(usize, usize)]) -> Vec<(StridedBlock, StridedBlock)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < pairs.len() {
        let (s0, d0) = pairs[i];
        let mut len = 1usize;
        if i + 1 < pairs.len() && pairs[i + 1].0 >= s0 {
            let ds = pairs[i + 1].0 - s0;
            let dd = pairs[i + 1].1 - d0;
            len = 2;
            while i + len < pairs.len() {
                let (ps, pd) = pairs[i + len - 1];
                let (cs, cd) = pairs[i + len];
                if cs < ps || cs - ps != ds || cd - pd != dd {
                    break;
                }
                len += 1;
            }
            out.push((
                StridedBlock::plane(s0, 1, 0, len, ds),
                StridedBlock::plane(d0, 1, 0, len, dd),
            ));
        } else {
            out.push((StridedBlock::row(s0, 1), StridedBlock::row(d0, 1)));
        }
        i += len;
    }
    out
}

/// Structure inference, step 2: stack consecutive congruent pencils (same
/// width and column stride on both sides) whose offsets advance by constant
/// deltas into multi-row planes — this is what reconstructs a 3D face from
/// its rows, or a whole halo column from singleton cells.
fn stack_pencils(pencils: &[(StridedBlock, StridedBlock)]) -> Vec<(StridedBlock, StridedBlock)> {
    let congruent = |a: &StridedBlock, b: &StridedBlock| {
        a.cols == b.cols && a.col_stride == b.col_stride
    };
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < pencils.len() {
        let (s0, d0) = pencils[i];
        let mut rows = 1usize;
        if i + 1 < pencils.len() {
            let (s1, d1) = pencils[i + 1];
            if congruent(&s0, &s1)
                && congruent(&d0, &d1)
                && s1.offset >= s0.offset
                && d1.offset > d0.offset
            {
                let ds = s1.offset - s0.offset;
                let dd = d1.offset - d0.offset;
                rows = 2;
                while i + rows < pencils.len() {
                    let (ps, pd) = pencils[i + rows - 1];
                    let (cs, cd) = pencils[i + rows];
                    if !congruent(&s0, &cs)
                        || !congruent(&d0, &cd)
                        || cs.offset < ps.offset
                        || cs.offset - ps.offset != ds
                        || cd.offset <= pd.offset
                        || cd.offset - pd.offset != dd
                    {
                        break;
                    }
                    rows += 1;
                }
                out.push((
                    StridedBlock::plane(s0.offset, rows, ds, s0.cols, s0.col_stride),
                    StridedBlock::plane(d0.offset, rows, dd, d0.cols, d0.col_stride),
                ));
                i += rows;
                continue;
            }
        }
        out.push((s0, d0));
        i += rows;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Analysis;
    use crate::matrix::Ellpack;
    use crate::pgas::{Layout, Topology};

    /// Every (src cell → dst cell) assignment of a strided plan, as a
    /// sorted set — the semantic content the optimizer must preserve.
    fn assignments(p: &StridedPlan) -> Vec<(usize, usize, usize, usize)> {
        let mut v: Vec<_> = p
            .copies()
            .iter()
            .flat_map(|&(s, r, src, dst)| {
                block_cells(&src)
                    .zip(block_cells(&dst))
                    .map(move |(a, b)| (s, r, a, b))
                    .collect::<Vec<_>>()
            })
            .collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn condensing_raw_gather_reproduces_the_analyzer_plan() {
        let m = Ellpack::random(240, 5, 42);
        let layout = Layout::new(240, 16, 4);
        let topo = Topology::single_node(4);
        let a = Analysis::build(&m.j, m.r_nz, layout, topo, usize::MAX);
        let raw = Analysis::raw_gather_plan(&m.j, m.r_nz, &layout);
        let opt = PlanOptimizer::default();
        let (condensed, before, after) = opt.optimize_with_stats(&raw.clone().into());
        // The condensed plan is exactly the analyzer's compiled plan.
        assert_eq!(
            condensed.fingerprint(),
            ExchangePlan::from(a.plan.clone()).fingerprint(),
            "condensing the raw plan must reproduce the compiled plan"
        );
        assert!(after.improves_on(&before), "stats must improve: {before:?} → {after:?}");
        // Idempotence: optimizing the optimized plan is a no-op.
        let again = opt.optimize(&condensed);
        assert_eq!(again.fingerprint(), condensed.fingerprint());
    }

    #[test]
    fn consolidating_refined_halos_preserves_assignments() {
        for plan in [
            crate::heat2d::halo_plan(&crate::model::HeatGrid::new(24, 24, 2, 2)),
            crate::stencil3d::face_plan(&crate::stencil3d::Stencil3dGrid::new(8, 8, 8, 2, 2, 2)),
        ] {
            let raw = refine_strided(&plan);
            raw.validate(&|_| usize::MAX).unwrap();
            let opt = PlanOptimizer::default();
            let (optimized, before, after) = opt.optimize_with_stats(&raw.clone().into());
            let optimized = optimized.as_strided().unwrap().clone();
            // Same assignments, far fewer messages.
            assert_eq!(assignments(&optimized), assignments(&plan));
            assert_eq!(assignments(&optimized), assignments(&raw));
            assert_eq!(optimized.num_messages(), plan.num_messages());
            assert!(after.improves_on(&before));
            // Raw and compiled inputs converge to the same optimized plan.
            let from_compiled = opt.optimize(&plan.clone().into());
            assert_eq!(
                from_compiled.fingerprint(),
                ExchangePlan::from(optimized.clone()).fingerprint()
            );
            // Idempotence.
            let again = opt.optimize(&ExchangePlan::from(optimized.clone()));
            assert_eq!(
                again.fingerprint(),
                ExchangePlan::from(optimized.clone()).fingerprint()
            );
        }
    }

    #[test]
    fn z_faces_reconstruct_exactly() {
        // A doubly-strided 3D face refined to cells must come back as the
        // same plane descriptor.
        let face = StridedBlock::plane(7, 4, 36, 5, 6);
        let dst = StridedBlock::plane(1, 4, 36, 5, 6);
        let plan = StridedPlan::from_msgs(2, &[(0, 1, face, dst)]);
        let opt = PlanOptimizer::default().optimize(&refine_strided(&plan).into());
        let opt = opt.as_strided().unwrap();
        assert_eq!(opt.num_messages(), 1);
        let copies = opt.copies();
        assert_eq!(copies[0].2, face);
        assert_eq!(copies[0].3, dst);
    }

    #[test]
    fn granularity_follows_the_model() {
        // A 6-row face. With any realistic transport (τ ≫ L/W_private) the
        // slab wins; with a hypothetical sub-cache-line-latency transport
        // the pencils win and the plan splits into per-row messages.
        let src = StridedBlock::plane(0, 6, 40, 8, 1);
        let dst = StridedBlock::plane(2, 6, 40, 8, 1);
        let plan: ExchangePlan = StridedPlan::from_msgs(2, &[(0, 1, src, dst)]).into();
        let slabby = PlanOptimizer::default().optimize(&plan);
        assert_eq!(slabby.num_messages(), 1);
        let hw = HwParams::abel();
        let fast = TransportModel::socket(1e-12, 1e12);
        let pencils = PlanOptimizer::new(hw, fast).optimize(&plan);
        assert_eq!(pencils.num_messages(), 6);
        // Both keep every assignment.
        assert_eq!(
            assignments(pencils.as_strided().unwrap()),
            assignments(plan.as_strided().unwrap())
        );
    }

    #[test]
    fn stats_count_blocks_and_maxima() {
        // Gather: one message with indices {2,3,4, 9} = 2 consecutive runs.
        let layout = Layout::new(12, 6, 2);
        let needs =
            vec![vec![(1u32, 6u32), (1, 7), (1, 8), (1, 11)], vec![]];
        let plan: ExchangePlan = CommPlan::from_recv_needs(&layout, &needs).into();
        let s = PlanStats::of(&plan);
        assert_eq!(s.messages, 1);
        assert_eq!(s.values, 4);
        assert_eq!(s.payload_bytes, 32);
        assert_eq!(s.blocks, 2);
        assert_eq!(s.index_arena_bytes, 2 * 4 * SIZEOF_INT);
        assert_eq!(s.max_thread_messages, 1);
        assert_eq!(s.max_thread_values, 4);
        // Strided: a 3-row contiguous-row block = 3 unpack segments; a
        // strided-column block = 1 cell per row.
        let copies = vec![
            (0usize, 1usize, StridedBlock::plane(0, 3, 8, 4, 1), StridedBlock::plane(1, 3, 8, 4, 1)),
            (1, 0, StridedBlock::column(0, 3, 8), StridedBlock::column(5, 3, 8)),
        ];
        let plan: ExchangePlan = StridedPlan::from_msgs(2, &copies).into();
        let s = PlanStats::of(&plan);
        assert_eq!(s.messages, 2);
        assert_eq!(s.values, 15);
        assert_eq!(s.blocks, 3 + 3);
        assert_eq!(s.max_thread_messages, 1);
        assert_eq!(s.max_thread_values, 12);
    }
}
