//! Pipeline-safe tree reductions: convergence checks without a global
//! barrier.
//!
//! Iterative solvers need one scalar per step — `max |x_new − x_old|` —
//! compared against a tolerance to decide "stop". The classical shape is a
//! global barrier plus a shared accumulator, which is exactly the
//! primitive the whole engine was built to avoid. [`ReductionPlan`]
//! replaces it with the same machinery the exchange protocols already use:
//! per-thread cache-line-padded monotone epoch flags, `Release` publishes,
//! `Acquire` waits on *specific* peers.
//!
//! Threads form an implicit binary heap (children of `t` are `2t+1` and
//! `2t+2`). At epoch `e`, thread `t` waits for its (at most two) children's
//! reduce flags to reach `e`, folds its own contribution with the
//! children's published subtree values in the fixed order
//! `op(op(own, left), right)`, publishes the result in its epoch-parity
//! slot, and bumps its flag. The root's fold is the global value; the root
//! additionally publishes a **verdict**: the first epoch whose global value
//! reached the tolerance. Every wait is on a tree edge (or the root's
//! verdict counter) — no thread ever waits on "everyone".
//!
//! Stopping is exact, not heuristic: a worker enters epoch `k` only after
//! reading the verdict for `k − 1` (lag 1 — the minimum knowledge needed to
//! decide "is step `k` required?"), so every worker executes exactly epochs
//! `1..=e*` where `e*` is the first epoch with
//! `tree_fold(op, values) <= tol` — the same step a synchronous
//! check-every-step loop stops at, bitwise ([`tree_fold`] reproduces the
//! combine order for the sequential oracle). The lag-1 verdict gate is the
//! price of exactness: step `k` cannot start before step `k − 1` is known
//! unconverged. A speculative deeper gate (run ahead, roll back overshoot)
//! is a ROADMAP follow-up.
//!
//! Slot reuse is parity-2 and race-free by the verdict chain: a child
//! overwrites its slot for epoch `e + 2` only after passing the verdict
//! gate for `e + 1`, which the root publishes only after the parent
//! finished folding epoch `e + 1`, which (folds are sequential per thread)
//! happens after the parent's read of the child's epoch-`e` slot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Not-stopped sentinel for the verdict word.
const NOT_STOPPED: u64 = u64::MAX;

/// The combine operator. Fixed fold order makes the parallel tree and the
/// sequential [`tree_fold`] oracle bitwise identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// `max(a, b)` — residual / convergence checks.
    Max,
    /// `a + b` — norms, energy accounting.
    Sum,
}

impl ReduceOp {
    #[inline]
    fn apply(self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Max => a.max(b),
            ReduceOp::Sum => a + b,
        }
    }
}

/// Fold per-thread contributions exactly as the parallel tree does:
/// `node(t) = op(op(values[t], node(2t+1)), node(2t+2))`, missing children
/// skipped. This is the sequential oracle the equivalence tests pin the
/// parallel reduction against — same association order, same rounding.
pub fn tree_fold(op: ReduceOp, values: &[f64]) -> f64 {
    fn node(op: ReduceOp, values: &[f64], t: usize) -> f64 {
        let mut acc = values[t];
        for c in [2 * t + 1, 2 * t + 2] {
            if c < values.len() {
                acc = op.apply(acc, node(op, values, c));
            }
        }
        acc
    }
    assert!(!values.is_empty(), "reduction over zero threads");
    node(op, values, 0)
}

/// One thread's cell: a monotone reduce-epoch flag plus two epoch-parity
/// value slots (f64 bits in `AtomicU64`), padded so publishes never
/// false-share a waiter's line.
#[derive(Debug, Default)]
#[repr(align(64))]
struct ReduceCell {
    flag: AtomicU64,
    slot: [AtomicU64; 2],
}

/// A compiled tree reduction over `threads` workers — see the module docs
/// for the protocol. One instance serves one solve (epochs are relative,
/// starting at 1); build a fresh plan per tolerance run.
#[derive(Debug)]
pub struct ReductionPlan {
    op: ReduceOp,
    /// Stop when the root's folded value is `<= tol` (residual semantics).
    tol: f64,
    cells: Vec<ReduceCell>,
    /// Root-only writer: last epoch a verdict exists for (monotone).
    verdict_epoch: AtomicU64,
    /// Root-only writer: first epoch whose global value reached `tol`, or
    /// [`NOT_STOPPED`]. Written (at most once) before the `Release` bump of
    /// `verdict_epoch` for that epoch.
    stop_at: AtomicU64,
    /// Root's folded value per epoch parity, for reporting.
    root_value: [AtomicU64; 2],
    /// Give up a wait after this long; `None` waits forever (tests and
    /// trusted in-process runs).
    deadline: Option<Duration>,
}

impl ReductionPlan {
    pub fn new(threads: usize, op: ReduceOp, tol: f64) -> ReductionPlan {
        assert!(threads > 0, "reduction over zero threads");
        ReductionPlan {
            op,
            tol,
            cells: (0..threads).map(|_| ReduceCell::default()).collect(),
            verdict_epoch: AtomicU64::new(0),
            stop_at: AtomicU64::new(NOT_STOPPED),
            root_value: [AtomicU64::new(0), AtomicU64::new(0)],
            deadline: None,
        }
    }

    /// Bound every wait (children and verdict) by `deadline` — the same
    /// fail-fast contract as the exchange waits: a dead peer converts into
    /// an `Err` naming the edge instead of a hang.
    pub fn with_deadline(mut self, deadline: Option<Duration>) -> ReductionPlan {
        self.deadline = deadline;
        self
    }

    pub fn threads(&self) -> usize {
        self.cells.len()
    }

    /// Thread `t`'s combine at `epoch` (1-based): wait for the children's
    /// subtree values, fold `value` with them in the canonical order, and
    /// publish. Returns the folded subtree value (the global value at the
    /// root). Errors only on deadline expiry.
    pub fn combine(&self, t: usize, epoch: u64, value: f64) -> Result<f64, String> {
        debug_assert!(epoch >= 1, "reduce epochs are 1-based");
        let n = self.cells.len();
        let mut acc = value;
        for c in [2 * t + 1, 2 * t + 2] {
            if c < n {
                self.wait_flag(&self.cells[c].flag, epoch, t, c)?;
                let bits = self.cells[c].slot[(epoch % 2) as usize].load(Ordering::Relaxed);
                acc = self.op.apply(acc, f64::from_bits(bits));
            }
        }
        if t == 0 {
            let parity = (epoch % 2) as usize;
            self.root_value[parity].store(acc.to_bits(), Ordering::Relaxed);
            if acc <= self.tol && self.stop_at.load(Ordering::Relaxed) == NOT_STOPPED {
                self.stop_at.store(epoch, Ordering::Relaxed);
            }
            // Release publishes both the verdict word and the root value.
            self.verdict_epoch.store(epoch, Ordering::Release);
        } else {
            self.cells[t].slot[(epoch % 2) as usize].store(acc.to_bits(), Ordering::Relaxed);
            // Release: the slot store above happens-before a parent that
            // observes `flag >= epoch`.
            self.cells[t].flag.store(epoch, Ordering::Release);
        }
        Ok(acc)
    }

    /// Block until the root has judged `epoch`, then report whether the
    /// solve stopped at or before it. `wait_verdict(0)` is free (epoch 0
    /// is pre-judged "not stopped") — workers call this with `k − 1` before
    /// entering epoch `k`.
    pub fn wait_verdict(&self, epoch: u64, t: usize) -> Result<Option<u64>, String> {
        if epoch > 0 {
            self.wait_flag(&self.verdict_epoch, epoch, t, 0)?;
        }
        Ok(self.stopped_by(epoch))
    }

    /// Non-blocking: the stopping epoch, if the root has found one `<=
    /// epoch`.
    pub fn stopped_by(&self, epoch: u64) -> Option<u64> {
        // Acquire pairs with the root's Release verdict bump; the stop word
        // was stored before it.
        let _ = self.verdict_epoch.load(Ordering::Acquire);
        let stop = self.stop_at.load(Ordering::Relaxed);
        (stop <= epoch).then_some(stop)
    }

    /// The global folded value at `epoch` — valid once the verdict for
    /// `epoch` is in (i.e. after `wait_verdict(epoch)`), and until the
    /// parity slot is reused at `epoch + 2`.
    pub fn root_value(&self, epoch: u64) -> f64 {
        f64::from_bits(self.root_value[(epoch % 2) as usize].load(Ordering::Acquire))
    }

    /// The spin → yield → timed-park ladder of the exchange waits, for
    /// reduce edges. `peer` only labels the error.
    fn wait_flag(
        &self,
        flag: &AtomicU64,
        target: u64,
        t: usize,
        peer: usize,
    ) -> Result<(), String> {
        for _ in 0..128 {
            if flag.load(Ordering::Acquire) >= target {
                return Ok(());
            }
            std::hint::spin_loop();
        }
        let start = Instant::now();
        let mut rounds = 0u32;
        loop {
            if flag.load(Ordering::Acquire) >= target {
                return Ok(());
            }
            if let Some(d) = self.deadline {
                let waited = start.elapsed();
                if waited >= d {
                    return Err(format!(
                        "reduction stall: node {t} waited {waited:?} for node {peer} \
                         to combine epoch {target}"
                    ));
                }
            }
            rounds += 1;
            if rounds < 4096 {
                std::thread::yield_now();
            } else {
                std::thread::park_timeout(Duration::from_micros(100));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive a full solve on real threads: per-thread contributions come
    /// from `vals[step][t]`, every worker gates epoch `k` on the verdict
    /// for `k − 1`. Returns (steps each worker executed, root values).
    fn drive(threads: usize, vals: &[Vec<f64>], tol: f64) -> (Vec<u64>, Vec<f64>) {
        let plan = ReductionPlan::new(threads, ReduceOp::Max, tol)
            .with_deadline(Some(Duration::from_secs(5)));
        let mut executed = vec![0u64; threads];
        let mut roots = Vec::new();
        std::thread::scope(|s| {
            let plan = &plan;
            let mut handles = Vec::new();
            for t in 0..threads {
                handles.push(s.spawn(move || {
                    let mut done = 0u64;
                    let mut folded = Vec::new();
                    for k in 1..=vals.len() as u64 {
                        if plan.wait_verdict(k - 1, t).unwrap().is_some() {
                            break;
                        }
                        let v = plan.combine(t, k, vals[(k - 1) as usize][t]).unwrap();
                        done = k;
                        if t == 0 {
                            folded.push(v);
                        }
                    }
                    (done, folded)
                }));
            }
            for (t, h) in handles.into_iter().enumerate() {
                let (done, folded) = h.join().unwrap();
                executed[t] = done;
                if t == 0 {
                    roots = folded;
                }
            }
        });
        (executed, roots)
    }

    fn residual_schedule(threads: usize, steps: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = crate::util::Rng::new(seed);
        (0..steps)
            .map(|s| {
                // Decaying residuals with per-thread noise, like a solver.
                (0..threads).map(|_| rng.f64_in(0.5, 1.0) / (s + 1) as f64).collect()
            })
            .collect()
    }

    #[test]
    fn matches_sequential_oracle_bitwise() {
        for &threads in &[1usize, 2, 3, 5, 8] {
            let vals = residual_schedule(threads, 12, 42 + threads as u64);
            let tol = 0.09; // hit around step 8 of the 1/(s+1) decay
            let (executed, roots) = drive(threads, &vals, tol);
            // Sequential oracle: stop at the first step whose tree-fold
            // residual reaches tol.
            let mut stop = vals.len() as u64;
            let mut oracle = Vec::new();
            for (s, row) in vals.iter().enumerate() {
                let r = tree_fold(ReduceOp::Max, row);
                oracle.push(r);
                if r <= tol {
                    stop = s as u64 + 1;
                    break;
                }
            }
            assert!(
                executed.iter().all(|&e| e == stop),
                "threads={threads}: executed {executed:?}, oracle stop {stop}"
            );
            for (k, (&got, &want)) in roots.iter().zip(&oracle).enumerate() {
                assert_eq!(got.to_bits(), want.to_bits(), "threads={threads} epoch {}", k + 1);
            }
        }
    }

    #[test]
    fn never_converging_runs_every_step() {
        let vals = residual_schedule(4, 6, 7);
        let (executed, roots) = drive(4, &vals, 0.0);
        assert!(executed.iter().all(|&e| e == 6), "{executed:?}");
        assert_eq!(roots.len(), 6);
    }

    #[test]
    fn verdict_is_sticky_and_reports_first_epoch() {
        // Residuals dip under tol at step 2, rise again at step 3: the
        // verdict must pin the *first* qualifying epoch.
        let vals = vec![vec![1.0, 2.0], vec![0.01, 0.02], vec![5.0, 6.0]];
        let (executed, _) = drive(2, &vals, 0.1);
        assert!(executed.iter().all(|&e| e == 2), "{executed:?}");
    }

    #[test]
    fn sum_reduction_folds_in_tree_order() {
        let plan = ReductionPlan::new(1, ReduceOp::Sum, -1.0);
        assert_eq!(plan.combine(0, 1, 2.5).unwrap(), 2.5);
        let vals = [0.1, 0.2, 0.3, 0.4, 0.5];
        // Heap order: 0 + (1 + (3 + 4)) + 2.
        let want = 0.1 + (0.2 + (0.4 + 0.5)) + 0.3;
        assert_eq!(tree_fold(ReduceOp::Sum, &vals).to_bits(), want.to_bits());
    }

    #[test]
    fn dead_child_converts_to_deadline_error() {
        let plan = ReductionPlan::new(3, ReduceOp::Max, 0.0)
            .with_deadline(Some(Duration::from_millis(40)));
        // Thread 1 never combines; the root's wait on its edge must fail
        // with a structured message instead of hanging.
        let err = plan.combine(0, 1, 1.0).unwrap_err();
        assert!(err.contains("reduction stall"), "{err}");
        assert!(err.contains("node 1"), "{err}");
    }

    #[test]
    fn root_value_is_readable_after_verdict() {
        let plan = ReductionPlan::new(1, ReduceOp::Max, 0.5);
        plan.combine(0, 1, 0.75).unwrap();
        assert_eq!(plan.wait_verdict(1, 0).unwrap(), None);
        assert_eq!(plan.root_value(1), 0.75);
        plan.combine(0, 2, 0.25).unwrap();
        assert_eq!(plan.wait_verdict(2, 0).unwrap(), Some(2));
        assert_eq!(plan.root_value(2), 0.25);
        // The verdict is stable from every later epoch's viewpoint.
        assert_eq!(plan.stopped_by(9), Some(2));
    }
}
