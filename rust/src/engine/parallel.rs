//! The parallel SpMV executors on the persistent worker pool: one logical
//! UPC thread per pool worker.
//!
//! Execution model, per variant:
//!
//! * **Naive / V1** — one dispatch, one worker per UPC thread. Every worker
//!   computes its own rows (the `upc_forall` affinity set) straight into its
//!   private shard of `y` ([`SharedVec::locals_mut`]); off-owner `x` reads go
//!   through the shared-array interface exactly as in the sequential
//!   executor, so the byte/transfer counters match occurrence for
//!   occurrence.
//! * **V2** — one dispatch; each worker `upc_memget`s its needed blocks into
//!   its persistent private workspace, then computes. The workspace is
//!   **not** zero-filled between calls: a thread only ever reads positions
//!   its own transport pass refreshed, which removes the O(threads·n)
//!   refill traffic per iteration.
//! * **V3** — one dispatch with an internal [`WorkerCtx::barrier`] as the
//!   `upc_barrier` of Listing 5. Phase 1: every sender fills its compiled
//!   arena ranges ([`ArenaView`]) through the plan's pre-translated
//!   `local_src` offsets — a plain gather from the pointer-to-local, no
//!   allocation, no slot search. Phase 2: every receiver copies its own
//!   blocks, scatters its incoming arena ranges, and computes.
//!
//! The workers, their stacks, the barrier, the staging arena and the private
//! workspaces all persist across calls ([`WorkerPool`]), so a steady-state
//! time step performs **zero thread spawns and zero heap allocations** on
//! the transport path — a step costs barrier waits, not thread creation.
//!
//! All floating-point evaluation orders are identical to the sequential
//! executors, so `y` is bitwise identical; counters are per-worker sums of
//! the same per-thread quantities, so they are exactly equal too.
//!
//! [`SharedVec::locals_mut`]: crate::pgas::SharedVec::locals_mut

use super::fault::FaultPlan;
use super::kernels;
use super::pool::{
    ArenaView, EpochFlags, PerWorker, Phase, PoolHealth, WaitTuning, WorkerCtx, WorkerPool,
};
use super::Engine;
use crate::comm::{Analysis, RowRun};
use crate::machine::SIZEOF_DOUBLE;
use crate::pgas::Layout;
use crate::spmv::{spmv_block_gathered, spmv_block_global, ExecOutcome, SpmvState, Variant};
use crate::transport::{must, PoolEndpoint, Transport};
use std::time::Duration;

/// Persistent engine state, reused across calls/time steps: the worker pool
/// plus the per-worker workspaces.
#[derive(Debug)]
pub struct ParallelPool {
    /// The long-lived workers (one per logical UPC thread).
    pool: WorkerPool,
    /// `x_copies[t]` — thread t's private full-length x workspace (V2/V3).
    x_copies: Vec<Vec<f64>>,
    /// Staging arena for V3 message payloads: `depth × plan.total_values()`
    /// doubles (one slot per buffered epoch), shared by the synchronous,
    /// overlapped and pipelined paths.
    staging: Vec<f64>,
    /// Pipeline depth D: buffered staging slots, and the bound on how far a
    /// pipelined sender runs ahead of its slowest receiver. 2 by default.
    depth: usize,
    /// Per-worker `(bytes, transfers)` counters (naive/V1/V2).
    counts: Vec<(u64, u64)>,
    /// Per-thread published-epoch flags for the split-phase V3 paths.
    flags: EpochFlags,
    /// Per-thread consumed-epoch acks for the pipelined V3 path.
    acks: EpochFlags,
    /// Diagnostics: largest `published − consumed` distance any receiver
    /// observed against one of its senders (pipelined batches only); the
    /// ack protocol bounds it by the pipeline depth D. Folded once per
    /// worker per batch, never touched in the per-epoch hot loop.
    max_lead: std::sync::atomic::AtomicU64,
    /// Exchange epoch of the last V3 step (0 = none yet). Bumped uniformly
    /// by the synchronous, overlapped and pipelined paths so they can be
    /// mixed on one pool without pairing a stale arena half with fresh
    /// flags.
    epoch: u64,
    /// Injected faults for chaos testing; empty in production. Consulted
    /// only by the V3 protocol paths on the parallel engine.
    faults: FaultPlan,
}

impl Default for ParallelPool {
    fn default() -> ParallelPool {
        ParallelPool {
            pool: WorkerPool::new(),
            x_copies: Vec::new(),
            staging: Vec::new(),
            depth: 2,
            counts: Vec::new(),
            flags: EpochFlags::new(0),
            acks: EpochFlags::new(0),
            max_lead: std::sync::atomic::AtomicU64::new(0),
            epoch: 0,
            faults: FaultPlan::default(),
        }
    }
}

impl ParallelPool {
    pub fn new() -> ParallelPool {
        ParallelPool::default()
    }

    /// The configured pipeline depth D (buffered staging slots).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Reconfigure the pipeline depth between steps. The staging arena is
    /// (re)sized lazily by the next V3 step; epochs keep advancing
    /// monotonely, so protocols stay mixable across the change.
    pub fn set_depth(&mut self, depth: usize) {
        assert!(depth >= 1, "pipeline depth must be at least 1");
        self.depth = depth;
    }

    /// Size the persistent workspaces for the run's shape. Contents are
    /// never read before being written within a call, so no zero-fill.
    fn ensure(&mut self, threads: usize, n: usize) {
        if self.x_copies.len() != threads || self.x_copies.first().is_some_and(|v| v.len() != n) {
            self.x_copies = (0..threads).map(|_| vec![0.0f64; n]).collect();
        }
        self.counts.resize(threads, (0, 0));
    }

    /// Size the split-phase protocol state (flags, acks, epoch) for the
    /// run's thread count. A shape change resets the epoch: the old
    /// counters describe a different plan.
    fn ensure_protocol(&mut self, threads: usize) {
        if self.flags.len() != threads {
            self.flags = EpochFlags::new(threads);
            self.acks = EpochFlags::new(threads);
            self.epoch = 0;
        }
    }

    /// Largest `published − consumed` epoch distance any receiver observed
    /// against one of its senders across pipelined batches. The
    /// consumed-epoch ack protocol bounds this by the pipeline depth D —
    /// the V3 counterpart of
    /// [`ExchangeRuntime::max_sender_lead`](crate::engine::ExchangeRuntime::max_sender_lead).
    pub fn max_sender_lead(&self) -> u64 {
        self.max_lead.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Bound every protocol wait (flag, ack, barrier) by `deadline`;
    /// `None` restores unbounded waits. See
    /// [`WorkerPool::set_wait_deadline`].
    pub fn set_wait_deadline(&mut self, deadline: Option<Duration>) {
        self.pool.set_wait_deadline(deadline);
    }

    /// The current wait deadline.
    pub fn wait_deadline(&self) -> Option<Duration> {
        self.pool.wait_deadline()
    }

    /// Tune the spin → yield → timed-park wait ladder. See
    /// [`WorkerPool::set_wait_tuning`].
    pub fn set_wait_tuning(&mut self, tuning: WaitTuning) {
        self.pool.set_wait_tuning(tuning);
    }

    /// Install a fault plan for chaos testing. Faults act on the V3
    /// protocol paths of the parallel engine only.
    pub fn set_fault_plan(&mut self, faults: FaultPlan) {
        self.faults = faults;
    }

    /// Remove any installed fault plan.
    pub fn clear_faults(&mut self) {
        self.faults = FaultPlan::none();
    }

    /// Watchdog + progress snapshot of the underlying worker pool.
    pub fn health(&self) -> PoolHealth {
        self.pool.health()
    }

    /// Run one SpMV `y = Mx` on the worker pool. Bitwise identical to
    /// [`crate::spmv::run_variant`] in `y`, byte counts and transfer counts.
    pub fn run(
        &mut self,
        variant: Variant,
        state: &mut SpmvState,
        analysis: Option<&Analysis>,
    ) -> ExecOutcome {
        match variant {
            Variant::Naive => self.run_naive(state),
            Variant::V1 => self.run_v1(state),
            Variant::V2 => self.run_v2(state, analysis.expect("V2 needs an Analysis")),
            Variant::V3 => self.run_v3(state, analysis.expect("V3 needs an Analysis")),
        }
    }

    /// Listing 2 on the pool: every worker executes the rows with its
    /// affinity, reading through the shared-array interface.
    fn run_naive(&mut self, state: &mut SpmvState) -> ExecOutcome {
        let layout = state.layout;
        let r = state.r_nz;
        self.counts.resize(layout.threads, (0, 0));
        let x = &state.x;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let mut y_locals = state.y.locals_mut();
        let y = PerWorker::new(&mut y_locals);
        let counts = PerWorker::new(&mut self.counts);
        self.pool.run(layout.threads, &|ctx: WorkerCtx| {
            let t = ctx.id;
            // SAFETY: worker t claims only its own shard/counter slot.
            let y_local = unsafe { y.take(t) };
            let cnt = unsafe { counts.take(t) };
            let bs = layout.block_size;
            let mut inter = 0u64;
            let mut transfers = 0u64;
            for b in layout.blocks_of_thread(t) {
                let (start, len) = layout.block_range(b);
                let mb = layout.local_block_index(b);
                for (k, slot) in y_local[mb * bs..mb * bs + len].iter_mut().enumerate() {
                    let i = start + k;
                    let mut tmp = 0.0f64;
                    for jj in 0..r {
                        let col = *j.at(i * r + jj) as usize;
                        if col != i && layout.owner_of_index(col) != t {
                            inter += SIZEOF_DOUBLE as u64;
                            transfers += 1;
                        }
                        tmp += *a.at(i * r + jj) * *x.at(col);
                    }
                    *slot = *d.at(i) * *x.at(i) + tmp;
                }
            }
            *cnt = (inter, transfers);
        });
        finish(state, &self.counts)
    }

    /// Listing 3 on the pool: per-worker block loop with `y,D,A,J`
    /// privatized, `x` accessed element-wise through the shared interface.
    fn run_v1(&mut self, state: &mut SpmvState) -> ExecOutcome {
        let layout = state.layout;
        let r = state.r_nz;
        self.counts.resize(layout.threads, (0, 0));
        let x = &state.x;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let mut y_locals = state.y.locals_mut();
        let y = PerWorker::new(&mut y_locals);
        let counts = PerWorker::new(&mut self.counts);
        self.pool.run(layout.threads, &|ctx: WorkerCtx| {
            let t = ctx.id;
            // SAFETY: worker t claims only its own shard/counter slot.
            let y_local = unsafe { y.take(t) };
            let cnt = unsafe { counts.take(t) };
            let bs = layout.block_size;
            let mut inter = 0u64;
            let mut transfers = 0u64;
            for b in layout.blocks_of_thread(t) {
                let (offset, len) = layout.block_range(b);
                for i in offset..offset + len {
                    for jj in 0..r {
                        let col = *j.at(i * r + jj) as usize;
                        if col != i && layout.owner_of_index(col) != t {
                            inter += SIZEOF_DOUBLE as u64;
                            transfers += 1;
                        }
                    }
                }
                let mb = layout.local_block_index(b);
                spmv_block_global(
                    offset,
                    d.block(b),
                    a.block(b),
                    j.block(b),
                    r,
                    |i| *x.at(i),
                    &mut y_local[mb * bs..mb * bs + len],
                );
            }
            *cnt = (inter, transfers);
        });
        finish(state, &self.counts)
    }

    /// Listing 4 on the pool: per-worker block transport into the private
    /// workspace, then fully private compute.
    fn run_v2(&mut self, state: &mut SpmvState, analysis: &Analysis) -> ExecOutcome {
        let layout = state.layout;
        let r = state.r_nz;
        self.ensure(layout.threads, layout.n);
        let x = &state.x;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let mut y_locals = state.y.locals_mut();
        let y = PerWorker::new(&mut y_locals);
        let ws = PerWorker::new(&mut self.x_copies);
        let counts = PerWorker::new(&mut self.counts);
        self.pool.run(layout.threads, &|ctx: WorkerCtx| {
            let t = ctx.id;
            // SAFETY: worker t claims only its own shard/workspace/counter.
            let y_local = unsafe { y.take(t) };
            let ws = unsafe { ws.take(t) };
            let cnt = unsafe { counts.take(t) };
            let bs = layout.block_size;
            let mut inter = 0u64;
            let mut transfers = 0u64;
            for b in 0..layout.nblks() {
                if !analysis.block_needed(t, b) {
                    continue;
                }
                let (start, len) = layout.block_range(b);
                ws[start..start + len].copy_from_slice(x.block(b));
                if layout.owner_of_block(b) != t {
                    inter += (len * SIZEOF_DOUBLE) as u64;
                    transfers += 1;
                }
            }
            for b in layout.blocks_of_thread(t) {
                let (offset, len) = layout.block_range(b);
                let mb = layout.local_block_index(b);
                spmv_block_gathered(
                    offset,
                    d.block(b),
                    a.block(b),
                    j.block(b),
                    r,
                    ws,
                    &mut y_local[mb * bs..mb * bs + len],
                );
            }
            *cnt = (inter, transfers);
        });
        finish(state, &self.counts)
    }

    /// Listing 5 on the pool: pack + put phase, [`WorkerCtx::barrier`] (the
    /// `upc_barrier`), then unpack + compute — one dispatch, no per-step
    /// allocation.
    ///
    /// Epoch-uniform with the split-phase paths: the step bumps the shared
    /// exchange epoch, packs into that epoch's arena parity half (the
    /// staging buffer is always sized for both halves, so mixing protocols
    /// never resizes it), and publishes the flag/ack counters — pure
    /// bookkeeping under the global barrier, but it keeps a later
    /// overlapped or pipelined step from pairing a stale parity half with
    /// fresh flags.
    fn run_v3(&mut self, state: &mut SpmvState, analysis: &Analysis) -> ExecOutcome {
        let layout = state.layout;
        let r = state.r_nz;
        let threads = layout.threads;
        let plan = &analysis.plan;
        self.ensure(threads, layout.n);
        self.ensure_protocol(threads);
        let total = plan.total_values();
        let depth = self.depth;
        // Steady state: len already matches, so this is a no-op (no
        // zero-fill, no allocation). Contents are transient per epoch.
        self.staging.resize(depth * total, 0.0);
        self.epoch += 1;
        let epoch = self.epoch;

        // The byte/transfer counters are pure functions of the plan; summing
        // them in thread order reproduces the sequential executor's counts.
        let mut inter = 0u64;
        let mut transfers = 0u64;
        for t in 0..threads {
            for m in plan.send_msgs(t) {
                inter += (m.len() * SIZEOF_DOUBLE) as u64;
                transfers += 1;
            }
        }

        let x = &state.x;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let arena = ArenaView::new(&mut self.staging);
        let mut y_locals = state.y.locals_mut();
        let y = PerWorker::new(&mut y_locals);
        let ws = PerWorker::new(&mut self.x_copies);
        let (flags, acks) = (&self.flags, &self.acks);
        let faults = &self.faults;
        self.pool.run(threads, &|ctx: WorkerCtx| {
            let t = ctx.id;
            // SAFETY: plan ranges are disjoint per message (and halved by
            // epoch parity); each is packed by its sender only and read only
            // after the barrier.
            let mut ep =
                unsafe { PoolEndpoint::new(t, total, depth, flags, acks, &arena, &ctx) };
            // Phase 1: pack + put — each sender owns exactly the arena
            // ranges of its own messages (the zero-copy `upc_memput`),
            // through the kernel tier's unrolled gather.
            ctx.note_phase(Phase::Pack, epoch);
            faults.on_phase(t, epoch, Phase::Pack);
            let local_x = x.local(t);
            for m in plan.send_msgs(t) {
                kernels::pack_gather(local_x, m.local_src, ep.send_slot(epoch, m.range()));
            }
            if faults.before_publish(t, epoch) {
                must(ep.publish(epoch));
            }

            ctx.note_phase(Phase::Barrier, epoch);
            ctx.barrier(); // ---- upc_barrier ----

            // Phase 2: own-block copy + scatter + compute.
            // SAFETY: worker t claims only its own workspace/shard.
            ctx.note_phase(Phase::Unpack, epoch);
            faults.on_phase(t, epoch, Phase::Unpack);
            faults.before_unpack(t, epoch);
            let ws = unsafe { ws.take(t) };
            let bs = layout.block_size;
            for b in layout.blocks_of_thread(t) {
                let (start, len) = layout.block_range(b);
                ws[start..start + len].copy_from_slice(x.block(b));
            }
            for m in plan.recv_msgs(t) {
                kernels::scatter_indexed(ws, m.indices, ep.recv_slot(epoch, m.range()));
            }
            if faults.before_ack(t, epoch) {
                must(ep.ack(epoch));
            }
            ctx.note_phase(Phase::Boundary, epoch);
            faults.on_phase(t, epoch, Phase::Boundary);
            let y_local = unsafe { y.take(t) };
            for b in layout.blocks_of_thread(t) {
                let (offset, len) = layout.block_range(b);
                let mb = layout.local_block_index(b);
                spmv_block_gathered(
                    offset,
                    d.block(b),
                    a.block(b),
                    j.block(b),
                    r,
                    ws,
                    &mut y_local[mb * bs..mb * bs + len],
                );
            }
        });
        finish_counted(state, inter, transfers)
    }

    /// The split-phase overlapped Listing 5: pack + publish
    /// (`begin_exchange`), own-block copy + interior rows (the overlap
    /// window), per-peer epoch waits + scatter (`finish_exchange`), then
    /// boundary rows.
    ///
    /// Interior rows — rows whose column indices are all owner-local,
    /// classified once at analysis time ([`Analysis::row_split`]) — never
    /// read a scattered ghost, so computing them before the messages arrive
    /// changes nothing: every row runs the same kernel expression and `y`
    /// is bitwise identical to the synchronous V3 on either engine, with
    /// the same byte/transfer counters. The staging arena is
    /// double-buffered by epoch parity and there is **no global barrier**:
    /// a thread waits only on the peers that actually send to it.
    pub fn run_v3_overlapped(
        &mut self,
        engine: Engine,
        state: &mut SpmvState,
        analysis: &Analysis,
    ) -> ExecOutcome {
        // On the parallel engine a single overlapped step IS a 1-step
        // pipelined batch (the ack gate is skipped for the first D epochs
        // of any batch, D ≥ 1, so the protocols coincide exactly) — share
        // the one unsafe protocol body instead of maintaining a second copy.
        if engine == Engine::Parallel {
            return self.run_v3_pipelined(Engine::Parallel, 1, state, analysis);
        }

        let layout = state.layout;
        let r = state.r_nz;
        let threads = layout.threads;
        let plan = &analysis.plan;
        assert_eq!(analysis.row_split.len(), threads, "analysis/layout thread mismatch");
        self.ensure(threads, layout.n);
        self.ensure_protocol(threads);
        let total = plan.total_values();
        let depth = self.depth;
        // Steady state: len already matches, so this is a no-op (no
        // zero-fill, no allocation). Contents are transient per epoch.
        self.staging.resize(depth * total, 0.0);
        self.epoch += 1;
        let epoch = self.epoch;
        let half = (epoch % depth as u64) as usize * total;

        // Counters: the same pure function of the plan as the synchronous
        // path, so both protocols report identical traffic.
        let mut inter = 0u64;
        let mut transfers = 0u64;
        for t in 0..threads {
            for m in plan.send_msgs(t) {
                inter += (m.len() * SIZEOF_DOUBLE) as u64;
                transfers += 1;
            }
        }

        // Replay the split-phase schedule on the calling thread: all
        // begins, all interior computes, all finishes, all boundary
        // computes — the correctness oracle.
        let x = &state.x;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let split = &analysis.row_split;
        for t in 0..threads {
            let local_x = x.local(t);
            for m in plan.send_msgs(t) {
                let rng = m.range();
                let buf = &mut self.staging[half + rng.start..half + rng.end];
                kernels::pack_gather(local_x, m.local_src, buf);
            }
            self.flags.publish(t, epoch);
        }
        let mut y_locals = state.y.locals_mut();
        for t in 0..threads {
            let ws = &mut self.x_copies[t];
            for b in layout.blocks_of_thread(t) {
                let (start, len) = layout.block_range(b);
                ws[start..start + len].copy_from_slice(x.block(b));
            }
            let y_local = &mut y_locals[t][..];
            compute_row_runs(&layout, r, d, a, j, &split[t].interior, ws, y_local);
        }
        for t in 0..threads {
            let ws = &mut self.x_copies[t];
            for m in plan.recv_msgs(t) {
                let rng = m.range();
                let vals = &self.staging[half + rng.start..half + rng.end];
                kernels::scatter_indexed(ws, m.indices, vals);
            }
            self.acks.publish(t, epoch);
            let y_local = &mut y_locals[t][..];
            compute_row_runs(&layout, r, d, a, j, &split[t].boundary, ws, y_local);
        }
        drop(y_locals);
        finish_counted(state, inter, transfers)
    }

    /// The multi-step pipelined Listing 5: `steps` split-phase V3
    /// iterations (each followed by the §6.1 `x`/`y` pointer swap) inside
    /// **one** pool dispatch. Per epoch a worker runs the same
    /// pack → publish → own-copy + interior rows → per-peer waits +
    /// scatter → boundary rows schedule as
    /// [`run_v3_overlapped`](ParallelPool::run_v3_overlapped); across
    /// epochs the only back-pressure is the consumed-epoch acknowledgment
    /// (pack of epoch `e` waits for every receiver's ack of `e − D`, the
    /// last tenant of that arena slot), so a fast thread runs at most D
    /// epochs ahead of its slowest receiver and no global barrier or
    /// per-step dispatch remains.
    ///
    /// Each epoch's arithmetic is identical to the synchronous V3, so the
    /// batch is bitwise identical to `steps` oracle iterations. On return
    /// `state.y` holds the final iterate and `state.x` the previous one —
    /// the same convention as a single `run` (the caller's `swap_xy`
    /// completes the last pointer swap); byte/transfer counters accumulate
    /// over the batch.
    pub fn run_v3_pipelined(
        &mut self,
        engine: Engine,
        steps: usize,
        state: &mut SpmvState,
        analysis: &Analysis,
    ) -> ExecOutcome {
        if steps == 0 {
            // An empty batch is the identity, matching
            // `ExchangeRuntime::run_pipelined`'s no-op convention.
            return finish_counted(state, 0, 0);
        }
        let layout = state.layout;
        let r = state.r_nz;
        let threads = layout.threads;
        let plan = &analysis.plan;
        assert_eq!(analysis.row_split.len(), threads, "analysis/layout thread mismatch");
        self.ensure(threads, layout.n);
        self.ensure_protocol(threads);
        let total = plan.total_values();
        let depth = self.depth;
        // Steady state: len already matches, so this is a no-op (no
        // zero-fill, no allocation). Contents are transient per epoch.
        self.staging.resize(depth * total, 0.0);

        // Counters: the same pure function of the plan as the single-step
        // paths, accumulated over the batch.
        let mut inter = 0u64;
        let mut transfers = 0u64;
        for t in 0..threads {
            for m in plan.send_msgs(t) {
                inter += (m.len() * SIZEOF_DOUBLE) as u64;
                transfers += 1;
            }
        }
        inter *= steps as u64;
        transfers *= steps as u64;

        let split = &analysis.row_split;
        let bs = layout.block_size;
        match engine {
            Engine::Sequential => {
                // The oracle chains single overlapped steps — the same
                // body, epoch/flag/ack bookkeeping and all, so the two
                // oracle schedules cannot drift apart — with the §6.1
                // pointer swap *between* iterations (not after the last:
                // the contract leaves the final iterate in `y`, like a
                // single `run`).
                for k in 0..steps {
                    if k > 0 {
                        state.swap_xy();
                    }
                    self.run_v3_overlapped(Engine::Sequential, state, analysis);
                }
            }
            Engine::Parallel => {
                let base = self.epoch;
                self.epoch += steps as u64;
                let arena = ArenaView::new(&mut self.staging);
                let mut x_locals = state.x.locals_mut();
                let mut y_locals = state.y.locals_mut();
                let xw = PerWorker::new(&mut x_locals);
                let yw = PerWorker::new(&mut y_locals);
                let ws_view = PerWorker::new(&mut self.x_copies);
                let (flags, acks) = (&self.flags, &self.acks);
                let (d, a, j) = (&state.d, &state.a, &state.j);
                let max_lead = &self.max_lead;
                let faults = &self.faults;
                self.pool.run(threads, &|ctx: WorkerCtx| {
                    let t = ctx.id;
                    // SAFETY: plan ranges are disjoint per message and
                    // halved by epoch parity; the ack gate orders the
                    // previous tenant's reads before each overwrite, and
                    // scatters only follow an observed epoch publish.
                    let mut ep =
                        unsafe { PoolEndpoint::new(t, total, depth, flags, acks, &arena, &ctx) };
                    // SAFETY: worker t claims only its own x/y shards and
                    // workspace, each exactly once per dispatch; the
                    // per-epoch role flip below only swaps which local
                    // name points at which shard.
                    let src_ref = unsafe { xw.take(t) };
                    let dst_ref = unsafe { yw.take(t) };
                    let mut src: &mut [f64] = &mut **src_ref;
                    let mut dst: &mut [f64] = &mut **dst_ref;
                    let ws = unsafe { ws_view.take(t) };
                    // Thread-local max of the depth-bound diagnostic;
                    // folded into the shared counter once per batch.
                    let mut local_lead = 0u64;
                    for k in 1..=steps as u64 {
                        let epoch = base + k;

                        // Ack gate: the arena slot of this epoch was last
                        // drained at epoch − D, so every receiver must have
                        // acked it. A consolidated gather plan has exactly
                        // one send message per receiver, so waiting per
                        // message is waiting per distinct receiver — no
                        // adjacency list, no allocation. The first D
                        // epochs skip the gate: every slot is quiescent
                        // at dispatch entry.
                        if k > depth as u64 {
                            ctx.note_phase(Phase::AckGate, epoch);
                            for m in plan.send_msgs(t) {
                                must(ep.wait_for_ack(m.peer as usize, epoch - depth as u64));
                            }
                        }

                        // begin_exchange: pack this epoch's slot + publish,
                        // through the kernel tier's unrolled gather.
                        ctx.note_phase(Phase::Pack, epoch);
                        faults.on_phase(t, epoch, Phase::Pack);
                        for m in plan.send_msgs(t) {
                            kernels::pack_gather(src, m.local_src, ep.send_slot(epoch, m.range()));
                        }
                        if faults.before_publish(t, epoch) {
                            must(ep.publish(epoch));
                        }

                        // Overlap window: own-block copy + interior rows.
                        for b in layout.blocks_of_thread(t) {
                            let (start, len) = layout.block_range(b);
                            let mb = layout.local_block_index(b);
                            ws[start..start + len]
                                .copy_from_slice(&src[mb * bs..mb * bs + len]);
                        }
                        compute_row_runs(&layout, r, d, a, j, &split[t].interior, ws, dst);

                        // finish_exchange: per-peer waits, scatter, ack.
                        ctx.note_phase(Phase::Transfer, epoch);
                        faults.on_phase(t, epoch, Phase::Transfer);
                        for m in plan.recv_msgs(t) {
                            must(ep.wait_for_epoch(m.peer as usize, epoch));
                            kernels::scatter_indexed(ws, m.indices, ep.recv_slot(epoch, m.range()));
                        }
                        // A slow receiver sleeps after draining but before
                        // acking — exactly the window that stalls its
                        // senders' ack gates.
                        ctx.note_phase(Phase::Unpack, epoch);
                        faults.before_unpack(t, epoch);
                        if faults.before_ack(t, epoch) {
                            must(ep.ack(epoch));
                        }

                        // Depth-bound diagnostic: how far ahead of this
                        // just-consumed epoch has any of t's senders
                        // published? The ack protocol caps this at D.
                        for m in plan.recv_msgs(t) {
                            let lead =
                                flags.load(m.peer as usize).saturating_sub(epoch);
                            local_lead = local_lead.max(lead);
                        }

                        ctx.note_phase(Phase::Boundary, epoch);
                        faults.on_phase(t, epoch, Phase::Boundary);
                        compute_row_runs(&layout, r, d, a, j, &split[t].boundary, ws, dst);

                        // The §6.1 pointer swap, thread-locally.
                        std::mem::swap(&mut src, &mut dst);
                    }
                    max_lead.fetch_max(
                        local_lead,
                        std::sync::atomic::Ordering::Relaxed,
                    );
                });
                drop(x_locals);
                drop(y_locals);
                if steps % 2 == 0 {
                    // An even batch leaves the final iterate in the shard
                    // the workers called `src` last — the x storage. Swap
                    // so `y` holds it, per the single-run convention.
                    state.swap_xy();
                }
            }
        }
        finish_counted(state, inter, transfers)
    }
}

/// Run the gathered kernel over a list of block-contiguous row runs,
/// carving the `D`/`A`/`J`/`y` slices from each run's block. Kernel and FP
/// order are identical to the whole-block path, so a split row set produces
/// bitwise-identical `y` values. Shared with the multi-process SpMV rank
/// drivers (`repro launch`), which must replay the exact same FP order.
#[allow(clippy::too_many_arguments)]
pub(crate) fn compute_row_runs(
    layout: &Layout,
    r_nz: usize,
    d: &crate::pgas::SharedVec<f64>,
    a: &crate::pgas::SharedVec<f64>,
    j: &crate::pgas::SharedVec<u32>,
    runs: &[RowRun],
    ws: &[f64],
    y_local: &mut [f64],
) {
    let bs = layout.block_size;
    for run in runs {
        let i0 = run.start as usize;
        let len = run.len as usize;
        let b = layout.block_of_index(i0);
        let (bstart, _) = layout.block_range(b);
        let off = i0 - bstart;
        let ypos = layout.local_block_index(b) * bs + off;
        spmv_block_gathered(
            i0,
            &d.block(b)[off..off + len],
            &a.block(b)[off * r_nz..(off + len) * r_nz],
            &j.block(b)[off * r_nz..(off + len) * r_nz],
            r_nz,
            ws,
            &mut y_local[ypos..ypos + len],
        );
    }
}

/// Gather the freshly written shared `y` to global indexing and fold the
/// per-worker counters (in thread order, so sums match the oracle exactly).
fn finish(state: &SpmvState, counts: &[(u64, u64)]) -> ExecOutcome {
    let (inter, transfers) = counts
        .iter()
        .fold((0u64, 0u64), |acc, c| (acc.0 + c.0, acc.1 + c.1));
    finish_counted(state, inter, transfers)
}

fn finish_counted(state: &SpmvState, inter: u64, transfers: u64) -> ExecOutcome {
    let layout = state.layout;
    let mut y = vec![0.0f64; layout.n];
    for b in 0..layout.nblks() {
        let (start, len) = layout.block_range(b);
        y[start..start + len].copy_from_slice(state.y.block(b));
    }
    ExecOutcome { y, inter_thread_bytes: inter, transfers }
}
