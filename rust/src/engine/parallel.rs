//! The parallel worker pool: one OS thread per logical UPC thread.
//!
//! Execution model, per variant:
//!
//! * **Naive / V1** — one scope, one worker per UPC thread. Every worker
//!   computes its own rows (the `upc_forall` affinity set) straight into its
//!   private shard of `y` ([`SharedVec::locals_mut`]); off-owner `x` reads go
//!   through the shared-array interface exactly as in the sequential
//!   executor, so the byte/transfer counters match occurrence for
//!   occurrence.
//! * **V2** — one scope; each worker `upc_memget`s its needed blocks into
//!   its persistent private workspace, then computes. The workspace is
//!   **not** zero-filled between calls: a thread only ever reads positions
//!   its own transport pass refreshed, which removes the O(threads·n)
//!   refill traffic per iteration.
//! * **V3** — two scopes with the scope join as the `upc_barrier` of
//!   Listing 5. Phase 1: the staging arena is carved into disjoint
//!   per-message `&mut` slices (the compiled plan's ranges) and every sender
//!   packs through its pre-translated `local_src` offsets — a plain gather
//!   from the pointer-to-local, no allocation, no slot search. Phase 2:
//!   every receiver copies its own blocks, scatters its incoming arena
//!   ranges, and computes.
//!
//! All floating-point evaluation orders are identical to the sequential
//! executors, so `y` is bitwise identical; counters are per-worker sums of
//! the same per-thread quantities, so they are exactly equal too.

use crate::comm::Analysis;
use crate::machine::SIZEOF_DOUBLE;
use crate::spmv::{spmv_block_gathered, spmv_block_global, ExecOutcome, SpmvState, Variant};

/// Persistent per-worker state, reused across calls/time steps.
#[derive(Debug, Default)]
pub struct ParallelPool {
    /// `x_copies[t]` — thread t's private full-length x workspace (V2/V3).
    x_copies: Vec<Vec<f64>>,
    /// Flat staging arena for V3 message payloads (`plan.total_values()`).
    staging: Vec<f64>,
}

impl ParallelPool {
    pub fn new() -> ParallelPool {
        ParallelPool::default()
    }

    /// Size the persistent workspaces for the run's shape. Contents are
    /// never read before being written within a call, so no zero-fill.
    fn ensure(&mut self, threads: usize, n: usize) {
        if self.x_copies.len() != threads || self.x_copies.first().is_some_and(|v| v.len() != n) {
            self.x_copies = (0..threads).map(|_| vec![0.0f64; n]).collect();
        }
    }

    /// Run one SpMV `y = Mx` on the worker pool. Bitwise identical to
    /// [`crate::spmv::run_variant`] in `y`, byte counts and transfer counts.
    pub fn run(
        &mut self,
        variant: Variant,
        state: &mut SpmvState,
        analysis: Option<&Analysis>,
    ) -> ExecOutcome {
        match variant {
            Variant::Naive => run_naive(state),
            Variant::V1 => run_v1(state),
            Variant::V2 => self.run_v2(state, analysis.expect("V2 needs an Analysis")),
            Variant::V3 => self.run_v3(state, analysis.expect("V3 needs an Analysis")),
        }
    }

    /// Listing 4 on the pool: per-worker block transport into the private
    /// workspace, then fully private compute.
    fn run_v2(&mut self, state: &mut SpmvState, analysis: &Analysis) -> ExecOutcome {
        let layout = state.layout;
        let r = state.r_nz;
        self.ensure(layout.threads, layout.n);
        let x = &state.x;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let y_locals = state.y.locals_mut();
        let mut counts = vec![(0u64, 0u64); layout.threads];
        std::thread::scope(|s| {
            for ((t, y_local), (ws, cnt)) in y_locals
                .into_iter()
                .enumerate()
                .zip(self.x_copies.iter_mut().zip(counts.iter_mut()))
            {
                s.spawn(move || {
                    let bs = layout.block_size;
                    let mut inter = 0u64;
                    let mut transfers = 0u64;
                    for b in 0..layout.nblks() {
                        if !analysis.block_needed(t, b) {
                            continue;
                        }
                        let (start, len) = layout.block_range(b);
                        ws[start..start + len].copy_from_slice(x.block(b));
                        if layout.owner_of_block(b) != t {
                            inter += (len * SIZEOF_DOUBLE) as u64;
                            transfers += 1;
                        }
                    }
                    for b in layout.blocks_of_thread(t) {
                        let (offset, len) = layout.block_range(b);
                        let mb = layout.local_block_index(b);
                        spmv_block_gathered(
                            offset,
                            d.block(b),
                            a.block(b),
                            j.block(b),
                            r,
                            ws,
                            &mut y_local[mb * bs..mb * bs + len],
                        );
                    }
                    *cnt = (inter, transfers);
                });
            }
        });
        finish(state, &counts)
    }

    /// Listing 5 on the pool: pack/put scope, barrier (the scope join),
    /// then unpack + compute scope.
    fn run_v3(&mut self, state: &mut SpmvState, analysis: &Analysis) -> ExecOutcome {
        let layout = state.layout;
        let r = state.r_nz;
        let threads = layout.threads;
        let plan = &analysis.plan;
        self.ensure(threads, layout.n);
        self.staging.resize(plan.total_values(), 0.0);

        // The byte/transfer counters are pure functions of the plan; summing
        // them in thread order reproduces the sequential executor's counts.
        let mut inter = 0u64;
        let mut transfers = 0u64;
        for t in 0..threads {
            for m in plan.send_msgs(t) {
                inter += (m.len() * SIZEOF_DOUBLE) as u64;
                transfers += 1;
            }
        }

        let x = &state.x;
        // Carve the staging arena into disjoint per-message slices, grouped
        // by sender: each worker ends up owning exactly the `&mut` ranges it
        // must fill — the zero-copy `upc_memput`.
        let mut jobs: Vec<Vec<(&[u32], &mut [f64])>> =
            (0..threads).map(|_| Vec::new()).collect();
        {
            let mut rest: &mut [f64] = &mut self.staging;
            for (sender, _receiver, m) in plan.arena_msgs() {
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(m.len());
                jobs[sender].push((m.local_src, head));
                rest = tail;
            }
            debug_assert!(rest.is_empty(), "staging arena not fully carved");
        }

        // Phase 1: pack + put.
        std::thread::scope(|s| {
            for (t, thread_jobs) in jobs.into_iter().enumerate() {
                if thread_jobs.is_empty() {
                    continue;
                }
                s.spawn(move || {
                    let local_x = x.local(t);
                    for (src, buf) in thread_jobs {
                        for (slot, &off) in buf.iter_mut().zip(src) {
                            *slot = local_x[off as usize];
                        }
                    }
                });
            }
        });

        // ---- upc_barrier (the scope join) ----

        // Phase 2: own-block copy + scatter + compute.
        let staging = &self.staging;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let y_locals = state.y.locals_mut();
        std::thread::scope(|s| {
            for ((t, y_local), ws) in
                y_locals.into_iter().enumerate().zip(self.x_copies.iter_mut())
            {
                s.spawn(move || {
                    let bs = layout.block_size;
                    for b in layout.blocks_of_thread(t) {
                        let (start, len) = layout.block_range(b);
                        ws[start..start + len].copy_from_slice(x.block(b));
                    }
                    for m in plan.recv_msgs(t) {
                        let vals = &staging[m.range()];
                        for (&gidx, &v) in m.indices.iter().zip(vals) {
                            ws[gidx as usize] = v;
                        }
                    }
                    for b in layout.blocks_of_thread(t) {
                        let (offset, len) = layout.block_range(b);
                        let mb = layout.local_block_index(b);
                        spmv_block_gathered(
                            offset,
                            d.block(b),
                            a.block(b),
                            j.block(b),
                            r,
                            ws,
                            &mut y_local[mb * bs..mb * bs + len],
                        );
                    }
                });
            }
        });
        finish_counted(state, inter, transfers)
    }
}

/// Listing 2 on the pool: every worker executes the rows with its affinity,
/// reading through the shared-array interface.
fn run_naive(state: &mut SpmvState) -> ExecOutcome {
    let layout = state.layout;
    let r = state.r_nz;
    let x = &state.x;
    let d = &state.d;
    let a = &state.a;
    let j = &state.j;
    let y_locals = state.y.locals_mut();
    let mut counts = vec![(0u64, 0u64); layout.threads];
    std::thread::scope(|s| {
        for ((t, y_local), cnt) in y_locals.into_iter().enumerate().zip(counts.iter_mut()) {
            s.spawn(move || {
                let bs = layout.block_size;
                let mut inter = 0u64;
                let mut transfers = 0u64;
                for b in layout.blocks_of_thread(t) {
                    let (start, len) = layout.block_range(b);
                    let mb = layout.local_block_index(b);
                    for (k, slot) in y_local[mb * bs..mb * bs + len].iter_mut().enumerate() {
                        let i = start + k;
                        let mut tmp = 0.0f64;
                        for jj in 0..r {
                            let col = *j.at(i * r + jj) as usize;
                            if col != i && layout.owner_of_index(col) != t {
                                inter += SIZEOF_DOUBLE as u64;
                                transfers += 1;
                            }
                            tmp += *a.at(i * r + jj) * *x.at(col);
                        }
                        *slot = *d.at(i) * *x.at(i) + tmp;
                    }
                }
                *cnt = (inter, transfers);
            });
        }
    });
    finish(state, &counts)
}

/// Listing 3 on the pool: per-worker block loop with `y,D,A,J` privatized,
/// `x` accessed element-wise through the shared interface.
fn run_v1(state: &mut SpmvState) -> ExecOutcome {
    let layout = state.layout;
    let r = state.r_nz;
    let x = &state.x;
    let d = &state.d;
    let a = &state.a;
    let j = &state.j;
    let y_locals = state.y.locals_mut();
    let mut counts = vec![(0u64, 0u64); layout.threads];
    std::thread::scope(|s| {
        for ((t, y_local), cnt) in y_locals.into_iter().enumerate().zip(counts.iter_mut()) {
            s.spawn(move || {
                let bs = layout.block_size;
                let mut inter = 0u64;
                let mut transfers = 0u64;
                for b in layout.blocks_of_thread(t) {
                    let (offset, len) = layout.block_range(b);
                    for i in offset..offset + len {
                        for jj in 0..r {
                            let col = *j.at(i * r + jj) as usize;
                            if col != i && layout.owner_of_index(col) != t {
                                inter += SIZEOF_DOUBLE as u64;
                                transfers += 1;
                            }
                        }
                    }
                    let mb = layout.local_block_index(b);
                    spmv_block_global(
                        offset,
                        d.block(b),
                        a.block(b),
                        j.block(b),
                        r,
                        |i| *x.at(i),
                        &mut y_local[mb * bs..mb * bs + len],
                    );
                }
                *cnt = (inter, transfers);
            });
        }
    });
    finish(state, &counts)
}

/// Gather the freshly written shared `y` to global indexing and fold the
/// per-worker counters (in thread order, so sums match the oracle exactly).
fn finish(state: &SpmvState, counts: &[(u64, u64)]) -> ExecOutcome {
    let (inter, transfers) = counts
        .iter()
        .fold((0u64, 0u64), |acc, c| (acc.0 + c.0, acc.1 + c.1));
    finish_counted(state, inter, transfers)
}

fn finish_counted(state: &SpmvState, inter: u64, transfers: u64) -> ExecOutcome {
    let layout = state.layout;
    let mut y = vec![0.0f64; layout.n];
    for b in 0..layout.nblks() {
        let (start, len) = layout.block_range(b);
        y[start..start + len].copy_from_slice(state.y.block(b));
    }
    ExecOutcome { y, inter_thread_bytes: inter, transfers }
}
