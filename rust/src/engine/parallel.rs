//! The parallel SpMV executors on the persistent worker pool: one logical
//! UPC thread per pool worker.
//!
//! Execution model, per variant:
//!
//! * **Naive / V1** — one dispatch, one worker per UPC thread. Every worker
//!   computes its own rows (the `upc_forall` affinity set) straight into its
//!   private shard of `y` ([`SharedVec::locals_mut`]); off-owner `x` reads go
//!   through the shared-array interface exactly as in the sequential
//!   executor, so the byte/transfer counters match occurrence for
//!   occurrence.
//! * **V2** — one dispatch; each worker `upc_memget`s its needed blocks into
//!   its persistent private workspace, then computes. The workspace is
//!   **not** zero-filled between calls: a thread only ever reads positions
//!   its own transport pass refreshed, which removes the O(threads·n)
//!   refill traffic per iteration.
//! * **V3** — one dispatch with an internal [`WorkerCtx::barrier`] as the
//!   `upc_barrier` of Listing 5. Phase 1: every sender fills its compiled
//!   arena ranges ([`ArenaView`]) through the plan's pre-translated
//!   `local_src` offsets — a plain gather from the pointer-to-local, no
//!   allocation, no slot search. Phase 2: every receiver copies its own
//!   blocks, scatters its incoming arena ranges, and computes.
//!
//! The workers, their stacks, the barrier, the staging arena and the private
//! workspaces all persist across calls ([`WorkerPool`]), so a steady-state
//! time step performs **zero thread spawns and zero heap allocations** on
//! the transport path — a step costs barrier waits, not thread creation.
//!
//! All floating-point evaluation orders are identical to the sequential
//! executors, so `y` is bitwise identical; counters are per-worker sums of
//! the same per-thread quantities, so they are exactly equal too.
//!
//! [`SharedVec::locals_mut`]: crate::pgas::SharedVec::locals_mut

use super::pool::{ArenaView, PerWorker, WorkerCtx, WorkerPool};
use crate::comm::Analysis;
use crate::machine::SIZEOF_DOUBLE;
use crate::spmv::{spmv_block_gathered, spmv_block_global, ExecOutcome, SpmvState, Variant};

/// Persistent engine state, reused across calls/time steps: the worker pool
/// plus the per-worker workspaces.
#[derive(Debug, Default)]
pub struct ParallelPool {
    /// The long-lived workers (one per logical UPC thread).
    pool: WorkerPool,
    /// `x_copies[t]` — thread t's private full-length x workspace (V2/V3).
    x_copies: Vec<Vec<f64>>,
    /// Flat staging arena for V3 message payloads (`plan.total_values()`).
    staging: Vec<f64>,
    /// Per-worker `(bytes, transfers)` counters (naive/V1/V2).
    counts: Vec<(u64, u64)>,
}

impl ParallelPool {
    pub fn new() -> ParallelPool {
        ParallelPool::default()
    }

    /// Size the persistent workspaces for the run's shape. Contents are
    /// never read before being written within a call, so no zero-fill.
    fn ensure(&mut self, threads: usize, n: usize) {
        if self.x_copies.len() != threads || self.x_copies.first().is_some_and(|v| v.len() != n) {
            self.x_copies = (0..threads).map(|_| vec![0.0f64; n]).collect();
        }
        self.counts.resize(threads, (0, 0));
    }

    /// Run one SpMV `y = Mx` on the worker pool. Bitwise identical to
    /// [`crate::spmv::run_variant`] in `y`, byte counts and transfer counts.
    pub fn run(
        &mut self,
        variant: Variant,
        state: &mut SpmvState,
        analysis: Option<&Analysis>,
    ) -> ExecOutcome {
        match variant {
            Variant::Naive => self.run_naive(state),
            Variant::V1 => self.run_v1(state),
            Variant::V2 => self.run_v2(state, analysis.expect("V2 needs an Analysis")),
            Variant::V3 => self.run_v3(state, analysis.expect("V3 needs an Analysis")),
        }
    }

    /// Listing 2 on the pool: every worker executes the rows with its
    /// affinity, reading through the shared-array interface.
    fn run_naive(&mut self, state: &mut SpmvState) -> ExecOutcome {
        let layout = state.layout;
        let r = state.r_nz;
        self.counts.resize(layout.threads, (0, 0));
        let x = &state.x;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let mut y_locals = state.y.locals_mut();
        let y = PerWorker::new(&mut y_locals);
        let counts = PerWorker::new(&mut self.counts);
        self.pool.run(layout.threads, &|ctx: WorkerCtx| {
            let t = ctx.id;
            // SAFETY: worker t claims only its own shard/counter slot.
            let y_local = unsafe { y.take(t) };
            let cnt = unsafe { counts.take(t) };
            let bs = layout.block_size;
            let mut inter = 0u64;
            let mut transfers = 0u64;
            for b in layout.blocks_of_thread(t) {
                let (start, len) = layout.block_range(b);
                let mb = layout.local_block_index(b);
                for (k, slot) in y_local[mb * bs..mb * bs + len].iter_mut().enumerate() {
                    let i = start + k;
                    let mut tmp = 0.0f64;
                    for jj in 0..r {
                        let col = *j.at(i * r + jj) as usize;
                        if col != i && layout.owner_of_index(col) != t {
                            inter += SIZEOF_DOUBLE as u64;
                            transfers += 1;
                        }
                        tmp += *a.at(i * r + jj) * *x.at(col);
                    }
                    *slot = *d.at(i) * *x.at(i) + tmp;
                }
            }
            *cnt = (inter, transfers);
        });
        finish(state, &self.counts)
    }

    /// Listing 3 on the pool: per-worker block loop with `y,D,A,J`
    /// privatized, `x` accessed element-wise through the shared interface.
    fn run_v1(&mut self, state: &mut SpmvState) -> ExecOutcome {
        let layout = state.layout;
        let r = state.r_nz;
        self.counts.resize(layout.threads, (0, 0));
        let x = &state.x;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let mut y_locals = state.y.locals_mut();
        let y = PerWorker::new(&mut y_locals);
        let counts = PerWorker::new(&mut self.counts);
        self.pool.run(layout.threads, &|ctx: WorkerCtx| {
            let t = ctx.id;
            // SAFETY: worker t claims only its own shard/counter slot.
            let y_local = unsafe { y.take(t) };
            let cnt = unsafe { counts.take(t) };
            let bs = layout.block_size;
            let mut inter = 0u64;
            let mut transfers = 0u64;
            for b in layout.blocks_of_thread(t) {
                let (offset, len) = layout.block_range(b);
                for i in offset..offset + len {
                    for jj in 0..r {
                        let col = *j.at(i * r + jj) as usize;
                        if col != i && layout.owner_of_index(col) != t {
                            inter += SIZEOF_DOUBLE as u64;
                            transfers += 1;
                        }
                    }
                }
                let mb = layout.local_block_index(b);
                spmv_block_global(
                    offset,
                    d.block(b),
                    a.block(b),
                    j.block(b),
                    r,
                    |i| *x.at(i),
                    &mut y_local[mb * bs..mb * bs + len],
                );
            }
            *cnt = (inter, transfers);
        });
        finish(state, &self.counts)
    }

    /// Listing 4 on the pool: per-worker block transport into the private
    /// workspace, then fully private compute.
    fn run_v2(&mut self, state: &mut SpmvState, analysis: &Analysis) -> ExecOutcome {
        let layout = state.layout;
        let r = state.r_nz;
        self.ensure(layout.threads, layout.n);
        let x = &state.x;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let mut y_locals = state.y.locals_mut();
        let y = PerWorker::new(&mut y_locals);
        let ws = PerWorker::new(&mut self.x_copies);
        let counts = PerWorker::new(&mut self.counts);
        self.pool.run(layout.threads, &|ctx: WorkerCtx| {
            let t = ctx.id;
            // SAFETY: worker t claims only its own shard/workspace/counter.
            let y_local = unsafe { y.take(t) };
            let ws = unsafe { ws.take(t) };
            let cnt = unsafe { counts.take(t) };
            let bs = layout.block_size;
            let mut inter = 0u64;
            let mut transfers = 0u64;
            for b in 0..layout.nblks() {
                if !analysis.block_needed(t, b) {
                    continue;
                }
                let (start, len) = layout.block_range(b);
                ws[start..start + len].copy_from_slice(x.block(b));
                if layout.owner_of_block(b) != t {
                    inter += (len * SIZEOF_DOUBLE) as u64;
                    transfers += 1;
                }
            }
            for b in layout.blocks_of_thread(t) {
                let (offset, len) = layout.block_range(b);
                let mb = layout.local_block_index(b);
                spmv_block_gathered(
                    offset,
                    d.block(b),
                    a.block(b),
                    j.block(b),
                    r,
                    ws,
                    &mut y_local[mb * bs..mb * bs + len],
                );
            }
            *cnt = (inter, transfers);
        });
        finish(state, &self.counts)
    }

    /// Listing 5 on the pool: pack + put phase, [`WorkerCtx::barrier`] (the
    /// `upc_barrier`), then unpack + compute — one dispatch, no per-step
    /// allocation.
    fn run_v3(&mut self, state: &mut SpmvState, analysis: &Analysis) -> ExecOutcome {
        let layout = state.layout;
        let r = state.r_nz;
        let threads = layout.threads;
        let plan = &analysis.plan;
        self.ensure(threads, layout.n);
        self.staging.resize(plan.total_values(), 0.0);

        // The byte/transfer counters are pure functions of the plan; summing
        // them in thread order reproduces the sequential executor's counts.
        let mut inter = 0u64;
        let mut transfers = 0u64;
        for t in 0..threads {
            for m in plan.send_msgs(t) {
                inter += (m.len() * SIZEOF_DOUBLE) as u64;
                transfers += 1;
            }
        }

        let x = &state.x;
        let d = &state.d;
        let a = &state.a;
        let j = &state.j;
        let arena = ArenaView::new(&mut self.staging);
        let mut y_locals = state.y.locals_mut();
        let y = PerWorker::new(&mut y_locals);
        let ws = PerWorker::new(&mut self.x_copies);
        self.pool.run(threads, &|ctx: WorkerCtx| {
            let t = ctx.id;
            // Phase 1: pack + put — each sender owns exactly the arena
            // ranges of its own messages (the zero-copy `upc_memput`).
            let local_x = x.local(t);
            for m in plan.send_msgs(t) {
                // SAFETY: plan ranges are disjoint; message sent by t only.
                let buf = unsafe { arena.slice_mut(m.range()) };
                for (slot, &off) in buf.iter_mut().zip(m.local_src) {
                    *slot = local_x[off as usize];
                }
            }

            ctx.barrier(); // ---- upc_barrier ----

            // Phase 2: own-block copy + scatter + compute.
            // SAFETY: worker t claims only its own workspace/shard.
            let ws = unsafe { ws.take(t) };
            let bs = layout.block_size;
            for b in layout.blocks_of_thread(t) {
                let (start, len) = layout.block_range(b);
                ws[start..start + len].copy_from_slice(x.block(b));
            }
            for m in plan.recv_msgs(t) {
                // SAFETY: arena writes ended at the barrier; reads shared.
                let vals = unsafe { arena.slice(m.range()) };
                for (&gidx, &v) in m.indices.iter().zip(vals) {
                    ws[gidx as usize] = v;
                }
            }
            let y_local = unsafe { y.take(t) };
            for b in layout.blocks_of_thread(t) {
                let (offset, len) = layout.block_range(b);
                let mb = layout.local_block_index(b);
                spmv_block_gathered(
                    offset,
                    d.block(b),
                    a.block(b),
                    j.block(b),
                    r,
                    ws,
                    &mut y_local[mb * bs..mb * bs + len],
                );
            }
        });
        finish_counted(state, inter, transfers)
    }
}

/// Gather the freshly written shared `y` to global indexing and fold the
/// per-worker counters (in thread order, so sums match the oracle exactly).
fn finish(state: &SpmvState, counts: &[(u64, u64)]) -> ExecOutcome {
    let (inter, transfers) = counts
        .iter()
        .fold((0u64, 0u64), |acc, c| (acc.0 + c.0, acc.1 + c.1));
    finish_counted(state, inter, transfers)
}

fn finish_counted(state: &SpmvState, inter: u64, transfers: u64) -> ExecOutcome {
    let layout = state.layout;
    let mut y = vec![0.0f64; layout.n];
    for b in 0..layout.nblks() {
        let (start, len) = layout.block_range(b);
        y[start..start + len].copy_from_slice(state.y.block(b));
    }
    ExecOutcome { y, inter_thread_bytes: inter, transfers }
}
