//! Execution-engine selection: sequential oracle vs the parallel worker
//! pool.
//!
//! The sequential executors in [`crate::spmv::run_variant`] replay every
//! logical UPC thread on one OS thread — perfect as a correctness oracle,
//! useless as a performance claim. This module adds the other half of the
//! paper's story: [`Engine::Parallel`] runs the same four variants with
//! **one real OS thread per logical UPC thread**, each worker owning its
//! `x`/`y` shards privately, with values exchanged through the compiled
//! [`CommPlan`](crate::comm::CommPlan)'s flat staging arena (pack → put →
//! barrier → unpack, exactly Listing 5's phase structure). Remote operations
//! become plain `memcpy` between per-thread segments — the shared-memory
//! PGAS execution model of POSH (Coti 2014) driven by a precompiled
//! irregular-access schedule (Rolinger et al. 2023).
//!
//! Both engines produce **bitwise identical** results (`y`, byte counts,
//! message counts); the equivalence is enforced by
//! `rust/tests/engine_equivalence.rs` and the property tests below.
//!
//! The engine layer is workload-agnostic. Its pieces:
//!
//! * [`WorkerPool`] — long-lived workers + a reusable barrier; a dispatch
//!   costs a condvar wakeup, not `threads` thread creations. Shared by the
//!   SpMV executors and every grid workload.
//! * [`PerWorker`] / [`ArenaView`] — the disjoint-access views that let one
//!   shared job closure hand each worker its own field shard and its own
//!   compiled staging-arena ranges, with no locks and no per-step boxing.
//! * [`ParallelPool`] — the four SpMV variants on the pool (gather-form
//!   plans).
//! * [`ExchangeRuntime`] — plan + staging arena + pool bundled for the
//!   strided-form workloads (heat-2D, the 3D stencil): one `step_strided`
//!   call runs pack → barrier → unpack → per-thread stencil update on
//!   either engine.

mod checkpoint;
mod exchange;
mod fault;
pub mod kernels;
mod parallel;
mod pool;
mod reduce;

pub(crate) use checkpoint::{check_depth, check_generation, check_plan_hash};
pub use checkpoint::{Checkpoint, SpmvCheckpoint};
pub use exchange::ExchangeRuntime;
pub use fault::{Fault, FaultKind, FaultPlan, INJECTED_DELAY};
pub(crate) use parallel::compute_row_runs;
pub use parallel::ParallelPool;
pub use pool::{
    ArenaView, EpochFlags, PerWorker, Phase, PoolHealth, StallError, StallReport, WaitTuning,
    WorkerCtx, WorkerHealth, WorkerPool, DEFAULT_WAIT_DEADLINE,
};
pub use reduce::{tree_fold, ReduceOp, ReductionPlan};

use crate::comm::Analysis;
use crate::spmv::{run_variant, ExecOutcome, SpmvState, Variant};
use std::time::Duration;

/// Which execution engine drives the UPC-thread variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// Replay all logical threads on the calling OS thread (the oracle).
    #[default]
    Sequential,
    /// One OS thread per logical UPC thread over a scoped worker pool.
    Parallel,
}

impl Engine {
    pub const ALL: [Engine; 2] = [Engine::Sequential, Engine::Parallel];

    pub fn name(self) -> &'static str {
        match self {
            Engine::Sequential => "sequential",
            Engine::Parallel => "parallel",
        }
    }

    pub fn parse(s: &str) -> Option<Engine> {
        match s.to_ascii_lowercase().as_str() {
            "seq" | "sequential" => Some(Engine::Sequential),
            "par" | "parallel" => Some(Engine::Parallel),
            _ => None,
        }
    }
}

/// A reusable engine handle: mode plus the persistent per-worker state
/// (workspaces, staging arena) the parallel pool keeps across time steps.
#[derive(Debug, Default)]
pub struct SpmvEngine {
    mode: Engine,
    pool: ParallelPool,
}

impl SpmvEngine {
    pub fn new(mode: Engine) -> SpmvEngine {
        SpmvEngine { mode, pool: ParallelPool::new() }
    }

    pub fn mode(&self) -> Engine {
        self.mode
    }

    /// Run one SpMV `y = Mx` with the chosen variant on this engine.
    /// Semantics and outputs are bitwise identical across engines.
    pub fn run(
        &mut self,
        variant: Variant,
        state: &mut SpmvState,
        analysis: Option<&Analysis>,
    ) -> ExecOutcome {
        match self.mode {
            Engine::Sequential => run_variant(variant, state, analysis),
            Engine::Parallel => self.pool.run(variant, state, analysis),
        }
    }

    /// Run one split-phase overlapped UPCv3 SpMV (`begin_exchange` →
    /// interior rows → `finish_exchange` → boundary rows) on this engine.
    /// Output and counters are bitwise identical to `run(Variant::V3, ..)`;
    /// only the synchronization structure differs — see
    /// [`ParallelPool::run_v3_overlapped`].
    pub fn run_overlapped(&mut self, state: &mut SpmvState, analysis: &Analysis) -> ExecOutcome {
        self.pool.run_v3_overlapped(self.mode, state, analysis)
    }

    /// Run `steps` pipelined UPCv3 iterations (each with the §6.1 `x`/`y`
    /// swap) in one pool dispatch, bounded only by the consumed-epoch ack
    /// protocol. Bitwise identical to `steps` × (`run(Variant::V3, ..)` +
    /// `swap_xy`), with the final iterate left in `state.y` like a single
    /// `run` — see [`ParallelPool::run_v3_pipelined`].
    pub fn run_pipelined(
        &mut self,
        steps: usize,
        state: &mut SpmvState,
        analysis: &Analysis,
    ) -> ExecOutcome {
        self.pool.run_v3_pipelined(self.mode, steps, state, analysis)
    }

    /// Largest `published − consumed` epoch distance observed across this
    /// engine's pipelined batches — bounded by the consumed-epoch ack
    /// protocol's depth D. See [`ParallelPool::max_sender_lead`].
    pub fn max_sender_lead(&self) -> u64 {
        self.pool.max_sender_lead()
    }

    /// The configured pipeline depth D ([`ParallelPool::depth`]).
    pub fn depth(&self) -> usize {
        self.pool.depth()
    }

    /// Reconfigure the pipeline depth D between steps
    /// ([`ParallelPool::set_depth`]).
    pub fn set_depth(&mut self, depth: usize) {
        self.pool.set_depth(depth);
    }

    /// Tune the wait ladder every protocol wait spins through
    /// ([`WorkerPool::set_wait_tuning`]).
    pub fn set_wait_tuning(&mut self, tuning: WaitTuning) {
        self.pool.set_wait_tuning(tuning);
    }

    /// Bound every protocol wait by `deadline` (`None` = unbounded). See
    /// [`WorkerPool::set_wait_deadline`].
    pub fn set_wait_deadline(&mut self, deadline: Option<Duration>) {
        self.pool.set_wait_deadline(deadline);
    }

    /// The current wait deadline.
    pub fn wait_deadline(&self) -> Option<Duration> {
        self.pool.wait_deadline()
    }

    /// Install a fault plan for chaos testing ([`ParallelPool::set_fault_plan`]).
    pub fn set_fault_plan(&mut self, faults: FaultPlan) {
        self.pool.set_fault_plan(faults);
    }

    /// Remove any installed fault plan.
    pub fn clear_faults(&mut self) {
        self.pool.clear_faults();
    }

    /// Watchdog + progress snapshot of the underlying worker pool.
    pub fn health(&self) -> PoolHealth {
        self.pool.health()
    }

    /// Take a checkpoint of the SpMV time-stepping state as of `step`
    /// completed applications, stamped with the live plan's fingerprint and
    /// the engine's pipeline depth.
    pub fn checkpoint(&self, step: u64, state: &SpmvState, analysis: &Analysis) -> SpmvCheckpoint {
        SpmvCheckpoint {
            step,
            plan_hash: analysis.plan.fingerprint(),
            depth: self.depth(),
            x: state.x_global(),
            y: state.y_global(),
        }
    }

    /// Restore a checkpoint taken by
    /// [`run_pipelined_checkpointed`](Self::run_pipelined_checkpointed):
    /// verifies the plan fingerprint, rebuilds `x`/`y`, and performs the
    /// inter-batch pointer swap so the state is ready for the next batch
    /// (latest iterate in `x`). Returns the completed-step count to resume
    /// from. The engine's monotone exchange epochs are *not* reset — the
    /// pipelined ack gate skips a batch's first D epochs, so resuming is
    /// safe on a warm pool and on a fresh one alike (at any depth).
    pub fn restore(
        &mut self,
        ck: &SpmvCheckpoint,
        state: &mut SpmvState,
        analysis: &Analysis,
    ) -> Result<u64, String> {
        checkpoint::check_plan_hash("spmv", analysis.plan.fingerprint(), ck.plan_hash)?;
        checkpoint::check_depth("spmv", self.depth(), ck.depth)?;
        state.restore_from(&ck.x, &ck.y);
        state.swap_xy();
        Ok(ck.step)
    }

    /// Run `steps` pipelined UPCv3 iterations in batches of `every`,
    /// handing a checkpoint to `sink` after each batch. The result is
    /// bitwise identical to one `run_pipelined(steps, ..)` call — batching
    /// splits the schedule at swap boundaries, which the protocol already
    /// guarantees to be equivalent — and counters accumulate over the whole
    /// run. A run killed mid-batch resumes from the last sinked checkpoint
    /// via [`restore`](Self::restore) followed by
    /// `run_pipelined_checkpointed(steps - resumed, every, ..)`.
    pub fn run_pipelined_checkpointed(
        &mut self,
        steps: usize,
        every: usize,
        state: &mut SpmvState,
        analysis: &Analysis,
        sink: &mut dyn FnMut(SpmvCheckpoint),
    ) -> ExecOutcome {
        if steps == 0 {
            return self.run_pipelined(0, state, analysis);
        }
        let every = every.max(1);
        let mut done = 0usize;
        let mut inter = 0u64;
        let mut transfers = 0u64;
        let mut last = None;
        while done < steps {
            if done > 0 {
                state.swap_xy();
            }
            let batch = (steps - done).min(every);
            let out = self.run_pipelined(batch, state, analysis);
            inter += out.inter_thread_bytes;
            transfers += out.transfers;
            last = Some(out);
            done += batch;
            sink(self.checkpoint(done as u64, state, analysis));
        }
        let mut out = last.expect("steps > 0 ran at least one batch");
        out.inter_thread_bytes = inter;
        out.transfers = transfers;
        out
    }
}

/// One-shot convenience: run a variant on a fresh engine of the given mode.
/// Time-stepping callers should hold a [`SpmvEngine`] instead so the
/// parallel pool's workspaces persist across steps.
pub fn run_variant_on(
    engine: Engine,
    variant: Variant,
    state: &mut SpmvState,
    analysis: Option<&Analysis>,
) -> ExecOutcome {
    SpmvEngine::new(engine).run(variant, state, analysis)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Ellpack;
    use crate::pgas::{Layout, Topology};

    fn analysis_for(m: &Ellpack, bs: usize, nodes: usize, tpn: usize) -> Analysis {
        let layout = Layout::new(m.n, bs, nodes * tpn);
        Analysis::build(&m.j, m.r_nz, layout, Topology::new(nodes, tpn), usize::MAX)
    }

    #[test]
    fn parallel_engine_matches_oracle_bitwise() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let x0 = m.initial_vector(23);
        let analysis = analysis_for(&m, 128, 2, 4);
        let mut pool = SpmvEngine::new(Engine::Parallel);
        for v in Variant::ALL {
            let mut seq_state = SpmvState::new(&m, 128, 8, &x0);
            let want = run_variant(v, &mut seq_state, Some(&analysis));
            let mut par_state = SpmvState::new(&m, 128, 8, &x0);
            let got = pool.run(v, &mut par_state, Some(&analysis));
            assert_eq!(got.y, want.y, "{}: y diverges", v.name());
            assert_eq!(
                got.inter_thread_bytes, want.inter_thread_bytes,
                "{}: byte counts diverge",
                v.name()
            );
            assert_eq!(got.transfers, want.transfers, "{}: transfer counts diverge", v.name());
            assert_eq!(par_state.y_global(), seq_state.y_global(), "{}: shared y", v.name());
        }
    }

    #[test]
    fn pool_survives_layout_changes() {
        // One pool reused across different (n, threads) shapes must resize
        // its workspaces, not corrupt results.
        let mut pool = SpmvEngine::new(Engine::Parallel);
        for (n, rnz, bs, threads, seed) in
            [(60usize, 3usize, 4usize, 6usize, 1u64), (200, 5, 16, 3, 2), (97, 2, 8, 5, 3)]
        {
            let m = Ellpack::random(n, rnz, seed);
            let x0 = m.initial_vector(seed);
            let layout = Layout::new(n, bs, threads);
            let analysis =
                Analysis::build(&m.j, m.r_nz, layout, Topology::single_node(threads), usize::MAX);
            let mut want = vec![0.0; n];
            m.spmv_seq(&x0, &mut want);
            for v in Variant::ALL {
                let mut state = SpmvState::new(&m, bs, threads, &x0);
                let out = pool.run(v, &mut state, Some(&analysis));
                assert_eq!(out.y, want, "{} diverges at n={n}", v.name());
            }
        }
    }

    #[test]
    fn time_loop_parallel_equals_sequential() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let x0 = m.initial_vector(4);
        let analysis = analysis_for(&m, 64, 1, 4);
        let mut finals: Vec<Vec<f64>> = Vec::new();
        for mode in Engine::ALL {
            let mut engine = SpmvEngine::new(mode);
            let mut state = SpmvState::new(&m, 64, 4, &x0);
            for _ in 0..5 {
                engine.run(Variant::V3, &mut state, Some(&analysis));
                state.swap_xy();
            }
            finals.push(state.x_global());
        }
        assert_eq!(finals[0], finals[1]);
    }

    #[test]
    fn engine_parse_roundtrip() {
        assert_eq!(Engine::parse("seq"), Some(Engine::Sequential));
        assert_eq!(Engine::parse("Parallel"), Some(Engine::Parallel));
        assert_eq!(Engine::parse("par"), Some(Engine::Parallel));
        assert_eq!(Engine::parse("bogus"), None);
        for e in Engine::ALL {
            assert_eq!(Engine::parse(e.name()), Some(e));
        }
    }
}
