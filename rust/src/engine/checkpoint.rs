//! Checkpoint/restart for the pipelined solver drivers.
//!
//! A checkpoint is an in-memory snapshot of everything a solver needs to
//! resume a killed pipelined batch bitwise-identically: both field buffers
//! (current *and* scratch — the Jacobi update reads one and writes the
//! other, and fixed-boundary points are copied through, so both halves
//! carry state), the step count, the byte counter, and a structural
//! fingerprint of the compiled exchange plan
//! ([`ExchangePlan::fingerprint`](crate::comm::ExchangePlan::fingerprint)).
//!
//! The fingerprint is RNG-free and address-free, so it is stable across
//! runs and processes; `restore` refuses a checkpoint whose fingerprint
//! does not match the live plan, which catches "resumed onto a different
//! decomposition" bugs before they corrupt fields. Two more identity
//! checks ride along:
//!
//! * the **pipeline depth** — a batch checkpointed at `--depth 3` must not
//!   silently resume under depth 2 (the schedules are bitwise-equal, but
//!   the run's recorded configuration would lie, and a depth-1 resume of a
//!   deep batch changes the stall envelope the run was validated under);
//! * the **plan generation** — with the versioned plan lifecycle a
//!   fingerprint match alone is necessary but not sufficient bookkeeping:
//!   generation `g` under one delta history and generation `g'` under
//!   another can coincide structurally, yet the runtimes disagree about
//!   how many rebuilds happened (and will disagree about every future
//!   chain fingerprint). Restore requires both to match.
//!
//! Checkpoints deliberately stay in memory as `f64` vectors rather than a
//! serialized file format: the acceptance bar is *bitwise* identity with an
//! uninterrupted run, and a text round-trip (JSON) cannot guarantee that.
//! Restore is safe from any epoch: a restored runtime keeps its monotone
//! epoch counters (they are never reset), and the pipelined ack gate skips
//! a batch's first two epochs, so no stale ack can gate a resumed batch.

/// Snapshot of a grid solver (heat2d / stencil3d) between pipelined
/// batches.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Completed time steps at the moment of the snapshot.
    pub step: u64,
    /// [`ExchangePlan::fingerprint`](crate::comm::ExchangePlan::fingerprint)
    /// of the plan the snapshot was taken under.
    pub plan_hash: u64,
    /// Pipeline depth D the batch ran at; restore rejects a mismatch.
    pub depth: usize,
    /// Plan generation the snapshot was taken under
    /// ([`ExchangeRuntime::generation`](crate::engine::ExchangeRuntime::generation));
    /// restore rejects a mismatch.
    pub generation: u64,
    /// Per-thread primary fields (`phi`).
    pub fields: Vec<Vec<f64>>,
    /// Per-thread scratch fields (`phin`).
    pub scratch: Vec<Vec<f64>>,
    /// The solver's cumulative traffic counter, restored so resumed runs
    /// report the same totals as uninterrupted ones.
    pub inter_thread_bytes: u64,
}

/// Snapshot of the SpMV pipelined driver between batches: the global `x`
/// and `y` vectors (the per-thread shared blocks are rebuilt from them on
/// restore).
#[derive(Debug, Clone)]
pub struct SpmvCheckpoint {
    /// Completed SpMV applications at the moment of the snapshot.
    pub step: u64,
    /// Fingerprint of the communication plan
    /// ([`crate::comm::CommPlan::fingerprint`]).
    pub plan_hash: u64,
    /// Pipeline depth D the batch ran at; restore rejects a mismatch.
    pub depth: usize,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
}

/// Shared restore-time validation: a checkpoint taken under one plan must
/// not be restored under another.
pub(crate) fn check_plan_hash(kind: &str, expected: u64, got: u64) -> Result<(), String> {
    if expected == got {
        Ok(())
    } else {
        Err(format!(
            "{kind} checkpoint plan hash {got:#018x} does not match the live plan {expected:#018x}"
        ))
    }
}

/// Shared restore-time validation: a batch checkpointed at depth D must be
/// resumed at depth D.
pub(crate) fn check_depth(kind: &str, live: usize, recorded: usize) -> Result<(), String> {
    if live == recorded {
        Ok(())
    } else {
        Err(format!(
            "{kind} checkpoint was taken at pipeline depth {recorded} but the live runtime \
             does not match (depth {live})"
        ))
    }
}

/// Shared restore-time validation: the snapshot's plan generation must be
/// the runtime's current one.
pub(crate) fn check_generation(kind: &str, live: u64, recorded: u64) -> Result<(), String> {
    if live == recorded {
        Ok(())
    } else {
        Err(format!(
            "{kind} checkpoint was taken under plan generation {recorded} but the live runtime \
             does not match (generation {live})"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_hash_check() {
        assert!(check_plan_hash("heat2d", 7, 7).is_ok());
        let err = check_plan_hash("spmv", 1, 2).unwrap_err();
        assert!(err.contains("spmv"), "{err}");
        assert!(err.contains("does not match"), "{err}");
    }

    #[test]
    fn depth_and_generation_checks() {
        assert!(check_depth("heat2d", 3, 3).is_ok());
        let err = check_depth("heat2d", 2, 3).unwrap_err();
        assert!(err.contains("depth 3"), "{err}");
        assert!(err.contains("does not match"), "{err}");
        assert!(check_generation("stencil3d", 4, 4).is_ok());
        let err = check_generation("stencil3d", 0, 2).unwrap_err();
        assert!(err.contains("generation 2"), "{err}");
        assert!(err.contains("does not match"), "{err}");
    }
}
