//! Deterministic fault injection for the exchange runtime.
//!
//! A [`FaultPlan`] is a small, seedable list of [`Fault`]s threaded through
//! [`ExchangeRuntime`](super::ExchangeRuntime) and
//! [`ParallelPool`](super::ParallelPool). The protocol drivers consult it at
//! every phase transition, publish and ack, so a test (or the `repro chaos`
//! subcommand) can wedge one worker in a precisely chosen way — delay or
//! drop a publish/ack, panic at a protocol phase, slow a receiver — and
//! assert that the deadline/watchdog machinery converts the fault into a
//! structured [`StallError`](super::StallError) or poisoned dispatch
//! instead of a hang.
//!
//! Faults only act on the parallel engine's protocol paths; the sequential
//! oracle never consults the plan (there is no concurrency to wedge).
//!
//! Drop faults are *sticky*: `DropPublish`/`DropAck` suppress every publish
//! from the chosen epoch onward. A one-shot drop would self-heal on a
//! monotone flag — the very next epoch's publish satisfies any waiter
//! stalled on the dropped one — which is not what a wedged peer looks like.
//! Delay and slow faults are sticky for the same reason, except
//! `DelayPublish`/`DelayAck`, which fire once at their exact epoch (one
//! long stall is what they model).

use std::time::Duration;

use super::pool::Phase;
use crate::util::Rng;

/// What the injected fault does to the chosen thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Sleep for the duration just before publishing the chosen epoch.
    DelayPublish(Duration),
    /// Suppress the publish of the chosen epoch and every later one.
    DropPublish,
    /// Sleep for the duration just before acking the chosen epoch.
    DelayAck(Duration),
    /// Suppress the ack of the chosen epoch and every later one.
    DropAck,
    /// Panic when the thread enters the given phase at the chosen epoch.
    PanicAt(Phase),
    /// Sleep for the duration before unpacking, at the chosen epoch and
    /// every later one — a persistently slow receiver.
    SlowReceiver(Duration),
}

/// One injected fault: which logical thread, from which epoch, doing what.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    pub thread: usize,
    pub epoch: u64,
    pub kind: FaultKind,
}

/// A deterministic set of injected faults (usually one). Cheap to clone and
/// consult; an empty plan's hooks are a length check.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

/// Delay used by [`FaultPlan::random`]'s delay/slow faults: long enough to
/// blow any test-sized deadline, short enough that the sleeping worker
/// drains quickly once the dispatch is poisoned.
pub const INJECTED_DELAY: Duration = Duration::from_millis(250);

impl FaultPlan {
    /// An empty plan (injects nothing). Same as `FaultPlan::default()`.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Builder: add one fault.
    pub fn with(mut self, thread: usize, epoch: u64, kind: FaultKind) -> FaultPlan {
        self.faults.push(Fault { thread, epoch, kind });
        self
    }

    /// One random fault, fully determined by `seed`, targeting a thread in
    /// `0..threads` and an epoch in `1..=epochs`. Delay/slow kinds use
    /// [`INJECTED_DELAY`].
    pub fn random(seed: u64, threads: usize, epochs: u64) -> FaultPlan {
        let mut rng = Rng::new(seed);
        let thread = rng.usize_in(0, threads.max(1));
        let epoch = 1 + rng.next_below(epochs.max(1));
        let kind = match rng.next_below(6) {
            0 => FaultKind::DelayPublish(INJECTED_DELAY),
            1 => FaultKind::DropPublish,
            2 => FaultKind::DelayAck(INJECTED_DELAY),
            3 => FaultKind::DropAck,
            4 => FaultKind::PanicAt(Phase::Pack),
            _ => FaultKind::SlowReceiver(INJECTED_DELAY),
        };
        FaultPlan::default().with(thread, epoch, kind)
    }

    /// Whether the plan injects anything at all.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// The faults, for reporting.
    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Hook: thread `t` enters `phase` at `epoch`. Panics if a matching
    /// [`FaultKind::PanicAt`] is planned.
    pub fn on_phase(&self, t: usize, epoch: u64, phase: Phase) {
        for f in &self.faults {
            if f.thread != t || f.epoch != epoch || f.kind != FaultKind::PanicAt(phase) {
                continue;
            }
            panic!("injected fault: worker {t} panics at phase {phase}, epoch {epoch}");
        }
    }

    /// Hook: thread `t` is about to publish `epoch`. Sleeps through a
    /// matching delay; returns `false` if the publish must be suppressed
    /// (sticky drop).
    #[must_use]
    pub fn before_publish(&self, t: usize, epoch: u64) -> bool {
        let mut go = true;
        for f in &self.faults {
            if f.thread != t {
                continue;
            }
            match f.kind {
                FaultKind::DelayPublish(d) if f.epoch == epoch => std::thread::sleep(d),
                FaultKind::DropPublish if epoch >= f.epoch => go = false,
                _ => {}
            }
        }
        go
    }

    /// Hook: thread `t` is about to publish its consumed-epoch ack for
    /// `epoch`. Same semantics as [`before_publish`](Self::before_publish).
    #[must_use]
    pub fn before_ack(&self, t: usize, epoch: u64) -> bool {
        let mut go = true;
        for f in &self.faults {
            if f.thread != t {
                continue;
            }
            match f.kind {
                FaultKind::DelayAck(d) if f.epoch == epoch => std::thread::sleep(d),
                FaultKind::DropAck if epoch >= f.epoch => go = false,
                _ => {}
            }
        }
        go
    }

    /// Hook: thread `t` is about to unpack `epoch` — a
    /// [`FaultKind::SlowReceiver`] sleeps here, every epoch from its chosen
    /// one onward.
    pub fn before_unpack(&self, t: usize, epoch: u64) {
        for f in &self.faults {
            if f.thread == t {
                if let FaultKind::SlowReceiver(d) = f.kind {
                    if epoch >= f.epoch {
                        std::thread::sleep(d);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_inert() {
        let plan = FaultPlan::none();
        assert!(plan.is_empty());
        assert!(plan.before_publish(0, 1));
        assert!(plan.before_ack(3, 9));
        plan.on_phase(0, 1, Phase::Pack);
        plan.before_unpack(2, 4);
    }

    #[test]
    fn drop_publish_is_sticky() {
        let plan = FaultPlan::none().with(1, 3, FaultKind::DropPublish);
        assert!(plan.before_publish(1, 1));
        assert!(plan.before_publish(1, 2));
        assert!(!plan.before_publish(1, 3));
        assert!(!plan.before_publish(1, 4), "drop must persist past its epoch");
        assert!(plan.before_publish(0, 3), "other threads unaffected");
        assert!(plan.before_ack(1, 3), "acks unaffected by a publish drop");
    }

    #[test]
    fn drop_ack_is_sticky() {
        let plan = FaultPlan::none().with(0, 2, FaultKind::DropAck);
        assert!(plan.before_ack(0, 1));
        assert!(!plan.before_ack(0, 2));
        assert!(!plan.before_ack(0, 7));
        assert!(plan.before_publish(0, 2), "publishes unaffected by an ack drop");
    }

    #[test]
    #[should_panic(expected = "injected fault")]
    fn panic_at_matching_phase_fires() {
        let plan = FaultPlan::none().with(2, 5, FaultKind::PanicAt(Phase::Boundary));
        plan.on_phase(2, 5, Phase::Pack); // wrong phase: no-op
        plan.on_phase(2, 4, Phase::Boundary); // wrong epoch: no-op
        plan.on_phase(2, 5, Phase::Boundary); // fires
    }

    #[test]
    fn random_plans_are_deterministic() {
        let a = FaultPlan::random(99, 4, 8);
        let b = FaultPlan::random(99, 4, 8);
        assert_eq!(a.faults(), b.faults());
        assert_eq!(a.faults().len(), 1);
        let f = a.faults()[0];
        assert!(f.thread < 4);
        assert!((1..=8).contains(&f.epoch));
        // Different seeds eventually cover every kind.
        let mut kinds = std::collections::BTreeSet::new();
        for seed in 0..64u64 {
            let f = FaultPlan::random(seed, 4, 8).faults()[0];
            kinds.insert(match f.kind {
                FaultKind::DelayPublish(_) => 0u8,
                FaultKind::DropPublish => 1,
                FaultKind::DelayAck(_) => 2,
                FaultKind::DropAck => 3,
                FaultKind::PanicAt(_) => 4,
                FaultKind::SlowReceiver(_) => 5,
            });
        }
        assert_eq!(kinds.len(), 6, "64 seeds must cover all fault kinds");
    }
}
