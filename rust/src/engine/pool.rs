//! The persistent worker pool: long-lived OS threads, a reusable barrier,
//! and the disjoint-access views the zero-copy executors hand their workers.
//!
//! The seed engine paid one `std::thread::scope` — thread creation, stack
//! allocation, scheduler wakeup and join — per time step *and per phase*.
//! [`WorkerPool`] amortizes all of that to once per run shape: workers are
//! spawned the first time a shape is dispatched and then sit on a condvar;
//! a step costs one lock + wakeup on dispatch, a [`WorkerCtx::barrier`] wait
//! per phase boundary (the `upc_barrier` of Listings 5 & 7), and one
//! completion notification — no allocation, no thread creation.
//!
//! Two small unsafe views make the shared-closure dispatch model work
//! without per-step boxing:
//!
//! * [`PerWorker`] — hands worker `t` the `&mut` element `t` of a slice
//!   (per-thread fields, workspaces, counters). Sound because worker ids are
//!   distinct, so each element is claimed by exactly one thread per
//!   dispatch.
//! * [`ArenaView`] — hands out disjoint `&mut` ranges of the flat staging
//!   arena (a compiled plan's per-message slots). Sound because plan ranges
//!   partition the arena, every range is packed by exactly one sender before
//!   the barrier and only read after it.

use std::any::Any;
use std::fmt;
use std::marker::PhantomData;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default per-wait deadline: generous enough that no healthy workload on
/// any CI machine comes near it, small enough that a genuinely wedged peer
/// converts into a [`StallError`] instead of an infinite hang.
pub const DEFAULT_WAIT_DEADLINE: Duration = Duration::from_secs(30);

/// The spin → yield → timed-park wait-ladder constants, consolidated.
///
/// Every flag wait in the system climbs the same ladder: a burst of
/// clock-free spins (the peer is usually one store away), then
/// scheduler-yield rounds (waits in the scheduling-quantum range), then
/// timed parks (long waits burn no CPU but still poll the flag, the
/// poison flag and the deadline). Before this struct the rungs were
/// magic numbers scattered across [`WorkerCtx::wait_flag`], the
/// free-function `wait_epoch_flag` in the transport layer, and the socket
/// mailbox's condvar slices; they now live here, documented once, and are
/// configurable per pool via [`WorkerPool::set_wait_tuning`] (threaded
/// from `RunConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaitTuning {
    /// Clock-free `spin_loop` iterations before the ladder starts
    /// consulting the clock at all. Covers the common case where the
    /// awaited store is already in flight.
    pub spin: u32,
    /// `yield_now` rounds after the spin burst. Each yield donates the
    /// rest of the quantum, so this rung covers waits up to a few
    /// scheduling quanta without the latency cost of a park.
    pub yield_rounds: u32,
    /// `park_timeout` slice once yielding is exhausted: long waits poll
    /// the flag/poison/deadline once per slice and otherwise sleep.
    pub park: Duration,
    /// Condvar-wait slice for the socket transport's mailbox waits (the
    /// blocking analogue of `park` — sliced so deadline and shutdown are
    /// observed promptly even when no frame ever arrives).
    pub socket_slice: Duration,
}

impl Default for WaitTuning {
    /// The historical constants: 128 spins, 4096 yield rounds, 100 µs
    /// parks, 50 ms socket condvar slices.
    fn default() -> WaitTuning {
        WaitTuning {
            spin: 128,
            yield_rounds: 4096,
            park: Duration::from_micros(100),
            socket_slice: Duration::from_millis(50),
        }
    }
}

/// The protocol phase a worker is in, as advertised through
/// [`WorkerCtx::note_phase`] and reported by the stall watchdog and
/// [`StallError`]. Packed into 3 bits of a progress word, so at most 8
/// variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(u8)]
pub enum Phase {
    /// Not inside any protocol phase (fresh dispatch, or a job that does
    /// not report phases).
    #[default]
    Idle = 0,
    /// Waiting on receivers' consumed-epoch acks before reusing an arena
    /// half (pipelined back-pressure gate).
    AckGate = 1,
    /// Packing boundary values into the staging arena.
    Pack = 2,
    /// Waiting for peers' publishes — the "transfer" of the simulated
    /// exchange.
    Transfer = 3,
    /// Unpacking received values into ghost cells.
    Unpack = 4,
    /// Computing boundary (halo-dependent) points.
    Boundary = 5,
    /// Parked at a full-pool barrier.
    Barrier = 6,
}

impl Phase {
    /// Human-readable name, used by `Display` impls and reports.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Idle => "idle",
            Phase::AckGate => "ack-gate",
            Phase::Pack => "pack",
            Phase::Transfer => "transfer",
            Phase::Unpack => "unpack",
            Phase::Boundary => "boundary",
            Phase::Barrier => "barrier",
        }
    }

    fn from_u8(v: u8) -> Phase {
        match v {
            1 => Phase::AckGate,
            2 => Phase::Pack,
            3 => Phase::Transfer,
            4 => Phase::Unpack,
            5 => Phase::Boundary,
            6 => Phase::Barrier,
            _ => Phase::Idle,
        }
    }
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A structured deadline-expiry error: worker `waiter` gave up waiting for
/// `peer` (or the whole pool, for a barrier) to reach `epoch` while in
/// `phase`. Raised via `panic_any` so it travels the exact same
/// poison-and-unwind path as a worker panic; dispatchers can recover it
/// with [`StallError::from_panic`] on the payload `catch_unwind` returns.
#[derive(Debug, Clone)]
pub struct StallError {
    /// The worker whose wait expired.
    pub waiter: usize,
    /// The peer whose flag never arrived; `None` for a pool barrier, where
    /// no single peer is identified.
    pub peer: Option<usize>,
    /// The epoch the waiter needed (for a barrier: the waiter's own last
    /// reported epoch).
    pub epoch: u64,
    /// The protocol phase the waiter was stalled in.
    pub phase: Phase,
    /// How long the waiter actually waited before giving up.
    pub waited: Duration,
    /// The transport identity of the absent peer (e.g. `inproc:worker-3`,
    /// `socket:rank-1@127.0.0.1:4710`), when one is known. `None` for pool
    /// barriers, where no single peer is identified.
    pub transport: Option<String>,
}

impl StallError {
    /// Downcast a caught panic payload back into the `StallError` it
    /// carries, if any. Generic worker panics (including the peers a stall
    /// poisons) return `None`.
    pub fn from_panic(payload: &(dyn Any + Send)) -> Option<&StallError> {
        payload.downcast_ref::<StallError>()
    }
}

impl fmt::Display for StallError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.peer {
            Some(p) => write!(
                f,
                "stall: worker {} waited {:.1?} for peer {} to reach epoch {} (phase {})",
                self.waiter, self.waited, p, self.epoch, self.phase
            )?,
            None => write!(
                f,
                "stall: worker {} waited {:.1?} at the pool barrier (epoch {})",
                self.waiter, self.waited, self.epoch
            )?,
        }
        if let Some(t) = &self.transport {
            write!(f, " via {t}")?;
        }
        Ok(())
    }
}

impl std::error::Error for StallError {}

/// What the stall watchdog observed: the lagging worker (lowest progress
/// word) after a no-progress window, with the phase and epoch it last
/// reported.
#[derive(Debug, Clone)]
pub struct StallReport {
    /// The worker with the least progress when the stall was detected.
    pub worker: usize,
    /// The epoch that worker last reported.
    pub epoch: u64,
    /// The phase that worker last reported.
    pub phase: Phase,
    /// How long the pool had made no progress when the report was taken.
    pub stalled_for: Duration,
}

impl fmt::Display for StallReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "watchdog: no progress for {:.1?}; lagging worker {} (phase {}, epoch {})",
            self.stalled_for, self.worker, self.phase, self.epoch
        )
    }
}

/// One worker's last-reported progress, as returned by
/// [`WorkerPool::health`].
#[derive(Debug, Clone)]
pub struct WorkerHealth {
    pub id: usize,
    pub epoch: u64,
    pub phase: Phase,
}

/// A point-in-time snapshot of the pool: every worker's last-reported
/// phase/epoch, whether a dispatch is in flight, and the watchdog's sticky
/// stall report (cleared at the next dispatch).
#[derive(Debug, Clone, Default)]
pub struct PoolHealth {
    pub workers: Vec<WorkerHealth>,
    pub in_flight: bool,
    pub stall: Option<StallReport>,
}

impl fmt::Display for PoolHealth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "pool health ({} workers, dispatch {}):",
            self.workers.len(),
            if self.in_flight { "in flight" } else { "idle" }
        )?;
        for w in &self.workers {
            writeln!(f, "  worker {}: phase {}, epoch {}", w.id, w.phase, w.epoch)?;
        }
        if let Some(s) = &self.stall {
            writeln!(f, "  {s}")?;
        }
        Ok(())
    }
}

/// One cache-line-padded progress word per worker: `epoch << 3 | phase`.
/// Written `Relaxed` by the owning worker (it is diagnostic state, not a
/// synchronization edge) and sampled by the watchdog thread and `health()`.
/// The 3-bit phase truncates epochs above 2^61 — far beyond any run.
#[derive(Debug, Default)]
#[repr(align(64))]
struct ProgressCell(AtomicU64);

/// Per-dispatch context a worker receives: its id, the dispatch width, and
/// the pool's reusable barrier for intra-step phase boundaries.
pub struct WorkerCtx<'p> {
    /// This worker's id in `0..workers` (the logical UPC thread it plays).
    pub id: usize,
    /// Number of workers in this dispatch.
    pub workers: usize,
    ctrl: &'p Control,
}

impl WorkerCtx<'_> {
    /// Block until every worker of the dispatch reaches this point — the
    /// `upc_barrier` between a plan's pack and unpack phases. The job
    /// closure must call it unconditionally (same count on every worker) or
    /// the pool deadlocks. Panics if a peer worker panicked this dispatch,
    /// so a failing worker releases the others instead of stranding them.
    ///
    /// Deadline-aware: if the pool has a wait deadline configured (it does
    /// by default, [`DEFAULT_WAIT_DEADLINE`]) and the cohort does not form
    /// within it, this poisons the dispatch and raises a [`StallError`]
    /// with `phase == Barrier`, so one absent worker cannot strand the
    /// rest forever.
    pub fn barrier(&self) {
        let deadline = self.ctrl.deadline();
        match self.ctrl.barrier.wait_deadline(self.workers, deadline) {
            BarrierWait::Released => {}
            BarrierWait::Poisoned => {
                panic!("a pool worker panicked during this dispatch")
            }
            BarrierWait::TimedOut(waited) => {
                self.ctrl.barrier.poison();
                let word = self.ctrl.progress[self.id].0.load(Ordering::Relaxed);
                std::panic::panic_any(StallError {
                    waiter: self.id,
                    peer: None,
                    epoch: word >> 3,
                    phase: Phase::Barrier,
                    waited,
                    transport: None,
                });
            }
        }
    }

    /// Advertise the protocol phase this worker is entering at `epoch`.
    /// Purely diagnostic (`Relaxed` store into this worker's progress
    /// cell): the watchdog and [`WorkerPool::health`] read it to name the
    /// lagging worker and phase when progress stops.
    pub fn note_phase(&self, phase: Phase, epoch: u64) {
        self.ctrl.progress[self.id].0.store((epoch << 3) | phase as u64, Ordering::Relaxed);
    }

    /// The split-phase wait primitive: spin (then yield) until `flag`
    /// reaches `target` — the per-peer arrival wait of `finish_exchange`,
    /// replacing the global barrier with a wait on exactly the peers that
    /// send to this worker.
    ///
    /// Ordering: the load is `Acquire` and pairs with the sender's `Release`
    /// publish ([`EpochFlags::publish`]). The sender's pack writes are
    /// sequenced before its publish; observing `flag >= target` therefore
    /// gives a happens-before edge that makes every packed arena value of
    /// that epoch visible to the unpack reads that follow this wait. No
    /// stronger (SeqCst) ordering is needed: each flag is a single-writer
    /// monotone counter and the protocol never reasons about the relative
    /// order of *different* threads' publishes.
    ///
    /// Preserves the poisoned-barrier panic-propagation semantics: if a peer
    /// worker panics before publishing, the pool poisons the dispatch and
    /// this wait panics too instead of spinning forever. Additionally
    /// deadline-aware (see [`wait_flag`](Self::wait_flag) internals): a
    /// peer that never publishes converts into a [`StallError`] naming
    /// `peer` and `target` instead of an unbounded spin.
    pub fn wait_for_epoch(&self, flag: &AtomicU64, target: u64, peer: usize) {
        self.wait_flag(flag, target, peer, Phase::Transfer);
    }

    /// The pipeline back-pressure wait: spin until a *consumed-epoch* flag
    /// (a receiver's "I have unpacked epoch k" counter) reaches `target`.
    /// A sender packing epoch `e` into the depth-D arena waits for each of
    /// its receivers' acks to reach `e − D` first, so it never overwrites a
    /// buffer slot a slow receiver is still draining — and, equivalently,
    /// never runs more than D epochs ahead of its slowest receiver.
    ///
    /// Ordering: `Acquire`, pairing with the receiver's `Release` ack
    /// publish. The receiver's unpack *reads* are sequenced before its ack;
    /// observing `ack >= target` orders those reads before this sender's
    /// subsequent overwrites of the same arena slots — the reuse edge of the
    /// pipelined protocol (the publish edge is documented on
    /// [`wait_for_epoch`](WorkerCtx::wait_for_epoch)).
    ///
    /// Poison-aware exactly like `wait_for_epoch`: a peer panic releases
    /// this wait with a panic instead of a hang.
    pub fn wait_for_ack(&self, flag: &AtomicU64, target: u64, peer: usize) {
        self.wait_flag(flag, target, peer, Phase::AckGate);
    }

    /// The spin → yield → timed-park ladder shared by both flag waits; rung
    /// sizes come from the pool's [`WaitTuning`] (defaults documented
    /// there).
    ///
    /// * clock-free spins cover the common case (the peer is one store
    ///   away);
    /// * then yielding rounds, still cheap, for waits in the scheduling-
    ///   quantum range;
    /// * then `park_timeout` slices, so a long wait burns no CPU while
    ///   still polling the flag, the poison flag, and the deadline.
    ///
    /// On deadline expiry the waiter poisons the dispatch (releasing every
    /// peer parked at a barrier or flag wait) and raises a structured
    /// [`StallError`] identifying itself, the absent peer, the epoch it
    /// needed and the protocol phase it stalled in.
    fn wait_flag(&self, flag: &AtomicU64, target: u64, peer: usize, phase: Phase) {
        let tuning = self.ctrl.wait_tuning();
        for _ in 0..tuning.spin {
            if flag.load(Ordering::Acquire) >= target {
                return;
            }
            std::hint::spin_loop();
        }
        let deadline = self.ctrl.deadline();
        let start = Instant::now();
        let mut rounds = 0u32;
        loop {
            if flag.load(Ordering::Acquire) >= target {
                return;
            }
            if self.ctrl.barrier.is_poisoned() {
                panic!("a pool worker panicked during this dispatch");
            }
            if let Some(d) = deadline {
                let waited = start.elapsed();
                if waited >= d {
                    self.ctrl.barrier.poison();
                    std::panic::panic_any(StallError {
                        waiter: self.id,
                        peer: Some(peer),
                        epoch: target,
                        phase,
                        waited,
                        transport: Some(format!("inproc:worker-{peer}")),
                    });
                }
            }
            rounds += 1;
            if rounds < tuning.yield_rounds {
                std::thread::yield_now();
            } else {
                std::thread::park_timeout(tuning.park);
            }
        }
    }
}

/// One cache-line-padded monotone epoch counter per logical thread. Two
/// instances drive the split-phase protocols: a *published* set (thread
/// `t`'s counter is the epoch of the last exchange `t` fully packed every
/// outgoing message of; receivers in `finish_exchange` wait on the counters
/// of their actual senders) and, for the pipelined driver, a *consumed* set
/// (the epoch `t` last finished unpacking; senders wait on the counters of
/// their actual receivers before reusing an arena half).
///
/// Publishes are `Release` stores and waits are `Acquire` loads — the
/// required happens-before edges are documented on
/// [`WorkerCtx::wait_for_epoch`] and [`WorkerCtx::wait_for_ack`]; each
/// counter has exactly one writer, so no stronger ordering is needed.
///
/// The counters are monotone across steps and survive pool dispatches, so a
/// runtime can keep one `EpochFlags` for its whole lifetime; padding keeps
/// the per-thread stores from false-sharing the waiters' loads.
///
/// # u64 epoch semantics
///
/// Epochs are plain `u64` counters that start at 0 (nothing published) and
/// only ever grow; they are never reset and never wrap in practice (at one
/// epoch per nanosecond, overflow takes ~584 years), so the protocol code
/// compares them with ordinary `>=` and no wraparound handling exists
/// anywhere. All protocols that share a set of flags (sync, overlapped,
/// pipelined) must also share a single monotone epoch source — the runtime
/// owns one `epoch` counter and bumps it for every step regardless of
/// protocol, which is what makes protocol mixing safe. [`publish`]
/// (EpochFlags::publish) enforces the invariant: moving a flag backwards
/// is a protocol bug and panics immediately.
#[derive(Debug, Default)]
pub struct EpochFlags {
    flags: Vec<PaddedEpoch>,
}

#[derive(Debug, Default)]
#[repr(align(64))]
struct PaddedEpoch(AtomicU64);

impl EpochFlags {
    /// Flags for `threads` logical threads, all at epoch 0 (nothing
    /// published yet).
    pub fn new(threads: usize) -> EpochFlags {
        EpochFlags { flags: (0..threads).map(|_| PaddedEpoch::default()).collect() }
    }

    pub fn len(&self) -> usize {
        self.flags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.flags.is_empty()
    }

    /// Thread `t`'s published-epoch counter.
    pub fn flag(&self, t: usize) -> &AtomicU64 {
        &self.flags[t].0
    }

    /// Publish: thread `t` finished packing (published set) or unpacking
    /// (consumed set) every message of `epoch`. `Release`: orders the pack
    /// writes / unpack reads of the epoch before the store — see
    /// [`WorkerCtx::wait_for_epoch`] / [`WorkerCtx::wait_for_ack`] for the
    /// matching `Acquire` side.
    ///
    /// Panics if the publish would move the flag backwards: each flag is a
    /// single-writer monotone counter, so a smaller epoch means two
    /// protocol drivers disagree about the shared epoch sequence (e.g. a
    /// driver kept a private counter instead of the runtime's). The check
    /// is a `Relaxed` load of the writer's own cache line — effectively
    /// free — so it is enforced in release builds too.
    pub fn publish(&self, t: usize, epoch: u64) {
        let prev = self.flags[t].0.load(Ordering::Relaxed);
        assert!(
            epoch >= prev,
            "EpochFlags::publish would move thread {t}'s flag backwards ({prev} -> {epoch})"
        );
        self.flags[t].0.store(epoch, Ordering::Release);
    }

    /// Snapshot of thread `t`'s counter (`Acquire`, same edge as the waits).
    pub fn load(&self, t: usize) -> u64 {
        self.flags[t].0.load(Ordering::Acquire)
    }
}

/// A reusable sense-counting barrier that can be poisoned: when a worker
/// panics, [`poison`](PoolBarrier::poison) wakes every waiter and makes
/// every current and future `wait` of the dispatch panic too, so the whole
/// job unwinds instead of deadlocking (`std::sync::Barrier` has no
/// equivalent).
struct PoolBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
    /// Lock-free mirror of `BarrierState::poisoned` for the spin-wait of
    /// [`WorkerCtx::wait_for_epoch`] (checking the mutex per spin would
    /// serialize the waiters).
    poisoned_fast: AtomicBool,
}

struct BarrierState {
    /// Workers currently parked in `wait_deadline`.
    count: usize,
    /// Bumped each time a full cohort is released.
    generation: u64,
    poisoned: bool,
}

/// Outcome of [`PoolBarrier::wait_deadline`].
enum BarrierWait {
    /// The full cohort arrived.
    Released,
    /// A peer panicked (or stalled) and poisoned the dispatch.
    Poisoned,
    /// The deadline expired before the cohort formed; carries the actual
    /// wait time.
    TimedOut(Duration),
}

impl PoolBarrier {
    fn new() -> PoolBarrier {
        PoolBarrier {
            state: Mutex::new(BarrierState { count: 0, generation: 0, poisoned: false }),
            cv: Condvar::new(),
            poisoned_fast: AtomicBool::new(false),
        }
    }

    /// `Acquire`/`Release` with [`poison`](PoolBarrier::poison): the waiter
    /// only acts on the boolean itself (it panics), so even `Relaxed` would
    /// be correct — acquire is kept so the unwinding waiter also observes
    /// everything the panicking worker did first, which keeps panic messages
    /// and poisoned state coherent.
    fn is_poisoned(&self) -> bool {
        self.poisoned_fast.load(Ordering::Acquire)
    }

    /// Wait for the cohort, with an optional deadline. Returns instead of
    /// panicking so the caller ([`WorkerCtx::barrier`]) decides how each
    /// outcome unwinds; nothing panics while the state guard is held, so
    /// the mutex is never poisoned (waiters and `reset` keep using plain
    /// `unwrap`).
    fn wait_deadline(&self, workers: usize, deadline: Option<Duration>) -> BarrierWait {
        let mut st = self.state.lock().unwrap();
        if st.poisoned {
            return BarrierWait::Poisoned;
        }
        st.count += 1;
        if st.count == workers {
            st.count = 0;
            st.generation += 1;
            self.cv.notify_all();
            return BarrierWait::Released;
        }
        let gen = st.generation;
        let start = Instant::now();
        while st.generation == gen && !st.poisoned {
            match deadline {
                Some(d) => {
                    let waited = start.elapsed();
                    if waited >= d {
                        // Withdraw from the cohort so a late full count
                        // cannot release a generation this waiter already
                        // gave up on; the caller poisons next, which
                        // releases everyone else.
                        st.count -= 1;
                        return BarrierWait::TimedOut(waited);
                    }
                    st = self.cv.wait_timeout(st, d - waited).unwrap().0;
                }
                None => st = self.cv.wait(st).unwrap(),
            }
        }
        if st.poisoned {
            BarrierWait::Poisoned
        } else {
            BarrierWait::Released
        }
    }

    fn poison(&self) {
        self.poisoned_fast.store(true, Ordering::Release);
        self.state.lock().unwrap().poisoned = true;
        self.cv.notify_all();
    }

    /// Arm the barrier for a fresh dispatch. Sound because `run` only
    /// returns (and so only re-dispatches) once every worker has left the
    /// job — no thread can still be inside `wait`.
    fn reset(&self) {
        let mut st = self.state.lock().unwrap();
        st.count = 0;
        st.poisoned = false;
        self.poisoned_fast.store(false, Ordering::Release);
    }
}

/// The job pointer stored while a dispatch is in flight. The lifetime is
/// erased; soundness comes from `run` blocking until every worker finished.
type RawJob = *const (dyn Fn(WorkerCtx) + Sync);

struct State {
    /// Bumped once per dispatch; workers run the job when it advances.
    epoch: u64,
    job: Option<RawJob>,
    /// Workers that have not yet finished the current epoch.
    remaining: usize,
    /// First panic payload caught this dispatch; re-raised by `run`.
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

// SAFETY: the raw job pointer only crosses threads while `run` blocks the
// owner; the pointee is `Sync`, so shared calls from workers are sound.
unsafe impl Send for State {}

struct Control {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    barrier: PoolBarrier,
    /// Configured wait deadline in nanoseconds; 0 means "no deadline".
    /// Read `Relaxed` at the start of every flag/barrier wait.
    deadline_ns: AtomicU64,
    /// [`WaitTuning`] rungs, stored as atomics so reconfiguration takes
    /// effect on waits that start after the call without restarting the
    /// workers: spin count, yield rounds, park slice (ns), socket condvar
    /// slice (ns). All `Relaxed` — they are tuning knobs, not
    /// synchronization edges.
    tune_spin: AtomicU64,
    tune_yield_rounds: AtomicU64,
    tune_park_ns: AtomicU64,
    tune_socket_slice_ns: AtomicU64,
    /// One progress word per worker (see [`ProgressCell`]).
    progress: Vec<ProgressCell>,
    /// The watchdog's sticky stall report; cleared at each dispatch start.
    stall_report: Mutex<Option<StallReport>>,
}

impl Control {
    fn deadline(&self) -> Option<Duration> {
        match self.deadline_ns.load(Ordering::Relaxed) {
            0 => None,
            ns => Some(Duration::from_nanos(ns)),
        }
    }

    fn wait_tuning(&self) -> WaitTuning {
        WaitTuning {
            spin: self.tune_spin.load(Ordering::Relaxed) as u32,
            yield_rounds: self.tune_yield_rounds.load(Ordering::Relaxed) as u32,
            park: Duration::from_nanos(self.tune_park_ns.load(Ordering::Relaxed)),
            socket_slice: Duration::from_nanos(
                self.tune_socket_slice_ns.load(Ordering::Relaxed),
            ),
        }
    }

    fn store_wait_tuning(&self, t: WaitTuning) {
        self.tune_spin.store(t.spin as u64, Ordering::Relaxed);
        self.tune_yield_rounds.store(t.yield_rounds as u64, Ordering::Relaxed);
        self.tune_park_ns.store(t.park.as_nanos() as u64, Ordering::Relaxed);
        self.tune_socket_slice_ns.store(t.socket_slice.as_nanos() as u64, Ordering::Relaxed);
    }
}

/// A persistent pool of worker threads, one per logical UPC thread.
///
/// Created empty; `run(n, job)` lazily (re)spawns exactly `n` workers and
/// keeps them across calls, so steady-state time stepping never creates a
/// thread. Resizing (a run shape change) tears the old workers down and
/// spawns fresh ones — paid once per shape, like the plan compile itself.
///
/// Every pool also runs a low-cadence watchdog thread that samples the
/// workers' progress words and records a [`StallReport`] when an in-flight
/// dispatch makes no progress for a window — readable via
/// [`health`](WorkerPool::health) even before (or without) a wait deadline
/// converting the stall into a [`StallError`].
pub struct WorkerPool {
    workers: Vec<JoinHandle<()>>,
    control: Option<Arc<Control>>,
    watchdog: Option<JoinHandle<()>>,
    /// Deadline applied to every flag/barrier wait; `None` disables it
    /// (the pre-deadline unbounded behavior).
    deadline: Option<Duration>,
    /// Wait-ladder rung sizes applied to every flag wait.
    tuning: WaitTuning,
    /// Completed `run` calls — the protocol-level "how many wakeups did
    /// this cost" counter the pipelined driver's tests assert on (one
    /// dispatch per S-step batch).
    dispatches: u64,
}

impl Default for WorkerPool {
    fn default() -> WorkerPool {
        WorkerPool {
            workers: Vec::new(),
            control: None,
            watchdog: None,
            deadline: Some(DEFAULT_WAIT_DEADLINE),
            tuning: WaitTuning::default(),
            dispatches: 0,
        }
    }
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool").field("workers", &self.workers.len()).finish()
    }
}

impl WorkerPool {
    pub fn new() -> WorkerPool {
        WorkerPool::default()
    }

    /// Number of currently spawned workers (0 until the first dispatch).
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Number of `run` dispatches issued over the pool's lifetime.
    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    /// Set (or with `None`, disable) the deadline applied to every
    /// [`WorkerCtx::wait_for_epoch`] / [`WorkerCtx::wait_for_ack`] /
    /// [`WorkerCtx::barrier`] wait. Defaults to [`DEFAULT_WAIT_DEADLINE`].
    /// Takes effect for waits that *start* after the call.
    pub fn set_wait_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
        if let Some(control) = &self.control {
            let ns = deadline.map_or(0, |d| d.as_nanos() as u64);
            control.deadline_ns.store(ns, Ordering::Relaxed);
        }
    }

    /// The currently configured wait deadline.
    pub fn wait_deadline(&self) -> Option<Duration> {
        self.deadline
    }

    /// Set the wait-ladder rung sizes ([`WaitTuning`]) applied to every
    /// flag wait. Takes effect for waits that *start* after the call —
    /// live workers pick the new values up atomically, no respawn.
    pub fn set_wait_tuning(&mut self, tuning: WaitTuning) {
        self.tuning = tuning;
        if let Some(control) = &self.control {
            control.store_wait_tuning(tuning);
        }
    }

    /// The currently configured wait-ladder tuning.
    pub fn wait_tuning(&self) -> WaitTuning {
        self.tuning
    }

    /// Snapshot the pool's health: each worker's last-reported phase and
    /// epoch, whether a dispatch is in flight, and the watchdog's stall
    /// report if the current (or just-finished) dispatch stopped making
    /// progress.
    pub fn health(&self) -> PoolHealth {
        let Some(control) = &self.control else {
            return PoolHealth::default();
        };
        let workers = control
            .progress
            .iter()
            .enumerate()
            .map(|(id, cell)| {
                let word = cell.0.load(Ordering::Relaxed);
                let (epoch, phase) = (word >> 3, Phase::from_u8((word & 7) as u8));
                WorkerHealth { id, epoch, phase }
            })
            .collect();
        let in_flight = control.state.lock().unwrap().remaining > 0;
        let stall = control.stall_report.lock().unwrap().clone();
        PoolHealth { workers, in_flight, stall }
    }

    /// Run `job(ctx)` on every one of `n` persistent workers and block until
    /// all of them finished. The closure is shared (`Fn + Sync`): per-worker
    /// mutable state goes through [`PerWorker`] / [`ArenaView`].
    ///
    /// A panic inside the job is caught on the worker, poisons the barrier
    /// (releasing peers parked at a phase boundary), and is re-raised here
    /// once every worker has drained — the same observable behavior as the
    /// `std::thread::scope` join this pool replaced. Workers survive the
    /// panic, so the pool stays usable.
    pub fn run(&mut self, n: usize, job: &(dyn Fn(WorkerCtx) + Sync)) {
        assert!(n > 0, "cannot dispatch on zero workers");
        self.ensure(n);
        self.dispatches += 1;
        let control = self.control.as_ref().expect("ensure spawned workers");
        control.barrier.reset();
        // Fresh dispatch: workers start phase-less and the previous
        // dispatch's stall report (if any) is stale.
        for cell in &control.progress {
            cell.0.store(0, Ordering::Relaxed);
        }
        *control.stall_report.lock().unwrap() = None;
        // SAFETY: erase the borrow lifetime. The pointer is cleared and
        // never dereferenced again after the wait below observes that every
        // worker completed the epoch, which happens before `run` returns.
        let raw: RawJob = unsafe {
            std::mem::transmute::<&(dyn Fn(WorkerCtx) + Sync), RawJob>(job)
        };
        let mut st = control.state.lock().unwrap();
        st.job = Some(raw);
        st.remaining = n;
        st.epoch += 1;
        control.work_cv.notify_all();
        while st.remaining > 0 {
            st = control.done_cv.wait(st).unwrap();
        }
        st.job = None;
        if let Some(payload) = st.panic.take() {
            drop(st);
            std::panic::resume_unwind(payload);
        }
    }

    fn ensure(&mut self, n: usize) {
        if self.workers.len() == n {
            return;
        }
        self.teardown();
        let control = Arc::new(Control {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                remaining: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            barrier: PoolBarrier::new(),
            deadline_ns: AtomicU64::new(self.deadline.map_or(0, |d| d.as_nanos() as u64)),
            tune_spin: AtomicU64::new(self.tuning.spin as u64),
            tune_yield_rounds: AtomicU64::new(self.tuning.yield_rounds as u64),
            tune_park_ns: AtomicU64::new(self.tuning.park.as_nanos() as u64),
            tune_socket_slice_ns: AtomicU64::new(self.tuning.socket_slice.as_nanos() as u64),
            progress: (0..n).map(|_| ProgressCell::default()).collect(),
            stall_report: Mutex::new(None),
        });
        self.workers = (0..n)
            .map(|id| {
                let control = Arc::clone(&control);
                std::thread::Builder::new()
                    .name(format!("upc-worker-{id}"))
                    .spawn(move || worker_loop(id, n, &control))
                    .expect("spawn pool worker")
            })
            .collect();
        self.watchdog = Some({
            let control = Arc::clone(&control);
            std::thread::Builder::new()
                .name("upc-watchdog".to_string())
                .spawn(move || watchdog_loop(&control))
                .expect("spawn pool watchdog")
        });
        self.control = Some(control);
    }

    fn teardown(&mut self) {
        if let Some(control) = self.control.take() {
            control.state.lock().unwrap().shutdown = true;
            control.work_cv.notify_all();
            for w in self.workers.drain(..) {
                let _ = w.join();
            }
            if let Some(w) = self.watchdog.take() {
                let _ = w.join();
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.teardown();
    }
}

fn worker_loop(id: usize, workers: usize, control: &Control) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = control.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("epoch advanced without a job");
                }
                st = control.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: the leader keeps the closure alive until every worker
        // reports completion below. AssertUnwindSafe: on panic the leader
        // re-raises before any torn state can be observed (scope semantics).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            (unsafe { &*job })(WorkerCtx { id, workers, ctrl: control });
        }));
        if result.is_err() {
            control.barrier.poison();
        }
        let mut st = control.state.lock().unwrap();
        if let Err(payload) = result {
            // Keep the most informative payload: a stalled waiter's
            // structured StallError beats the generic "peer panicked"
            // panics the poison fans out to everyone else, regardless of
            // which worker happens to drain first.
            let incoming_stall = StallError::from_panic(payload.as_ref()).is_some();
            match &st.panic {
                None => st.panic = Some(payload),
                Some(kept) => {
                    let kept_stall = StallError::from_panic(kept.as_ref()).is_some();
                    if incoming_stall && !kept_stall {
                        st.panic = Some(payload);
                    }
                }
            }
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            control.done_cv.notify_one();
        }
    }
}

/// The stall watchdog: samples every worker's progress word at a low
/// cadence and, when an in-flight dispatch shows no movement for a full
/// window, records a sticky [`StallReport`] naming the lagging worker
/// (lowest progress word) and its phase/epoch. Detection only — the wait
/// deadline is what converts a stall into an error — but it fires earlier
/// than the deadline and gives `health()` something to show.
fn watchdog_loop(control: &Control) {
    const CADENCE: Duration = Duration::from_millis(25);
    const WINDOW: Duration = Duration::from_millis(250);
    fn sample(c: &Control) -> Vec<u64> {
        c.progress.iter().map(|p| p.0.load(Ordering::Relaxed)).collect()
    }
    let mut last = sample(control);
    let mut last_change = Instant::now();
    loop {
        std::thread::sleep(CADENCE);
        let in_flight = {
            let st = control.state.lock().unwrap();
            if st.shutdown {
                return;
            }
            st.remaining > 0
        };
        let now = sample(control);
        if now != last || !in_flight {
            last = now;
            last_change = Instant::now();
            continue;
        }
        let stalled_for = last_change.elapsed();
        if stalled_for < WINDOW {
            continue;
        }
        let (worker, word) = last
            .iter()
            .copied()
            .enumerate()
            .min_by_key(|&(_, w)| w)
            .expect("pool has at least one worker");
        *control.stall_report.lock().unwrap() = Some(StallReport {
            worker,
            epoch: word >> 3,
            phase: Phase::from_u8((word & 7) as u8),
            stalled_for,
        });
    }
}

/// A view over a slice that hands worker `i` the `&mut` element `i`.
///
/// Used for everything "one per logical thread": subdomain fields, private
/// workspaces, per-worker counters.
pub struct PerWorker<'a, T> {
    ptr: *mut T,
    len: usize,
    _life: PhantomData<&'a mut [T]>,
}

// SAFETY: disjoint `&mut` access is guaranteed by the `take` contract;
// moving those borrows across threads needs `T: Send`.
unsafe impl<T: Send> Sync for PerWorker<'_, T> {}

impl<'a, T> PerWorker<'a, T> {
    pub fn new(items: &'a mut [T]) -> PerWorker<'a, T> {
        PerWorker { ptr: items.as_mut_ptr(), len: items.len(), _life: PhantomData }
    }

    /// Element `i`, mutably.
    ///
    /// # Safety
    /// Each index must be claimed by at most one worker per dispatch (pool
    /// workers claim their `ctx.id`), and the borrow must end before the
    /// dispatch completes.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn take(&self, i: usize) -> &mut T {
        assert!(i < self.len, "worker index {i} out of {}", self.len);
        &mut *self.ptr.add(i)
    }

    /// Element `i`, shared — for phases where several workers read one
    /// worker's slot (e.g. ghost-cell fills from a sender's pack buffers).
    ///
    /// # Safety
    /// No worker may hold a `take(i)` borrow overlapping this read; order
    /// the phases with a barrier or an epoch-flag wait.
    pub unsafe fn peek(&self, i: usize) -> &T {
        assert!(i < self.len, "worker index {i} out of {}", self.len);
        &*self.ptr.add(i)
    }
}

/// A view over the flat staging arena that hands out per-message ranges.
pub struct ArenaView<'a> {
    ptr: *mut f64,
    len: usize,
    _life: PhantomData<&'a mut [f64]>,
}

// SAFETY: see the `slice_mut`/`slice` contracts — compiled-plan ranges are
// disjoint, and reads happen only after the barrier that ends the writes.
unsafe impl Sync for ArenaView<'_> {}

impl<'a> ArenaView<'a> {
    pub fn new(arena: &'a mut [f64]) -> ArenaView<'a> {
        ArenaView { ptr: arena.as_mut_ptr(), len: arena.len(), _life: PhantomData }
    }

    /// One message's slot range, mutably (the sender's `upc_memput` target).
    ///
    /// # Safety
    /// Ranges handed out mutably in one phase must be pairwise disjoint
    /// (compiled plans guarantee their messages partition the arena), and
    /// must not overlap concurrent `slice` reads — separate the phases with
    /// [`WorkerCtx::barrier`].
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, r: Range<usize>) -> &mut [f64] {
        assert!(r.start <= r.end && r.end <= self.len, "arena range {r:?} out of {}", self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(r.start), r.end - r.start)
    }

    /// One message's slot range, shared (the receiver's unpack source).
    ///
    /// # Safety
    /// No worker may hold a `slice_mut` overlapping `r` concurrently.
    pub unsafe fn slice(&self, r: Range<usize>) -> &[f64] {
        assert!(r.start <= r.end && r.end <= self.len, "arena range {r:?} out of {}", self.len);
        std::slice::from_raw_parts(self.ptr.add(r.start), r.end - r.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn dispatch_runs_every_worker_once() {
        let mut pool = WorkerPool::new();
        for round in 1..=3u64 {
            let hits = AtomicU64::new(0);
            pool.run(4, &|ctx| {
                hits.fetch_add(1 << (8 * ctx.id), Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 0x01010101, "round {round}");
        }
        assert_eq!(pool.size(), 4);
    }

    #[test]
    fn per_worker_gives_disjoint_muts() {
        let mut pool = WorkerPool::new();
        let mut data = vec![0usize; 6];
        let view = PerWorker::new(&mut data);
        pool.run(6, &|ctx| {
            // SAFETY: each worker claims only its own id.
            let slot = unsafe { view.take(ctx.id) };
            *slot = ctx.id * 10;
        });
        assert_eq!(data, vec![0, 10, 20, 30, 40, 50]);
    }

    #[test]
    fn barrier_separates_phases() {
        // Phase 1 writes arena[id]; phase 2 reads the *next* worker's slot.
        // Without the barrier this would race; with it the read is ordered.
        let mut pool = WorkerPool::new();
        let n = 5usize;
        let mut arena = vec![0.0f64; n];
        let mut out = vec![0.0f64; n];
        let av = ArenaView::new(&mut arena);
        let ov = PerWorker::new(&mut out);
        pool.run(n, &|ctx| {
            let t = ctx.id;
            // SAFETY: slot t written only by worker t before the barrier.
            unsafe { av.slice_mut(t..t + 1) }[0] = (t * t) as f64;
            ctx.barrier();
            // SAFETY: writes ended at the barrier; reads are shared.
            let peer = (t + 1) % ctx.workers;
            let v = unsafe { av.slice(peer..peer + 1) }[0];
            // SAFETY: each worker claims only its own output slot.
            *unsafe { ov.take(t) } = v;
        });
        for t in 0..n {
            assert_eq!(out[t], (((t + 1) % n) * ((t + 1) % n)) as f64);
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let mut pool = WorkerPool::new();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|ctx| {
                if ctx.id == 2 {
                    panic!("boom");
                }
                // Peers parked here must be released by the poison, not
                // stranded waiting for the panicked worker.
                ctx.barrier();
            });
        }));
        assert!(res.is_err(), "worker panic must reach the dispatcher");
        // The pool (workers, barrier) remains usable afterwards.
        let hits = AtomicU64::new(0);
        pool.run(4, &|ctx| {
            hits.fetch_add(1, Ordering::Relaxed);
            ctx.barrier();
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn epoch_flags_order_split_phase_exchange() {
        // A ring exchange with no barrier: each worker publishes its slot,
        // then waits only on its left neighbour's flag before reading.
        let mut pool = WorkerPool::new();
        let n = 6usize;
        let flags = EpochFlags::new(n);
        let mut arena = vec![0.0f64; n];
        let mut out = vec![0.0f64; n];
        let av = ArenaView::new(&mut arena);
        let ov = PerWorker::new(&mut out);
        for epoch in 1..=3u64 {
            pool.run(n, &|ctx| {
                let t = ctx.id;
                // SAFETY: slot t written only by worker t before publishing.
                unsafe { av.slice_mut(t..t + 1) }[0] = (epoch as usize * 100 + t) as f64;
                flags.publish(t, epoch);
                let peer = (t + 1) % ctx.workers;
                ctx.wait_for_epoch(flags.flag(peer), epoch, peer);
                // SAFETY: peer's write is ordered before its Release
                // publish, and the Acquire wait observed it.
                let v = unsafe { av.slice(peer..peer + 1) }[0];
                // SAFETY: each worker claims only its own output slot.
                *unsafe { ov.take(t) } = v;
            });
            for t in 0..n {
                assert_eq!(out[t], (epoch as usize * 100 + (t + 1) % n) as f64);
            }
        }
    }

    #[test]
    fn ack_flags_gate_buffer_reuse() {
        // A depth-2 producer/consumer pair on one slot pair: the producer
        // may write slot (e mod 2) only after the consumer acked epoch e−2.
        // The consumer checks it always reads the value of the epoch it
        // waited for — an overwrite racing ahead of the ack would break it.
        let mut pool = WorkerPool::new();
        let flags = EpochFlags::new(2);
        let acks = EpochFlags::new(2);
        let mut slots = vec![0.0f64; 2];
        let av = ArenaView::new(&mut slots);
        let flags_ref = &flags;
        let acks_ref = &acks;
        pool.run(2, &|ctx| {
            for epoch in 1..=20u64 {
                if ctx.id == 0 {
                    // Producer: respect the consumer's consumed-epoch ack.
                    if epoch > 2 {
                        ctx.wait_for_ack(acks_ref.flag(1), epoch - 2, 1);
                    }
                    let half = (epoch % 2) as usize;
                    // SAFETY: the ack wait ordered the consumer's reads of
                    // this slot (epoch − 2) before this overwrite.
                    unsafe { av.slice_mut(half..half + 1) }[0] = epoch as f64;
                    flags_ref.publish(0, epoch);
                } else {
                    ctx.wait_for_epoch(flags_ref.flag(0), epoch, 0);
                    let half = (epoch % 2) as usize;
                    // SAFETY: the publish wait ordered the producer's write
                    // before this read; the ack below orders the read
                    // before any reuse.
                    let got = unsafe { av.slice(half..half + 1) }[0];
                    // Exactly this epoch's value: the *next* write to this
                    // slot (epoch + 2) is gated on the ack published below.
                    assert!(got == epoch as f64, "epoch {epoch}: read {got}");
                    acks_ref.publish(1, epoch);
                }
            }
        });
        assert_eq!(flags.load(0), 20);
        assert_eq!(acks.load(1), 20);
    }

    #[test]
    fn ack_wait_released_by_poison() {
        // Worker 2 panics before acking; a sender spinning in wait_for_ack
        // on its flag must be released by the poison and panic, not hang.
        let mut pool = WorkerPool::new();
        let acks = EpochFlags::new(4);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|ctx| {
                if ctx.id == 2 {
                    panic!("boom before ack");
                }
                acks.publish(ctx.id, 1);
                ctx.wait_for_ack(acks.flag(2), 1, 2);
            });
        }));
        assert!(res.is_err(), "worker panic must reach the dispatcher");
        // The pool stays usable afterwards (reset clears the fast flag).
        let hits = AtomicU64::new(0);
        pool.run(4, &|ctx| {
            hits.fetch_add(1, Ordering::Relaxed);
            ctx.barrier();
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn dispatch_counter_counts_runs() {
        let mut pool = WorkerPool::new();
        assert_eq!(pool.dispatches(), 0);
        for _ in 0..3 {
            pool.run(2, &|_| {});
        }
        assert_eq!(pool.dispatches(), 3);
    }

    #[test]
    fn epoch_wait_released_by_poison() {
        // Worker 2 panics before publishing; the peers spinning on its flag
        // must be released by the poison and panic, not hang — the same
        // semantics as the poisoned barrier.
        let mut pool = WorkerPool::new();
        let flags = EpochFlags::new(4);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|ctx| {
                if ctx.id == 2 {
                    panic!("boom before publish");
                }
                flags.publish(ctx.id, 1);
                ctx.wait_for_epoch(flags.flag(2), 1, 2);
            });
        }));
        assert!(res.is_err(), "worker panic must reach the dispatcher");
        // The pool stays usable afterwards (reset clears the fast flag).
        let hits = AtomicU64::new(0);
        pool.run(4, &|ctx| {
            hits.fetch_add(1, Ordering::Relaxed);
            ctx.barrier();
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn pool_resizes_across_dispatch_widths() {
        let mut pool = WorkerPool::new();
        for &n in &[3usize, 8, 1, 8] {
            let hits = AtomicU64::new(0);
            pool.run(n, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed) as usize, n);
            assert_eq!(pool.size(), n);
        }
    }

    #[test]
    fn borrowed_state_survives_dispatch() {
        // The job borrows stack data; `run` must not return before workers
        // stopped touching it.
        let mut pool = WorkerPool::new();
        for _ in 0..50 {
            let mut sums = vec![0u64; 4];
            let view = PerWorker::new(&mut sums);
            pool.run(4, &|ctx| {
                let s = unsafe { view.take(ctx.id) };
                for k in 0..1000u64 {
                    *s += k;
                }
            });
            assert!(sums.iter().all(|&s| s == 499_500));
        }
    }

    #[test]
    fn stalled_epoch_wait_raises_stall_error() {
        // Worker 0 simply never publishes; worker 1's deadline-bounded wait
        // must convert into a structured StallError naming waiter, peer,
        // epoch and phase — not an infinite spin.
        let mut pool = WorkerPool::new();
        pool.set_wait_deadline(Some(Duration::from_millis(50)));
        let flags = EpochFlags::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, &|ctx| {
                if ctx.id == 1 {
                    ctx.note_phase(Phase::Transfer, 1);
                    ctx.wait_for_epoch(flags.flag(0), 1, 0);
                }
            });
        }));
        let payload = res.expect_err("stall must unwind the dispatcher");
        let stall = StallError::from_panic(payload.as_ref())
            .expect("payload must carry the StallError");
        assert_eq!(stall.waiter, 1);
        assert_eq!(stall.peer, Some(0));
        assert_eq!(stall.epoch, 1);
        assert_eq!(stall.phase, Phase::Transfer);
        assert!(stall.waited >= Duration::from_millis(50));
        // The pool survives and later dispatches are clean.
        let hits = AtomicU64::new(0);
        pool.run(2, &|ctx| {
            hits.fetch_add(1, Ordering::Relaxed);
            ctx.barrier();
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn stalled_ack_wait_raises_stall_error() {
        let mut pool = WorkerPool::new();
        pool.set_wait_deadline(Some(Duration::from_millis(50)));
        let acks = EpochFlags::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, &|ctx| {
                if ctx.id == 0 {
                    ctx.wait_for_ack(acks.flag(1), 3, 1);
                }
            });
        }));
        let payload = res.expect_err("stall must unwind the dispatcher");
        let stall = StallError::from_panic(payload.as_ref()).expect("StallError payload");
        assert_eq!((stall.waiter, stall.peer), (0, Some(1)));
        assert_eq!(stall.phase, Phase::AckGate);
    }

    #[test]
    fn stalled_barrier_raises_stall_error() {
        // Worker 0 returns without ever reaching the barrier; worker 1 must
        // time out with phase == Barrier instead of waiting forever.
        let mut pool = WorkerPool::new();
        pool.set_wait_deadline(Some(Duration::from_millis(50)));
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, &|ctx| {
                if ctx.id == 1 {
                    ctx.note_phase(Phase::Pack, 7);
                    ctx.barrier();
                }
            });
        }));
        let payload = res.expect_err("barrier stall must unwind the dispatcher");
        let stall = StallError::from_panic(payload.as_ref()).expect("StallError payload");
        assert_eq!(stall.waiter, 1);
        assert_eq!(stall.peer, None);
        assert_eq!(stall.phase, Phase::Barrier);
        assert_eq!(stall.epoch, 7, "barrier stall reports the waiter's own epoch");
    }

    #[test]
    fn stall_error_beats_generic_poison_payload() {
        // Three workers park at the barrier while one stalls on a flag wait:
        // whichever order the panics drain in, the dispatcher must see the
        // StallError, not a generic "peer panicked".
        let mut pool = WorkerPool::new();
        pool.set_wait_deadline(Some(Duration::from_millis(50)));
        let flags = EpochFlags::new(4);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|ctx| {
                if ctx.id == 3 {
                    ctx.wait_for_epoch(flags.flag(0), 9, 0);
                } else {
                    // Arrive at the barrier well after worker 3's deadline
                    // has fired, so the generic poison panic is what these
                    // workers raise (not barrier stalls of their own).
                    std::thread::sleep(Duration::from_millis(150));
                    ctx.barrier(); // released (with a panic) by the poison
                }
            });
        }));
        let payload = res.expect_err("stall must unwind the dispatcher");
        let stall = StallError::from_panic(payload.as_ref())
            .expect("dispatcher must prefer the StallError payload");
        assert_eq!((stall.waiter, stall.epoch), (3, 9));
    }

    #[test]
    fn disabled_deadline_keeps_waits_unbounded() {
        // With the deadline off, a slow (but live) publisher must not trip
        // anything: the waiter just waits.
        let mut pool = WorkerPool::new();
        pool.set_wait_deadline(None);
        let flags = EpochFlags::new(2);
        pool.run(2, &|ctx| {
            if ctx.id == 0 {
                std::thread::sleep(Duration::from_millis(30));
                flags.publish(0, 1);
            } else {
                ctx.wait_for_epoch(flags.flag(0), 1, 0);
            }
        });
        assert_eq!(flags.load(0), 1);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn publish_backwards_panics() {
        let flags = EpochFlags::new(1);
        flags.publish(0, 5);
        flags.publish(0, 3);
    }

    #[test]
    fn watchdog_reports_lagging_worker() {
        // Both workers advertise a phase then stop moving for longer than
        // the watchdog window; the sticky report must name the worker with
        // the lowest progress word and survive until the next dispatch.
        let mut pool = WorkerPool::new();
        pool.run(2, &|ctx| {
            if ctx.id == 0 {
                ctx.note_phase(Phase::Pack, 3);
            } else {
                ctx.note_phase(Phase::Unpack, 5);
            }
            std::thread::sleep(Duration::from_millis(700));
        });
        let health = pool.health();
        assert!(!health.in_flight);
        assert_eq!(health.workers.len(), 2);
        assert_eq!(health.workers[0].phase, Phase::Pack);
        assert_eq!(health.workers[0].epoch, 3);
        assert_eq!(health.workers[1].phase, Phase::Unpack);
        assert_eq!(health.workers[1].epoch, 5);
        let stall = health.stall.expect("watchdog must have recorded the stall");
        assert_eq!(stall.worker, 0, "lagging worker is the lowest progress word");
        assert_eq!(stall.phase, Phase::Pack);
        assert_eq!(stall.epoch, 3);
        assert!(stall.stalled_for >= Duration::from_millis(250));
        // A fresh dispatch clears the sticky report.
        pool.run(2, &|_| {});
        assert!(pool.health().stall.is_none());
    }

    #[test]
    fn wait_tuning_defaults_and_reconfiguration() {
        // Defaults are the historical ladder constants.
        let t = WaitTuning::default();
        assert_eq!(t.spin, 128);
        assert_eq!(t.yield_rounds, 4096);
        assert_eq!(t.park, Duration::from_micros(100));
        assert_eq!(t.socket_slice, Duration::from_millis(50));

        // A reconfigured ladder (tiny spin, immediate parks) still
        // completes a real flag-gated exchange — the rungs only trade
        // latency for CPU, never correctness.
        let mut pool = WorkerPool::new();
        let custom = WaitTuning {
            spin: 1,
            yield_rounds: 0,
            park: Duration::from_micros(10),
            socket_slice: Duration::from_millis(5),
        };
        pool.set_wait_tuning(custom);
        assert_eq!(pool.wait_tuning(), custom);
        let flags = EpochFlags::new(2);
        pool.run(2, &|ctx| {
            if ctx.id == 0 {
                std::thread::sleep(Duration::from_millis(20));
                flags.publish(0, 1);
            } else {
                ctx.wait_for_epoch(flags.flag(0), 1, 0);
            }
        });
        assert_eq!(flags.load(0), 1);
        // Reconfiguring with workers already spawned reaches the live
        // Control atomics too (no respawn).
        pool.set_wait_tuning(WaitTuning::default());
        assert_eq!(pool.wait_tuning(), WaitTuning::default());
        pool.run(2, &|ctx| {
            ctx.barrier();
        });
    }

    #[test]
    fn health_on_fresh_pool_is_empty() {
        let pool = WorkerPool::new();
        let health = pool.health();
        assert!(health.workers.is_empty());
        assert!(!health.in_flight);
        assert!(health.stall.is_none());
        assert_eq!(pool.wait_deadline(), Some(DEFAULT_WAIT_DEADLINE));
    }
}
