//! The persistent worker pool: long-lived OS threads, a reusable barrier,
//! and the disjoint-access views the zero-copy executors hand their workers.
//!
//! The seed engine paid one `std::thread::scope` — thread creation, stack
//! allocation, scheduler wakeup and join — per time step *and per phase*.
//! [`WorkerPool`] amortizes all of that to once per run shape: workers are
//! spawned the first time a shape is dispatched and then sit on a condvar;
//! a step costs one lock + wakeup on dispatch, a [`WorkerCtx::barrier`] wait
//! per phase boundary (the `upc_barrier` of Listings 5 & 7), and one
//! completion notification — no allocation, no thread creation.
//!
//! Two small unsafe views make the shared-closure dispatch model work
//! without per-step boxing:
//!
//! * [`PerWorker`] — hands worker `t` the `&mut` element `t` of a slice
//!   (per-thread fields, workspaces, counters). Sound because worker ids are
//!   distinct, so each element is claimed by exactly one thread per
//!   dispatch.
//! * [`ArenaView`] — hands out disjoint `&mut` ranges of the flat staging
//!   arena (a compiled plan's per-message slots). Sound because plan ranges
//!   partition the arena, every range is packed by exactly one sender before
//!   the barrier and only read after it.

use std::fmt;
use std::marker::PhantomData;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Per-dispatch context a worker receives: its id, the dispatch width, and
/// the pool's reusable barrier for intra-step phase boundaries.
pub struct WorkerCtx<'p> {
    /// This worker's id in `0..workers` (the logical UPC thread it plays).
    pub id: usize,
    /// Number of workers in this dispatch.
    pub workers: usize,
    barrier: &'p PoolBarrier,
}

impl WorkerCtx<'_> {
    /// Block until every worker of the dispatch reaches this point — the
    /// `upc_barrier` between a plan's pack and unpack phases. The job
    /// closure must call it unconditionally (same count on every worker) or
    /// the pool deadlocks. Panics if a peer worker panicked this dispatch,
    /// so a failing worker releases the others instead of stranding them.
    pub fn barrier(&self) {
        self.barrier.wait(self.workers);
    }

    /// The split-phase wait primitive: spin (then yield) until `flag`
    /// reaches `target` — the per-peer arrival wait of `finish_exchange`,
    /// replacing the global barrier with a wait on exactly the peers that
    /// send to this worker.
    ///
    /// Ordering: the load is `Acquire` and pairs with the sender's `Release`
    /// publish ([`EpochFlags::publish`]). The sender's pack writes are
    /// sequenced before its publish; observing `flag >= target` therefore
    /// gives a happens-before edge that makes every packed arena value of
    /// that epoch visible to the unpack reads that follow this wait. No
    /// stronger (SeqCst) ordering is needed: each flag is a single-writer
    /// monotone counter and the protocol never reasons about the relative
    /// order of *different* threads' publishes.
    ///
    /// Preserves the poisoned-barrier panic-propagation semantics: if a peer
    /// worker panics before publishing, the pool poisons the dispatch and
    /// this wait panics too instead of spinning forever.
    pub fn wait_for_epoch(&self, flag: &AtomicU64, target: u64) {
        self.spin_until(flag, target);
    }

    /// The pipeline back-pressure wait: spin until a *consumed-epoch* flag
    /// (a receiver's "I have unpacked epoch k" counter) reaches `target`.
    /// A sender packing epoch `e` into the depth-2 arena waits for each of
    /// its receivers' acks to reach `e − 2` first, so it never overwrites a
    /// parity half a slow receiver is still draining — and, equivalently,
    /// never runs more than two epochs ahead of its slowest receiver.
    ///
    /// Ordering: `Acquire`, pairing with the receiver's `Release` ack
    /// publish. The receiver's unpack *reads* are sequenced before its ack;
    /// observing `ack >= target` orders those reads before this sender's
    /// subsequent overwrites of the same arena slots — the reuse edge of the
    /// pipelined protocol (the publish edge is documented on
    /// [`wait_for_epoch`](WorkerCtx::wait_for_epoch)).
    ///
    /// Poison-aware exactly like `wait_for_epoch`: a peer panic releases
    /// this wait with a panic instead of a hang.
    pub fn wait_for_ack(&self, flag: &AtomicU64, target: u64) {
        self.spin_until(flag, target);
    }

    fn spin_until(&self, flag: &AtomicU64, target: u64) {
        let mut spins = 0u32;
        while flag.load(Ordering::Acquire) < target {
            if self.barrier.is_poisoned() {
                panic!("a pool worker panicked during this dispatch");
            }
            spins += 1;
            if spins < 128 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }
}

/// One cache-line-padded monotone epoch counter per logical thread. Two
/// instances drive the split-phase protocols: a *published* set (thread
/// `t`'s counter is the epoch of the last exchange `t` fully packed every
/// outgoing message of; receivers in `finish_exchange` wait on the counters
/// of their actual senders) and, for the pipelined driver, a *consumed* set
/// (the epoch `t` last finished unpacking; senders wait on the counters of
/// their actual receivers before reusing an arena half).
///
/// Publishes are `Release` stores and waits are `Acquire` loads — the
/// required happens-before edges are documented on
/// [`WorkerCtx::wait_for_epoch`] and [`WorkerCtx::wait_for_ack`]; each
/// counter has exactly one writer, so no stronger ordering is needed.
///
/// The counters are monotone across steps and survive pool dispatches, so a
/// runtime can keep one `EpochFlags` for its whole lifetime; padding keeps
/// the per-thread stores from false-sharing the waiters' loads.
#[derive(Debug, Default)]
pub struct EpochFlags {
    flags: Vec<PaddedEpoch>,
}

#[derive(Debug, Default)]
#[repr(align(64))]
struct PaddedEpoch(AtomicU64);

impl EpochFlags {
    /// Flags for `threads` logical threads, all at epoch 0 (nothing
    /// published yet).
    pub fn new(threads: usize) -> EpochFlags {
        EpochFlags { flags: (0..threads).map(|_| PaddedEpoch::default()).collect() }
    }

    pub fn len(&self) -> usize {
        self.flags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.flags.is_empty()
    }

    /// Thread `t`'s published-epoch counter.
    pub fn flag(&self, t: usize) -> &AtomicU64 {
        &self.flags[t].0
    }

    /// Publish: thread `t` finished packing (published set) or unpacking
    /// (consumed set) every message of `epoch`. `Release`: orders the pack
    /// writes / unpack reads of the epoch before the store — see
    /// [`WorkerCtx::wait_for_epoch`] / [`WorkerCtx::wait_for_ack`] for the
    /// matching `Acquire` side.
    pub fn publish(&self, t: usize, epoch: u64) {
        self.flags[t].0.store(epoch, Ordering::Release);
    }

    /// Snapshot of thread `t`'s counter (`Acquire`, same edge as the waits).
    pub fn load(&self, t: usize) -> u64 {
        self.flags[t].0.load(Ordering::Acquire)
    }
}

/// A reusable sense-counting barrier that can be poisoned: when a worker
/// panics, [`poison`](PoolBarrier::poison) wakes every waiter and makes
/// every current and future `wait` of the dispatch panic too, so the whole
/// job unwinds instead of deadlocking (`std::sync::Barrier` has no
/// equivalent).
struct PoolBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
    /// Lock-free mirror of `BarrierState::poisoned` for the spin-wait of
    /// [`WorkerCtx::wait_for_epoch`] (checking the mutex per spin would
    /// serialize the waiters).
    poisoned_fast: AtomicBool,
}

struct BarrierState {
    /// Workers currently parked in `wait`.
    count: usize,
    /// Bumped each time a full cohort is released.
    generation: u64,
    poisoned: bool,
}

impl PoolBarrier {
    fn new() -> PoolBarrier {
        PoolBarrier {
            state: Mutex::new(BarrierState { count: 0, generation: 0, poisoned: false }),
            cv: Condvar::new(),
            poisoned_fast: AtomicBool::new(false),
        }
    }

    /// `Acquire`/`Release` with [`poison`](PoolBarrier::poison): the waiter
    /// only acts on the boolean itself (it panics), so even `Relaxed` would
    /// be correct — acquire is kept so the unwinding waiter also observes
    /// everything the panicking worker did first, which keeps panic messages
    /// and poisoned state coherent.
    fn is_poisoned(&self) -> bool {
        self.poisoned_fast.load(Ordering::Acquire)
    }

    fn wait(&self, workers: usize) {
        let mut st = self.state.lock().unwrap();
        let mut poisoned = st.poisoned;
        if !poisoned {
            st.count += 1;
            if st.count == workers {
                st.count = 0;
                st.generation += 1;
                self.cv.notify_all();
                return;
            }
            let gen = st.generation;
            while st.generation == gen && !st.poisoned {
                st = self.cv.wait(st).unwrap();
            }
            poisoned = st.poisoned;
        }
        // Panic only after the guard is gone, so the mutex is never
        // poisoned (waiters and `reset` keep using plain `unwrap`).
        drop(st);
        if poisoned {
            panic!("a pool worker panicked during this dispatch");
        }
    }

    fn poison(&self) {
        self.poisoned_fast.store(true, Ordering::Release);
        self.state.lock().unwrap().poisoned = true;
        self.cv.notify_all();
    }

    /// Arm the barrier for a fresh dispatch. Sound because `run` only
    /// returns (and so only re-dispatches) once every worker has left the
    /// job — no thread can still be inside `wait`.
    fn reset(&self) {
        let mut st = self.state.lock().unwrap();
        st.count = 0;
        st.poisoned = false;
        self.poisoned_fast.store(false, Ordering::Release);
    }
}

/// The job pointer stored while a dispatch is in flight. The lifetime is
/// erased; soundness comes from `run` blocking until every worker finished.
type RawJob = *const (dyn Fn(WorkerCtx) + Sync);

struct State {
    /// Bumped once per dispatch; workers run the job when it advances.
    epoch: u64,
    job: Option<RawJob>,
    /// Workers that have not yet finished the current epoch.
    remaining: usize,
    /// First panic payload caught this dispatch; re-raised by `run`.
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

// SAFETY: the raw job pointer only crosses threads while `run` blocks the
// owner; the pointee is `Sync`, so shared calls from workers are sound.
unsafe impl Send for State {}

struct Control {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    barrier: PoolBarrier,
}

/// A persistent pool of worker threads, one per logical UPC thread.
///
/// Created empty; `run(n, job)` lazily (re)spawns exactly `n` workers and
/// keeps them across calls, so steady-state time stepping never creates a
/// thread. Resizing (a run shape change) tears the old workers down and
/// spawns fresh ones — paid once per shape, like the plan compile itself.
#[derive(Default)]
pub struct WorkerPool {
    workers: Vec<JoinHandle<()>>,
    control: Option<Arc<Control>>,
    /// Completed `run` calls — the protocol-level "how many wakeups did
    /// this cost" counter the pipelined driver's tests assert on (one
    /// dispatch per S-step batch).
    dispatches: u64,
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool").field("workers", &self.workers.len()).finish()
    }
}

impl WorkerPool {
    pub fn new() -> WorkerPool {
        WorkerPool::default()
    }

    /// Number of currently spawned workers (0 until the first dispatch).
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Number of `run` dispatches issued over the pool's lifetime.
    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    /// Run `job(ctx)` on every one of `n` persistent workers and block until
    /// all of them finished. The closure is shared (`Fn + Sync`): per-worker
    /// mutable state goes through [`PerWorker`] / [`ArenaView`].
    ///
    /// A panic inside the job is caught on the worker, poisons the barrier
    /// (releasing peers parked at a phase boundary), and is re-raised here
    /// once every worker has drained — the same observable behavior as the
    /// `std::thread::scope` join this pool replaced. Workers survive the
    /// panic, so the pool stays usable.
    pub fn run(&mut self, n: usize, job: &(dyn Fn(WorkerCtx) + Sync)) {
        assert!(n > 0, "cannot dispatch on zero workers");
        self.ensure(n);
        self.dispatches += 1;
        let control = self.control.as_ref().expect("ensure spawned workers");
        control.barrier.reset();
        // SAFETY: erase the borrow lifetime. The pointer is cleared and
        // never dereferenced again after the wait below observes that every
        // worker completed the epoch, which happens before `run` returns.
        let raw: RawJob = unsafe {
            std::mem::transmute::<&(dyn Fn(WorkerCtx) + Sync), RawJob>(job)
        };
        let mut st = control.state.lock().unwrap();
        st.job = Some(raw);
        st.remaining = n;
        st.epoch += 1;
        control.work_cv.notify_all();
        while st.remaining > 0 {
            st = control.done_cv.wait(st).unwrap();
        }
        st.job = None;
        if let Some(payload) = st.panic.take() {
            drop(st);
            std::panic::resume_unwind(payload);
        }
    }

    fn ensure(&mut self, n: usize) {
        if self.workers.len() == n {
            return;
        }
        self.teardown();
        let control = Arc::new(Control {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                remaining: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            barrier: PoolBarrier::new(),
        });
        self.workers = (0..n)
            .map(|id| {
                let control = Arc::clone(&control);
                std::thread::Builder::new()
                    .name(format!("upc-worker-{id}"))
                    .spawn(move || worker_loop(id, n, &control))
                    .expect("spawn pool worker")
            })
            .collect();
        self.control = Some(control);
    }

    fn teardown(&mut self) {
        if let Some(control) = self.control.take() {
            control.state.lock().unwrap().shutdown = true;
            control.work_cv.notify_all();
            for w in self.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.teardown();
    }
}

fn worker_loop(id: usize, workers: usize, control: &Control) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = control.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("epoch advanced without a job");
                }
                st = control.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: the leader keeps the closure alive until every worker
        // reports completion below. AssertUnwindSafe: on panic the leader
        // re-raises before any torn state can be observed (scope semantics).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            (unsafe { &*job })(WorkerCtx { id, workers, barrier: &control.barrier });
        }));
        if result.is_err() {
            control.barrier.poison();
        }
        let mut st = control.state.lock().unwrap();
        if let Err(payload) = result {
            st.panic.get_or_insert(payload);
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            control.done_cv.notify_one();
        }
    }
}

/// A view over a slice that hands worker `i` the `&mut` element `i`.
///
/// Used for everything "one per logical thread": subdomain fields, private
/// workspaces, per-worker counters.
pub struct PerWorker<'a, T> {
    ptr: *mut T,
    len: usize,
    _life: PhantomData<&'a mut [T]>,
}

// SAFETY: disjoint `&mut` access is guaranteed by the `take` contract;
// moving those borrows across threads needs `T: Send`.
unsafe impl<T: Send> Sync for PerWorker<'_, T> {}

impl<'a, T> PerWorker<'a, T> {
    pub fn new(items: &'a mut [T]) -> PerWorker<'a, T> {
        PerWorker { ptr: items.as_mut_ptr(), len: items.len(), _life: PhantomData }
    }

    /// Element `i`, mutably.
    ///
    /// # Safety
    /// Each index must be claimed by at most one worker per dispatch (pool
    /// workers claim their `ctx.id`), and the borrow must end before the
    /// dispatch completes.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn take(&self, i: usize) -> &mut T {
        assert!(i < self.len, "worker index {i} out of {}", self.len);
        &mut *self.ptr.add(i)
    }
}

/// A view over the flat staging arena that hands out per-message ranges.
pub struct ArenaView<'a> {
    ptr: *mut f64,
    len: usize,
    _life: PhantomData<&'a mut [f64]>,
}

// SAFETY: see the `slice_mut`/`slice` contracts — compiled-plan ranges are
// disjoint, and reads happen only after the barrier that ends the writes.
unsafe impl Sync for ArenaView<'_> {}

impl<'a> ArenaView<'a> {
    pub fn new(arena: &'a mut [f64]) -> ArenaView<'a> {
        ArenaView { ptr: arena.as_mut_ptr(), len: arena.len(), _life: PhantomData }
    }

    /// One message's slot range, mutably (the sender's `upc_memput` target).
    ///
    /// # Safety
    /// Ranges handed out mutably in one phase must be pairwise disjoint
    /// (compiled plans guarantee their messages partition the arena), and
    /// must not overlap concurrent `slice` reads — separate the phases with
    /// [`WorkerCtx::barrier`].
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, r: Range<usize>) -> &mut [f64] {
        assert!(r.start <= r.end && r.end <= self.len, "arena range {r:?} out of {}", self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(r.start), r.end - r.start)
    }

    /// One message's slot range, shared (the receiver's unpack source).
    ///
    /// # Safety
    /// No worker may hold a `slice_mut` overlapping `r` concurrently.
    pub unsafe fn slice(&self, r: Range<usize>) -> &[f64] {
        assert!(r.start <= r.end && r.end <= self.len, "arena range {r:?} out of {}", self.len);
        std::slice::from_raw_parts(self.ptr.add(r.start), r.end - r.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn dispatch_runs_every_worker_once() {
        let mut pool = WorkerPool::new();
        for round in 1..=3u64 {
            let hits = AtomicU64::new(0);
            pool.run(4, &|ctx| {
                hits.fetch_add(1 << (8 * ctx.id), Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 0x01010101, "round {round}");
        }
        assert_eq!(pool.size(), 4);
    }

    #[test]
    fn per_worker_gives_disjoint_muts() {
        let mut pool = WorkerPool::new();
        let mut data = vec![0usize; 6];
        let view = PerWorker::new(&mut data);
        pool.run(6, &|ctx| {
            // SAFETY: each worker claims only its own id.
            let slot = unsafe { view.take(ctx.id) };
            *slot = ctx.id * 10;
        });
        assert_eq!(data, vec![0, 10, 20, 30, 40, 50]);
    }

    #[test]
    fn barrier_separates_phases() {
        // Phase 1 writes arena[id]; phase 2 reads the *next* worker's slot.
        // Without the barrier this would race; with it the read is ordered.
        let mut pool = WorkerPool::new();
        let n = 5usize;
        let mut arena = vec![0.0f64; n];
        let mut out = vec![0.0f64; n];
        let av = ArenaView::new(&mut arena);
        let ov = PerWorker::new(&mut out);
        pool.run(n, &|ctx| {
            let t = ctx.id;
            // SAFETY: slot t written only by worker t before the barrier.
            unsafe { av.slice_mut(t..t + 1) }[0] = (t * t) as f64;
            ctx.barrier();
            // SAFETY: writes ended at the barrier; reads are shared.
            let peer = (t + 1) % ctx.workers;
            let v = unsafe { av.slice(peer..peer + 1) }[0];
            // SAFETY: each worker claims only its own output slot.
            *unsafe { ov.take(t) } = v;
        });
        for t in 0..n {
            assert_eq!(out[t], (((t + 1) % n) * ((t + 1) % n)) as f64);
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let mut pool = WorkerPool::new();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|ctx| {
                if ctx.id == 2 {
                    panic!("boom");
                }
                // Peers parked here must be released by the poison, not
                // stranded waiting for the panicked worker.
                ctx.barrier();
            });
        }));
        assert!(res.is_err(), "worker panic must reach the dispatcher");
        // The pool (workers, barrier) remains usable afterwards.
        let hits = AtomicU64::new(0);
        pool.run(4, &|ctx| {
            hits.fetch_add(1, Ordering::Relaxed);
            ctx.barrier();
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn epoch_flags_order_split_phase_exchange() {
        // A ring exchange with no barrier: each worker publishes its slot,
        // then waits only on its left neighbour's flag before reading.
        let mut pool = WorkerPool::new();
        let n = 6usize;
        let flags = EpochFlags::new(n);
        let mut arena = vec![0.0f64; n];
        let mut out = vec![0.0f64; n];
        let av = ArenaView::new(&mut arena);
        let ov = PerWorker::new(&mut out);
        for epoch in 1..=3u64 {
            pool.run(n, &|ctx| {
                let t = ctx.id;
                // SAFETY: slot t written only by worker t before publishing.
                unsafe { av.slice_mut(t..t + 1) }[0] = (epoch as usize * 100 + t) as f64;
                flags.publish(t, epoch);
                let peer = (t + 1) % ctx.workers;
                ctx.wait_for_epoch(flags.flag(peer), epoch);
                // SAFETY: peer's write is ordered before its Release
                // publish, and the Acquire wait observed it.
                let v = unsafe { av.slice(peer..peer + 1) }[0];
                // SAFETY: each worker claims only its own output slot.
                *unsafe { ov.take(t) } = v;
            });
            for t in 0..n {
                assert_eq!(out[t], (epoch as usize * 100 + (t + 1) % n) as f64);
            }
        }
    }

    #[test]
    fn ack_flags_gate_buffer_reuse() {
        // A depth-2 producer/consumer pair on one slot pair: the producer
        // may write slot (e mod 2) only after the consumer acked epoch e−2.
        // The consumer checks it always reads the value of the epoch it
        // waited for — an overwrite racing ahead of the ack would break it.
        let mut pool = WorkerPool::new();
        let flags = EpochFlags::new(2);
        let acks = EpochFlags::new(2);
        let mut slots = vec![0.0f64; 2];
        let av = ArenaView::new(&mut slots);
        let flags_ref = &flags;
        let acks_ref = &acks;
        pool.run(2, &|ctx| {
            for epoch in 1..=20u64 {
                if ctx.id == 0 {
                    // Producer: respect the consumer's consumed-epoch ack.
                    if epoch > 2 {
                        ctx.wait_for_ack(acks_ref.flag(1), epoch - 2);
                    }
                    let half = (epoch % 2) as usize;
                    // SAFETY: the ack wait ordered the consumer's reads of
                    // this slot (epoch − 2) before this overwrite.
                    unsafe { av.slice_mut(half..half + 1) }[0] = epoch as f64;
                    flags_ref.publish(0, epoch);
                } else {
                    ctx.wait_for_epoch(flags_ref.flag(0), epoch);
                    let half = (epoch % 2) as usize;
                    // SAFETY: the publish wait ordered the producer's write
                    // before this read; the ack below orders the read
                    // before any reuse.
                    let got = unsafe { av.slice(half..half + 1) }[0];
                    // Exactly this epoch's value: the *next* write to this
                    // slot (epoch + 2) is gated on the ack published below.
                    assert!(got == epoch as f64, "epoch {epoch}: read {got}");
                    acks_ref.publish(1, epoch);
                }
            }
        });
        assert_eq!(flags.load(0), 20);
        assert_eq!(acks.load(1), 20);
    }

    #[test]
    fn ack_wait_released_by_poison() {
        // Worker 2 panics before acking; a sender spinning in wait_for_ack
        // on its flag must be released by the poison and panic, not hang.
        let mut pool = WorkerPool::new();
        let acks = EpochFlags::new(4);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|ctx| {
                if ctx.id == 2 {
                    panic!("boom before ack");
                }
                acks.publish(ctx.id, 1);
                ctx.wait_for_ack(acks.flag(2), 1);
            });
        }));
        assert!(res.is_err(), "worker panic must reach the dispatcher");
        // The pool stays usable afterwards (reset clears the fast flag).
        let hits = AtomicU64::new(0);
        pool.run(4, &|ctx| {
            hits.fetch_add(1, Ordering::Relaxed);
            ctx.barrier();
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn dispatch_counter_counts_runs() {
        let mut pool = WorkerPool::new();
        assert_eq!(pool.dispatches(), 0);
        for _ in 0..3 {
            pool.run(2, &|_| {});
        }
        assert_eq!(pool.dispatches(), 3);
    }

    #[test]
    fn epoch_wait_released_by_poison() {
        // Worker 2 panics before publishing; the peers spinning on its flag
        // must be released by the poison and panic, not hang — the same
        // semantics as the poisoned barrier.
        let mut pool = WorkerPool::new();
        let flags = EpochFlags::new(4);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|ctx| {
                if ctx.id == 2 {
                    panic!("boom before publish");
                }
                flags.publish(ctx.id, 1);
                ctx.wait_for_epoch(flags.flag(2), 1);
            });
        }));
        assert!(res.is_err(), "worker panic must reach the dispatcher");
        // The pool stays usable afterwards (reset clears the fast flag).
        let hits = AtomicU64::new(0);
        pool.run(4, &|ctx| {
            hits.fetch_add(1, Ordering::Relaxed);
            ctx.barrier();
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn pool_resizes_across_dispatch_widths() {
        let mut pool = WorkerPool::new();
        for &n in &[3usize, 8, 1, 8] {
            let hits = AtomicU64::new(0);
            pool.run(n, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed) as usize, n);
            assert_eq!(pool.size(), n);
        }
    }

    #[test]
    fn borrowed_state_survives_dispatch() {
        // The job borrows stack data; `run` must not return before workers
        // stopped touching it.
        let mut pool = WorkerPool::new();
        for _ in 0..50 {
            let mut sums = vec![0u64; 4];
            let view = PerWorker::new(&mut sums);
            pool.run(4, &|ctx| {
                let s = unsafe { view.take(ctx.id) };
                for k in 0..1000u64 {
                    *s += k;
                }
            });
            assert!(sums.iter().all(|&s| s == 499_500));
        }
    }
}
