//! The kernel tier: specialized pack/unpack inner loops.
//!
//! Every exchanged byte in this repo flows through one of three loop
//! shapes — a contiguous block copy (unit-stride halo rows/planes), an
//! indexed gather (the V3 arena fill over `CommPlan::local_src`), or an
//! indexed scatter (the V3 ghost write over `CommPlan::indices`). The
//! protocol layers above (ExchangeRuntime, `ParallelPool::run_v3_*`, the
//! socket frame pack) used to spell these as naive element loops; this
//! module is the single home for their tuned forms, plus the scalar
//! references they are benched and property-tested against.
//!
//! Tuning levers (all bitwise-neutral — a `f64` copy is a `f64` copy in
//! any order):
//!
//! * **Contiguous fast path** — unit-stride blocks collapse to
//!   `copy_from_slice` (LLVM lowers this to `memcpy` /
//!   `copy_nonoverlapping`), the fastest bytes-per-cycle shape the host
//!   offers.
//! * **Unrolled, bounds-check-free gather/scatter** — the index slice is
//!   validated against the operand length *once* up front, then the hot
//!   loop runs `get_unchecked` in chunks of [`LANES`]. Hoisting the
//!   bounds check out of the loop is what lets LLVM keep the loads
//!   pipelined (and, for the gather, auto-vectorize the contiguous
//!   stores).
//! * **`simd` feature gate** — widens the unroll factor from 4 to 8
//!   lanes, the shape that maps onto two 4-wide vector gathers on AVX2
//!   class hardware. It is a plain cargo feature (no nightly APIs, no new
//!   dependencies), so the default build stays exactly as portable as
//!   before.
//!
//! The `repro calibrate` pack probe ([`crate::microbench`]) measures
//! these kernels' streaming rates to calibrate `HwParams::w_pack`, and
//! `benches/pack_kernels.rs` pins the speedup over the scalar references
//! in `BENCH_simd.json`.

/// Unroll width of the gather/scatter hot loops. 4 lanes by default (one
/// AVX2 vector of `f64`); the `simd` feature doubles it to 8 so the
/// compiler can emit two independent vector chains per iteration.
#[cfg(not(feature = "simd"))]
pub const LANES: usize = 4;
/// Unroll width of the gather/scatter hot loops (8 under `--features
/// simd`).
#[cfg(feature = "simd")]
pub const LANES: usize = 8;

/// Validate that every index in `idx` addresses into `len`, returning the
/// slice length. One pass up front buys `get_unchecked` in the hot loops.
#[inline]
fn check_indices(idx: &[u32], len: usize) {
    // A single max over the indices is itself a vectorizable reduction —
    // far cheaper than a bounds check per element in the gather loop.
    let max = idx.iter().copied().max().unwrap_or(0) as usize;
    assert!(
        idx.is_empty() || max < len,
        "index {max} out of bounds for operand of length {len}"
    );
}

/// Gather `src[idx[i]]` into `dst[i]` — the pack loop of the V3 arena
/// fill and of every gather-plan frame. `dst.len()` must equal
/// `idx.len()`; indices are validated against `src` once, then the loop
/// runs unchecked in [`LANES`]-wide chunks with contiguous stores (the
/// store side auto-vectorizes; the load side pipelines).
pub fn pack_gather(src: &[f64], idx: &[u32], dst: &mut [f64]) {
    assert_eq!(idx.len(), dst.len(), "gather: index/destination length mismatch");
    check_indices(idx, src.len());
    let mut di = dst.chunks_exact_mut(LANES);
    let mut ii = idx.chunks_exact(LANES);
    for (d, ix) in (&mut di).zip(&mut ii) {
        for l in 0..LANES {
            // SAFETY: chunk shapes guarantee l < LANES elements exist on
            // both sides; check_indices proved every idx < src.len().
            unsafe {
                *d.get_unchecked_mut(l) = *src.get_unchecked(*ix.get_unchecked(l) as usize);
            }
        }
    }
    for (d, &i) in di.into_remainder().iter_mut().zip(ii.remainder()) {
        // SAFETY: check_indices proved i < src.len().
        *d = unsafe { *src.get_unchecked(i as usize) };
    }
}

/// Scalar reference for [`pack_gather`]: the exact element loop the V3
/// runtimes used before the kernel tier. Kept for the equivalence
/// property tests and as the `BENCH_simd.json` baseline.
pub fn pack_gather_scalar(src: &[f64], idx: &[u32], dst: &mut [f64]) {
    for (slot, &off) in dst.iter_mut().zip(idx) {
        *slot = src[off as usize];
    }
}

/// Scatter `vals[i]` into `dst[idx[i]]` — the V3 ghost write. Indices are
/// validated once, then the loop runs unchecked in [`LANES`]-wide chunks
/// (scattered stores do not vectorize, but hoisting the bounds check and
/// unrolling keeps the store queue full).
pub fn scatter_indexed(dst: &mut [f64], idx: &[u32], vals: &[f64]) {
    assert_eq!(idx.len(), vals.len(), "scatter: index/value length mismatch");
    check_indices(idx, dst.len());
    let mut vi = vals.chunks_exact(LANES);
    let mut ii = idx.chunks_exact(LANES);
    for (v, ix) in (&mut vi).zip(&mut ii) {
        for l in 0..LANES {
            // SAFETY: chunk shapes guarantee l < LANES elements exist on
            // both sides; check_indices proved every idx < dst.len().
            unsafe {
                *dst.get_unchecked_mut(*ix.get_unchecked(l) as usize) = *v.get_unchecked(l);
            }
        }
    }
    for (&v, &i) in vi.remainder().iter().zip(ii.remainder()) {
        // SAFETY: check_indices proved i < dst.len().
        unsafe { *dst.get_unchecked_mut(i as usize) = v };
    }
}

/// Scalar reference for [`scatter_indexed`]: the exact element loop the
/// V3 runtimes used before the kernel tier.
pub fn scatter_indexed_scalar(dst: &mut [f64], idx: &[u32], vals: &[f64]) {
    for (&gidx, &v) in idx.iter().zip(vals) {
        dst[gidx as usize] = v;
    }
}

/// Contiguous block copy — the unit-stride fast path of every strided
/// pack/unpack and of the socket frame pack. `copy_from_slice` lowers to
/// `ptr::copy_nonoverlapping` (memcpy), which is the speed-of-light shape
/// for moving bytes on the host.
#[inline]
pub fn copy_block(src: &[f64], dst: &mut [f64]) {
    dst.copy_from_slice(src);
}

/// Scalar reference for [`copy_block`]: the per-element loop, kept only
/// as the bench baseline (`black_box` on the index keeps LLVM from
/// rediscovering the memcpy).
pub fn copy_block_scalar(src: &[f64], dst: &mut [f64]) {
    assert_eq!(src.len(), dst.len());
    for i in 0..src.len() {
        dst[std::hint::black_box(i)] = src[i];
    }
}

/// Fused unpack + 5-point boundary update for one ghost-adjacent halo
/// row (the heat-2D fusion rule): in a single pass over the row, write
/// the received ghost value into `phi[ghost_pos + c]` *and* compute the
/// adjacent owned row `phin[row_pos + c] = 0.25 · (up + down + left +
/// right)`, where one of up/down is the ghost value just written.
///
/// Bitwise equivalence with the two-pass form (unpack row, then Jacobi
/// over it) holds because the arithmetic expression is identical — the
/// fused kernel merely reads the ghost value from the register it is
/// about to store instead of re-loading it from `phi`. `other_pos` is
/// the row on the far side of the computed row from the ghost
/// (`row_pos ± stride`), and the row spans `ghost.len()` interior
/// columns starting at the given positions (so `phi[row_pos − 1]` and
/// `phi[row_pos + len]` are the flanking column cells, already unpacked
/// — the halo-plan copy order lands columns before rows).
pub fn fused_unpack_jacobi_row(
    ghost: &[f64],
    phi: &mut [f64],
    ghost_pos: usize,
    row_pos: usize,
    other_pos: usize,
    phin: &mut [f64],
) {
    let len = ghost.len();
    assert!(ghost_pos + len <= phi.len() && other_pos + len <= phi.len());
    assert!(row_pos >= 1 && row_pos + len + 1 <= phi.len() && row_pos + len <= phin.len());
    for c in 0..len {
        let g = ghost[c];
        phi[ghost_pos + c] = g;
        phin[row_pos + c] =
            0.25 * (g + phi[other_pos + c] + phi[row_pos + c - 1] + phi[row_pos + c + 1]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn field(n: usize) -> Vec<f64> {
        (0..n).map(|i| (i as f64).sin() * 3.0 + i as f64 * 0.01).collect()
    }

    #[test]
    fn gather_matches_scalar_bitwise() {
        let src = field(257);
        // Deliberately irregular indices, length not a multiple of LANES.
        let idx: Vec<u32> = (0..131u32).map(|i| (i * 97 + 13) % 257).collect();
        let mut fast = vec![0.0; idx.len()];
        let mut slow = vec![0.0; idx.len()];
        pack_gather(&src, &idx, &mut fast);
        pack_gather_scalar(&src, &idx, &mut slow);
        assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn scatter_matches_scalar_bitwise() {
        let vals = field(131);
        // Unique targets (a scatter with duplicate indices is order-
        // dependent; the plans never produce duplicates within a message).
        let mut idx: Vec<u32> = (0..131u32).map(|i| (i * 2 + 5) % 262).collect();
        idx.sort_unstable();
        idx.dedup();
        let vals = &vals[..idx.len()];
        let mut fast = vec![0.0; 262];
        let mut slow = vec![0.0; 262];
        scatter_indexed(&mut fast, &idx, vals);
        scatter_indexed_scalar(&mut slow, &idx, vals);
        assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn empty_and_tiny_operands() {
        let src = field(8);
        let mut dst: Vec<f64> = vec![];
        pack_gather(&src, &[], &mut dst);
        let mut one = [0.0f64];
        pack_gather(&src, &[7], &mut one);
        assert_eq!(one[0].to_bits(), src[7].to_bits());
        let mut out = vec![0.0; 8];
        scatter_indexed(&mut out, &[3], &[42.0]);
        assert_eq!(out[3], 42.0);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn gather_rejects_wild_index() {
        let src = field(4);
        let mut dst = [0.0f64; 1];
        pack_gather(&src, &[9], &mut dst);
    }

    #[test]
    fn copy_block_matches_scalar() {
        let src = field(100);
        let mut a = vec![0.0; 100];
        let mut b = vec![0.0; 100];
        copy_block(&src, &mut a);
        copy_block_scalar(&src, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn fused_row_matches_two_pass() {
        // A 6×8 mini-grid: ghost row 0, computed row 1, other row 2.
        let n = 8usize;
        let base = field(6 * n);
        let ghost: Vec<f64> = field(n - 2).iter().map(|v| v * 1.7).collect();

        // Two-pass reference: unpack, then Jacobi over the row.
        let mut phi_ref = base.clone();
        let mut phin_ref = vec![0.0; 6 * n];
        phi_ref[1..1 + ghost.len()].copy_from_slice(&ghost);
        for c in 1..n - 1 {
            phin_ref[n + c] = 0.25
                * (phi_ref[c] + phi_ref[2 * n + c] + phi_ref[n + c - 1] + phi_ref[n + c + 1]);
        }

        let mut phi = base.clone();
        let mut phin = vec![0.0; 6 * n];
        fused_unpack_jacobi_row(&ghost, &mut phi, 1, n + 1, 2 * n + 1, &mut phin);
        assert!(phi.iter().zip(&phi_ref).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(phin.iter().zip(&phin_ref).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
