//! The workload-agnostic exchange runtime: a compiled [`ExchangePlan`], its
//! depth-D buffered staging arena (depth 2 by default), and a persistent
//! [`WorkerPool`] — everything a grid/halo workload needs to execute time
//! steps on either engine.
//!
//! Three step protocols, all driven entirely by the plan:
//!
//! **Synchronous** ([`step_strided`]) — the Listing 7 phase structure:
//!
//! ```text
//! pack: every sender gathers its compiled blocks into its arena ranges
//! ---- upc_barrier ----
//! unpack: every receiver scatters its arena ranges into its own halo
//! update: per-thread stencil kernel on the thread's own (field, out) pair
//! ```
//!
//! **Split-phase overlapped** ([`step_overlapped`]) — the nonblocking
//! begin/finish protocol that hides the exchange behind halo-independent
//! compute:
//!
//! ```text
//! begin_exchange:  pack into the current epoch's arena half, publish the
//!                  per-thread epoch flag (seqcst)
//! overlap window:  compute the interior (no halo dependence)
//! finish_exchange: wait on the flags of this thread's actual senders only
//!                  (no global barrier), unpack
//! boundary:        compute the halo-adjacent cells
//! ```
//!
//! **Multi-step pipelined** ([`run_pipelined`]) — S split-phase steps in
//! **one** pool dispatch. Fast threads start epoch `k+1` while slow peers
//! finish epoch `k`; the only back-pressure is the consumed-epoch
//! acknowledgment: before packing epoch `k` a sender waits until every one
//! of its receivers has *unpacked* epoch `k − D`, because that is when the
//! arena slot `k mod D` was last read (D = the configured pipeline depth,
//! 2 by default). This bounds any sender to at most D epochs ahead of its
//! slowest receiver — exactly the number of buffered arena slots — and
//! removes the per-step pool dispatch, the last global synchronization on
//! the critical path.
//!
//! On [`Engine::Sequential`] the phases are replayed on the calling thread
//! (the correctness oracle); on [`Engine::Parallel`] each logical thread is
//! a persistent pool worker. All paths run the same pack/unpack/update
//! code on the same data — and because interior ∪ boundary covers every
//! owned cell exactly once with the unchanged per-cell expression, the
//! overlapped and pipelined steps are **bitwise identical** to the
//! synchronous one. None of them allocates or spawns anything per step:
//! plan, arena, flags, acks and workers all persist.
//!
//! The staging arena is D-buffered receiver-major: epoch `k` packs into
//! slot `k mod D`, so a sender beginning epoch `k+1` writes a different
//! slot and never overwrites values a slow receiver is still reading from
//! epoch `k` (for any D ≥ 2; a depth-1 arena serializes epochs through the
//! ack gate instead). Every protocol advances the epoch uniformly (a
//! synchronous step too), so they can be mixed freely on one runtime
//! without pairing a stale parity slot with fresh flags.
//!
//! [`step_strided`]: ExchangeRuntime::step_strided
//! [`step_overlapped`]: ExchangeRuntime::step_overlapped
//! [`run_pipelined`]: ExchangeRuntime::run_pipelined

use super::fault::FaultPlan;
use super::pool::{
    ArenaView, EpochFlags, PerWorker, Phase, PoolHealth, WaitTuning, WorkerCtx, WorkerPool,
};
use super::reduce::ReductionPlan;
use super::Engine;
use crate::comm::{ExchangePlan, PlanDelta};
use crate::transport::{must, PoolEndpoint, Transport};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Compile the per-thread peer lists (distinct senders and receivers) from
/// a plan — the exact flag/ack sets the split-phase waits touch. Re-run on
/// every generation swap, since dirty pairs can add or remove edges.
fn compile_peers(plan: &ExchangePlan) -> (Vec<Vec<u32>>, Vec<Vec<u32>>) {
    let threads = plan.threads();
    let dedup_peers = |mut s: Vec<u32>| {
        s.sort_unstable();
        s.dedup();
        s
    };
    let senders = (0..threads)
        .map(|t| {
            dedup_peers(match plan {
                ExchangePlan::Gather(p) => p.recv_msgs(t).map(|m| m.peer).collect(),
                ExchangePlan::Strided(p) => p.recv_msgs(t).map(|m| m.peer).collect(),
            })
        })
        .collect();
    let receivers = (0..threads)
        .map(|t| {
            dedup_peers(match plan {
                ExchangePlan::Gather(p) => p.send_msgs(t).map(|m| m.peer).collect(),
                ExchangePlan::Strided(p) => p.send_msgs(t).map(|m| m.peer).collect(),
            })
        })
        .collect();
    (senders, receivers)
}

/// A compiled plan bound to its staging arena and worker pool. Workloads
/// (heat-2D, the 3D stencil) own one and call [`step_strided`] or
/// [`step_overlapped`] per time step, or [`run_pipelined`] for a whole
/// batch; the SpMV engine shares the same pool/arena machinery through
/// [`crate::engine::ParallelPool`].
///
/// [`step_strided`]: ExchangeRuntime::step_strided
/// [`step_overlapped`]: ExchangeRuntime::step_overlapped
/// [`run_pipelined`]: ExchangeRuntime::run_pipelined
#[derive(Debug)]
pub struct ExchangeRuntime {
    plan: ExchangePlan,
    /// D-buffered staging arena: `depth × plan.total_values()` doubles,
    /// allocated once. Epoch `k` uses the slot at `(k mod depth) · total`.
    staging: Vec<f64>,
    /// Pipeline depth D: how many epochs' staging slots exist, and how far
    /// a pipelined sender may run ahead of its slowest receiver. 2 by
    /// default (the classic double buffer).
    depth: usize,
    /// Long-lived workers; empty until the first parallel step.
    pool: WorkerPool,
    /// Per-thread published-epoch counters for the split-phase protocol.
    flags: EpochFlags,
    /// Per-thread consumed-epoch counters (the pipelined ack protocol:
    /// thread t has unpacked every message of epoch `acks[t]`).
    acks: EpochFlags,
    /// Exchange epoch of the last executed step (0 = none yet). Every step
    /// protocol bumps it uniformly, so mixing `step_strided`,
    /// `step_overlapped` and `run_pipelined` on one runtime keeps arena
    /// parity, flags and acks consistent.
    epoch: u64,
    /// `senders[t]` — the distinct threads that send to `t`, i.e. exactly
    /// the flags `finish_exchange` waits on. Compiled once from the plan.
    senders: Vec<Vec<u32>>,
    /// `receivers[t]` — the distinct threads `t` sends to, i.e. exactly the
    /// acks a pipelined sender waits on before reusing an arena half.
    receivers: Vec<Vec<u32>>,
    /// Diagnostics: the largest `published − consumed` distance any
    /// receiver ever observed against one of its senders (pipelined steps
    /// only). The ack protocol bounds it by the pipeline depth D.
    max_lead: AtomicU64,
    /// Injected faults consulted by the parallel protocol arms (empty by
    /// default — the hooks are length checks). The sequential oracle never
    /// consults it.
    faults: FaultPlan,
    /// Structural fingerprint of `plan`, cached at construction; checkpoint
    /// restore verifies against it.
    plan_hash: u64,
    /// Plan generation: 0 for the construction-time plan, bumped by every
    /// [`install_plan`](ExchangeRuntime::install_plan) /
    /// [`apply_delta`](ExchangeRuntime::apply_delta). Checkpoints record it
    /// alongside the fingerprint so a restore lands on the exact generation
    /// it was taken under.
    generation: u64,
}

impl ExchangeRuntime {
    pub fn new(plan: impl Into<ExchangePlan>) -> ExchangeRuntime {
        ExchangeRuntime::with_depth(plan, 2)
    }

    /// Like [`ExchangeRuntime::new`] but with an explicit pipeline depth D
    /// (number of buffered staging slots; the pipelined ack gate waits on
    /// epoch `e − D`). Depth 2 is the classic double buffer; depth 1
    /// serializes epochs through the gate; deeper arenas absorb more
    /// sender/receiver jitter at the cost of `D × total_values()` staging.
    pub fn with_depth(plan: impl Into<ExchangePlan>, depth: usize) -> ExchangeRuntime {
        assert!(depth >= 1, "pipeline depth must be at least 1");
        let plan = plan.into();
        debug_assert!(
            plan.validate(&|_| usize::MAX).is_ok(),
            "compiled exchange plan failed validation: {:?}",
            plan.validate(&|_| usize::MAX)
        );
        let threads = plan.threads();
        let staging = vec![0.0f64; depth * plan.total_values()];
        let (senders, receivers) = compile_peers(&plan);
        let plan_hash = plan.fingerprint();
        ExchangeRuntime {
            plan,
            staging,
            depth,
            pool: WorkerPool::new(),
            flags: EpochFlags::new(threads),
            acks: EpochFlags::new(threads),
            epoch: 0,
            senders,
            receivers,
            max_lead: AtomicU64::new(0),
            faults: FaultPlan::default(),
            plan_hash,
            generation: 0,
        }
    }

    pub fn plan(&self) -> &ExchangePlan {
        &self.plan
    }

    /// The configured pipeline depth D (buffered staging slots).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Reconfigure the pipeline depth between steps (resizes the staging
    /// arena to `depth × total_values()`). Safe at any step boundary: the
    /// staging contents are transient per epoch and `&mut self` guarantees
    /// no dispatch is in flight. Epoch counters keep advancing monotonely,
    /// so protocols stay mixable across the change.
    pub fn set_depth(&mut self, depth: usize) {
        assert!(depth >= 1, "pipeline depth must be at least 1");
        self.depth = depth;
        self.staging.clear();
        self.staging.resize(depth * self.plan.total_values(), 0.0);
    }

    /// The current plan generation (0 = the construction-time plan; each
    /// successful [`install_plan`](ExchangeRuntime::install_plan) or
    /// [`apply_delta`](ExchangeRuntime::apply_delta) bumps it).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Swap in the next plan generation **without tearing anything down**:
    /// the worker pool keeps running, the epoch counters keep their
    /// monotone history (so protocols stay mixable across the swap), and
    /// the staging arena grows or shrinks in place to
    /// `depth × total_values()` of the new plan. Only the plan-derived
    /// state is recompiled: peer lists, fingerprint, arena size.
    ///
    /// `&mut self` *is* the epoch boundary — no dispatch can be in flight —
    /// which is what makes the swap race-free without a barrier. The new
    /// plan must be compiled for the same thread count (the flag/ack arrays
    /// and pool cohort are sized by it). Returns the new generation number.
    pub fn install_plan(&mut self, plan: impl Into<ExchangePlan>) -> Result<u64, String> {
        let plan = plan.into();
        if plan.threads() != self.flags.len() {
            return Err(format!(
                "generation swap changes thread count ({} -> {})",
                self.flags.len(),
                plan.threads()
            ));
        }
        plan.validate(&|_| usize::MAX)
            .map_err(|e| format!("next plan generation failed validation: {e}"))?;
        let (senders, receivers) = compile_peers(&plan);
        self.plan_hash = plan.fingerprint();
        self.senders = senders;
        self.receivers = receivers;
        self.plan = plan;
        self.staging.clear();
        self.staging.resize(self.depth * self.plan.total_values(), 0.0);
        self.generation += 1;
        Ok(self.generation)
    }

    /// Advance the plan by a [`PlanDelta`] — the incremental-recompile
    /// path: patch only the dirty `(receiver, sender)` pairs
    /// ([`ExchangePlan::apply_delta`]), then swap the patched generation in
    /// via [`install_plan`](ExchangeRuntime::install_plan). The delta's
    /// base fingerprint must match the live plan, so a stale or misrouted
    /// delta is rejected before anything is touched. Returns the new
    /// generation number.
    pub fn apply_delta(&mut self, delta: &PlanDelta) -> Result<u64, String> {
        let next = self.plan.apply_delta(delta)?;
        self.install_plan(next)
    }

    /// The distinct senders of thread `t` (the peers `finish_exchange`
    /// waits on).
    pub fn senders_of(&self, t: usize) -> &[u32] {
        &self.senders[t]
    }

    /// The distinct receivers of thread `t` (the peers whose consumed-epoch
    /// acks a pipelined sender waits on before reusing an arena half).
    pub fn receivers_of(&self, t: usize) -> &[u32] {
        &self.receivers[t]
    }

    /// Pool dispatches issued so far — `run_pipelined` costs exactly one
    /// per S-step batch on the parallel engine (and zero on the oracle).
    pub fn dispatches(&self) -> u64 {
        self.pool.dispatches()
    }

    /// Largest `published − consumed` epoch distance any receiver observed
    /// against one of its senders during pipelined steps. The consumed-epoch
    /// ack protocol bounds this by the pipeline depth: a sender packs epoch
    /// `e` only after every receiver acked `e − D`, so the lead never
    /// exceeds D.
    pub fn max_sender_lead(&self) -> u64 {
        self.max_lead.load(Ordering::Relaxed)
    }

    /// Payload bytes every step moves across thread boundaries (a constant
    /// of the compiled plan — the workloads' traffic counters add this).
    pub fn payload_bytes(&self) -> u64 {
        self.plan.payload_bytes()
    }

    /// Structural fingerprint of the compiled plan
    /// ([`ExchangePlan::fingerprint`], cached at construction). Checkpoints
    /// record it so restore can refuse a snapshot from a different
    /// decomposition.
    pub fn plan_fingerprint(&self) -> u64 {
        self.plan_hash
    }

    /// The exchange epoch of the last executed step (0 = none yet).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Thread `t`'s published-epoch counter (diagnostics).
    pub fn published_epoch(&self, t: usize) -> u64 {
        self.flags.load(t)
    }

    /// Thread `t`'s consumed-epoch counter (diagnostics).
    pub fn consumed_epoch(&self, t: usize) -> u64 {
        self.acks.load(t)
    }

    /// Set (or with `None`, disable) the deadline on every wait the
    /// parallel protocol arms perform. See
    /// [`WorkerPool::set_wait_deadline`].
    pub fn set_wait_deadline(&mut self, deadline: Option<Duration>) {
        self.pool.set_wait_deadline(deadline);
    }

    /// The configured wait deadline.
    pub fn wait_deadline(&self) -> Option<Duration> {
        self.pool.wait_deadline()
    }

    /// Tune the spin → yield → timed-park wait ladder. See
    /// [`WorkerPool::set_wait_tuning`].
    pub fn set_wait_tuning(&mut self, tuning: WaitTuning) {
        self.pool.set_wait_tuning(tuning);
    }

    /// Install a fault-injection plan consulted by the parallel protocol
    /// arms (testing/chaos only; an empty plan is free).
    pub fn set_fault_plan(&mut self, faults: FaultPlan) {
        self.faults = faults;
    }

    /// Remove any installed fault plan.
    pub fn clear_faults(&mut self) {
        self.faults = FaultPlan::default();
    }

    /// Snapshot the worker pool's health (per-worker phase/epoch progress
    /// plus the watchdog's stall report, if any).
    pub fn health(&self) -> PoolHealth {
        self.pool.health()
    }

    /// One full exchange-then-update time step of a strided plan.
    ///
    /// `fields[t]`/`out[t]` are thread t's current and next local fields;
    /// `update(t, field, out)` is the per-thread stencil kernel, called
    /// after t's halo is complete. Panics if the plan is not the strided
    /// form.
    ///
    /// Epoch-uniform with the split-phase protocols: the step bumps the
    /// exchange epoch, packs into that epoch's arena parity half, and
    /// publishes both the published- and consumed-epoch counters (the
    /// global barrier already provides the synchronization, so the
    /// publishes are pure bookkeeping). Without this, a synchronous step
    /// sandwiched between overlapped/pipelined ones would silently reuse an
    /// arena half while leaving the flags describing the *previous* epoch —
    /// a stale parity/flag pairing the mixed-protocol tests pin down.
    pub fn step_strided<U>(
        &mut self,
        engine: Engine,
        fields: &mut [Vec<f64>],
        out: &mut [Vec<f64>],
        update: U,
    ) where
        U: Fn(usize, &mut [f64], &mut [f64]) + Sync,
    {
        let plan = self
            .plan
            .as_strided()
            .expect("step_strided needs a strided exchange plan");
        let threads = plan.threads();
        assert_eq!(fields.len(), threads, "one field per thread");
        assert_eq!(out.len(), threads, "one output field per thread");
        let total = plan.total_values();
        let depth = self.depth;
        debug_assert_eq!(self.staging.len(), depth * total);
        self.epoch += 1;
        let epoch = self.epoch;
        let half = (epoch % depth as u64) as usize * total;
        match engine {
            Engine::Sequential => {
                for (t, field) in fields.iter().enumerate() {
                    for m in plan.send_msgs(t) {
                        let r = m.range();
                        m.pack(field, &mut self.staging[half + r.start..half + r.end]);
                    }
                    self.flags.publish(t, epoch);
                }
                // ---- upc_barrier ----
                for (t, field) in fields.iter_mut().enumerate() {
                    for m in plan.recv_msgs(t) {
                        let r = m.range();
                        m.unpack(&self.staging[half + r.start..half + r.end], field);
                    }
                    self.acks.publish(t, epoch);
                }
                for (t, (field, o)) in fields.iter_mut().zip(out.iter_mut()).enumerate() {
                    update(t, field.as_mut_slice(), o.as_mut_slice());
                }
            }
            Engine::Parallel => {
                let arena = ArenaView::new(&mut self.staging);
                let fw = PerWorker::new(fields);
                let ow = PerWorker::new(out);
                let update = &update;
                let (flags, acks) = (&self.flags, &self.acks);
                let faults = &self.faults;
                self.pool.run(threads, &|ctx: WorkerCtx| {
                    let t = ctx.id;
                    // SAFETY: plan ranges are disjoint per message (and
                    // halved per epoch parity); packed by the sender only and
                    // read only after the barrier.
                    let mut ep =
                        unsafe { PoolEndpoint::new(t, total, depth, flags, acks, &arena, &ctx) };
                    ctx.note_phase(Phase::Pack, epoch);
                    faults.on_phase(t, epoch, Phase::Pack);
                    // SAFETY: worker t claims only its own field/out pair.
                    let field = unsafe { fw.take(t) }.as_mut_slice();
                    for m in plan.send_msgs(t) {
                        m.pack(field, ep.send_slot(epoch, m.range()));
                    }
                    if faults.before_publish(t, epoch) {
                        must(ep.publish(epoch));
                    }

                    ctx.note_phase(Phase::Barrier, epoch);
                    ctx.barrier(); // ---- upc_barrier ----

                    ctx.note_phase(Phase::Unpack, epoch);
                    faults.on_phase(t, epoch, Phase::Unpack);
                    faults.before_unpack(t, epoch);
                    for m in plan.recv_msgs(t) {
                        m.unpack(ep.recv_slot(epoch, m.range()), field);
                    }
                    if faults.before_ack(t, epoch) {
                        must(ep.ack(epoch));
                    }
                    ctx.note_phase(Phase::Boundary, epoch);
                    faults.on_phase(t, epoch, Phase::Boundary);
                    update(t, field, unsafe { ow.take(t) }.as_mut_slice());
                });
            }
        }
    }

    /// One split-phase overlapped time step of a strided plan:
    /// `begin_exchange` (pack + publish) → interior compute (overlaps the
    /// exchange) → `finish_exchange` (per-peer epoch waits, no global
    /// barrier) → unpack → boundary compute.
    ///
    /// `interior(t, field, out)` must update exactly the cells with no halo
    /// dependence and `boundary(t, field, out)` exactly the rest, each cell
    /// once with the synchronous step's expression — then the result is
    /// bitwise identical to [`step_strided`](ExchangeRuntime::step_strided).
    /// Panics if the plan is not the strided form.
    pub fn step_overlapped<UI, UB>(
        &mut self,
        engine: Engine,
        fields: &mut [Vec<f64>],
        out: &mut [Vec<f64>],
        interior: UI,
        boundary: UB,
    ) where
        UI: Fn(usize, &mut [f64], &mut [f64]) + Sync,
        UB: Fn(usize, &mut [f64], &mut [f64]) + Sync,
    {
        let plan = self
            .plan
            .as_strided()
            .expect("step_overlapped needs a strided exchange plan");
        let threads = plan.threads();
        assert_eq!(fields.len(), threads, "one field per thread");
        assert_eq!(out.len(), threads, "one output field per thread");
        let total = plan.total_values();
        let depth = self.depth;
        debug_assert_eq!(self.staging.len(), depth * total);
        self.epoch += 1;
        let epoch = self.epoch;
        // D-buffering: this epoch's receiver-major arena slot.
        let half = (epoch % depth as u64) as usize * total;
        match engine {
            Engine::Sequential => {
                for (t, field) in fields.iter().enumerate() {
                    for m in plan.send_msgs(t) {
                        let r = m.range();
                        m.pack(field, &mut self.staging[half + r.start..half + r.end]);
                    }
                    self.flags.publish(t, epoch);
                }
                for (t, (field, o)) in fields.iter_mut().zip(out.iter_mut()).enumerate() {
                    interior(t, field.as_mut_slice(), o.as_mut_slice());
                }
                // finish_exchange is trivially satisfied on one OS thread.
                for (t, field) in fields.iter_mut().enumerate() {
                    for m in plan.recv_msgs(t) {
                        let r = m.range();
                        m.unpack(&self.staging[half + r.start..half + r.end], field);
                    }
                    self.acks.publish(t, epoch);
                }
                for (t, (field, o)) in fields.iter_mut().zip(out.iter_mut()).enumerate() {
                    boundary(t, field.as_mut_slice(), o.as_mut_slice());
                }
            }
            Engine::Parallel => {
                let arena = ArenaView::new(&mut self.staging);
                let fw = PerWorker::new(fields);
                let ow = PerWorker::new(out);
                let (interior, boundary) = (&interior, &boundary);
                let (flags, acks) = (&self.flags, &self.acks);
                let senders = &self.senders;
                let faults = &self.faults;
                self.pool.run(threads, &|ctx: WorkerCtx| {
                    let t = ctx.id;
                    // SAFETY: plan ranges are disjoint per message and halved
                    // per epoch parity; packed by the sender only, read only
                    // after the sender's epoch publish was observed.
                    let mut ep =
                        unsafe { PoolEndpoint::new(t, total, depth, flags, acks, &arena, &ctx) };
                    ctx.note_phase(Phase::Pack, epoch);
                    faults.on_phase(t, epoch, Phase::Pack);
                    // SAFETY: worker t claims only its own field/out pair,
                    // exactly once per dispatch.
                    let field = unsafe { fw.take(t) }.as_mut_slice();
                    let o = unsafe { ow.take(t) }.as_mut_slice();
                    // begin_exchange: pack into this epoch's half + publish.
                    for m in plan.send_msgs(t) {
                        m.pack(field, ep.send_slot(epoch, m.range()));
                    }
                    if faults.before_publish(t, epoch) {
                        must(ep.publish(epoch));
                    }

                    // Overlap window: halo-independent compute.
                    interior(t, field, o);

                    // finish_exchange: wait on actual senders only.
                    ctx.note_phase(Phase::Transfer, epoch);
                    faults.on_phase(t, epoch, Phase::Transfer);
                    for &peer in &senders[t] {
                        must(ep.wait_for_epoch(peer as usize, epoch));
                    }
                    ctx.note_phase(Phase::Unpack, epoch);
                    faults.before_unpack(t, epoch);
                    for m in plan.recv_msgs(t) {
                        m.unpack(ep.recv_slot(epoch, m.range()), field);
                    }
                    if faults.before_ack(t, epoch) {
                        must(ep.ack(epoch));
                    }
                    ctx.note_phase(Phase::Boundary, epoch);
                    faults.on_phase(t, epoch, Phase::Boundary);
                    boundary(t, field, o);
                });
            }
        }
    }

    /// One split-phase overlapped step with **unpack/compute fusion**, on
    /// the sequential oracle engine: identical to the
    /// [`Engine::Sequential`] arm of
    /// [`step_overlapped`](ExchangeRuntime::step_overlapped), except each
    /// received message is first offered to
    /// `fuse(t, i, staged, field, out)` — `i` is the message's index in
    /// `recv_msgs(t)` order and `staged` its packed values in this epoch's
    /// arena slot. Returning `true` means the closure consumed the message:
    /// it wrote the staged values into `field` *and* computed every `out`
    /// cell that depends on them, in one pass (e.g.
    /// [`kernels::fused_unpack_jacobi_row`]). Returning `false` falls back
    /// to the plan's `unpack`. `boundary` then computes the residual
    /// boundary cells — those no fused message covered — so interior ∪
    /// fused ∪ residual must cover every owned cell exactly once with the
    /// synchronous step's expression; then the step stays bitwise identical
    /// to [`step_strided`](ExchangeRuntime::step_strided).
    ///
    /// Epoch/flag/ack bookkeeping matches `step_overlapped` exactly, so
    /// fused steps mix freely with every other protocol on one runtime.
    /// There is no parallel arm yet: the oracle defines the fused
    /// semantics, and workloads fall back to `step_overlapped` on
    /// [`Engine::Parallel`].
    ///
    /// [`kernels::fused_unpack_jacobi_row`]: crate::engine::kernels::fused_unpack_jacobi_row
    pub fn step_overlapped_fused<UI, F, UB>(
        &mut self,
        fields: &mut [Vec<f64>],
        out: &mut [Vec<f64>],
        interior: UI,
        fuse: F,
        boundary: UB,
    ) where
        UI: Fn(usize, &mut [f64], &mut [f64]),
        F: Fn(usize, usize, &[f64], &mut [f64], &mut [f64]) -> bool,
        UB: Fn(usize, &mut [f64], &mut [f64]),
    {
        let plan = self
            .plan
            .as_strided()
            .expect("step_overlapped_fused needs a strided exchange plan");
        let threads = plan.threads();
        assert_eq!(fields.len(), threads, "one field per thread");
        assert_eq!(out.len(), threads, "one output field per thread");
        let total = plan.total_values();
        let depth = self.depth;
        debug_assert_eq!(self.staging.len(), depth * total);
        self.epoch += 1;
        let epoch = self.epoch;
        let half = (epoch % depth as u64) as usize * total;
        for (t, field) in fields.iter().enumerate() {
            for m in plan.send_msgs(t) {
                let r = m.range();
                m.pack(field, &mut self.staging[half + r.start..half + r.end]);
            }
            self.flags.publish(t, epoch);
        }
        for (t, (field, o)) in fields.iter_mut().zip(out.iter_mut()).enumerate() {
            interior(t, field.as_mut_slice(), o.as_mut_slice());
        }
        // finish_exchange is trivially satisfied on one OS thread. Fusing
        // the boundary compute into the unpack sweep is safe per thread:
        // unpack reads only the (fully packed) staging arena and writes
        // only t's own field, boundary reads only t's own pair.
        for (t, (field, o)) in fields.iter_mut().zip(out.iter_mut()).enumerate() {
            for (i, m) in plan.recv_msgs(t).enumerate() {
                let r = m.range();
                let staged = &self.staging[half + r.start..half + r.end];
                if !fuse(t, i, staged, field.as_mut_slice(), o.as_mut_slice()) {
                    m.unpack(staged, field);
                }
            }
            self.acks.publish(t, epoch);
            boundary(t, field.as_mut_slice(), o.as_mut_slice());
        }
    }

    /// The multi-step pipelined driver: run `steps` split-phase time steps
    /// inside **one** pool dispatch. No global barrier and no per-step
    /// dispatch remain on the hot path — a worker's only synchronization is
    /// the per-peer epoch waits of `finish_exchange` plus the consumed-epoch
    /// acknowledgment gate:
    ///
    /// ```text
    /// per worker t, for each epoch e of the batch:
    ///   ack gate   wait until every receiver of t acked epoch e − D
    ///              (the arena slot of e was last drained at e − D)
    ///   begin      pack epoch e into arena slot (e mod D), publish flag
    ///   overlap    interior compute of the step
    ///   finish     wait on t's senders' flags ≥ e, unpack, publish ack
    ///   boundary   boundary compute, flip (field, out) roles
    /// ```
    ///
    /// The ack gate is what makes the depth-D arena reuse sound *without*
    /// re-synchronizing the pool: a fast sender may run ahead of its
    /// slowest receiver, but by at most D epochs — exactly the number of
    /// buffered slots. The first D epochs of a batch skip the gate (every
    /// slot is quiescent at dispatch entry, since `run` only returns
    /// once every worker finished the previous batch), which also makes the
    /// driver robust to ack counters left stale by earlier single-step
    /// protocols.
    ///
    /// `interior`/`boundary` are the same kernels as
    /// [`step_overlapped`](ExchangeRuntime::step_overlapped); each epoch
    /// computes every owned cell exactly once with the unchanged
    /// expression, so the batch is **bitwise identical** to `steps`
    /// synchronous (or overlapped) steps on either engine. On return,
    /// `fields` holds the final state and `out` the previous step's — the
    /// same post-swap convention as `steps` calls of a single-step protocol
    /// each followed by the caller's buffer swap.
    pub fn run_pipelined<UI, UB>(
        &mut self,
        engine: Engine,
        steps: usize,
        fields: &mut [Vec<f64>],
        out: &mut [Vec<f64>],
        interior: UI,
        boundary: UB,
    ) where
        UI: Fn(usize, &mut [f64], &mut [f64]) + Sync,
        UB: Fn(usize, &mut [f64], &mut [f64]) + Sync,
    {
        let plan = self
            .plan
            .as_strided()
            .expect("run_pipelined needs a strided exchange plan");
        let threads = plan.threads();
        assert_eq!(fields.len(), threads, "one field per thread");
        assert_eq!(out.len(), threads, "one output field per thread");
        if steps == 0 {
            return;
        }
        let total = plan.total_values();
        let depth = self.depth;
        debug_assert_eq!(self.staging.len(), depth * total);
        match engine {
            Engine::Sequential => {
                // The oracle is one overlapped step at a time — literally
                // the same single-step body (phases, epoch/flag/ack
                // bookkeeping and all), plus the per-step buffer-role swap
                // the parallel workers perform locally. Sharing the body
                // keeps the two oracle schedules from drifting apart.
                for _ in 0..steps {
                    self.step_overlapped(engine, fields, out, &interior, &boundary);
                    for (field, o) in fields.iter_mut().zip(out.iter_mut()) {
                        std::mem::swap(field, o);
                    }
                }
            }
            Engine::Parallel => {
                let base = self.epoch;
                self.epoch += steps as u64;
                let arena = ArenaView::new(&mut self.staging);
                let fw = PerWorker::new(fields);
                let ow = PerWorker::new(out);
                let (interior, boundary) = (&interior, &boundary);
                let (flags, acks) = (&self.flags, &self.acks);
                let (senders, receivers) = (&self.senders, &self.receivers);
                let max_lead = &self.max_lead;
                let faults = &self.faults;
                self.pool.run(threads, &|ctx: WorkerCtx| {
                    let t = ctx.id;
                    // SAFETY: plan ranges are disjoint per message and halved
                    // by epoch parity; the ack gate orders the previous
                    // tenant's reads before each overwrite, and unpacks only
                    // follow an observed epoch publish.
                    let mut ep =
                        unsafe { PoolEndpoint::new(t, total, depth, flags, acks, &arena, &ctx) };
                    // SAFETY: worker t claims only its own field/out pair,
                    // exactly once per dispatch; the per-epoch role flip
                    // below only swaps which local name points where.
                    let mut cur = unsafe { fw.take(t) };
                    let mut nxt = unsafe { ow.take(t) };
                    // Thread-local max of the depth-bound diagnostic; folded
                    // into the shared counter once per batch, so the hot
                    // loop never touches a contended cache line.
                    let mut local_lead = 0u64;
                    for k in 1..=steps as u64 {
                        let epoch = base + k;
                        let field = cur.as_mut_slice();
                        let o = nxt.as_mut_slice();

                        // Ack gate: slot (epoch mod D) was last packed at
                        // epoch − D; every receiver must have drained it.
                        // The first D epochs skip the gate — at dispatch
                        // entry every slot is quiescent.
                        if k > depth as u64 {
                            ctx.note_phase(Phase::AckGate, epoch);
                            for &r in &receivers[t] {
                                must(ep.wait_for_ack(r as usize, epoch - depth as u64));
                            }
                        }

                        // begin_exchange: pack this epoch's half + publish.
                        ctx.note_phase(Phase::Pack, epoch);
                        faults.on_phase(t, epoch, Phase::Pack);
                        for m in plan.send_msgs(t) {
                            m.pack(field, ep.send_slot(epoch, m.range()));
                        }
                        if faults.before_publish(t, epoch) {
                            must(ep.publish(epoch));
                        }

                        // Overlap window: halo-independent compute.
                        interior(t, field, o);

                        // finish_exchange: wait on actual senders only.
                        ctx.note_phase(Phase::Transfer, epoch);
                        faults.on_phase(t, epoch, Phase::Transfer);
                        for &peer in &senders[t] {
                            must(ep.wait_for_epoch(peer as usize, epoch));
                        }
                        ctx.note_phase(Phase::Unpack, epoch);
                        faults.before_unpack(t, epoch);
                        for m in plan.recv_msgs(t) {
                            m.unpack(ep.recv_slot(epoch, m.range()), field);
                        }
                        if faults.before_ack(t, epoch) {
                            must(ep.ack(epoch));
                        }

                        // Depth-bound diagnostic: how far ahead of this
                        // just-consumed epoch has any of t's senders
                        // published? The ack protocol caps this at D.
                        for &peer in &senders[t] {
                            let lead = flags.load(peer as usize).saturating_sub(epoch);
                            local_lead = local_lead.max(lead);
                        }

                        ctx.note_phase(Phase::Boundary, epoch);
                        faults.on_phase(t, epoch, Phase::Boundary);
                        boundary(t, field, o);
                        std::mem::swap(&mut cur, &mut nxt);
                    }
                    max_lead.fetch_max(local_lead, Ordering::Relaxed);
                });
                if steps % 2 == 1 {
                    // Workers flipped roles an odd number of times: move the
                    // final state under the caller's `fields` name.
                    for (field, o) in fields.iter_mut().zip(out.iter_mut()) {
                        std::mem::swap(field, o);
                    }
                }
            }
        }
    }

    /// [`run_pipelined`](ExchangeRuntime::run_pipelined) with an exact
    /// tolerance stop: after each epoch's boundary compute, every worker
    /// contributes `metric(t, cur, nxt)` (e.g. its local `max |nxt − cur|`)
    /// to `reduction`'s tree combine, and gates the *next* epoch on the
    /// root's verdict for this one. The batch therefore executes exactly
    /// epochs `1..=e*`, where `e*` is the first epoch whose tree-folded
    /// metric reaches the reduction's tolerance — the same step a
    /// synchronous check-every-step loop stops at, bitwise (both engines
    /// fold in [`tree_fold`](crate::engine::tree_fold) order). No global
    /// barrier appears anywhere: the only new waits are tree edges and the
    /// root's verdict counter (see [`ReductionPlan`]).
    ///
    /// `reduction` must be fresh for this call (its epochs are relative to
    /// the batch) and compiled for the plan's thread count. Returns the
    /// number of steps executed (`e*`, or `max_steps` if the tolerance was
    /// never reached). On return `fields` holds the final state, exactly as
    /// `run_pipelined(executed, ..)` would leave it.
    #[allow(clippy::too_many_arguments)]
    pub fn run_pipelined_until<UI, UB, M>(
        &mut self,
        engine: Engine,
        max_steps: usize,
        fields: &mut [Vec<f64>],
        out: &mut [Vec<f64>],
        interior: UI,
        boundary: UB,
        metric: M,
        reduction: &ReductionPlan,
    ) -> usize
    where
        UI: Fn(usize, &mut [f64], &mut [f64]) + Sync,
        UB: Fn(usize, &mut [f64], &mut [f64]) + Sync,
        M: Fn(usize, &[f64], &[f64]) -> f64 + Sync,
    {
        let plan = self
            .plan
            .as_strided()
            .expect("run_pipelined_until needs a strided exchange plan");
        let threads = plan.threads();
        assert_eq!(fields.len(), threads, "one field per thread");
        assert_eq!(out.len(), threads, "one output field per thread");
        assert_eq!(reduction.threads(), threads, "reduction tree arity must match the plan");
        if max_steps == 0 {
            return 0;
        }
        let total = plan.total_values();
        let depth = self.depth;
        debug_assert_eq!(self.staging.len(), depth * total);
        match engine {
            Engine::Sequential => {
                // The oracle: overlapped steps with a check after every one,
                // feeding the same reduction tree (children before parents,
                // so every wait is already satisfied) — which keeps the
                // stopping decision, not just the fields, on the shared
                // code path.
                let mut executed = 0usize;
                for k in 1..=max_steps as u64 {
                    self.step_overlapped(engine, fields, out, &interior, &boundary);
                    for t in (0..threads).rev() {
                        let v = metric(t, &fields[t], &out[t]);
                        reduction
                            .combine(t, k, v)
                            .unwrap_or_else(|e| panic!("sequential reduce: {e}"));
                    }
                    for (field, o) in fields.iter_mut().zip(out.iter_mut()) {
                        std::mem::swap(field, o);
                    }
                    executed = k as usize;
                    if reduction.stopped_by(k).is_some() {
                        break;
                    }
                }
                executed
            }
            Engine::Parallel => {
                let base = self.epoch;
                let arena = ArenaView::new(&mut self.staging);
                let fw = PerWorker::new(fields);
                let ow = PerWorker::new(out);
                let (interior, boundary, metric) = (&interior, &boundary, &metric);
                let (flags, acks) = (&self.flags, &self.acks);
                let (senders, receivers) = (&self.senders, &self.receivers);
                let faults = &self.faults;
                self.pool.run(threads, &|ctx: WorkerCtx| {
                    let t = ctx.id;
                    // SAFETY: same disjointness argument as `run_pipelined`;
                    // the verdict gate only *shortens* the epoch sequence,
                    // uniformly across workers.
                    let mut ep =
                        unsafe { PoolEndpoint::new(t, total, depth, flags, acks, &arena, &ctx) };
                    // SAFETY: worker t claims only its own field/out pair.
                    let mut cur = unsafe { fw.take(t) };
                    let mut nxt = unsafe { ow.take(t) };
                    for k in 1..=max_steps as u64 {
                        // Stop gate: enter epoch k only once the root judged
                        // k − 1 unconverged. Lag 1 keeps the stop exact.
                        match reduction.wait_verdict(k - 1, t) {
                            Ok(None) => {}
                            Ok(Some(_)) => break,
                            Err(e) => panic!("reduce verdict wait: {e}"),
                        }
                        let epoch = base + k;
                        let field = cur.as_mut_slice();
                        let o = nxt.as_mut_slice();

                        if k > depth as u64 {
                            ctx.note_phase(Phase::AckGate, epoch);
                            for &r in &receivers[t] {
                                must(ep.wait_for_ack(r as usize, epoch - depth as u64));
                            }
                        }

                        ctx.note_phase(Phase::Pack, epoch);
                        faults.on_phase(t, epoch, Phase::Pack);
                        for m in plan.send_msgs(t) {
                            m.pack(field, ep.send_slot(epoch, m.range()));
                        }
                        if faults.before_publish(t, epoch) {
                            must(ep.publish(epoch));
                        }

                        interior(t, field, o);

                        ctx.note_phase(Phase::Transfer, epoch);
                        faults.on_phase(t, epoch, Phase::Transfer);
                        for &peer in &senders[t] {
                            must(ep.wait_for_epoch(peer as usize, epoch));
                        }
                        ctx.note_phase(Phase::Unpack, epoch);
                        faults.before_unpack(t, epoch);
                        for m in plan.recv_msgs(t) {
                            m.unpack(ep.recv_slot(epoch, m.range()), field);
                        }
                        if faults.before_ack(t, epoch) {
                            must(ep.ack(epoch));
                        }

                        ctx.note_phase(Phase::Boundary, epoch);
                        faults.on_phase(t, epoch, Phase::Boundary);
                        boundary(t, field, o);

                        // Contribute this epoch's metric to the tree; the
                        // root's fold decides whether epoch k + 1 happens.
                        let v = metric(t, field, o);
                        if let Err(e) = reduction.combine(t, k, v) {
                            panic!("reduce combine: {e}");
                        }
                        std::mem::swap(&mut cur, &mut nxt);
                    }
                });
                // Every worker broke at the same epoch (the verdict gate is
                // uniform): account the executed steps into the shared
                // monotone epoch, and restore the caller's buffer naming.
                let executed =
                    reduction.stopped_by(max_steps as u64).unwrap_or(max_steps as u64) as usize;
                self.epoch = base + executed as u64;
                if executed % 2 == 1 {
                    for (field, o) in fields.iter_mut().zip(out.iter_mut()) {
                        std::mem::swap(field, o);
                    }
                }
                executed
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StridedBlock, StridedPlan};

    /// A 2-thread 1D "halo": each thread owns 4 cells + 1 ghost on each
    /// side; the update averages left/right neighbours.
    fn ring_runtime() -> ExchangeRuntime {
        let copies = vec![
            // t0's last interior cell -> t1's left ghost (offset 0).
            (0usize, 1usize, StridedBlock::row(4, 1), StridedBlock::row(0, 1)),
            // t1's first interior cell -> t0's right ghost (offset 5).
            (1, 0, StridedBlock::row(1, 1), StridedBlock::row(5, 1)),
        ];
        ExchangeRuntime::new(StridedPlan::from_msgs(2, &copies))
    }

    /// [`ring_runtime`] with an explicit pipeline depth.
    fn ring_runtime_depth(depth: usize) -> ExchangeRuntime {
        let copies = vec![
            (0usize, 1usize, StridedBlock::row(4, 1), StridedBlock::row(0, 1)),
            (1, 0, StridedBlock::row(1, 1), StridedBlock::row(5, 1)),
        ];
        ExchangeRuntime::with_depth(StridedPlan::from_msgs(2, &copies), depth)
    }

    fn step(rt: &mut ExchangeRuntime, engine: Engine, fields: &mut [Vec<f64>]) -> Vec<Vec<f64>> {
        let mut out = fields.to_vec();
        rt.step_strided(engine, fields, &mut out, |_t, field, out| {
            for i in 1..5 {
                out[i] = 0.5 * (field[i - 1] + field[i + 1]);
            }
        });
        out
    }

    #[test]
    fn engines_agree_bitwise() {
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let mut rt_seq = ring_runtime();
        let mut rt_par = ring_runtime();
        let mut f_seq = init.clone();
        let mut f_par = init.clone();
        for _ in 0..4 {
            let o_seq = step(&mut rt_seq, Engine::Sequential, &mut f_seq);
            let o_par = step(&mut rt_par, Engine::Parallel, &mut f_par);
            assert_eq!(o_seq, o_par);
            // Ghost cells were exchanged identically too.
            assert_eq!(f_seq, f_par);
            f_seq = o_seq;
            f_par = o_par;
        }
    }

    /// The overlapped version of [`step`]: cells 2..4 never read a ghost
    /// (interior), cells 1 and 4 do (boundary).
    fn step_ovl(
        rt: &mut ExchangeRuntime,
        engine: Engine,
        fields: &mut [Vec<f64>],
    ) -> Vec<Vec<f64>> {
        let mut out = fields.to_vec();
        rt.step_overlapped(
            engine,
            fields,
            &mut out,
            |_t, field, out| {
                for i in 2..4 {
                    out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                }
            },
            |_t, field, out| {
                for i in [1usize, 4] {
                    out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                }
            },
        );
        out
    }

    #[test]
    fn overlapped_matches_synchronous_bitwise() {
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let mut rt_sync = ring_runtime();
        let mut rt_seq = ring_runtime();
        let mut rt_par = ring_runtime();
        let mut f_sync = init.clone();
        let mut f_seq = init.clone();
        let mut f_par = init.clone();
        // NB: don't name the loop variable `step` — it would shadow the
        // `step` helper fn and turn the calls below into E0618.
        for s in 0..6 {
            let o_sync = step(&mut rt_sync, Engine::Sequential, &mut f_sync);
            let o_seq = step_ovl(&mut rt_seq, Engine::Sequential, &mut f_seq);
            let o_par = step_ovl(&mut rt_par, Engine::Parallel, &mut f_par);
            assert_eq!(o_sync, o_seq, "seq overlap diverges at step {s}");
            assert_eq!(o_sync, o_par, "par overlap diverges at step {s}");
            assert_eq!(f_sync, f_seq);
            assert_eq!(f_sync, f_par);
            f_sync = o_sync;
            f_seq = o_seq;
            f_par = o_par;
        }
        // Epochs advanced once per overlapped step.
        assert_eq!(rt_par.epoch, 6);
    }

    #[test]
    fn fused_step_matches_synchronous_bitwise() {
        // The fused sequential step: each thread's single recv message
        // (the neighbour ghost) is consumed by a closure that writes the
        // ghost AND computes the dependent boundary cell in one pass; the
        // other boundary cell stays in the residual closure. Must stay
        // bitwise locked to the synchronous oracle — and with a
        // never-consuming closure it must degenerate to step_overlapped.
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let mut rt_sync = ring_runtime();
        let mut rt_fused = ring_runtime();
        let mut rt_fallback = ring_runtime();
        let mut f_sync = init.clone();
        let mut f_fused = init.clone();
        let mut f_fallback = init;
        let interior = |_t: usize, field: &mut [f64], out: &mut [f64]| {
            for i in 2..4 {
                out[i] = 0.5 * (field[i - 1] + field[i + 1]);
            }
        };
        for s in 0..5 {
            f_sync = step(&mut rt_sync, Engine::Sequential, &mut f_sync);

            let mut o = f_fused.clone();
            rt_fused.step_overlapped_fused(
                &mut f_fused,
                &mut o,
                interior,
                |t, _i, staged, field, out| {
                    // Ghost write + the ghost-adjacent cell, one pass.
                    if t == 0 {
                        field[5] = staged[0];
                        out[4] = 0.5 * (field[3] + field[5]);
                    } else {
                        field[0] = staged[0];
                        out[1] = 0.5 * (field[0] + field[2]);
                    }
                    true
                },
                |t, field, out| {
                    let i = if t == 0 { 1 } else { 4 };
                    out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                },
            );
            f_fused = o;

            let mut o = f_fallback.clone();
            rt_fallback.step_overlapped_fused(
                &mut f_fallback,
                &mut o,
                interior,
                |_t, _i, _staged, _field, _out| false,
                |_t, field, out| {
                    for i in [1usize, 4] {
                        out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                    }
                },
            );
            f_fallback = o;

            assert_eq!(f_sync, f_fused, "fused diverges at step {s}");
            assert_eq!(f_sync, f_fallback, "fallback diverges at step {s}");
        }
        // Epoch/flag/ack bookkeeping advanced uniformly.
        assert_eq!(rt_fused.epoch, 5);
        assert_eq!(rt_fused.consumed_epoch(0), 5);
        assert_eq!(rt_fused.published_epoch(1), 5);
    }

    #[test]
    fn senders_compiled_from_plan() {
        let rt = ring_runtime();
        assert_eq!(rt.senders_of(0), &[1]);
        assert_eq!(rt.senders_of(1), &[0]);
        assert_eq!(rt.receivers_of(0), &[1]);
        assert_eq!(rt.receivers_of(1), &[0]);
        // Double-buffered arena.
        assert_eq!(rt.staging.len(), 2 * rt.plan().total_values());
    }

    /// The pipelined version of [`step_ovl`]: one call drives S steps.
    fn steps_pipelined(
        rt: &mut ExchangeRuntime,
        engine: Engine,
        steps: usize,
        fields: &mut [Vec<f64>],
    ) {
        let mut out = fields.to_vec();
        rt.run_pipelined(
            engine,
            steps,
            fields,
            &mut out,
            |_t, field, out| {
                for i in 2..4 {
                    out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                }
            },
            |_t, field, out| {
                for i in [1usize, 4] {
                    out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                }
            },
        );
    }

    /// The owned (non-ghost) cells of every thread — what the protocols
    /// must agree on bitwise. Ghost-cell *contents* between steps are
    /// protocol-internal (each step overwrites them before reading).
    fn owned_cells(fields: &[Vec<f64>]) -> Vec<Vec<f64>> {
        fields.iter().map(|f| f[1..5].to_vec()).collect()
    }

    #[test]
    fn pipelined_matches_synchronous_bitwise() {
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        for steps in [1usize, 2, 3, 7] {
            let mut rt_sync = ring_runtime();
            let mut f_sync = init.clone();
            for _ in 0..steps {
                f_sync = step(&mut rt_sync, Engine::Sequential, &mut f_sync);
            }
            for engine in Engine::ALL {
                let mut rt = ring_runtime();
                let mut f = init.clone();
                steps_pipelined(&mut rt, engine, steps, &mut f);
                assert_eq!(
                    owned_cells(&f),
                    owned_cells(&f_sync),
                    "{} S={steps}",
                    engine.name()
                );
                assert_eq!(rt.epoch, steps as u64);
            }
        }
    }

    #[test]
    fn pipelined_batch_is_one_dispatch() {
        let mut rt = ring_runtime();
        let mut f = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        steps_pipelined(&mut rt, Engine::Parallel, 5, &mut f); // spawns pool
        let before = rt.dispatches();
        steps_pipelined(&mut rt, Engine::Parallel, 6, &mut f);
        assert_eq!(rt.dispatches(), before + 1, "one dispatch per batch");
        assert!(rt.max_sender_lead() <= 2, "lead {}", rt.max_sender_lead());
    }

    #[test]
    fn depth_d_pipelines_match_synchronous_bitwise() {
        // For every pipeline depth D ∈ {1,2,3,4}: a pipelined batch is
        // bitwise identical to the synchronous oracle, the sender lead
        // stays ≤ D, and the arena holds exactly D slots.
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let steps = 7usize;
        let mut rt_sync = ring_runtime();
        let mut f_sync = init.clone();
        for _ in 0..steps {
            f_sync = step(&mut rt_sync, Engine::Sequential, &mut f_sync);
        }
        for depth in 1..=4usize {
            for engine in Engine::ALL {
                let mut rt = ring_runtime_depth(depth);
                assert_eq!(rt.depth(), depth);
                assert_eq!(rt.staging.len(), depth * rt.plan().total_values());
                let mut f = init.clone();
                steps_pipelined(&mut rt, engine, steps, &mut f);
                assert_eq!(
                    owned_cells(&f),
                    owned_cells(&f_sync),
                    "{} D={depth} diverged",
                    engine.name()
                );
                assert!(
                    rt.max_sender_lead() <= depth as u64,
                    "D={depth} lead {}",
                    rt.max_sender_lead()
                );
            }
        }
    }

    #[test]
    fn set_depth_reconfigures_between_batches() {
        // Changing D at a batch boundary keeps the run bitwise locked to
        // the synchronous oracle (epochs stay monotone; staging contents
        // are transient per epoch).
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let mut rt_sync = ring_runtime();
        let mut f_sync = init.clone();
        let mut rt = ring_runtime();
        let mut f = init.clone();
        for (depth, steps) in [(3usize, 4usize), (1, 2), (4, 5), (2, 3)] {
            rt.set_depth(depth);
            steps_pipelined(&mut rt, Engine::Parallel, steps, &mut f);
            for _ in 0..steps {
                f_sync = step(&mut rt_sync, Engine::Sequential, &mut f_sync);
            }
            assert_eq!(owned_cells(&f), owned_cells(&f_sync), "after D={depth}");
        }
    }

    #[test]
    fn mixed_protocols_stay_bitwise_locked() {
        // Interleave all three protocols (and both engines) on ONE runtime
        // against a pure-synchronous oracle: the epoch-uniform accounting
        // must keep arena parity, flags and acks consistent throughout.
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let mut rt_oracle = ring_runtime();
        let mut f_oracle = init.clone();
        let mut rt = ring_runtime();
        let mut f = init.clone();
        let schedule: &[(&str, Engine, usize)] = &[
            ("sync", Engine::Parallel, 1),
            ("ovl", Engine::Parallel, 1),
            ("sync", Engine::Sequential, 1),
            ("pipe", Engine::Parallel, 3),
            ("ovl", Engine::Sequential, 1),
            ("pipe", Engine::Sequential, 2),
            ("sync", Engine::Parallel, 1),
            ("pipe", Engine::Parallel, 4),
            ("ovl", Engine::Parallel, 1),
        ];
        for &(proto, engine, steps) in schedule {
            match proto {
                "sync" => f = step(&mut rt, engine, &mut f),
                "ovl" => f = step_ovl(&mut rt, engine, &mut f),
                _ => steps_pipelined(&mut rt, engine, steps, &mut f),
            }
            for _ in 0..steps {
                f_oracle = step(&mut rt_oracle, Engine::Sequential, &mut f_oracle);
            }
            assert_eq!(owned_cells(&f), owned_cells(&f_oracle), "{proto} x{steps} diverged");
        }
        // Every protocol advanced the shared epoch uniformly.
        let total: usize = schedule.iter().map(|&(_, _, s)| s).sum();
        assert_eq!(rt.epoch, total as u64);
    }

    #[test]
    fn injected_drop_publish_stalls_cleanly() {
        use super::super::fault::FaultKind;
        use super::super::pool::StallError;
        // Thread 0 stops publishing from epoch 2 onward; the pipelined
        // batch must convert into a StallError at the transfer wait within
        // the deadline, not hang. (Which worker's deadline fires first is
        // timing-dependent; the phase and the structured payload are not.)
        let mut rt = ring_runtime();
        rt.set_wait_deadline(Some(std::time::Duration::from_millis(60)));
        rt.set_fault_plan(FaultPlan::none().with(0, 2, FaultKind::DropPublish));
        let mut f = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            steps_pipelined(&mut rt, Engine::Parallel, 4, &mut f);
        }));
        let payload = res.expect_err("dropped publish must unwind the batch");
        let stall = StallError::from_panic(payload.as_ref())
            .expect("payload must carry the structured StallError");
        assert_eq!(stall.phase, Phase::Transfer);
        assert!(stall.peer.is_some());
        // The runtime (pool included) stays usable once faults are cleared.
        rt.clear_faults();
        let mut f2 = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        f2 = step(&mut rt, Engine::Parallel, &mut f2);
        assert!(f2.iter().all(|v| v.iter().all(|x| x.is_finite())));
    }

    #[test]
    fn plan_fingerprint_is_stable_and_structural() {
        let a = ring_runtime();
        let b = ring_runtime();
        assert_eq!(a.plan_fingerprint(), b.plan_fingerprint());
        // A structurally different plan (extra message) fingerprints
        // differently.
        let copies = vec![
            (0usize, 1usize, StridedBlock::row(4, 1), StridedBlock::row(0, 1)),
            (1, 0, StridedBlock::row(1, 1), StridedBlock::row(5, 1)),
            (0, 1, StridedBlock::row(3, 1), StridedBlock::row(5, 1)),
        ];
        let c = ExchangeRuntime::new(StridedPlan::from_msgs(2, &copies));
        assert_ne!(a.plan_fingerprint(), c.plan_fingerprint());
    }

    #[test]
    fn halo_values_actually_cross() {
        let mut rt = ring_runtime();
        let mut fields = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        step(&mut rt, Engine::Parallel, &mut fields);
        // t1's left ghost got t0's cell 4; t0's right ghost got t1's cell 1.
        assert_eq!(fields[1][0], 4.0);
        assert_eq!(fields[0][5], 5.0);
        assert_eq!(rt.payload_bytes(), 16);
    }

    /// The ring plan plus one extra copy (t0's cell 3 into t1's right
    /// ghost) — a structurally different next generation.
    fn ring_plan_v2() -> ExchangePlan {
        let copies = vec![
            (0usize, 1usize, StridedBlock::row(4, 1), StridedBlock::row(0, 1)),
            (1, 0, StridedBlock::row(1, 1), StridedBlock::row(5, 1)),
            (0, 1, StridedBlock::row(3, 1), StridedBlock::row(5, 1)),
        ];
        ExchangePlan::Strided(StridedPlan::from_msgs(2, &copies))
    }

    #[test]
    fn apply_delta_advances_generation_in_place() {
        let mut rt = ring_runtime();
        assert_eq!(rt.generation(), 0);
        let old_fp = rt.plan_fingerprint();
        let next = ring_plan_v2();
        let d = PlanDelta::diff(rt.plan(), &next).unwrap();
        assert_eq!(rt.apply_delta(&d).unwrap(), 1);
        assert_eq!(rt.generation(), 1);
        assert_eq!(rt.plan_fingerprint(), next.fingerprint());
        assert_ne!(rt.plan_fingerprint(), old_fp);
        // Arena resized in place to the new plan's footprint.
        assert_eq!(rt.staging.len(), rt.depth() * rt.plan().total_values());
        // The same delta is now stale: its base is generation 0.
        let err = rt.apply_delta(&d).unwrap_err();
        assert!(err.contains("does not match"), "{err}");
        // A generation compiled for a different thread count is refused.
        let foreign = StridedPlan::from_msgs(
            3,
            &[(0usize, 1usize, StridedBlock::row(4, 1), StridedBlock::row(0, 1))],
        );
        let err = rt.install_plan(foreign).unwrap_err();
        assert!(err.contains("thread count"), "{err}");
    }

    #[test]
    fn generation_swap_mid_run_stays_bitwise() {
        // 3 steps on gen 0, swap plans without touching pool/flags/fields,
        // 3 steps on gen 1 — versus oracles that were *born* on each plan.
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let mut rt = ring_runtime();
        let mut f = init.clone();
        let mut f_oracle = init;
        {
            let mut rt_o = ring_runtime();
            for _ in 0..3 {
                f = step(&mut rt, Engine::Parallel, &mut f);
                f_oracle = step(&mut rt_o, Engine::Sequential, &mut f_oracle);
            }
        }
        rt.install_plan(ring_plan_v2()).unwrap();
        let mut rt_o = ExchangeRuntime::new(ring_plan_v2());
        for s in 0..3 {
            f = step(&mut rt, Engine::Parallel, &mut f);
            f_oracle = step(&mut rt_o, Engine::Sequential, &mut f_oracle);
            assert_eq!(owned_cells(&f), owned_cells(&f_oracle), "gen-1 step {s}");
        }
        // The pool kept its workers and the epoch its history.
        assert_eq!(rt.epoch(), 6);
    }

    /// Sync oracle for the tolerance stop: overlapped-equivalent steps with
    /// a tree-folded residual check after every one. Returns (steps, final
    /// fields).
    fn until_oracle(init: &[Vec<f64>], max_steps: usize, tol: f64) -> (usize, Vec<Vec<f64>>) {
        use crate::engine::{tree_fold, ReduceOp};
        let mut rt = ring_runtime();
        let mut f = init.to_vec();
        for k in 1..=max_steps {
            let o = step(&mut rt, Engine::Sequential, &mut f);
            let metrics: Vec<f64> = f
                .iter()
                .zip(&o)
                .map(|(cur, nxt)| {
                    (1..5).map(|i| (nxt[i] - cur[i]).abs()).fold(f64::NEG_INFINITY, f64::max)
                })
                .collect();
            let r = tree_fold(ReduceOp::Max, &metrics);
            f = o;
            if r <= tol {
                return (k, f);
            }
        }
        (max_steps, f)
    }

    #[test]
    fn pipelined_until_matches_synchronous_stop_exactly() {
        use crate::engine::{ReduceOp, ReductionPlan};
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let metric = |_t: usize, cur: &[f64], nxt: &[f64]| {
            (1..5).map(|i| (nxt[i] - cur[i]).abs()).fold(f64::NEG_INFINITY, f64::max)
        };
        for tol in [1.0, 0.25, 0.02] {
            let (want_steps, want_f) = until_oracle(&init, 60, tol);
            assert!(want_steps < 60, "tolerance {tol} must be reachable for this test");
            for engine in Engine::ALL {
                let mut rt = ring_runtime();
                let mut f = init.clone();
                let mut out = f.to_vec();
                let reduction = ReductionPlan::new(2, ReduceOp::Max, tol)
                    .with_deadline(Some(std::time::Duration::from_secs(5)));
                let executed = rt.run_pipelined_until(
                    engine,
                    60,
                    &mut f,
                    &mut out,
                    |_t, field, out| {
                        for i in 2..4 {
                            out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                        }
                    },
                    |_t, field, out| {
                        for i in [1usize, 4] {
                            out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                        }
                    },
                    metric,
                    &reduction,
                );
                assert_eq!(executed, want_steps, "{} tol={tol}", engine.name());
                assert_eq!(owned_cells(&f), owned_cells(&want_f), "{} tol={tol}", engine.name());
                assert_eq!(rt.epoch(), executed as u64);
            }
        }
    }

    #[test]
    fn pipelined_until_exhausts_unreachable_tolerance() {
        use crate::engine::{ReduceOp, ReductionPlan};
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let (want_steps, want_f) = until_oracle(&init, 5, -1.0);
        assert_eq!(want_steps, 5);
        for engine in Engine::ALL {
            let mut rt = ring_runtime();
            let mut f = init.clone();
            let mut out = f.to_vec();
            let reduction = ReductionPlan::new(2, ReduceOp::Max, -1.0)
                .with_deadline(Some(std::time::Duration::from_secs(5)));
            let executed = rt.run_pipelined_until(
                engine,
                5,
                &mut f,
                &mut out,
                |_t, field, out| {
                    for i in 2..4 {
                        out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                    }
                },
                |_t, field, out| {
                    for i in [1usize, 4] {
                        out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                    }
                },
                |_t: usize, cur: &[f64], nxt: &[f64]| {
                    (1..5).map(|i| (nxt[i] - cur[i]).abs()).fold(f64::NEG_INFINITY, f64::max)
                },
                &reduction,
            );
            assert_eq!(executed, 5, "{}", engine.name());
            assert_eq!(owned_cells(&f), owned_cells(&want_f), "{}", engine.name());
        }
    }
}
