//! The workload-agnostic exchange runtime: a compiled [`ExchangePlan`], its
//! flat staging arena, and a persistent [`WorkerPool`] — everything a
//! grid/halo workload needs to execute time steps on either engine.
//!
//! One step is the Listing 7 phase structure, driven entirely by the plan:
//!
//! ```text
//! pack: every sender gathers its compiled blocks into its arena ranges
//! ---- upc_barrier ----
//! unpack: every receiver scatters its arena ranges into its own halo
//! update: per-thread stencil kernel on the thread's own (field, out) pair
//! ```
//!
//! On [`Engine::Sequential`] the phases are replayed on the calling thread
//! (the correctness oracle); on [`Engine::Parallel`] each logical thread is
//! a persistent pool worker and the barrier is real. Both paths run the
//! same pack/unpack/update code on the same data in the same order, so the
//! results are **bitwise identical** — and neither allocates nor spawns
//! anything per step: plan, arena, and workers all persist.

use super::pool::{ArenaView, PerWorker, WorkerCtx, WorkerPool};
use super::Engine;
use crate::comm::ExchangePlan;

/// A compiled plan bound to its staging arena and worker pool. Workloads
/// (heat-2D, the 3D stencil) own one and call [`step_strided`] per time
/// step; the SpMV engine shares the same pool/arena machinery through
/// [`crate::engine::ParallelPool`].
///
/// [`step_strided`]: ExchangeRuntime::step_strided
#[derive(Debug)]
pub struct ExchangeRuntime {
    plan: ExchangePlan,
    /// Flat staging arena of `plan.total_values()` doubles, allocated once.
    staging: Vec<f64>,
    /// Long-lived workers; empty until the first parallel step.
    pool: WorkerPool,
}

impl ExchangeRuntime {
    pub fn new(plan: impl Into<ExchangePlan>) -> ExchangeRuntime {
        let plan = plan.into();
        let staging = vec![0.0f64; plan.total_values()];
        ExchangeRuntime { plan, staging, pool: WorkerPool::new() }
    }

    pub fn plan(&self) -> &ExchangePlan {
        &self.plan
    }

    /// Payload bytes every step moves across thread boundaries (a constant
    /// of the compiled plan — the workloads' traffic counters add this).
    pub fn payload_bytes(&self) -> u64 {
        self.plan.payload_bytes()
    }

    /// One full exchange-then-update time step of a strided plan.
    ///
    /// `fields[t]`/`out[t]` are thread t's current and next local fields;
    /// `update(t, field, out)` is the per-thread stencil kernel, called
    /// after t's halo is complete. Panics if the plan is not the strided
    /// form.
    pub fn step_strided<U>(
        &mut self,
        engine: Engine,
        fields: &mut [Vec<f64>],
        out: &mut [Vec<f64>],
        update: U,
    ) where
        U: Fn(usize, &mut [f64], &mut [f64]) + Sync,
    {
        let plan = self
            .plan
            .as_strided()
            .expect("step_strided needs a strided exchange plan");
        let threads = plan.threads();
        assert_eq!(fields.len(), threads, "one field per thread");
        assert_eq!(out.len(), threads, "one output field per thread");
        debug_assert_eq!(self.staging.len(), plan.total_values());
        match engine {
            Engine::Sequential => {
                for (t, field) in fields.iter().enumerate() {
                    for m in plan.send_msgs(t) {
                        m.pack(field, &mut self.staging[m.range()]);
                    }
                }
                // ---- upc_barrier ----
                for (t, field) in fields.iter_mut().enumerate() {
                    for m in plan.recv_msgs(t) {
                        m.unpack(&self.staging[m.range()], field);
                    }
                }
                for (t, (field, o)) in fields.iter_mut().zip(out.iter_mut()).enumerate() {
                    update(t, field.as_mut_slice(), o.as_mut_slice());
                }
            }
            Engine::Parallel => {
                let arena = ArenaView::new(&mut self.staging);
                let fw = PerWorker::new(fields);
                let ow = PerWorker::new(out);
                let update = &update;
                self.pool.run(threads, &|ctx: WorkerCtx| {
                    let t = ctx.id;
                    // SAFETY: worker t claims only its own field/out pair.
                    let field = unsafe { fw.take(t) }.as_mut_slice();
                    for m in plan.send_msgs(t) {
                        // SAFETY: plan ranges are disjoint per message, and
                        // each message is packed by its sender only.
                        m.pack(field, unsafe { arena.slice_mut(m.range()) });
                    }

                    ctx.barrier(); // ---- upc_barrier ----

                    for m in plan.recv_msgs(t) {
                        // SAFETY: arena writes ended at the barrier.
                        m.unpack(unsafe { arena.slice(m.range()) }, field);
                    }
                    update(t, field, unsafe { ow.take(t) }.as_mut_slice());
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StridedBlock, StridedPlan};

    /// A 2-thread 1D "halo": each thread owns 4 cells + 1 ghost on each
    /// side; the update averages left/right neighbours.
    fn ring_runtime() -> ExchangeRuntime {
        let copies = vec![
            // t0's last interior cell -> t1's left ghost (offset 0).
            (0usize, 1usize, StridedBlock::row(4, 1), StridedBlock::row(0, 1)),
            // t1's first interior cell -> t0's right ghost (offset 5).
            (1, 0, StridedBlock::row(1, 1), StridedBlock::row(5, 1)),
        ];
        ExchangeRuntime::new(StridedPlan::from_msgs(2, &copies))
    }

    fn step(rt: &mut ExchangeRuntime, engine: Engine, fields: &mut [Vec<f64>]) -> Vec<Vec<f64>> {
        let mut out = fields.to_vec();
        rt.step_strided(engine, fields, &mut out, |_t, field, out| {
            for i in 1..5 {
                out[i] = 0.5 * (field[i - 1] + field[i + 1]);
            }
        });
        out
    }

    #[test]
    fn engines_agree_bitwise() {
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let mut rt_seq = ring_runtime();
        let mut rt_par = ring_runtime();
        let mut f_seq = init.clone();
        let mut f_par = init.clone();
        for _ in 0..4 {
            let o_seq = step(&mut rt_seq, Engine::Sequential, &mut f_seq);
            let o_par = step(&mut rt_par, Engine::Parallel, &mut f_par);
            assert_eq!(o_seq, o_par);
            // Ghost cells were exchanged identically too.
            assert_eq!(f_seq, f_par);
            f_seq = o_seq;
            f_par = o_par;
        }
    }

    #[test]
    fn halo_values_actually_cross() {
        let mut rt = ring_runtime();
        let mut fields = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        step(&mut rt, Engine::Parallel, &mut fields);
        // t1's left ghost got t0's cell 4; t0's right ghost got t1's cell 1.
        assert_eq!(fields[1][0], 4.0);
        assert_eq!(fields[0][5], 5.0);
        assert_eq!(rt.payload_bytes(), 16);
    }
}
