//! The workload-agnostic exchange runtime: a compiled [`ExchangePlan`], its
//! double-buffered staging arena, and a persistent [`WorkerPool`] —
//! everything a grid/halo workload needs to execute time steps on either
//! engine.
//!
//! Two step protocols, both driven entirely by the plan:
//!
//! **Synchronous** ([`step_strided`]) — the Listing 7 phase structure:
//!
//! ```text
//! pack: every sender gathers its compiled blocks into its arena ranges
//! ---- upc_barrier ----
//! unpack: every receiver scatters its arena ranges into its own halo
//! update: per-thread stencil kernel on the thread's own (field, out) pair
//! ```
//!
//! **Split-phase overlapped** ([`step_overlapped`]) — the nonblocking
//! begin/finish protocol that hides the exchange behind halo-independent
//! compute:
//!
//! ```text
//! begin_exchange:  pack into the current epoch's arena half, publish the
//!                  per-thread epoch flag (seqcst)
//! overlap window:  compute the interior (no halo dependence)
//! finish_exchange: wait on the flags of this thread's actual senders only
//!                  (no global barrier), unpack
//! boundary:        compute the halo-adjacent cells
//! ```
//!
//! On [`Engine::Sequential`] the phases are replayed on the calling thread
//! (the correctness oracle); on [`Engine::Parallel`] each logical thread is
//! a persistent pool worker. Both paths run the same pack/unpack/update
//! code on the same data — and because interior ∪ boundary covers every
//! owned cell exactly once with the unchanged per-cell expression, the
//! overlapped step is **bitwise identical** to the synchronous one. Neither
//! allocates nor spawns anything per step: plan, arena, flags and workers
//! all persist.
//!
//! The staging arena is double-buffered receiver-major: epoch `k` packs
//! into half `k mod 2`, so a sender beginning epoch `k+1` writes the other
//! half and never overwrites slots a slow receiver is still reading from
//! epoch `k`.
//!
//! [`step_strided`]: ExchangeRuntime::step_strided
//! [`step_overlapped`]: ExchangeRuntime::step_overlapped

use super::pool::{ArenaView, EpochFlags, PerWorker, WorkerCtx, WorkerPool};
use super::Engine;
use crate::comm::ExchangePlan;

/// A compiled plan bound to its staging arena and worker pool. Workloads
/// (heat-2D, the 3D stencil) own one and call [`step_strided`] or
/// [`step_overlapped`] per time step; the SpMV engine shares the same
/// pool/arena machinery through [`crate::engine::ParallelPool`].
///
/// [`step_strided`]: ExchangeRuntime::step_strided
/// [`step_overlapped`]: ExchangeRuntime::step_overlapped
#[derive(Debug)]
pub struct ExchangeRuntime {
    plan: ExchangePlan,
    /// Double-buffered staging arena: `2 × plan.total_values()` doubles,
    /// allocated once. Epoch `k` uses the half at `(k mod 2) · total`.
    staging: Vec<f64>,
    /// Long-lived workers; empty until the first parallel step.
    pool: WorkerPool,
    /// Per-thread published-epoch counters for the split-phase protocol.
    flags: EpochFlags,
    /// Exchange epoch of the last overlapped step (0 = none yet).
    epoch: u64,
    /// `senders[t]` — the distinct threads that send to `t`, i.e. exactly
    /// the flags `finish_exchange` waits on. Compiled once from the plan.
    senders: Vec<Vec<u32>>,
}

impl ExchangeRuntime {
    pub fn new(plan: impl Into<ExchangePlan>) -> ExchangeRuntime {
        let plan = plan.into();
        debug_assert!(
            plan.validate(&|_| usize::MAX).is_ok(),
            "compiled exchange plan failed validation: {:?}",
            plan.validate(&|_| usize::MAX)
        );
        let threads = plan.threads();
        let staging = vec![0.0f64; 2 * plan.total_values()];
        let senders = (0..threads)
            .map(|t| {
                let mut s: Vec<u32> = match &plan {
                    ExchangePlan::Gather(p) => p.recv_msgs(t).map(|m| m.peer).collect(),
                    ExchangePlan::Strided(p) => p.recv_msgs(t).map(|m| m.peer).collect(),
                };
                s.sort_unstable();
                s.dedup();
                s
            })
            .collect();
        ExchangeRuntime {
            plan,
            staging,
            pool: WorkerPool::new(),
            flags: EpochFlags::new(threads),
            epoch: 0,
            senders,
        }
    }

    pub fn plan(&self) -> &ExchangePlan {
        &self.plan
    }

    /// The distinct senders of thread `t` (the peers `finish_exchange`
    /// waits on).
    pub fn senders_of(&self, t: usize) -> &[u32] {
        &self.senders[t]
    }

    /// Payload bytes every step moves across thread boundaries (a constant
    /// of the compiled plan — the workloads' traffic counters add this).
    pub fn payload_bytes(&self) -> u64 {
        self.plan.payload_bytes()
    }

    /// One full exchange-then-update time step of a strided plan.
    ///
    /// `fields[t]`/`out[t]` are thread t's current and next local fields;
    /// `update(t, field, out)` is the per-thread stencil kernel, called
    /// after t's halo is complete. Panics if the plan is not the strided
    /// form.
    pub fn step_strided<U>(
        &mut self,
        engine: Engine,
        fields: &mut [Vec<f64>],
        out: &mut [Vec<f64>],
        update: U,
    ) where
        U: Fn(usize, &mut [f64], &mut [f64]) + Sync,
    {
        let plan = self
            .plan
            .as_strided()
            .expect("step_strided needs a strided exchange plan");
        let threads = plan.threads();
        assert_eq!(fields.len(), threads, "one field per thread");
        assert_eq!(out.len(), threads, "one output field per thread");
        debug_assert_eq!(self.staging.len(), 2 * plan.total_values());
        match engine {
            Engine::Sequential => {
                for (t, field) in fields.iter().enumerate() {
                    for m in plan.send_msgs(t) {
                        m.pack(field, &mut self.staging[m.range()]);
                    }
                }
                // ---- upc_barrier ----
                for (t, field) in fields.iter_mut().enumerate() {
                    for m in plan.recv_msgs(t) {
                        m.unpack(&self.staging[m.range()], field);
                    }
                }
                for (t, (field, o)) in fields.iter_mut().zip(out.iter_mut()).enumerate() {
                    update(t, field.as_mut_slice(), o.as_mut_slice());
                }
            }
            Engine::Parallel => {
                let arena = ArenaView::new(&mut self.staging);
                let fw = PerWorker::new(fields);
                let ow = PerWorker::new(out);
                let update = &update;
                self.pool.run(threads, &|ctx: WorkerCtx| {
                    let t = ctx.id;
                    // SAFETY: worker t claims only its own field/out pair.
                    let field = unsafe { fw.take(t) }.as_mut_slice();
                    for m in plan.send_msgs(t) {
                        // SAFETY: plan ranges are disjoint per message, and
                        // each message is packed by its sender only.
                        m.pack(field, unsafe { arena.slice_mut(m.range()) });
                    }

                    ctx.barrier(); // ---- upc_barrier ----

                    for m in plan.recv_msgs(t) {
                        // SAFETY: arena writes ended at the barrier.
                        m.unpack(unsafe { arena.slice(m.range()) }, field);
                    }
                    update(t, field, unsafe { ow.take(t) }.as_mut_slice());
                });
            }
        }
    }

    /// One split-phase overlapped time step of a strided plan:
    /// `begin_exchange` (pack + publish) → interior compute (overlaps the
    /// exchange) → `finish_exchange` (per-peer epoch waits, no global
    /// barrier) → unpack → boundary compute.
    ///
    /// `interior(t, field, out)` must update exactly the cells with no halo
    /// dependence and `boundary(t, field, out)` exactly the rest, each cell
    /// once with the synchronous step's expression — then the result is
    /// bitwise identical to [`step_strided`](ExchangeRuntime::step_strided).
    /// Panics if the plan is not the strided form.
    pub fn step_overlapped<UI, UB>(
        &mut self,
        engine: Engine,
        fields: &mut [Vec<f64>],
        out: &mut [Vec<f64>],
        interior: UI,
        boundary: UB,
    ) where
        UI: Fn(usize, &mut [f64], &mut [f64]) + Sync,
        UB: Fn(usize, &mut [f64], &mut [f64]) + Sync,
    {
        let plan = self
            .plan
            .as_strided()
            .expect("step_overlapped needs a strided exchange plan");
        let threads = plan.threads();
        assert_eq!(fields.len(), threads, "one field per thread");
        assert_eq!(out.len(), threads, "one output field per thread");
        let total = plan.total_values();
        debug_assert_eq!(self.staging.len(), 2 * total);
        self.epoch += 1;
        let epoch = self.epoch;
        // Double buffering: this epoch's receiver-major half.
        let half = (epoch % 2) as usize * total;
        match engine {
            Engine::Sequential => {
                for (t, field) in fields.iter().enumerate() {
                    for m in plan.send_msgs(t) {
                        let r = m.range();
                        m.pack(field, &mut self.staging[half + r.start..half + r.end]);
                    }
                    self.flags.publish(t, epoch);
                }
                for (t, (field, o)) in fields.iter_mut().zip(out.iter_mut()).enumerate() {
                    interior(t, field.as_mut_slice(), o.as_mut_slice());
                }
                // finish_exchange is trivially satisfied on one OS thread.
                for (t, field) in fields.iter_mut().enumerate() {
                    for m in plan.recv_msgs(t) {
                        let r = m.range();
                        m.unpack(&self.staging[half + r.start..half + r.end], field);
                    }
                }
                for (t, (field, o)) in fields.iter_mut().zip(out.iter_mut()).enumerate() {
                    boundary(t, field.as_mut_slice(), o.as_mut_slice());
                }
            }
            Engine::Parallel => {
                let arena = ArenaView::new(&mut self.staging);
                let fw = PerWorker::new(fields);
                let ow = PerWorker::new(out);
                let (interior, boundary) = (&interior, &boundary);
                let (flags, senders) = (&self.flags, &self.senders);
                self.pool.run(threads, &|ctx: WorkerCtx| {
                    let t = ctx.id;
                    // SAFETY: worker t claims only its own field/out pair,
                    // exactly once per dispatch.
                    let field = unsafe { fw.take(t) }.as_mut_slice();
                    let o = unsafe { ow.take(t) }.as_mut_slice();
                    // begin_exchange: pack into this epoch's half + publish.
                    for m in plan.send_msgs(t) {
                        let r = m.range();
                        // SAFETY: plan ranges are disjoint per message and
                        // halved per epoch parity; packed by the sender only.
                        m.pack(field, unsafe { arena.slice_mut(half + r.start..half + r.end) });
                    }
                    flags.publish(t, epoch);

                    // Overlap window: halo-independent compute.
                    interior(t, field, o);

                    // finish_exchange: wait on actual senders only.
                    for &peer in &senders[t] {
                        ctx.wait_for_epoch(flags.flag(peer as usize), epoch);
                    }
                    for m in plan.recv_msgs(t) {
                        let r = m.range();
                        // SAFETY: the sender's seqcst publish ordered its
                        // pack writes before this read.
                        m.unpack(unsafe { arena.slice(half + r.start..half + r.end) }, field);
                    }
                    boundary(t, field, o);
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StridedBlock, StridedPlan};

    /// A 2-thread 1D "halo": each thread owns 4 cells + 1 ghost on each
    /// side; the update averages left/right neighbours.
    fn ring_runtime() -> ExchangeRuntime {
        let copies = vec![
            // t0's last interior cell -> t1's left ghost (offset 0).
            (0usize, 1usize, StridedBlock::row(4, 1), StridedBlock::row(0, 1)),
            // t1's first interior cell -> t0's right ghost (offset 5).
            (1, 0, StridedBlock::row(1, 1), StridedBlock::row(5, 1)),
        ];
        ExchangeRuntime::new(StridedPlan::from_msgs(2, &copies))
    }

    fn step(rt: &mut ExchangeRuntime, engine: Engine, fields: &mut [Vec<f64>]) -> Vec<Vec<f64>> {
        let mut out = fields.to_vec();
        rt.step_strided(engine, fields, &mut out, |_t, field, out| {
            for i in 1..5 {
                out[i] = 0.5 * (field[i - 1] + field[i + 1]);
            }
        });
        out
    }

    #[test]
    fn engines_agree_bitwise() {
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let mut rt_seq = ring_runtime();
        let mut rt_par = ring_runtime();
        let mut f_seq = init.clone();
        let mut f_par = init.clone();
        for _ in 0..4 {
            let o_seq = step(&mut rt_seq, Engine::Sequential, &mut f_seq);
            let o_par = step(&mut rt_par, Engine::Parallel, &mut f_par);
            assert_eq!(o_seq, o_par);
            // Ghost cells were exchanged identically too.
            assert_eq!(f_seq, f_par);
            f_seq = o_seq;
            f_par = o_par;
        }
    }

    /// The overlapped version of [`step`]: cells 2..4 never read a ghost
    /// (interior), cells 1 and 4 do (boundary).
    fn step_ovl(
        rt: &mut ExchangeRuntime,
        engine: Engine,
        fields: &mut [Vec<f64>],
    ) -> Vec<Vec<f64>> {
        let mut out = fields.to_vec();
        rt.step_overlapped(
            engine,
            fields,
            &mut out,
            |_t, field, out| {
                for i in 2..4 {
                    out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                }
            },
            |_t, field, out| {
                for i in [1usize, 4] {
                    out[i] = 0.5 * (field[i - 1] + field[i + 1]);
                }
            },
        );
        out
    }

    #[test]
    fn overlapped_matches_synchronous_bitwise() {
        let init = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        let mut rt_sync = ring_runtime();
        let mut rt_seq = ring_runtime();
        let mut rt_par = ring_runtime();
        let mut f_sync = init.clone();
        let mut f_seq = init.clone();
        let mut f_par = init.clone();
        for step in 0..6 {
            let o_sync = step(&mut rt_sync, Engine::Sequential, &mut f_sync);
            let o_seq = step_ovl(&mut rt_seq, Engine::Sequential, &mut f_seq);
            let o_par = step_ovl(&mut rt_par, Engine::Parallel, &mut f_par);
            assert_eq!(o_sync, o_seq, "seq overlap diverges at step {step}");
            assert_eq!(o_sync, o_par, "par overlap diverges at step {step}");
            assert_eq!(f_sync, f_seq);
            assert_eq!(f_sync, f_par);
            f_sync = o_sync;
            f_seq = o_seq;
            f_par = o_par;
        }
        // Epochs advanced once per overlapped step.
        assert_eq!(rt_par.epoch, 6);
    }

    #[test]
    fn senders_compiled_from_plan() {
        let rt = ring_runtime();
        assert_eq!(rt.senders_of(0), &[1]);
        assert_eq!(rt.senders_of(1), &[0]);
        // Double-buffered arena.
        assert_eq!(rt.staging.len(), 2 * rt.plan().total_values());
    }

    #[test]
    fn halo_values_actually_cross() {
        let mut rt = ring_runtime();
        let mut fields = vec![
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0],
            vec![0.0, 5.0, 6.0, 7.0, 8.0, 0.0],
        ];
        step(&mut rt, Engine::Parallel, &mut fields);
        // t1's left ghost got t0's cell 4; t0's right ghost got t1's cell 1.
        assert_eq!(fields[1][0], 4.0);
        assert_eq!(fields[0][5], 5.0);
        assert_eq!(rt.payload_bytes(), 16);
    }
}
