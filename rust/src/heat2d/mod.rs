//! The §8 2D heat-equation solver (Rabenseifner-style UPC code) and its
//! simulated-cluster timing.
//!
//! The solver partitions a global `M × N` mesh over a `mprocs × nprocs`
//! thread grid; each thread owns an `(m−2) × (n−2)` interior plus a one-cell
//! halo (Listing 7's data structure). A time step is: halo exchange
//! (pack horizontal → barrier → `upc_memget` from all ≤ 4 neighbours +
//! unpack) followed by the 5-point Jacobi update (Listing 8).
//!
//! * [`Heat2dSolver`] executes real numerics on per-thread storage through
//!   the unified exchange runtime — the halo pattern is compiled once into
//!   a [`StridedPlan`](crate::comm::StridedPlan) and replayed through the
//!   persistent staging arena + worker pool
//!   ([`ExchangeRuntime`](crate::engine::ExchangeRuntime)), so a steady
//!   time step allocates and spawns nothing — and is validated against a
//!   sequential reference.
//! * [`simulate_heat_step`] produces the "measured" per-step times for
//!   Table 5 on the simulated cluster (the model side is
//!   [`crate::model::predict_heat2d`]).

mod solver;

pub use solver::{seq_reference_step, Heat2dSolver};
pub(crate) use solver::{compute_split, halo_plan, initial_field, jacobi_blocks};

use crate::machine::{HwParams, SIZEOF_DOUBLE};
use crate::model::HeatGrid;
use crate::pgas::Topology;
use crate::sim::SimParams;

/// The paper's Table 5 thread-grid schedule.
pub fn partition_for(threads: usize) -> Option<(usize, usize)> {
    match threads {
        16 => Some((4, 4)),
        32 => Some((4, 8)),
        64 => Some((8, 8)),
        128 => Some((8, 16)),
        256 => Some((16, 16)),
        512 => Some((16, 32)),
        _ => None,
    }
}

/// "Measured" times for one heat-2D step on the simulated cluster.
#[derive(Debug, Clone, Copy)]
pub struct HeatSimStep {
    pub t_halo: f64,
    pub t_comp: f64,
}

/// Simulate one time step. Mirrors [`crate::model::predict_heat2d`] but adds
/// the second-order effects of [`SimParams`]: concurrency-dependent τ,
/// per-message software overhead, and inbound NIC sharing — the same terms
/// that make Table 5's "actual" halo times exceed the predictions by tens of
/// percent.
pub fn simulate_heat_step(
    grid: &HeatGrid,
    topo: &Topology,
    hw: &HwParams,
    params: &SimParams,
) -> HeatSimStep {
    assert_eq!(topo.threads(), grid.threads());
    const D: f64 = SIZEOF_DOUBLE as f64;
    let w = hw.w_thread_private;
    let cl = hw.cache_line as f64;

    // Inbound bulk bytes per node (memgets executed by *other* nodes pulling
    // from this node's threads).
    let mut outbound_bytes = vec![0.0f64; topo.nodes];
    for t in 0..grid.threads() {
        for (peer, len, _) in grid.neighbours(t) {
            if !topo.same_node(t, peer) {
                // t pulls `len` doubles from peer: peer's node serves them.
                outbound_bytes[topo.node_of_thread(peer)] += len as f64 * D;
            }
        }
    }

    let mut t_halo = 0.0f64;
    for node in 0..topo.nodes {
        let communicating = topo
            .threads_of_node(node)
            .filter(|&t| grid.neighbours(t).iter().any(|&(p, _, _)| !topo.same_node(t, p)))
            .count();
        let tau_eff = params.tau_eff(communicating);
        let mut pack_max = 0.0f64;
        let mut local_max = 0.0f64;
        let mut remote_sum = 0.0f64;
        for t in topo.threads_of_node(node) {
            let mut s_horiz = 0usize;
            let mut s_local = 0usize;
            let mut s_remote = 0usize;
            let mut c_remote = 0usize;
            let mut msgs = 0usize;
            for (peer, len, horiz) in grid.neighbours(t) {
                msgs += 1;
                if horiz {
                    s_horiz += len;
                }
                if topo.same_node(t, peer) {
                    s_local += len;
                } else {
                    s_remote += len;
                    c_remote += 1;
                }
            }
            // Pack + unpack both pay a line per element on the strided side.
            let pack = s_horiz as f64 * (D + cl) / w + msgs as f64 * params.c_msg;
            pack_max = pack_max.max(pack);
            local_max = local_max.max(2.0 * s_local as f64 * D / w);
            remote_sum += c_remote as f64 * tau_eff + s_remote as f64 * D / hw.w_node_remote;
        }
        let nic_busy = remote_sum + outbound_bytes[node] / hw.w_node_remote;
        // pack → barrier-ish → memget + unpack (unpack modeled = pack).
        t_halo = t_halo.max(pack_max + local_max + nic_busy + pack_max);
    }

    let (m, n) = grid.subdomain();
    let t_comp = 3.0 * ((m - 2) * (n - 2)) as f64 * D / w;
    HeatSimStep { t_halo, t_comp }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_match_paper() {
        assert_eq!(partition_for(16), Some((4, 4)));
        assert_eq!(partition_for(512), Some((16, 32)));
        assert_eq!(partition_for(7), None);
        for t in [16, 32, 64, 128, 256, 512] {
            let (mp, np) = partition_for(t).unwrap();
            assert_eq!(mp * np, t);
        }
    }

    #[test]
    fn sim_halo_exceeds_model_halo() {
        // Table 5 shape: actual ≥ predicted for the halo time.
        let hw = HwParams::abel();
        let params = SimParams::from_hw(&hw);
        for threads in [16usize, 64, 256] {
            let (mp, np) = partition_for(threads).unwrap();
            let grid = HeatGrid::new(20_000, 20_000, mp, np);
            let topo = Topology::new((threads / 16).max(1), threads.min(16));
            let sim = simulate_heat_step(&grid, &topo, &hw, &params);
            let model = crate::model::predict_heat2d(&grid, &topo, &hw);
            assert!(
                sim.t_halo >= model.t_halo * 0.99,
                "{threads} threads: sim {} < model {}",
                sim.t_halo,
                model.t_halo
            );
            // And within the paper's observed ~3× band.
            assert!(sim.t_halo < model.t_halo * 3.5);
            // Compute side matches the model almost exactly.
            assert!((sim.t_comp - model.t_comp).abs() < 1e-9);
        }
    }

    #[test]
    fn table5_actual_halo_magnitude() {
        // Paper, 20000², 16 threads: actual 0.52 s / 1000 steps.
        let hw = HwParams::abel();
        let params = SimParams::from_hw(&hw);
        let grid = HeatGrid::new(20_000, 20_000, 4, 4);
        let topo = Topology::new(1, 16);
        let sim = simulate_heat_step(&grid, &topo, &hw, &params);
        let total = sim.t_halo * 1000.0;
        assert!((0.2..1.2).contains(&total), "halo 1000 steps = {total}");
    }
}
