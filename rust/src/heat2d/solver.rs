//! Executable heat-2D solver with per-thread storage and real halo traffic
//! (Listings 7 & 8), validated against a sequential reference.

use crate::engine::Engine;
use crate::model::HeatGrid;

/// Per-thread subdomain state: `phi` (with halo) and the scratch vectors of
/// Listing 7 for horizontal pack/unpack.
#[derive(Debug, Clone)]
pub struct Heat2dSolver {
    pub grid: HeatGrid,
    /// `phi[t]` — the m×n (halo-included) field of thread t, row-major.
    phi: Vec<Vec<f64>>,
    /// New-timestep buffers (`phin` in Listing 8).
    phin: Vec<Vec<f64>>,
    /// Halo-exchange byte counter (payload crossing thread boundaries).
    pub inter_thread_bytes: u64,
}

impl Heat2dSolver {
    /// Initialize from a global field of `m_glob × n_glob` values.
    /// Boundary values of the global domain are treated as fixed (Dirichlet).
    pub fn new(grid: HeatGrid, global: &[f64]) -> Heat2dSolver {
        assert_eq!(global.len(), grid.m_glob * grid.n_glob);
        let (m, n) = grid.subdomain();
        let mut phi = Vec::with_capacity(grid.threads());
        for t in 0..grid.threads() {
            let (ip, kp) = grid.coords(t);
            let (row0, col0) = (ip * (m - 2), kp * (n - 2));
            let mut field = vec![0.0f64; m * n];
            // Fill interior + whatever halo overlaps the global domain.
            for i in 0..m {
                for k in 0..n {
                    let gi = row0 as isize + i as isize - 1;
                    let gk = col0 as isize + k as isize - 1;
                    if gi >= 0
                        && (gi as usize) < grid.m_glob
                        && gk >= 0
                        && (gk as usize) < grid.n_glob
                    {
                        field[i * n + k] = global[gi as usize * grid.n_glob + gk as usize];
                    }
                }
            }
            phi.push(field);
        }
        let phin = phi.clone();
        Heat2dSolver { grid, phi, phin, inter_thread_bytes: 0 }
    }

    /// One time step: halo exchange then 5-point Jacobi update (on the
    /// sequential oracle engine).
    pub fn step(&mut self) {
        self.step_with(Engine::Sequential);
    }

    /// One time step on the chosen engine. Both engines produce bitwise
    /// identical fields and identical halo byte counts;
    /// [`Engine::Parallel`] runs one OS thread per grid thread.
    pub fn step_with(&mut self, engine: Engine) {
        match engine {
            Engine::Sequential => self.step_seq(),
            Engine::Parallel => self.step_par(),
        }
    }

    fn step_seq(&mut self) {
        self.halo_exchange();
        for t in 0..self.grid.threads() {
            Self::jacobi_update(self.grid, t, &self.phi[t], &mut self.phin[t]);
        }
        std::mem::swap(&mut self.phi, &mut self.phin);
    }

    /// Listing 8 for one thread: the 5-point Jacobi update of the interior
    /// plus the fixed global-boundary copy-through. Shared by both engines —
    /// it only touches thread `t`'s own `(phi, phin)` pair, so fusing it
    /// per-thread is order-independent.
    fn jacobi_update(grid: HeatGrid, t: usize, phi: &[f64], phin: &mut [f64]) {
        let (m, n) = grid.subdomain();
        for i in 1..m - 1 {
            for k in 1..n - 1 {
                phin[i * n + k] = 0.25
                    * (phi[(i - 1) * n + k]
                        + phi[(i + 1) * n + k]
                        + phi[i * n + k - 1]
                        + phi[i * n + k + 1]);
            }
        }
        // Global-boundary rows/cols stay fixed: copy them through.
        let (ip, kp) = grid.coords(t);
        if ip == 0 {
            for k in 0..n {
                phin[n + k] = phi[n + k];
            }
        }
        if ip == grid.mprocs - 1 {
            for k in 0..n {
                phin[(m - 2) * n + k] = phi[(m - 2) * n + k];
            }
        }
        if kp == 0 {
            for i in 0..m {
                phin[i * n + 1] = phi[i * n + 1];
            }
        }
        if kp == grid.nprocs - 1 {
            for i in 0..m {
                phin[i * n + n - 2] = phi[i * n + n - 2];
            }
        }
    }

    /// Parallel step: stage every boundary strip before the barrier (the
    /// Listing 7 pack phase, extended to the row strips `upc_memget` reads),
    /// then run one worker per thread that unpacks its halos and applies the
    /// Jacobi update on its own `(phi, phin)` pair — all cross-thread reads
    /// go through the staged strips, so workers share nothing mutable.
    fn step_par(&mut self) {
        let grid = self.grid;
        let (m, n) = grid.subdomain();
        struct Strips {
            col_first: Vec<f64>,
            col_last: Vec<f64>,
            row_first: Vec<f64>,
            row_last: Vec<f64>,
        }
        let strips: Vec<Strips> = (0..grid.threads())
            .map(|t| {
                let phi = &self.phi[t];
                Strips {
                    col_first: (1..m - 1).map(|i| phi[i * n + 1]).collect(),
                    col_last: (1..m - 1).map(|i| phi[i * n + n - 2]).collect(),
                    row_first: phi[n + 1..n + n - 1].to_vec(),
                    row_last: phi[(m - 2) * n + 1..(m - 2) * n + n - 1].to_vec(),
                }
            })
            .collect();
        // ---- upc_barrier ----
        let strips = &strips;
        let mut bytes = vec![0u64; grid.threads()];
        std::thread::scope(|s| {
            for ((t, (phi, phin)), byt) in self
                .phi
                .iter_mut()
                .zip(self.phin.iter_mut())
                .enumerate()
                .zip(bytes.iter_mut())
            {
                s.spawn(move || {
                    let (ip, kp) = grid.coords(t);
                    let mut local_bytes = 0u64;
                    // Halo unpack, same neighbour order as the sequential
                    // path (left, right, up, down).
                    if kp > 0 {
                        let src = &strips[grid.rank(ip, kp - 1)].col_last;
                        local_bytes += (src.len() * 8) as u64;
                        for (i, v) in src.iter().enumerate() {
                            phi[(i + 1) * n] = *v;
                        }
                    }
                    if kp < grid.nprocs - 1 {
                        let src = &strips[grid.rank(ip, kp + 1)].col_first;
                        local_bytes += (src.len() * 8) as u64;
                        for (i, v) in src.iter().enumerate() {
                            phi[(i + 1) * n + n - 1] = *v;
                        }
                    }
                    if ip > 0 {
                        let src = &strips[grid.rank(ip - 1, kp)].row_last;
                        local_bytes += (src.len() * 8) as u64;
                        phi[1..n - 1].copy_from_slice(src);
                    }
                    if ip < grid.mprocs - 1 {
                        let src = &strips[grid.rank(ip + 1, kp)].row_first;
                        local_bytes += (src.len() * 8) as u64;
                        phi[(m - 1) * n + 1..(m - 1) * n + n - 1].copy_from_slice(src);
                    }
                    Self::jacobi_update(grid, t, phi, phin);
                    *byt = local_bytes;
                });
            }
        });
        self.inter_thread_bytes += bytes.iter().sum::<u64>();
        std::mem::swap(&mut self.phi, &mut self.phin);
    }

    /// Listing 7: vertical halos are contiguous `upc_memget`s; horizontal
    /// halos are packed into scratch vectors, fetched, and unpacked.
    fn halo_exchange(&mut self) {
        let grid = self.grid;
        let (m, n) = grid.subdomain();
        // Pack phase: each thread exposes its first/last interior columns.
        let mut col_first: Vec<Vec<f64>> = Vec::with_capacity(grid.threads());
        let mut col_last: Vec<Vec<f64>> = Vec::with_capacity(grid.threads());
        for t in 0..grid.threads() {
            let phi = &self.phi[t];
            col_first.push((1..m - 1).map(|i| phi[i * n + 1]).collect());
            col_last.push((1..m - 1).map(|i| phi[i * n + n - 2]).collect());
        }
        // ---- upc_barrier ----
        // Transfer + unpack phase.
        for t in 0..grid.threads() {
            let (ip, kp) = grid.coords(t);
            // Left neighbour's last column → my col 0.
            if kp > 0 {
                let src = &col_last[grid.rank(ip, kp - 1)];
                self.inter_thread_bytes += (src.len() * 8) as u64;
                for (i, v) in src.iter().enumerate() {
                    self.phi[t][(i + 1) * n] = *v;
                }
            }
            // Right neighbour's first column → my col n−1.
            if kp < grid.nprocs - 1 {
                let src = &col_first[grid.rank(ip, kp + 1)];
                self.inter_thread_bytes += (src.len() * 8) as u64;
                for (i, v) in src.iter().enumerate() {
                    self.phi[t][(i + 1) * n + n - 1] = *v;
                }
            }
            // Upper neighbour's last interior row → my row 0 (contiguous).
            if ip > 0 {
                let peer = grid.rank(ip - 1, kp);
                let row: Vec<f64> =
                    self.phi[peer][(m - 2) * n + 1..(m - 2) * n + n - 1].to_vec();
                self.inter_thread_bytes += (row.len() * 8) as u64;
                self.phi[t][1..n - 1].copy_from_slice(&row);
            }
            // Lower neighbour's first interior row → my row m−1.
            if ip < grid.mprocs - 1 {
                let peer = grid.rank(ip + 1, kp);
                let row: Vec<f64> = self.phi[peer][n + 1..n + n - 1].to_vec();
                self.inter_thread_bytes += (row.len() * 8) as u64;
                self.phi[t][(m - 1) * n + 1..(m - 1) * n + n - 1].copy_from_slice(&row);
            }
        }
    }

    /// Gather the global interior field (for comparison with the reference).
    pub fn to_global(&self) -> Vec<f64> {
        let grid = self.grid;
        let (m, n) = grid.subdomain();
        let mut out = vec![0.0f64; grid.m_glob * grid.n_glob];
        for t in 0..grid.threads() {
            let (ip, kp) = grid.coords(t);
            let (row0, col0) = (ip * (m - 2), kp * (n - 2));
            for i in 1..m - 1 {
                for k in 1..n - 1 {
                    out[(row0 + i - 1) * grid.n_glob + (col0 + k - 1)] =
                        self.phi[t][i * n + k];
                }
            }
        }
        out
    }
}

/// Sequential reference: one Jacobi step on the global field (fixed global
/// boundary).
pub fn seq_reference_step(m_glob: usize, n_glob: usize, phi: &[f64]) -> Vec<f64> {
    let mut out = phi.to_vec();
    for i in 1..m_glob - 1 {
        for k in 1..n_glob - 1 {
            out[i * n_glob + k] = 0.25
                * (phi[(i - 1) * n_glob + k]
                    + phi[(i + 1) * n_glob + k]
                    + phi[i * n_glob + k - 1]
                    + phi[i * n_glob + k + 1]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_field(m: usize, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..m * n).map(|_| rng.f64_in(0.0, 100.0)).collect()
    }

    #[test]
    fn parallel_matches_sequential_over_steps() {
        let (mg, ng) = (36, 48);
        let grid = HeatGrid::new(mg, ng, 3, 4);
        let f0 = random_field(mg, ng, 42);
        let mut solver = Heat2dSolver::new(grid, &f0);
        let mut reference = f0.clone();
        for step in 0..10 {
            solver.step();
            reference = seq_reference_step(mg, ng, &reference);
            let got = solver.to_global();
            for (idx, (a, b)) in got.iter().zip(&reference).enumerate() {
                assert!(
                    (a - b).abs() < 1e-12,
                    "step {step} idx {idx}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn single_thread_grid_works() {
        let grid = HeatGrid::new(16, 16, 1, 1);
        let f0 = random_field(16, 16, 7);
        let mut solver = Heat2dSolver::new(grid, &f0);
        solver.step();
        let want = seq_reference_step(16, 16, &f0);
        let got = solver.to_global();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
        // No neighbours → no inter-thread traffic.
        assert_eq!(solver.inter_thread_bytes, 0);
    }

    #[test]
    fn halo_traffic_counted() {
        let grid = HeatGrid::new(24, 24, 2, 2);
        let f0 = random_field(24, 24, 3);
        let mut solver = Heat2dSolver::new(grid, &f0);
        solver.step();
        // Each of 4 threads has 2 neighbours; message length = 12 doubles.
        // Total = 8 messages · 12 · 8 bytes.
        assert_eq!(solver.inter_thread_bytes, 8 * 12 * 8);
    }

    #[test]
    fn parallel_engine_matches_sequential_bitwise() {
        let grid = HeatGrid::new(36, 48, 3, 4);
        let f0 = random_field(36, 48, 11);
        let mut seq = Heat2dSolver::new(grid, &f0);
        let mut par = Heat2dSolver::new(grid, &f0);
        for step in 0..6 {
            seq.step_with(Engine::Sequential);
            par.step_with(Engine::Parallel);
            assert_eq!(seq.to_global(), par.to_global(), "step {step}");
            assert_eq!(seq.inter_thread_bytes, par.inter_thread_bytes, "step {step}");
        }
    }

    #[test]
    fn diffusion_smooths() {
        let grid = HeatGrid::new(32, 32, 2, 2);
        let mut f0 = vec![0.0f64; 32 * 32];
        f0[16 * 32 + 16] = 1000.0; // hot spot
        let mut solver = Heat2dSolver::new(grid, &f0);
        for _ in 0..20 {
            solver.step();
        }
        let out = solver.to_global();
        let max = out.iter().cloned().fold(0.0f64, f64::max);
        assert!(max < 1000.0 * 0.5, "peak should diffuse, max={max}");
        assert!(out.iter().all(|&v| v >= -1e-12));
    }
}
